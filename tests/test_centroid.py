"""Centroid index: exact threshold pruning (paper §4.1) + lean-blob serving.

The ``max_distance`` bound makes threshold pruning *exact*: a file whose
centroid distance minus its radius exceeds the threshold can never contain a
match.  The hypothesis test drives that invariant over random corpora.
"""

import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.centroid_index import CentroidIndex, build_centroid_index
from repro.lakehouse.table import LakehouseTable
from repro.runtime.coordinator import IndexConfig
from conftest import clustered_vectors


@settings(max_examples=15, deadline=None)
@given(
    n_files=st.integers(2, 8),
    rows=st.integers(5, 60),
    dim=st.integers(2, 12),
    thresh=st.floats(0.1, 5.0),
    seed=st.integers(0, 1000),
)
def test_property_threshold_pruning_is_exact(n_files, rows, dim, thresh, seed):
    rng = np.random.default_rng(seed)
    files = [rng.normal(size=(rows, dim)).astype(np.float32) * rng.uniform(0.2, 2)
             for _ in range(n_files)]
    cents = np.stack([f.mean(axis=0) for f in files])
    radii = np.asarray(
        [np.sqrt(((f - f.mean(0)) ** 2).sum(1).max()) for f in files], np.float32
    )
    ci = CentroidIndex(cents, radii, [f"f{i}" for i in range(n_files)])
    q = rng.normal(size=dim).astype(np.float32)
    kept = set(ci.probe_threshold(q, thresh))
    # every vector within the threshold must live in a kept file
    for i, f in enumerate(files):
        d = np.sqrt(((f - q) ** 2).sum(1))
        if (d <= thresh).any():
            assert f"f{i}" in kept, (i, d.min(), thresh)


def test_topk_probe_orders_by_centroid_distance(rng):
    X, centers = clustered_vectors(rng, n_clusters=6, per_cluster=50, dim=8)
    cents = centers
    ci = CentroidIndex(
        cents, np.ones(6, np.float32), [f"f{i}" for i in range(6)]
    )
    got = ci.probe_topk(centers[2], 2)
    assert got[0] == "f2"


def test_blob_roundtrip_preserves_pruning(tmp_store, rng):
    from repro.iceberg.catalog import RestCatalog

    cat = RestCatalog(tmp_store)
    t = LakehouseTable(cat, "v")
    t.create(dim=8)
    X, _ = clustered_vectors(rng, n_clusters=4, per_cluster=64, dim=8)
    t.append_vectors(X, num_files=4, rows_per_group=64)
    ci = build_centroid_index(t)
    ci2 = CentroidIndex.from_blob(ci.to_blob())
    q = X[0]
    assert ci.probe_threshold(q, 1.5) == ci2.probe_threshold(q, 1.5)
    assert ci.probe_topk(q, 3) == ci2.probe_topk(q, 3)


def test_lean_blob_end_to_end_probe(tmp_path):
    """include_vectors=False: executors re-fetch vectors from Parquet (§4.3)."""
    from repro.runtime.cluster import make_local_cluster
    from repro.core.vamana import brute_force_topk

    rng = np.random.default_rng(0)
    c = make_local_cluster(str(tmp_path), num_executors=2)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=16)
    X, _ = clustered_vectors(rng, n_clusters=8, per_cluster=100, dim=16)
    t.append_vectors(X, num_files=4, rows_per_group=128)
    rep = c.coordinator.create_index(
        "emb",
        IndexConfig(name="idx", R=16, L=32, include_vectors=False,
                    partitions_per_shard=2, build_passes=1),
    )
    # lean blobs are much smaller than the data they index
    assert rep.total_bytes < X.nbytes
    Q = X[:8]
    _, truth = brute_force_topk(X, Q, 5)
    pr = c.coordinator.probe("emb", Q, 5, strategy="diskann", L=64)
    vecs_all, locs_all = t.scan_vectors()
    tl = [{(locs_all[i].file_path, locs_all[i].row_group_id, locs_all[i].row_offset)
           for i in row} for row in truth]
    rec = np.mean([
        len({(h.file_path, h.row_group, h.row_offset) for h in hits} & s) / len(s)
        for hits, s in zip(pr.hits, tl)
    ])
    assert rec >= 0.85, rec
