"""Serving-tier cache hierarchy (serving/cache.py) — the ``--cache`` CI stage.

Covers both layers and every invariant the hierarchy claims:

- LRU byte-bound eviction (the cache never exceeds its byte budget);
- snapshot invalidation on refresh (a committed refresh can never serve a
  pre-refresh candidate list or answer);
- time-travel isolation (a probe of an OLD snapshot must not hit a newer
  snapshot's cache entries — snapshot ids are random, so this is pure key
  identity, never an ordering comparison);
- bit-parity on every hit (cached candidates re-merge through the
  unchanged Stage-A merge: final hits identical to the uncached path);
- semantic layer: exact-duplicate fast path, L2 distance threshold,
  per-tenant scoping, and the admission interplay (a semantic hit must
  not consume token-bucket budget it didn't use);
- the degradation rule: a shrink_k-degraded answer is cached under its
  DEGRADED k and never returned to a later full-k query;
- concurrent submit hit accounting (hits + misses == lookups under
  threaded submission).

All cases are `cache`-marked so `scripts/ci.sh --cache` re-runs them in
isolation; they also ride the ordinary tier-1 run.
"""

import threading

import numpy as np
import pytest

from repro.serving.cache import (
    SemanticResultCache,
    ShardProbeCache,
    query_digest,
)

pytestmark = pytest.mark.cache


# ---------------------------------------------------------------- unit: LRU


class _Cand:
    """Stand-in for fragments.ProbeCandidate in pure-unit cases."""

    def __init__(self, file_path="f.parquet", dist=0.0):
        self.file_path = file_path
        self.approx_distance = dist


def _key(i, snapshot_id=1, table="t"):
    return (table, snapshot_id, i, None, (10, 32, False, 4), None, bytes([i]))


def test_shard_cache_lru_byte_bound_eviction():
    cache = ShardProbeCache(max_bytes=2000)
    for i in range(20):
        cache.put(
            _key(i),
            [_Cand()] * 4,
            table_name="t",
            snapshot_id=1,
            served_by="ex-0",
        )
        assert cache.total_bytes <= cache.max_bytes
    assert cache.stats.evictions > 0
    assert len(cache) < 20
    # LRU order: the survivors are the most recently inserted keys
    surviving = {k for k, _ in cache.entries_snapshot()}
    assert _key(19) in surviving
    assert _key(0) not in surviving


def test_shard_cache_get_refreshes_lru_position():
    cache = ShardProbeCache(max_bytes=10_000)
    for i in range(5):
        cache.put(_key(i), [_Cand()], table_name="t", snapshot_id=1, served_by="e")
    cache.get(_key(0))  # touch the oldest
    order = [k for k, _ in cache.entries_snapshot()]
    assert order[-1] == _key(0)


def test_shard_cache_oversized_entry_is_skipped():
    cache = ShardProbeCache(max_bytes=200)
    cache.put(
        _key(0),
        [_Cand("x" * 500)],
        table_name="t",
        snapshot_id=1,
        served_by="e",
    )
    assert len(cache) == 0  # one entry would evict the whole cache


def test_shard_cache_invalidate_is_identity_not_ordering():
    cache = ShardProbeCache(max_bytes=10_000)
    # snapshot ids are random — a "newer" snapshot may have a SMALLER id
    cache.put(_key(0, snapshot_id=999), [_Cand()], table_name="t",
              snapshot_id=999, served_by="e")
    cache.put(_key(1, snapshot_id=5), [_Cand()], table_name="t",
              snapshot_id=5, served_by="e")
    cache.put(_key(2, snapshot_id=5, table="other"), [_Cand()],
              table_name="other", snapshot_id=5, served_by="e")
    dropped = cache.invalidate("t", 5)  # 5 is now current for table "t"
    assert dropped == 1  # only the id-999 entry for "t"
    assert cache.stats.invalidations == 1
    surviving = {k for k, _ in cache.entries_snapshot()}
    assert _key(1, snapshot_id=5) in surviving
    assert _key(2, snapshot_id=5, table="other") in surviving


# ------------------------------------------------------- unit: semantic layer


def _hits(n=3):
    return [("f.parquet", 0, i) for i in range(n)]


def test_semantic_exact_duplicate_fast_path():
    sem = SemanticResultCache(max_bytes=1 << 16)
    q = np.arange(8, dtype=np.float32)
    sem.observe_snapshot(7)
    sem.put("a", q, 10, None, _hits(), snapshot_id=7)
    hit = sem.lookup("a", q.copy(), 10, None)
    assert hit is not None and hit.hits == _hits()
    assert sem.stats.hits == 1
    # different k or filter is a different scope — never a hit
    assert sem.lookup("a", q, 5, None) is None
    assert sem.lookup("a", q, 10, "price < 30") is None


def test_semantic_distance_threshold():
    sem = SemanticResultCache(max_bytes=1 << 16, distance_threshold=0.5)
    q = np.zeros(8, np.float32)
    sem.observe_snapshot(7)
    sem.put("a", q, 10, None, _hits(), snapshot_id=7)
    near = q + 0.1  # ||near - q|| ≈ 0.28 < 0.5
    far = q + 1.0   # ||far - q|| ≈ 2.8 > 0.5
    assert sem.lookup("a", near, 10, None) is not None
    assert sem.lookup("a", far, 10, None) is None


def test_semantic_tenant_scoping():
    sem = SemanticResultCache(max_bytes=1 << 16, distance_threshold=10.0)
    q = np.zeros(8, np.float32)
    sem.observe_snapshot(7)
    sem.put("tenant_a", q, 10, None, _hits(), snapshot_id=7)
    assert sem.lookup("tenant_b", q, 10, None) is None
    assert sem.lookup("tenant_a", q, 10, None) is not None


def test_semantic_snapshot_watermark_invalidation():
    sem = SemanticResultCache(max_bytes=1 << 16)
    q = np.zeros(8, np.float32)
    sem.observe_snapshot(7)
    sem.put("a", q, 10, None, _hits(), snapshot_id=7)
    assert sem.lookup("a", q, 10, None) is not None
    # a refresh committed: reports now carry a new (random) id
    dropped = sem.observe_snapshot(3)
    assert dropped == 1 and sem.stats.invalidations == 1
    assert sem.lookup("a", q, 10, None) is None
    assert len(sem) == 0


def test_semantic_byte_bound_eviction():
    sem = SemanticResultCache(max_bytes=3000)
    sem.observe_snapshot(1)
    for i in range(20):
        q = np.full(32, float(i), np.float32)
        sem.put("a", q, 10, None, _hits(), snapshot_id=1)
        assert sem.total_bytes <= sem.max_bytes
    assert sem.stats.evictions > 0 and len(sem) < 20
    # the most recent entry survived, the oldest did not
    assert sem.lookup("a", np.full(32, 19.0, np.float32), 10, None) is not None
    assert sem.lookup("a", np.full(32, 0.0, np.float32), 10, None) is None


# ------------------------------------------------- integration: shard layer


@pytest.fixture(scope="module")
def cache_cluster(tmp_path_factory):
    """Module-own cluster + index (refresh tests mutate it, so the shared
    session fixture is off-limits)."""
    import numpy as np

    from repro.lakehouse.table import LakehouseTable
    from repro.runtime.cluster import make_local_cluster
    from repro.runtime.coordinator import IndexConfig

    from conftest import BUILT_CFG, clustered_vectors

    rng = np.random.default_rng(7)
    root = tmp_path_factory.mktemp("cache_cluster")
    c = make_local_cluster(str(root), num_executors=3)
    X, _ = clustered_vectors(rng, n_clusters=24, per_cluster=80)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=X.shape[1])
    cats = rng.choice(["news", "blog", "docs"], size=len(X))
    price = rng.integers(1, 100, size=len(X))
    t.append_vectors(
        X,
        num_files=9,
        rows_per_group=128,
        attributes={"category": cats, "price": price},
    )
    c.coordinator.create_index("emb", IndexConfig(name="idx", **BUILT_CFG))
    dim = X.shape[1]
    Q = X[rng.choice(len(X), 6)] + 0.05 * rng.normal(size=(6, dim)).astype(
        np.float32
    )
    return c, t, X, Q.astype(np.float32), rng


def _locs(report):
    return [
        [(h.file_path, h.row_group, h.row_offset) for h in hits]
        for hits in report.hits
    ]


def test_shard_cache_hit_is_bit_parity_and_skips_dispatch(cache_cluster):
    c, t, X, Q, rng = cache_cluster
    cache = ShardProbeCache(max_bytes=8 << 20)
    c.coordinator.probe_cache = None
    off = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
    try:
        c.coordinator.probe_cache = cache
        warm1 = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
        warm2 = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
    finally:
        c.coordinator.probe_cache = None
    # non-repeating traffic: the caching pass is bit-identical to cache-off
    assert _locs(warm1) == _locs(off)
    assert warm1.shard_cache_hits == 0
    # repeat traffic: every Stage-A fragment served from cache, same bits
    assert _locs(warm2) == _locs(off)
    assert warm2.shard_cache_hits > 0
    assert warm2.cache == "shard"
    assert warm1.cache is None
    # a fully-cached Stage A dispatches no shard-probe fragments
    assert warm2.probe_fragments < warm1.probe_fragments
    assert cache.stats.hits == warm2.shard_cache_hits


def test_shard_cache_filtered_hit_parity(cache_cluster):
    c, t, X, Q, rng = cache_cluster
    cache = ShardProbeCache(max_bytes=8 << 20)
    pred = "category = 'news'"
    c.coordinator.probe_cache = None
    off = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=pred)
    try:
        c.coordinator.probe_cache = cache
        c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=pred)
        warm = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=pred)
    finally:
        c.coordinator.probe_cache = None
    assert _locs(warm) == _locs(off)
    assert warm.shard_cache_hits > 0
    # the predicate is part of the key: an unfiltered repeat cannot hit
    try:
        c.coordinator.probe_cache = cache
        other = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
    finally:
        c.coordinator.probe_cache = None
    assert other.shard_cache_hits == 0


def test_invalidation_on_refresh_no_stale_hits(cache_cluster):
    c, t, X, Q, rng = cache_cluster
    cache = ShardProbeCache(max_bytes=8 << 20)
    try:
        c.coordinator.probe_cache = cache
        c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")  # warm
        assert len(cache) > 0
        tail = rng.normal(size=(96, 32)).astype(np.float32)
        t.append_vectors(
            tail,
            num_files=1,
            rows_per_group=96,
            attributes={
                "category": np.array(["news"] * 96),
                "price": np.full(96, 50),
            },
        )
        c.coordinator.refresh_index("emb", "idx")
        assert cache.stats.invalidations > 0
        # post-refresh probe: zero stale hits, exact parity with cache-off
        warm = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
        assert warm.shard_cache_hits == 0
        c.coordinator.probe_cache = None
        off = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
        assert _locs(warm) == _locs(off)
    finally:
        c.coordinator.probe_cache = None


def test_time_travel_probe_does_not_hit_newer_snapshot(cache_cluster):
    c, t, X, Q, rng = cache_cluster
    # snapshot history: this test runs after the refresh test (module
    # order), but derives its own old/new pair to stay order-independent
    meta = t.metadata()
    old_sid = meta.current_snapshot_id
    t.append_vectors(
        rng.normal(size=(96, 32)).astype(np.float32),
        num_files=1,
        rows_per_group=96,
        attributes={
            "category": np.array(["blog"] * 96),
            "price": np.full(96, 10),
        },
    )
    c.coordinator.refresh_index("emb", "idx")
    cache = ShardProbeCache(max_bytes=8 << 20)
    try:
        c.coordinator.probe_cache = cache
        # warm the cache against the CURRENT snapshot
        c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
        assert len(cache) > 0
        # a time-travel probe of the old snapshot: its keys carry the old
        # id, so nothing the current snapshot cached can serve it
        tt = c.coordinator.probe_batch(
            "emb", Q, 10, strategy="diskann", snapshot_id=old_sid
        )
        assert tt.shard_cache_hits == 0
        c.coordinator.probe_cache = None
        off = c.coordinator.probe_batch(
            "emb", Q, 10, strategy="diskann", snapshot_id=old_sid
        )
        assert _locs(tt) == _locs(off)
        # repeats of the SAME old snapshot may hit its own entries — same
        # snapshot means same data, so that is correct, and still parity
        c.coordinator.probe_cache = cache
        tt2 = c.coordinator.probe_batch(
            "emb", Q, 10, strategy="diskann", snapshot_id=old_sid
        )
        assert tt2.shard_cache_hits > 0
        assert _locs(tt2) == _locs(off)
    finally:
        c.coordinator.probe_cache = None


# --------------------------------------------- integration: semantic layer


def test_semantic_hit_skips_admission_token(cache_cluster):
    from repro.serving.admission import AdmissionRejected, TenantPolicy
    from repro.serving.serve_loop import ProbeMicroBatcher

    c, t, X, Q, rng = cache_cluster
    sem = SemanticResultCache(max_bytes=1 << 20)
    with ProbeMicroBatcher(
        c.coordinator,
        "emb",
        strategy="diskann",
        max_wait_s=0.001,
        tenant_policies={"a": TenantPolicy(rate_qps=0.001, burst=1.0)},
        semantic_cache=sem,
    ) as mb:
        first = mb.submit(Q[0], 10, tenant="a").result()  # spends the only token
        # the exact repeat is answered at the door — no token consumed
        again = mb.submit(Q[0], 10, tenant="a").result()
        assert [
            (h.file_path, h.row_group, h.row_offset) for h in again
        ] == [(h.file_path, h.row_group, h.row_offset) for h in first]
        assert mb.stats.semantic_hits == 1
        # a fresh query still needs a token the bucket doesn't have
        with pytest.raises(AdmissionRejected):
            mb.submit(Q[1], 10, tenant="a")


def test_degraded_answer_cached_under_degraded_k(cache_cluster):
    from repro.serving.admission import DegradationPolicy, ShrinkK
    from repro.serving.serve_loop import ProbeMicroBatcher

    c, t, X, Q, rng = cache_cluster
    sem = SemanticResultCache(max_bytes=1 << 20)
    # degrade-on: the answer comes back at k_eff = 5, cached under k=5
    with ProbeMicroBatcher(
        c.coordinator,
        "emb",
        strategy="diskann",
        max_wait_s=0.001,
        degradation=DegradationPolicy(steps=(ShrinkK(),)),
        force_degrade="on",
        semantic_cache=sem,
    ) as mb:
        degraded = mb.submit(Q[0], 10, tenant="a").result()
        assert len(degraded) == 5
    # degrade-off, same cache, same query at full k: the degraded answer
    # must NOT be served — the k=10 lookup misses and a real probe answers
    with ProbeMicroBatcher(
        c.coordinator,
        "emb",
        strategy="diskann",
        max_wait_s=0.001,
        semantic_cache=sem,
    ) as mb:
        full = mb.submit(Q[0], 10, tenant="a").result()
        assert len(full) == 10
        assert mb.stats.semantic_hits == 0
    # the degraded answer is still present — under its DEGRADED k
    q0 = np.asarray(Q[0], np.float32)
    assert sem.lookup("a", q0, 5, None) is not None
    entry = sem.lookup("a", q0, 5, None)
    assert entry.report is not None and entry.report.cache == "semantic"


def test_semantic_invalidation_on_refresh(cache_cluster):
    from repro.serving.serve_loop import ProbeMicroBatcher

    c, t, X, Q, rng = cache_cluster
    sem = SemanticResultCache(max_bytes=1 << 20)
    with ProbeMicroBatcher(
        c.coordinator,
        "emb",
        strategy="diskann",
        max_wait_s=0.001,
        semantic_cache=sem,
    ) as mb:
        mb.submit(Q[0], 10, tenant="a").result()
        assert len(sem) == 1
        t.append_vectors(
            rng.normal(size=(96, 32)).astype(np.float32),
            num_files=1,
            rows_per_group=96,
            attributes={
                "category": np.array(["docs"] * 96),
                "price": np.full(96, 20),
            },
        )
        c.coordinator.refresh_index("emb", "idx")
        # the next drained report carries the new snapshot id → watermark
        # moves, pre-refresh answers are evicted, the repeat re-probes
        fresh = mb.submit(Q[0], 10, tenant="a").result()
        assert mb.stats.semantic_hits == 0
        assert mb.stats.cache_invalidations >= 1
        assert sem.stats.invalidations >= 1
        # the fresh answer matches a cache-off probe exactly
        rep = c.coordinator.probe_batch(
            "emb", Q[0][None, :], 10, strategy="diskann"
        )
        assert [
            (h.file_path, h.row_group, h.row_offset) for h in fresh
        ] == [(h.file_path, h.row_group, h.row_offset) for h in rep.hits[0]]


def test_concurrent_submit_hit_accounting(cache_cluster):
    from repro.serving.serve_loop import ProbeMicroBatcher

    c, t, X, Q, rng = cache_cluster
    sem = SemanticResultCache(max_bytes=1 << 20)
    with ProbeMicroBatcher(
        c.coordinator,
        "emb",
        strategy="diskann",
        max_wait_s=0.001,
        semantic_cache=sem,
    ) as mb:
        prime = mb.submit(Q[0], 10, tenant="a").result()
        results = []
        errs = []

        def worker():
            try:
                results.append(mb.submit(Q[0], 10, tenant="a").result(timeout=30))
            except Exception as e:  # pragma: no cover - failure detail
                errs.append(e)

        threads = [threading.Thread(target=worker) for _ in range(8)]
        for th in threads:
            th.start()
        for th in threads:
            th.join()
        assert not errs
        assert len(results) == 8
        ref = [(h.file_path, h.row_group, h.row_offset) for h in prime]
        for got in results:
            assert [(h.file_path, h.row_group, h.row_offset) for h in got] == ref
        # every submission is accounted exactly once: the priming miss plus
        # eight lookups, each a hit or a miss, nothing double-counted
        assert mb.stats.semantic_hits + mb.stats.semantic_misses == 9
        assert mb.stats.semantic_hits == 8
        assert sem.stats.hits == 8


def test_query_digest_is_content_addressed():
    q = np.arange(16, dtype=np.float32)
    assert query_digest(q) == query_digest(q.copy())
    assert query_digest(q) != query_digest(q + 1e-6)
