"""Logical-axis sharding rules + mesh factory."""


import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch.mesh import make_debug_mesh
from repro.models.sharding import (
    DEFAULT_RULES,
    logical_to_sharding,
    resolve_rule,
    spec_for,
    with_rules,
)


@pytest.fixture(scope="module")
def mesh():
    return make_debug_mesh(1, 1)


def test_resolve_drops_missing_axes(mesh):
    assert resolve_rule(("pod", "data"), ["data", "model"]) == "data"
    assert resolve_rule("pod", ["data", "model"]) is None
    assert resolve_rule(None, ["data"]) is None


def test_spec_for_basic(mesh):
    spec = spec_for(("batch", "seq", "embed"), DEFAULT_RULES, mesh)
    assert spec == P("data")  # pod dropped, seq/embed None trimmed


def test_spec_for_divisibility_fallback(mesh):
    # dim size 3 can't shard over data axis -> falls back to replicated
    spec = spec_for(("batch",), DEFAULT_RULES, mesh, dim_sizes=(3,))
    # with a size-1 mesh everything divides; simulate via strict flag on a
    # fake mesh of 2 below — here just assert no crash
    assert isinstance(spec, P)


def test_no_duplicate_mesh_axes(mesh):
    # two logical dims mapping to the same mesh axis: second must drop
    rules = with_rules(DEFAULT_RULES, embed="model")
    spec = spec_for(("heads", "embed"), rules, mesh)
    flat = [a for a in spec if a is not None]
    assert len(flat) == len(set(flat))


def test_logical_to_sharding_tree(mesh):
    axes = {"w": ("embed", "mlp"), "b": ("mlp",), "nested": {"v": ("vocab", "embed")}}
    sh = logical_to_sharding(axes, DEFAULT_RULES, mesh)
    assert sh["w"].spec == P(None, "model")
    assert sh["nested"]["v"].spec == P("model")


def test_with_rules_override():
    rules = with_rules(DEFAULT_RULES, cache_seq="model")
    assert rules["cache_seq"] == "model"
    assert DEFAULT_RULES["cache_seq"] is None  # original untouched


def test_mesh_factory_requires_devices():
    from repro.launch.mesh import make_production_mesh

    with pytest.raises(RuntimeError):
        make_production_mesh()  # only 1 CPU device in tests


def test_divisibility_fallback_with_shapes(mesh):
    import jax.numpy as jnp

    axes = {"w": ("kv_heads", "head_dim")}
    shapes = {"w": jax.ShapeDtypeStruct((2, 128), jnp.float32)}
    sh = logical_to_sharding(axes, DEFAULT_RULES, mesh, shapes_tree=shapes)
    # mesh model axis = 1 here so it divides; the dryrun covers the 16-way
    # case — this asserts the API accepts shape trees
    assert sh["w"].spec is not None
