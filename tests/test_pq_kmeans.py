"""k-means + product quantization: convergence, codec quality, ADC."""

import numpy as np

from repro.core.kmeans import assign, train_kmeans
from repro.core.pq import (
    PQCodebook,
    adc_scores,
    decode,
    encode,
    reconstruction_error,
    train_pq,
)
from conftest import clustered_vectors


def test_kmeans_recovers_clusters(rng):
    X, centers = clustered_vectors(rng, n_clusters=8, per_cluster=200, dim=16, scale=8.0)
    cents, inertia = train_kmeans(X, 8, iters=25, seed=0)
    # every true center has a learned centroid nearby
    d = np.sqrt(((centers[:, None, :] - cents[None]) ** 2).sum(-1)).min(axis=1)
    assert (d < 2.0).all(), d


def test_kmeans_inertia_decreases(rng):
    X, _ = clustered_vectors(rng, n_clusters=5, per_cluster=100, dim=8)
    _, i1 = train_kmeans(X, 5, iters=2, seed=0)
    _, i2 = train_kmeans(X, 5, iters=20, seed=0)
    assert i2 <= i1 * 1.001


def test_kmeans_no_empty_clusters(rng):
    X = rng.normal(size=(500, 4)).astype(np.float32)
    cents, _ = train_kmeans(X, 64, iters=10, seed=1)
    counts = np.bincount(assign(X, cents), minlength=64)
    assert (counts > 0).all()


def test_pq_roundtrip_shapes(rng):
    X = rng.normal(size=(2000, 64)).astype(np.float32)
    pq = train_pq(X, m=8, nbits=6, iters=5)
    codes = encode(pq, X)
    assert codes.shape == (2000, 8) and codes.dtype == np.uint8
    assert codes.max() < 64
    approx = decode(pq, codes)
    assert approx.shape == X.shape


def test_pq_error_improves_with_bits(rng):
    X, _ = clustered_vectors(rng, n_clusters=8, per_cluster=250, dim=32)
    e_small = reconstruction_error(train_pq(X, m=4, nbits=4, iters=6), X)
    e_big = reconstruction_error(train_pq(X, m=16, nbits=8, iters=6), X)
    assert e_big < e_small * 0.5


def test_adc_approximates_exact(rng):
    X, _ = clustered_vectors(rng, n_clusters=8, per_cluster=125, dim=32)
    pq = train_pq(X, m=16, nbits=8, iters=6)
    codes = encode(pq, X)
    Q = X[:8]
    s = np.asarray(adc_scores(pq, Q, codes, backend="ref"))
    exact = ((Q[:, None, :] - X[None]) ** 2).sum(-1)
    for qi in range(8):
        corr = np.corrcoef(s[qi], exact[qi])[0, 1]
        assert corr > 0.95
    # ADC of a vector against its own code ≈ its reconstruction error
    own = s[np.arange(8), np.arange(8)]
    recon = ((decode(pq, codes[:8]) - Q) ** 2).sum(-1)
    np.testing.assert_allclose(own, recon, rtol=1e-3, atol=1e-3)


def test_codebook_serialization(rng):
    X = rng.normal(size=(1000, 32)).astype(np.float32)
    pq = train_pq(X, m=8, nbits=5, iters=4)
    blob = pq.tobytes()
    pq2 = PQCodebook.frombytes(blob, pq.m, pq.K, pq.dsub, pq.metric)
    np.testing.assert_allclose(pq.codebook, pq2.codebook)
    np.testing.assert_array_equal(encode(pq, X[:50]), encode(pq2, X[:50]))


def test_paper_pq_memory_claim():
    """Paper §9.2: 2.5e8 vectors × m=48 = 12 GB of PQ codes per shard."""
    n, m = 2.5e8, 48
    assert abs(n * m / 1e9 - 12.0) < 0.1
