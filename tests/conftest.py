import os
import sys

# Tests run on the real single CPU device — the 512-device override is ONLY
# for launch/dryrun.py (see system design note).  Keep allocations small.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

try:  # property tests prefer real hypothesis; fall back to the local shim
    import hypothesis  # noqa: F401
except ImportError:
    import _hypothesis_shim

    _hypothesis_shim.install()

import numpy as np
import pytest


@pytest.fixture()
def rng():
    # function-scoped: each test sees the same stream regardless of which
    # other tests ran (a session-scoped generator made borderline tests
    # depend on suite composition)
    return np.random.default_rng(0)


@pytest.fixture()
def tmp_store(tmp_path):
    from repro.lakehouse.objectstore import ObjectStore

    return ObjectStore(str(tmp_path / "s3"))


@pytest.fixture()
def cluster(tmp_path):
    from repro.runtime.cluster import make_local_cluster

    return make_local_cluster(str(tmp_path), num_executors=3)


def clustered_vectors(rng, n_clusters=16, per_cluster=100, dim=32, scale=4.0):
    centers = rng.normal(size=(n_clusters, dim)) * scale
    X = np.concatenate(
        [c + rng.normal(size=(per_cluster, dim)) for c in centers]
    ).astype(np.float32)
    perm = rng.permutation(len(X))
    return X[perm], centers.astype(np.float32)


# index params shared by the integration fixtures/tests (small but structured)
BUILT_CFG = dict(R=16, L=32, partitions_per_shard=3, build_passes=1, build_batch=128)


@pytest.fixture(scope="session")
def built_cluster(tmp_path_factory):
    """Session-shared cluster with table "emb" and a built index "idx".

    Shared by test_runtime and test_probe_batch — building a cluster + index
    dominates suite wall-clock, so it happens once.  Tests may mutate the
    table (append/refresh); assertions must not depend on table contents
    beyond what each test arranges itself."""
    from repro.lakehouse.table import LakehouseTable
    from repro.runtime.cluster import make_local_cluster
    from repro.runtime.coordinator import IndexConfig

    rng = np.random.default_rng(0)
    root = str(tmp_path_factory.mktemp("cluster"))
    c = make_local_cluster(root, num_executors=3)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=32)
    # geometry matters: row groups small enough that warm index probes read
    # far less than a scan, table big enough that recall thresholds are
    # meaningful — but ~half the seed's vector count for suite speed
    X, centers = clustered_vectors(rng, n_clusters=24, per_cluster=80, dim=32)
    t.append_vectors(X, num_files=9, rows_per_group=128)
    rep = c.coordinator.create_index("emb", IndexConfig(name="idx", **BUILT_CFG))
    return c, t, X, centers, rep
