import os
import sys

# Tests run on the real single CPU device — the 512-device override is ONLY
# for launch/dryrun.py (see system design note).  Keep allocations small.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture()
def rng():
    # function-scoped: each test sees the same stream regardless of which
    # other tests ran (a session-scoped generator made borderline tests
    # depend on suite composition)
    return np.random.default_rng(0)


@pytest.fixture()
def tmp_store(tmp_path):
    from repro.lakehouse.objectstore import ObjectStore

    return ObjectStore(str(tmp_path / "s3"))


@pytest.fixture()
def cluster(tmp_path):
    from repro.runtime.cluster import make_local_cluster

    return make_local_cluster(str(tmp_path), num_executors=3)


def clustered_vectors(rng, n_clusters=16, per_cluster=100, dim=32, scale=4.0):
    centers = rng.normal(size=(n_clusters, dim)) * scale
    X = np.concatenate(
        [c + rng.normal(size=(per_cluster, dim)) for c in centers]
    ).astype(np.float32)
    perm = rng.permutation(len(X))
    return X[perm], centers.astype(np.float32)
