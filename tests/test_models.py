"""All 10 assigned architectures: smoke tests on reduced configs.

Per the assignment: instantiate a REDUCED config of the same family and run
one forward/train step on CPU asserting output shapes + no NaNs; plus
prefill/decode consistency and the mixer-specific oracles.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, reduced, shape_cells
from repro.models.model import build_model
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6


@pytest.fixture(scope="module", params=ARCH_IDS)
def arch_setup(request):
    arch = request.param
    cfg = reduced(get_config(arch))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return arch, cfg, model, params


def _ids(cfg, B, S, seed=0):
    rng = np.random.default_rng(seed)
    shape = (B, S, cfg.num_codebooks) if cfg.num_codebooks else (B, S)
    return jnp.asarray(rng.integers(0, cfg.vocab_size, size=shape))


def test_forward_shapes_and_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    ids = _ids(cfg, 2, 64)
    logits, aux = jax.jit(model.forward)(params, ids)
    expect = (2, 64, cfg.num_codebooks, cfg.vocab_size) if cfg.num_codebooks else (2, 64, cfg.vocab_size)
    assert logits.shape == expect
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all()), arch


@pytest.mark.slow  # ~10 archs × jit'd train step dominates suite wall-clock
def test_train_step_runs_and_loss_finite(arch_setup):
    arch, cfg, model, params = arch_setup
    from repro.launch.mesh import make_debug_mesh
    from repro.training.train_loop import TrainStepConfig, make_train_step
    from repro.training.optimizer import adamw_init

    mesh = make_debug_mesh(1, 1)
    step, sh = make_train_step(model, mesh, cfg=TrainStepConfig(microbatches=2, remat=True))
    # the step donates params/opt buffers — work on a copy, the fixture's
    # params are shared across tests
    params_c = jax.tree.map(jnp.copy, params)
    opt = adamw_init(params_c)
    ids = _ids(cfg, 4, 32)
    params2, opt2, metrics = step(params_c, opt, ids, ids)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    # params actually changed
    l0 = jax.tree_util.tree_leaves(params2)[0]
    assert l0.dtype == jnp.float32


@pytest.mark.slow  # per-token jit'd decode loop × 10 archs
def test_prefill_decode_matches_forward(arch_setup):
    arch, cfg, model, params = arch_setup
    B, S, P = 2, 32, 24
    ids = _ids(cfg, B, S, seed=3)
    full_logits, _ = jax.jit(model.forward)(params, ids)
    cache = model.init_cache(B, S)
    lp, cache = jax.jit(model.prefill)(params, ids[:, :P], cache)
    errs = [float(jnp.abs(lp[:, 0] - full_logits[:, P - 1]).max())]
    dec = jax.jit(model.decode)
    for t in range(P, S):
        lg, cache = dec(params, ids[:, t : t + 1], cache, jnp.int32(t))
        errs.append(float(jnp.abs(lg[:, 0] - full_logits[:, t]).max()))
    scale = float(jnp.abs(full_logits).max())
    assert max(errs) < 0.06 * max(scale, 1.0), (arch, errs)


def test_long_500k_applicability_flags():
    """The long_500k skip set is exactly the pure full-attention archs."""
    expected_runs = {"mixtral-8x7b", "rwkv6-3b", "zamba2-1.2b"}
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        names = {s.name for s in shape_cells(cfg)}
        assert ("long_500k" in names) == (arch in expected_runs), arch


def test_configs_match_assignment():
    """Exact public config numbers from the assignment table."""
    spec = {
        "dbrx-132b": (40, 6144, 48, 8, 10752, 100352, 16, 4),
        "mixtral-8x7b": (32, 4096, 32, 8, 14336, 32000, 8, 2),
        "chameleon-34b": (48, 8192, 64, 8, 22016, 65536, 0, 0),
        "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024, 0, 0),
        "qwen2.5-3b": (36, 2048, 16, 2, 11008, 151936, 0, 0),
        "minitron-8b": (32, 4096, 32, 8, 16384, 256000, 0, 0),
        "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064, 0, 0),
        "musicgen-medium": (48, 1536, 24, 24, 6144, 2048, 0, 0),
        "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536, 0, 0),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000, 0, 0),
    }
    for arch, (L, d, H, KV, ff, V, E, K) in spec.items():
        c = get_config(arch)
        assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
                c.vocab_size, c.num_experts, c.top_k) == (L, d, H, KV, ff, V, E, K), arch


# ---------------------------------------------------------------------------
# mixer oracles
# ---------------------------------------------------------------------------

def test_moe_gshard_matches_dense_when_no_drops():
    cfg = reduced(get_config("dbrx-132b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)).astype(np.float32))
    out, aux = MOE.moe_block(lp["moe"], x, num_experts=cfg.num_experts,
                             top_k=cfg.top_k, capacity_factor=8.0)
    want = MOE.moe_block_dense_ref(lp["moe"], x, num_experts=cfg.num_experts, top_k=cfg.top_k)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-4, atol=2e-4)
    assert float(aux) >= 1.0  # Switch aux loss lower bound E·Σ f·p ≥ 1


@pytest.mark.parametrize("chunk", [8, 16, 32])
def test_rwkv6_chunked_matches_stepwise(chunk):
    cfg = reduced(get_config("rwkv6-3b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(3))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    rng = np.random.default_rng(4)
    B, S, D = 2, 64, cfg.d_model
    x = jnp.asarray(rng.normal(size=(B, S, D)).astype(np.float32)) * 0.5
    xp = jnp.asarray(rng.normal(size=(B, D)).astype(np.float32)) * 0.5
    H, N = cfg.ssm_heads_eff, cfg.head_dim
    st = jnp.asarray(rng.normal(size=(B, H, N, N)).astype(np.float32)) * 0.1
    oc, xc, sc = R6.rwkv6_chunked(lp["tmix"], x, xp, st, chunk=chunk)
    orf, xr, sr = R6.rwkv6_ref(lp["tmix"], x, xp, st)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(orf), rtol=2e-2, atol=2e-2)
    np.testing.assert_allclose(np.asarray(sc), np.asarray(sr), rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("chunk", [8, 32])
def test_mamba2_chunked_matches_stepwise(chunk):
    cfg = reduced(get_config("zamba2-1.2b"))
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(5))
    lp = jax.tree.map(lambda x: x[0], params["layers"])
    rng = np.random.default_rng(6)
    B, S = 2, 64
    x = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)).astype(np.float32)) * 0.5
    conv, ssm = M2.init_mamba2_state(cfg, B)
    oc, cv, st = M2.mamba2_chunked(lp["mixer"], x, conv, ssm, chunk=chunk)
    orf, cvr, sr = M2.mamba2_ref(lp["mixer"], x, conv, ssm)
    np.testing.assert_allclose(np.asarray(oc), np.asarray(orf), rtol=1e-3, atol=1e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(sr), rtol=1e-3, atol=1e-3)


def test_swa_window_masks_old_tokens():
    """Mixtral SWA: tokens beyond the window must not influence logits."""
    import dataclasses
    cfg = dataclasses.replace(reduced(get_config("mixtral-8x7b")), window=8, num_layers=1)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(7))
    rng = np.random.default_rng(8)
    ids1 = rng.integers(0, cfg.vocab_size, size=(1, 32))
    ids2 = ids1.copy()
    ids2[0, :8] = (ids2[0, :8] + 7) % cfg.vocab_size  # outside last token's window
    l1, _ = jax.jit(model.forward)(params, jnp.asarray(ids1))
    l2, _ = jax.jit(model.forward)(params, jnp.asarray(ids2))
    np.testing.assert_allclose(
        np.asarray(l1[0, -1]), np.asarray(l2[0, -1]), rtol=1e-4, atol=1e-4
    )
