"""Catalog semantics: optimistic concurrency, snapshots, diff, GC, time travel."""

import threading

import numpy as np
import pytest

from repro.iceberg.catalog import CommitConflict, RestCatalog
from repro.iceberg.diff import diff_snapshots
from repro.iceberg.gc import collect_orphans, expire_and_collect
from repro.lakehouse.table import LakehouseTable


@pytest.fixture()
def table(tmp_store):
    cat = RestCatalog(tmp_store)
    t = LakehouseTable(cat, "t")
    t.create(dim=8)
    return t


def _vecs(n, d=8, seed=0):
    return np.random.default_rng(seed).normal(size=(n, d)).astype(np.float32)


def test_append_and_scan(table):
    table.append_vectors(_vecs(100), num_files=4)
    vecs, locs = table.scan_vectors()
    assert vecs.shape == (100, 8)
    assert len({l.file_path for l in locs}) == 4


def test_snapshot_chain_and_time_travel(table):
    m1 = table.append_vectors(_vecs(10))
    m2 = table.append_vectors(_vecs(10, seed=1))
    assert m2.current_snapshot().parent_snapshot_id == m1.current_snapshot_id
    old = m2.snapshot_by_id(m1.current_snapshot_id)
    as_of = m2.snapshot_as_of(old.timestamp_ms)
    assert as_of.snapshot_id in (m1.current_snapshot_id, m2.current_snapshot_id)


def test_diff_added_deleted(table):
    m1 = table.append_vectors(_vecs(100), num_files=2)
    s1 = m1.current_snapshot_id
    table.append_vectors(_vecs(50, seed=1), num_files=1)
    doomed = table.current_files()[0].path
    m3 = table.delete_files([doomed])
    d = diff_snapshots(table.store, m3, s1, m3.current_snapshot_id)
    assert len(d.added) == 1
    assert len(d.deleted) == 1
    assert d.deleted[0].path == doomed
    assert len(d.existing) == 1


def test_commit_conflict_and_retry(tmp_store):
    cat = RestCatalog(tmp_store)
    t = LakehouseTable(cat, "x")
    t.create(dim=8)
    base = cat.load_table("x")

    def add_prop(key):
        def mutate(meta):
            meta.properties[key] = "1"
            return meta

        return mutate

    cat.commit("x", base, add_prop("a"))
    # second commit against the SAME stale base must conflict
    with pytest.raises(CommitConflict):
        cat.commit("x", base, add_prop("b"))
    # retry path rebases
    cat.commit_with_retries("x", add_prop("b"))
    final = cat.load_table("x")
    assert final.properties == {"a": "1", "b": "1"}


def test_concurrent_committers_one_wins_per_round(tmp_store):
    cat = RestCatalog(tmp_store)
    t = LakehouseTable(cat, "y")
    t.create(dim=8)
    errors = []

    def worker(i):
        try:
            cat.commit_with_retries(
                "y", lambda m: (m.properties.__setitem__(f"k{i}", "v"), m)[1]
            )
        except Exception as e:  # noqa: BLE001
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    [th.start() for th in threads]
    [th.join() for th in threads]
    assert not errors
    final = cat.load_table("y")
    assert len(final.properties) == 8
    # every thread's write must have landed despite the race (conflicts are
    # timing-dependent; the deterministic conflict path is tested above)
    assert final.version == 8


def test_statistics_file_binding_and_staleness(table):
    m1 = table.append_vectors(_vecs(10))
    table.store.put("warehouse/t/metadata/idx.puffin", b"fake")
    m2 = table.catalog.set_statistics_file(
        "t", "warehouse/t/metadata/idx.puffin",
        expected_base_snapshot_id=m1.current_snapshot_id,
    )
    assert m2.current_snapshot().statistics_file == "warehouse/t/metadata/idx.puffin"
    # appending carries the binding forward as stale (twice!)
    table.append_vectors(_vecs(5, seed=2))
    m4 = table.append_vectors(_vecs(5, seed=3))
    assert m4.current_snapshot().statistics_file is None
    assert (
        m4.current_snapshot().summary["ann.stale-statistics-file"]
        == "warehouse/t/metadata/idx.puffin"
    )


def test_stale_base_guard(table):
    m1 = table.append_vectors(_vecs(10))
    table.append_vectors(_vecs(10, seed=1))  # table advances
    table.store.put("warehouse/t/metadata/idx2.puffin", b"fake")
    with pytest.raises(CommitConflict):
        table.catalog.set_statistics_file(
            "t", "warehouse/t/metadata/idx2.puffin",
            expected_base_snapshot_id=m1.current_snapshot_id,  # stale base
        )


def test_orphan_gc(table):
    table.append_vectors(_vecs(50), num_files=2)
    # an uncommitted leftover (e.g. crashed index build)
    table.store.put("warehouse/t/metadata/leftover-shard.blob", b"junk")
    orphans = collect_orphans(table.store, table.metadata())
    assert orphans == ["warehouse/t/metadata/leftover-shard.blob"]
    # expiring old snapshots orphans their unique files
    table.append_vectors(_vecs(10, seed=1))
    meta = table.metadata()
    orphans = expire_and_collect(table.store, meta, keep_last=1, delete=True)
    for key in orphans:
        assert not table.store.exists(key)
    # table still readable at the retained snapshot
    vecs, _ = table.scan_vectors()
    assert vecs.shape[0] == 60


def test_snapshot_as_of_edge_cases():
    """Time-travel edges: before the first snapshot raises; an exact
    boundary timestamp is inclusive; equal timestamps break ties by
    sequence number (the later commit wins)."""
    from repro.iceberg.snapshot import Snapshot, TableMetadata

    snaps = [
        Snapshot(1, None, 1, 1000, "ml1", "append"),
        Snapshot(2, 1, 2, 2000, "ml2", "append"),
    ]
    meta = TableMetadata("u", "loc", {}, 0, 2, snaps)
    with pytest.raises(KeyError):
        meta.snapshot_as_of(999)
    assert meta.snapshot_as_of(1000).snapshot_id == 1  # exact boundary
    assert meta.snapshot_as_of(1999).snapshot_id == 1
    assert meta.snapshot_as_of(2000).snapshot_id == 2
    assert meta.snapshot_as_of(10**15).snapshot_id == 2
    # same-millisecond commits: sequence number breaks the tie
    meta.snapshots.append(Snapshot(3, 2, 3, 2000, "ml3", "append"))
    assert meta.snapshot_as_of(2000).snapshot_id == 3


def test_catalog_expire_snapshots_commit(table):
    table.append_vectors(_vecs(30), num_files=1)
    table.append_vectors(_vecs(30, seed=1), num_files=1)
    table.append_vectors(_vecs(30, seed=2), num_files=1)
    before = table.metadata()
    assert len(before.snapshots) == 3
    meta = table.catalog.expire_snapshots("t", keep_last=2)
    assert len(meta.snapshots) == 2
    assert meta.version == before.version + 1  # a real metadata commit
    # the expiration is what every reader now sees
    assert len(table.catalog.load_table("t").snapshots) == 2
    with pytest.raises(ValueError):
        table.catalog.expire_snapshots("t", keep_last=0)
