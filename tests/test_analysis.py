"""Roofline analysis machinery: jaxpr FLOP counter + HLO collective parser."""

import jax
import jax.numpy as jnp
import pytest

from repro.analysis.flops import count_jaxpr_flops
from repro.analysis.hlo import _shape_bytes, _trip_count, collective_bytes_from_hlo


def test_flops_plain_matmul():
    a = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    b = jax.ShapeDtypeStruct((128, 32), jnp.float32)
    def f(x, y):
        return x @ y
    got = count_jaxpr_flops(f, a, b)
    assert got == 2 * 64 * 128 * 32


def test_flops_scan_multiplies_by_length():
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)

    def f(w, x):
        def body(c, _):
            return c @ w, None

        out, _ = jax.lax.scan(body, x, None, length=10)
        return out

    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    got = count_jaxpr_flops(f, w, x)
    assert got >= 10 * 2 * 4 * 16 * 16


def test_flops_includes_backward():
    w = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 32), jnp.float32)

    def loss(w, x):
        return jnp.sum((x @ w) ** 2)

    fwd = count_jaxpr_flops(loss, w, x)
    both = count_jaxpr_flops(jax.grad(loss), w, x)
    assert both > 2 * fwd  # bwd matmuls ≈ 2× fwd


_FAKE_HLO = """\
HloModule test

%cond (p: (s32[], f32[4])) -> pred[] {
  %p = (s32[], f32[4]) parameter(0)
  %iv = s32[] get-tuple-element(%p), index=0
  %c = s32[] constant(7)
  ROOT %cmp = pred[] compare(%iv, %c), direction=LT
}

%body (p: (s32[], f32[4])) -> (s32[], f32[4]) {
  %p = (s32[], f32[4]) parameter(0)
  %x = f32[4] get-tuple-element(%p), index=1
  %ar = f32[4] all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %iv = s32[] get-tuple-element(%p), index=0
  ROOT %t = (s32[], f32[4]) tuple(%iv, %ar)
}

ENTRY %main (a: f32[4]) -> f32[4] {
  %a = f32[4] parameter(0)
  %ag = f32[8] all-gather(%a), replica_groups=[2,4]<=[8], dimensions={0}
  %w = (s32[], f32[4]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[4] get-tuple-element(%w), index=1
}
"""


def test_hlo_parser_trip_counts_and_groups():
    stats = collective_bytes_from_hlo(_FAKE_HLO)
    # raw: one all-gather (8*4=32B) + one all-reduce (4*4=16B)
    assert stats.raw_bytes == 32 + 16
    # corrected: while body ×7
    assert stats.corrected_bytes == 32 + 7 * 16
    # global: ag ×4 participants, ar ×4 participants
    assert stats.global_bytes == 32 * 4 + 7 * 16 * 4


def test_shape_bytes():
    assert _shape_bytes("bf16[16,512,128]") == 16 * 512 * 128 * 2
    assert _shape_bytes("(f32[4], f32[2,2])") == 16 + 16
    assert _shape_bytes("pred[]") == 1


def test_trip_count_le_direction():
    lines = ["%c = s32[] constant(5)", "ROOT %cmp = pred[] compare(%iv, %c), direction=LE"]
    assert _trip_count(lines) == 6


def test_dryrun_results_complete():
    """The committed dry-run table must cover all 40 cells × 2 meshes."""
    import json
    import os

    path = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun.jsonl")
    if not os.path.exists(path):
        pytest.skip("dry-run results not generated yet")
    rows = [json.loads(l) for l in open(path)]
    seen = {(r["arch"], r["shape"], r["mesh"]) for r in rows}
    from repro.configs import ARCH_IDS

    missing = []
    for arch in ARCH_IDS:
        for shape in ("train_4k", "prefill_32k", "decode_32k", "long_500k"):
            for mesh in ("single", "multi"):
                if (arch, shape, mesh) not in seen:
                    missing.append((arch, shape, mesh))
    assert not missing, missing
    errors = [r for r in rows if r.get("kind") == "error"]
    assert not errors, [(r["arch"], r["shape"], r["mesh"]) for r in errors]
