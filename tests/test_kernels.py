"""Per-kernel validation: shape/dtype sweeps, Pallas(interpret) vs ref oracle.

Every test here carries the ``kernels`` marker: ``pytest -m "kernels and not
slow"`` is the CI tier-1 kernel-parity gate (scripts/ci.sh) asserting that
the Pallas path (``interpret=True`` off-TPU) agrees with the ref.py oracle
for every op in ops.py — including the masked ops' all-masked / one-row /
non-tile-aligned edge cases.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

pytestmark = pytest.mark.kernels


def _np(*shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).normal(size=shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# exact distances (rerank kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,n,d", [(1, 1, 1), (7, 33, 5), (37, 301, 100), (128, 256, 768), (3, 500, 17)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_rerank_matches_ref(q, n, d, metric):
    Q, X = _np(q, d, seed=1), _np(n, d, seed=2)
    got = ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), metric=metric, backend="pallas")
    want = ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), metric=metric, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3)


def test_rerank_topk_order():
    Q, X = _np(4, 16, seed=3), _np(100, 16, seed=4)
    d, i = ops.exact_topk(jnp.asarray(Q), jnp.asarray(X), 5, backend="pallas")
    full = np.asarray(ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="ref"))
    for qi in range(4):
        np.testing.assert_array_equal(
            np.sort(np.asarray(i)[qi]), np.sort(np.argsort(full[qi])[:5])
        )


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_rerank_dtypes(dtype):
    Q = _np(8, 32, seed=5).astype(dtype)
    X = _np(64, 32, seed=6).astype(dtype)
    got = ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="pallas")
    want = ref.l2_distances(jnp.asarray(Q, jnp.float32), jnp.asarray(X, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-2)


# ---------------------------------------------------------------------------
# PQ ADC scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,n,m,K", [(1, 1, 1, 2), (5, 77, 8, 16), (16, 300, 48, 256), (2, 130, 4, 64)])
def test_pq_scan_matches_ref(q, n, m, K):
    rng = np.random.default_rng(7)
    luts = rng.normal(size=(q, m, K)).astype(np.float32)
    codes = rng.integers(0, K, size=(n, m)).astype(np.int32)
    got = ops.pq_scan(jnp.asarray(luts), jnp.asarray(codes), backend="pallas", tile_q=4, tile_n=32)
    want = ops.pq_scan(jnp.asarray(luts), jnp.asarray(codes), backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_pq_scan_topk():
    rng = np.random.default_rng(8)
    luts = rng.normal(size=(3, 8, 32)).astype(np.float32)
    codes = rng.integers(0, 32, size=(50, 8)).astype(np.int32)
    d, i = ops.pq_scan_topk(jnp.asarray(luts), jnp.asarray(codes), 7, backend="pallas")
    full = np.asarray(ref.pq_adc_scores(jnp.asarray(luts), jnp.asarray(codes)))
    for qi in range(3):
        np.testing.assert_array_equal(np.sort(np.asarray(i)[qi]), np.sort(np.argsort(full[qi])[:7]))


# ---------------------------------------------------------------------------
# k-means assignment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,d", [(1, 1, 1), (100, 10, 8), (555, 100, 48), (1000, 257, 16)])
def test_kmeans_assign_matches_ref(n, k, d):
    X = _np(n, d, seed=9)
    C = _np(k, d, seed=10)
    ip, dp = ops.kmeans_assign(jnp.asarray(X), jnp.asarray(C), backend="pallas", tile_n=128, tile_k=32)
    ir, dr = ops.kmeans_assign(jnp.asarray(X), jnp.asarray(C), backend="ref")
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# masked top-k (mask-aware filtered-probe kernels)
# ---------------------------------------------------------------------------


def _assert_masked_contract(dists, ids, full_d, mask, k):
    """Masked-op contract: rows ascending, only passing rows appear, each
    returned distance equals the oracle's distance for that id, and exactly
    min(k, passing) slots are populated (the rest are (+inf, -1))."""
    q = dists.shape[0]
    n_pass = int(np.asarray(mask).sum())
    for qi in range(q):
        d_row, i_row = np.asarray(dists[qi]), np.asarray(ids[qi])
        valid = i_row >= 0
        assert valid.sum() == min(k, n_pass)
        assert np.isfinite(d_row[valid]).all() and np.isinf(d_row[~valid]).all()
        assert (i_row[~valid] == -1).all()
        assert np.all(np.diff(d_row[valid]) >= -1e-4)  # ascending
        if valid.any():
            assert np.asarray(mask)[i_row[valid]].all()  # never a masked row
            np.testing.assert_allclose(
                d_row[valid], full_d[qi, i_row[valid]], rtol=2e-4, atol=2e-3
            )


# shapes deliberately non-tile-aligned (tile_q=8, tile_n=128 defaults),
# plus the one-row and k>N edges
@pytest.mark.parametrize("q,n,k", [(1, 1, 1), (3, 37, 5), (7, 130, 10), (5, 300, 320)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_masked_exact_topk_matches_ref(q, n, k, metric):
    rng = np.random.default_rng(q * 13 + n)
    Q, X = _np(q, 16, seed=q), _np(n, 16, seed=n)
    mask = rng.random(n) < 0.4
    full = np.asarray(
        ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), metric=metric, backend="ref")
    )
    for backend in ("pallas", "ref"):
        d, i = ops.masked_exact_topk(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), k,
            metric=metric, backend=backend,
        )
        _assert_masked_contract(np.asarray(d), np.asarray(i), full, mask, k)
    dp, ipal = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), k, metric=metric, backend="pallas"
    )
    dr, _ = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), k, metric=metric, backend="ref"
    )
    dp, dr = np.asarray(dp), np.asarray(dr)
    np.testing.assert_allclose(
        np.where(np.isinf(dp), 0.0, dp), np.where(np.isinf(dr), 0.0, dr),
        rtol=2e-4, atol=2e-3,
    )
    assert (np.isinf(dp) == np.isinf(dr)).all()


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_masked_exact_topk_all_masked(backend):
    Q, X = _np(2, 8, seed=1), _np(40, 8, seed=2)
    d, i = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.zeros(40, bool), 5, backend=backend
    )
    assert np.isinf(np.asarray(d)).all() and (np.asarray(i) == -1).all()


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_masked_exact_topk_single_passing_row(backend):
    """One passing row, k > 1: exactly one populated slot, and it is that row."""
    Q, X = _np(3, 8, seed=3), _np(50, 8, seed=4)
    mask = np.zeros(50, bool)
    mask[17] = True
    d, i = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), 4, backend=backend
    )
    i = np.asarray(i)
    assert (i[:, 0] == 17).all() and (i[:, 1:] == -1).all()
    assert np.isinf(np.asarray(d)[:, 1:]).all()


@pytest.mark.parametrize("q,n,m,K,k", [(1, 1, 1, 2, 1), (5, 77, 8, 16, 9), (3, 300, 4, 64, 12)])
def test_masked_pq_topk_matches_ref(q, n, m, K, k):
    rng = np.random.default_rng(q * 31 + n)
    luts = rng.normal(size=(q, m, K)).astype(np.float32)
    codes = rng.integers(0, K, size=(n, m)).astype(np.int32)
    mask = rng.random(n) < 0.5
    full = np.asarray(ref.pq_adc_scores(jnp.asarray(luts), jnp.asarray(codes)))
    for backend in ("pallas", "ref"):
        d, i = ops.masked_pq_topk(
            jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(mask), k, backend=backend
        )
        _assert_masked_contract(np.asarray(d), np.asarray(i), full, mask, k)
    dp, _ = ops.masked_pq_topk(
        jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(mask), k, backend="pallas"
    )
    dr, _ = ops.masked_pq_topk(
        jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(mask), k, backend="ref"
    )
    dp, dr = np.asarray(dp), np.asarray(dr)
    np.testing.assert_allclose(
        np.where(np.isinf(dp), 0.0, dp), np.where(np.isinf(dr), 0.0, dr),
        rtol=1e-4, atol=1e-4,
    )
    assert (np.isinf(dp) == np.isinf(dr)).all()


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_masked_pq_topk_all_masked(backend):
    rng = np.random.default_rng(5)
    luts = rng.normal(size=(2, 4, 16)).astype(np.float32)
    codes = rng.integers(0, 16, size=(60, 4)).astype(np.int32)
    d, i = ops.masked_pq_topk(
        jnp.asarray(luts), jnp.asarray(codes), jnp.zeros(60, bool), 6, backend=backend
    )
    assert np.isinf(np.asarray(d)).all() and (np.asarray(i) == -1).all()


# ---------------------------------------------------------------------------
# multi-mask top-k (per-query (Q, N) mask planes — heterogeneous filters)
# ---------------------------------------------------------------------------


def _assert_masked_contract_multi(dists, ids, full_d, masks, k):
    """Per-query plane contract: each row obeys the single-mask contract
    under ITS OWN mask row."""
    for qi in range(dists.shape[0]):
        _assert_masked_contract(
            dists[qi : qi + 1], ids[qi : qi + 1], full_d[qi : qi + 1], masks[qi], k
        )


# non-tile-aligned Q and N (tile_q=8, tile_n=128 defaults), single-row, and
# k > passing-rows edges
@pytest.mark.parametrize("q,n,k", [(2, 1, 1), (3, 37, 5), (9, 130, 10), (5, 300, 320)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_masked_exact_topk_multi_matches_ref(q, n, k, metric):
    rng = np.random.default_rng(q * 17 + n)
    Q, X = _np(q, 16, seed=q), _np(n, 16, seed=n)
    masks = rng.random((q, n)) < 0.4
    if q > 1:
        masks[1] = False  # one all-masked QUERY among live ones
    full = np.asarray(
        ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), metric=metric, backend="ref")
    )
    outs = {}
    for backend in ("pallas", "ref"):
        d, i = ops.masked_exact_topk_multi(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(masks), k,
            metric=metric, backend=backend,
        )
        d, i = np.asarray(d), np.asarray(i)
        _assert_masked_contract_multi(d, i, full, masks, k)
        if q > 1:
            assert np.isinf(d[1]).all() and (i[1] == -1).all()
        outs[backend] = (d, i)
    dp, dr = outs["pallas"][0], outs["ref"][0]
    np.testing.assert_allclose(
        np.where(np.isinf(dp), 0.0, dp), np.where(np.isinf(dr), 0.0, dr),
        rtol=2e-4, atol=2e-3,
    )
    assert (np.isinf(dp) == np.isinf(dr)).all()


@pytest.mark.parametrize("q,n,m,K,k", [(2, 1, 1, 2, 1), (5, 77, 8, 16, 9), (3, 300, 4, 64, 12)])
def test_masked_pq_topk_multi_matches_ref(q, n, m, K, k):
    rng = np.random.default_rng(q * 29 + n)
    luts = rng.normal(size=(q, m, K)).astype(np.float32)
    codes = rng.integers(0, K, size=(n, m)).astype(np.int32)
    masks = rng.random((q, n)) < 0.5
    full = np.asarray(ref.pq_adc_scores(jnp.asarray(luts), jnp.asarray(codes)))
    outs = {}
    for backend in ("pallas", "ref"):
        d, i = ops.masked_pq_topk_multi(
            jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(masks), k, backend=backend
        )
        d, i = np.asarray(d), np.asarray(i)
        _assert_masked_contract_multi(d, i, full, masks, k)
        outs[backend] = (d, i)
    dp, dr = outs["pallas"][0], outs["ref"][0]
    np.testing.assert_allclose(
        np.where(np.isinf(dp), 0.0, dp), np.where(np.isinf(dr), 0.0, dr),
        rtol=1e-4, atol=1e-4,
    )
    assert (np.isinf(dp) == np.isinf(dr)).all()


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_masked_multi_all_queries_masked(backend):
    Q, X = _np(3, 8, seed=1), _np(40, 8, seed=2)
    d, i = ops.masked_exact_topk_multi(
        jnp.asarray(Q), jnp.asarray(X), jnp.zeros((3, 40), bool), 5, backend=backend
    )
    assert np.isinf(np.asarray(d)).all() and (np.asarray(i) == -1).all()
    rng = np.random.default_rng(3)
    luts = rng.normal(size=(2, 4, 16)).astype(np.float32)
    codes = rng.integers(0, 16, size=(60, 4)).astype(np.int32)
    d, i = ops.masked_pq_topk_multi(
        jnp.asarray(luts), jnp.asarray(codes), jnp.zeros((2, 60), bool), 6, backend=backend
    )
    assert np.isinf(np.asarray(d)).all() and (np.asarray(i) == -1).all()


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_masked_multi_q1_degenerates_to_single_mask(backend):
    """Q == 1 planes dispatch to the single-mask kernels and must return
    exactly what the single-mask op returns."""
    rng = np.random.default_rng(11)
    Q, X = _np(1, 16, seed=5), _np(90, 16, seed=6)
    mask = rng.random(90) < 0.3
    dm, im = ops.masked_exact_topk_multi(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask[None, :]), 7, backend=backend
    )
    ds, is_ = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), 7, backend=backend
    )
    np.testing.assert_array_equal(np.asarray(im), np.asarray(is_))
    np.testing.assert_array_equal(np.asarray(dm), np.asarray(ds))


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_masked_multi_rows_match_per_query_single_calls(backend):
    """The plane call is semantically Q independent single-mask calls: each
    row must equal the single-mask op run with that query's own bitmask."""
    rng = np.random.default_rng(13)
    Q, X = _np(6, 16, seed=7), _np(150, 16, seed=8)
    masks = rng.random((6, 150)) < 0.35
    dm, im = ops.masked_exact_topk_multi(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(masks), 8, backend=backend
    )
    for qi in range(6):
        ds, is_ = ops.masked_exact_topk(
            jnp.asarray(Q[qi : qi + 1]), jnp.asarray(X), jnp.asarray(masks[qi]), 8,
            backend=backend,
        )
        np.testing.assert_array_equal(np.asarray(im)[qi], np.asarray(is_)[0])
        np.testing.assert_allclose(
            np.asarray(dm)[qi], np.asarray(ds)[0], rtol=2e-4, atol=2e-3
        )


# ---------------------------------------------------------------------------
# unified exact/PQ kernel (mixed-flavor single dispatch) + dedup'd planes
# ---------------------------------------------------------------------------


def _unified_inputs(q, n, d, m, K, seed):
    rng = np.random.default_rng(seed)
    Q = _np(q, d, seed=seed)
    X = _np(n, d, seed=seed + 1)
    luts = rng.normal(size=(q, m, K)).astype(np.float32)
    codes = rng.integers(0, K, size=(n, m)).astype(np.int32)
    masks = rng.random((q, n)) < 0.4
    flavor = (np.arange(q) % 2).astype(bool)
    return Q, X, luts, codes, masks, flavor


# non-tile-aligned Q/N, single-row, and k > passing edges, both metrics
@pytest.mark.parametrize("q,n,k", [(2, 1, 1), (5, 77, 9), (9, 130, 10), (4, 300, 320)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_unified_masked_topk_matches_ref(q, n, k, metric):
    Q, X, luts, codes, masks, flavor = _unified_inputs(q, n, 16, 4, 16, seed=q * 7 + n)
    dp, ip_ = ops.unified_masked_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(luts), jnp.asarray(codes),
        jnp.asarray(masks), jnp.asarray(flavor), k, metric=metric, backend="pallas",
    )
    dr, ir = ops.unified_masked_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(luts), jnp.asarray(codes),
        jnp.asarray(masks), jnp.asarray(flavor), k, metric=metric, backend="ref",
    )
    np.testing.assert_array_equal(np.asarray(ip_), np.asarray(ir))
    dp, dr = np.asarray(dp), np.asarray(dr)
    np.testing.assert_allclose(
        np.where(np.isinf(dp), 0.0, dp), np.where(np.isinf(dr), 0.0, dr),
        rtol=2e-4, atol=2e-3,
    )
    assert (np.isinf(dp) == np.isinf(dr)).all()


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_unified_rows_match_per_flavor_split_ops(backend):
    """The acceptance contract of the fused dispatch: every exact-flavor
    row equals the dedicated exact multi-op's row, every ADC-flavor row
    equals the dedicated PQ multi-op's row — the unified kernel is the two
    split dispatches, bit-for-bit, in one call."""
    Q, X, luts, codes, masks, flavor = _unified_inputs(7, 210, 16, 4, 16, seed=3)
    k = 12
    du, iu = ops.unified_masked_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(luts), jnp.asarray(codes),
        jnp.asarray(masks), jnp.asarray(flavor), k, backend=backend,
    )
    de, ie = ops.masked_exact_topk_multi(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(masks), k, backend=backend
    )
    da, ia = ops.masked_pq_topk_multi(
        jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(masks), k, backend=backend
    )
    du, iu = np.asarray(du), np.asarray(iu)
    for qi in range(7):
        want_i = np.asarray(ia if flavor[qi] else ie)[qi]
        want_d = np.asarray(da if flavor[qi] else de)[qi]
        np.testing.assert_array_equal(iu[qi], want_i)
        np.testing.assert_allclose(
            np.where(np.isinf(du[qi]), 0.0, du[qi]),
            np.where(np.isinf(want_d), 0.0, want_d),
            rtol=2e-4, atol=2e-3,
        )


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_unified_all_masked_and_all_one_flavor(backend):
    """Degenerate flavors: an all-masked plane yields pure sentinels; an
    all-exact (or all-ADC) flavor vector reproduces the single-flavor op."""
    Q, X, luts, codes, masks, _ = _unified_inputs(4, 90, 8, 4, 16, seed=9)
    d, i = ops.unified_masked_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(luts), jnp.asarray(codes),
        jnp.zeros((4, 90), bool), jnp.zeros(4, bool), 5, backend=backend,
    )
    assert np.isinf(np.asarray(d)).all() and (np.asarray(i) == -1).all()
    for flav, split in (
        (np.zeros(4, bool), lambda: ops.masked_exact_topk_multi(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(masks), 5, backend=backend)),
        (np.ones(4, bool), lambda: ops.masked_pq_topk_multi(
            jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(masks), 5,
            backend=backend)),
    ):
        du, iu = ops.unified_masked_topk(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(luts), jnp.asarray(codes),
            jnp.asarray(masks), jnp.asarray(flav), 5, backend=backend,
        )
        ds, is_ = split()
        np.testing.assert_array_equal(np.asarray(iu), np.asarray(is_))


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_dedup_plane_matches_dense_plane(backend):
    """Dedup-then-broadcast contract: the (m unique rows, row index)
    factored plane returns exactly what the dense (Q, N) plane returns,
    for the exact, PQ, and unified ops alike."""
    rng = np.random.default_rng(21)
    Q, X = _np(9, 16, seed=31), _np(140, 16, seed=32)
    luts = rng.normal(size=(9, 4, 16)).astype(np.float32)
    codes = rng.integers(0, 16, size=(140, 4)).astype(np.int32)
    unique = rng.random((3, 140)) < 0.4
    idx = rng.integers(0, 3, size=9)
    dense = unique[idx]
    flavor = (np.arange(9) % 2).astype(bool)
    pairs = [
        (
            ops.masked_exact_topk_dedup(
                jnp.asarray(Q), jnp.asarray(X), jnp.asarray(unique),
                jnp.asarray(idx), 8, backend=backend,
            ),
            ops.masked_exact_topk_multi(
                jnp.asarray(Q), jnp.asarray(X), jnp.asarray(dense), 8,
                backend=backend,
            ),
        ),
        (
            ops.masked_pq_topk_dedup(
                jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(unique),
                jnp.asarray(idx), 8, backend=backend,
            ),
            ops.masked_pq_topk_multi(
                jnp.asarray(luts), jnp.asarray(codes), jnp.asarray(dense), 8,
                backend=backend,
            ),
        ),
        (
            ops.unified_masked_topk_dedup(
                jnp.asarray(Q), jnp.asarray(X), jnp.asarray(luts),
                jnp.asarray(codes), jnp.asarray(unique), jnp.asarray(idx),
                jnp.asarray(flavor), 8, backend=backend,
            ),
            ops.unified_masked_topk(
                jnp.asarray(Q), jnp.asarray(X), jnp.asarray(luts),
                jnp.asarray(codes), jnp.asarray(dense), jnp.asarray(flavor), 8,
                backend=backend,
            ),
        ),
    ]
    for (dd, di), (dm, im) in pairs:
        np.testing.assert_array_equal(np.asarray(di), np.asarray(im))
        np.testing.assert_array_equal(np.asarray(dd), np.asarray(dm))


# ---------------------------------------------------------------------------
# property-based sweeps
# ---------------------------------------------------------------------------

@pytest.mark.slow  # every drawn shape pays a fresh Pallas-interpret compile
@settings(max_examples=20, deadline=None)
@given(
    q=st.integers(1, 24),
    n=st.integers(1, 200),
    d=st.integers(1, 64),
)
def test_property_rerank(q, n, d):
    Q, X = _np(q, d, seed=q * 7 + n), _np(n, d, seed=d)
    got = np.asarray(
        ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="pallas")
    )
    want = np.asarray(ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="ref"))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)
    # metric properties: non-negative, d(x,x)=0
    self_d = np.asarray(
        ops.exact_distances(jnp.asarray(X[:5]), jnp.asarray(X[:5]), backend="pallas")
    )
    assert np.all(self_d > -1e-2)
    np.testing.assert_allclose(np.diag(self_d), 0.0, atol=1e-2)


@pytest.mark.slow  # every drawn shape pays a fresh Pallas-interpret compile
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 150),
    m=st.integers(1, 16),
    nbits=st.integers(1, 8),
)
def test_property_pq_scan(n, m, nbits):
    K = 1 << nbits
    rng = np.random.default_rng(n * 31 + m)
    luts = rng.normal(size=(3, m, K)).astype(np.float32)
    codes = rng.integers(0, K, size=(n, m)).astype(np.int32)
    got = np.asarray(ops.pq_scan(jnp.asarray(luts), jnp.asarray(codes), backend="pallas", tile_q=4, tile_n=32))
    want = np.asarray(ref.pq_adc_scores(jnp.asarray(luts), jnp.asarray(codes)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)


# ---------------------------------------------------------------------------
# gather-rerank (device candidate-pool rerank — the host-rerank replacement)
# ---------------------------------------------------------------------------


def _host_rerank(Q, X, pids, k, metric="l2"):
    """The removed NumPy rerank, verbatim in shape: clip-gather the pool
    vectors, score, push sentinels to +inf, argsort top-k.  Kept here only
    as the bit-parity oracle for the kernel that replaced it."""
    n = X.shape[0]
    safe = np.clip(pids, 0, n - 1)
    vecs = X[safe]  # (Q, P, D)
    if metric == "ip":
        d = -np.einsum("qpd,qd->qp", vecs, Q)
    else:
        d = np.sum((vecs - Q[:, None, :]) ** 2, axis=-1)
    d = np.where((pids < 0) | (pids >= n), np.inf, d)
    order = np.argsort(d, axis=1, kind="stable")[:, :k]
    out_d = np.take_along_axis(d, order, axis=1)
    out_i = np.take_along_axis(pids, order, axis=1)
    out_i = np.where(np.isfinite(out_d), out_i, -1)
    return out_d.astype(np.float32), out_i.astype(np.int64)


# Q / N / P deliberately non-tile-aligned (tile_q=8, tile_n=128 defaults)
@pytest.mark.parametrize("q,n,p,d", [(1, 1, 1, 1), (3, 90, 7, 16), (9, 300, 33, 24), (5, 130, 130, 100)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_gather_rerank_matches_ref(q, n, p, d, metric):
    rng = np.random.default_rng(q * 11 + n)
    Q, X = _np(q, d, seed=q), _np(n, d, seed=n + 1)
    pids = rng.choice(n, size=(q, p), replace=p <= n).astype(np.int32) if p <= n \
        else rng.integers(0, n, size=(q, p)).astype(np.int32)
    k = min(5, p)
    outs = {}
    for backend in ("pallas", "ref"):
        dd, ii = ops.gather_rerank(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(pids), k,
            metric=metric, backend=backend,
        )
        outs[backend] = (np.asarray(dd), np.asarray(ii))
    np.testing.assert_array_equal(outs["pallas"][1], outs["ref"][1])
    dp, dr = outs["pallas"][0], outs["ref"][0]
    np.testing.assert_allclose(
        np.where(np.isinf(dp), 0.0, dp), np.where(np.isinf(dr), 0.0, dr),
        rtol=2e-4, atol=2e-3,
    )
    assert (np.isinf(dp) == np.isinf(dr)).all()


@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_gather_rerank_bit_parity_with_host_rerank(metric):
    """The kernel answers exactly what the NumPy gather+einsum it replaced
    answered (distinct pool ids — the unstable-argsort duplicate tie order
    was never part of the old contract)."""
    rng = np.random.default_rng(42)
    Q, X = _np(6, 32, seed=1), _np(200, 32, seed=2)
    pids = np.stack([rng.choice(200, size=24, replace=False) for _ in range(6)]).astype(np.int32)
    pids[2, 5:] = -1  # one mostly-empty pool
    want_d, want_i = _host_rerank(Q, X, pids, 10, metric=metric)
    for backend in ("pallas", "ref"):
        got_d, got_i = ops.gather_rerank(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(pids), 10,
            metric=metric, backend=backend,
        )
        np.testing.assert_array_equal(np.asarray(got_i, np.int64), want_i)
        np.testing.assert_allclose(
            np.where(np.isinf(np.asarray(got_d)), 0.0, np.asarray(got_d)),
            np.where(np.isinf(want_d), 0.0, want_d),
            rtol=2e-4, atol=2e-3,
        )


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_gather_rerank_sentinels_and_out_of_range(backend):
    """pid < 0 and pid >= N slots never score: they surface as (+inf, -1),
    and an all-sentinel pool row is all (+inf, -1)."""
    Q, X = _np(4, 16, seed=3), _np(50, 16, seed=4)
    pids = np.full((4, 8), -1, np.int32)
    pids[0, :3] = [5, 7, 50]  # 50 is out of range -> sentinel
    pids[1, 0] = 999
    d, i = ops.gather_rerank(jnp.asarray(Q), jnp.asarray(X), jnp.asarray(pids), 8, backend=backend)
    d, i = np.asarray(d), np.asarray(i)
    assert set(i[0][i[0] >= 0]) == {5, 7}
    assert (i[1] == -1).all() and np.isinf(d[1]).all()
    assert (i[2:] == -1).all() and np.isinf(d[2:]).all()
    assert np.isfinite(d[0][:2]).all() and np.isinf(d[0][2:]).all()


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_gather_rerank_k_exceeds_pool(backend):
    """k > P: the extra slots are (+inf, -1) and the live prefix is the
    whole pool, ascending."""
    Q, X = _np(2, 8, seed=5), _np(60, 8, seed=6)
    pids = np.array([[3, 9, 41], [0, 59, 17]], np.int32)
    d, i = ops.gather_rerank(jnp.asarray(Q), jnp.asarray(X), jnp.asarray(pids), 10, backend=backend)
    d, i = np.asarray(d), np.asarray(i)
    assert d.shape == (2, 10)
    for qi in range(2):
        assert set(i[qi][:3]) == set(pids[qi].tolist())
        assert (i[qi][3:] == -1).all() and np.isinf(d[qi][3:]).all()
        assert np.all(np.diff(d[qi][:3]) >= -1e-5)


@pytest.mark.parametrize("backend", ["pallas", "ref"])
def test_gather_rerank_duplicate_pids(backend):
    """Duplicate pool ids are allowed: the top-k multiset matches the
    brute-force multiset (tie ORDER among equal ids is unspecified, exactly
    as it was for the unstable host argsort)."""
    rng = np.random.default_rng(9)
    Q, X = _np(3, 16, seed=7), _np(40, 16, seed=8)
    pids = rng.integers(0, 40, size=(3, 12)).astype(np.int32)
    pids[:, 6:] = pids[:, :6]  # force duplicates
    k = 5
    d, i = ops.gather_rerank(jnp.asarray(Q), jnp.asarray(X), jnp.asarray(pids), k, backend=backend)
    d, i = np.asarray(d), np.asarray(i)
    want_d, want_i = _host_rerank(Q, X, pids, k)
    for qi in range(3):
        np.testing.assert_allclose(d[qi], want_d[qi], rtol=2e-4, atol=2e-3)
        assert sorted(i[qi].tolist()) == sorted(want_i[qi].tolist())


# ---------------------------------------------------------------------------
# quantized scan flavors (bf16 / int8) + full-precision guard
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_quantized_exact_matches_quant_oracle(dtype, metric):
    """Pallas quantized scan vs the ref quantized oracle: identical id sets
    (both score the SAME quantized values) and close scores."""
    rng = np.random.default_rng(17)
    Q, X = _np(5, 48, seed=11), _np(300, 48, seed=12)
    mask = rng.random(300) < 0.5
    k = 10
    dp, ip_ = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), k,
        metric=metric, backend="pallas", dtype=dtype,
    )
    dr, ir = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), k,
        metric=metric, backend="ref", dtype=dtype,
    )
    ip_, ir = np.asarray(ip_), np.asarray(ir)
    dp, dr = np.asarray(dp), np.asarray(dr)
    # quantized ties can swap adjacent ids; compare as sets + score values
    for qi in range(5):
        assert set(ip_[qi].tolist()) == set(ir[qi].tolist())
    np.testing.assert_allclose(
        np.where(np.isinf(dp), 0.0, dp), np.where(np.isinf(dr), 0.0, dr),
        rtol=5e-3, atol=5e-2,
    )


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_quantized_prestored_points_match_fresh_quantization(dtype):
    """Passing the cached pre-quantized stored matrix (+ its x_scale) must
    answer exactly like quantize-on-the-fly from f32."""
    rng = np.random.default_rng(19)
    Q, X = _np(4, 32, seed=13), _np(200, 32, seed=14)
    mask = rng.random(200) < 0.6
    stored, x_scale = ref.quantize_points(jnp.asarray(X), dtype)
    for backend in ("pallas", "ref"):
        d1, i1 = ops.masked_exact_topk(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), 8,
            backend=backend, dtype=dtype,
        )
        d2, i2 = ops.masked_exact_topk(
            jnp.asarray(Q), stored, jnp.asarray(mask), 8,
            backend=backend, dtype=dtype, x_scale=x_scale,
        )
        np.testing.assert_array_equal(np.asarray(i1), np.asarray(i2))
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d2))


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_quantized_scan_plus_guard_restores_f32_recall(dtype):
    """The planner's two-stage contract: quantized scan at the oversampled
    quant_guard_pool, then full-precision gather_rerank — top-k recall vs
    the f32 scan must be >= 0.95, and the emitted distances are exact f32
    distances (never quantized scores)."""
    from repro.runtime import planner

    rng = np.random.default_rng(23)
    Q, X = _np(8, 64, seed=15), _np(500, 64, seed=16)
    mask = rng.random(500) < 0.7
    k = 10
    pool = min(planner.quant_guard_pool(k), 500)
    _qd, pids = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), pool,
        backend="auto", dtype=dtype,
    )
    gd, gi = ops.gather_rerank(jnp.asarray(Q), jnp.asarray(X), pids, k, backend="auto")
    fd, fi = ops.masked_exact_topk(
        jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), k, backend="auto"
    )
    gd, gi = np.asarray(gd), np.asarray(gi)
    fd, fi = np.asarray(fd), np.asarray(fi)
    hits = sum(
        len(set(gi[qi][gi[qi] >= 0]) & set(fi[qi][fi[qi] >= 0])) for qi in range(8)
    )
    total = int((fi >= 0).sum())
    assert hits / total >= 0.95
    # guarded distances are full-precision: every returned id's distance
    # equals the f32 oracle distance for that id
    full = np.asarray(ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="ref"))
    for qi in range(8):
        live = gi[qi] >= 0
        np.testing.assert_allclose(gd[qi][live], full[qi, gi[qi][live]], rtol=2e-4, atol=2e-3)


def test_quantize_roundtrip_error_bounds():
    """int8 symmetric quantization error is bounded by scale/2 per value;
    bf16 by ~2^-8 relative."""
    X = _np(100, 32, seed=21, scale=3.0)
    for dtype, tol in (("int8", None), ("bf16", 0.01)):
        stored, scale = ref.quantize_points(jnp.asarray(X), dtype)
        back = np.asarray(ref.dequantize_points(stored, scale))
        if dtype == "int8":
            assert np.abs(back - X).max() <= float(scale) * 0.5 + 1e-6
        else:
            assert np.abs(back - X).max() <= tol * np.abs(X).max() + 1e-6


# ---------------------------------------------------------------------------
# unified-kernel VMEM budget (BlockSpec walk)
# ---------------------------------------------------------------------------


def test_unified_block_shapes_walk():
    """Independently recompute every resident block of one unified grid
    step and assert the budget table (which the kernel builds its
    BlockSpecs from) matches — the docstring numbers cannot drift."""
    from repro.kernels import masked_topk as mt

    tq, tn, d, m, K, k = 8, 128, 1024, 16, 256, 128
    shapes = mt.unified_block_shapes(tq, tn, d, m, K, k)
    assert shapes["queries"] == ((tq, d), jnp.float32)
    assert shapes["points"] == ((tn, d), jnp.float32)
    assert shapes["luts"] == ((tq, m, K), jnp.float32)
    assert shapes["codes"] == ((tn, m), jnp.int32)
    assert shapes["selector"] == ((tq, tn), jnp.float32)
    assert shapes["out_dists"] == ((tq, k), jnp.float32)
    assert shapes["out_ids"] == ((tq, k), jnp.int32)
    assert shapes["score_scratch"] == ((tq, tn), jnp.float32)
    resident = sum(
        int(np.prod(s)) * np.dtype(dt).itemsize for s, dt in shapes.values()
    )
    assert mt.unified_vmem_bytes(tq, tn, d, m, K, k) == 2 * resident + tn * K * 4


def test_unified_vmem_fits_16mb_at_d4096():
    """Acceptance: the restructured unified kernel's worst-case estimate at
    D=4096 (m=16, K=256, k=128) fits a 16 MB VMEM budget WITHOUT halving
    tile_q — the old dual-buffer layout did not."""
    from repro.kernels import masked_topk as mt

    budget = 16 * 1024 * 1024
    assert mt.unified_vmem_bytes(8, 128, 4096, 16, 256, 128) < budget
    # and the shared-buffer design keeps even D=8192 under budget
    assert mt.unified_vmem_bytes(8, 128, 8192, 16, 256, 128) < budget


# ---------------------------------------------------------------------------
# autotuner (measured tile selection)
# ---------------------------------------------------------------------------


def test_autotune_defaults_on_cache_miss(tmp_path):
    from repro.kernels import autotune

    autotune.clear_cache()
    assert autotune.get_tiles(4096, 128, "exact", cache_path=tmp_path / "nope.json") \
        == autotune.DEFAULT_TILES
    autotune.clear_cache()


def test_autotune_reads_fixture_and_rejects_unknown_tiles(tmp_path):
    import json

    from repro.kernels import autotune

    path = tmp_path / "cache.json"
    path.write_text(json.dumps({
        "tiles": {
            autotune.cache_key(4096, 128, "exact"): [16, 256],
            autotune.cache_key(4096, 128, "pq"): [13, 77],  # never swept
        }
    }))
    autotune.clear_cache()
    assert autotune.get_tiles(4096, 128, "exact", cache_path=path) == (16, 256)
    # bucketing: 3000 rows round up to the same 4096 bucket
    assert autotune.get_tiles(3000, 128, "exact", cache_path=path) == (16, 256)
    # invalid tiles are discarded -> defaults
    assert autotune.get_tiles(4096, 128, "pq", cache_path=path) == autotune.DEFAULT_TILES
    autotune.clear_cache()


def test_autotune_candidates_include_defaults():
    """Structural never-regress: the default tiling is always a candidate,
    and a challenger must beat it by the hysteresis margin."""
    from repro.kernels import autotune

    assert autotune.DEFAULT_TILES in autotune.CANDIDATES
    assert 0.0 < autotune.HYSTERESIS < 0.5


def test_autotune_tiles_give_identical_results():
    """Whatever tiles the autotuner picks, the kernel answers identically —
    tiling is a performance knob, never a semantics knob."""
    rng = np.random.default_rng(29)
    Q, X = _np(9, 40, seed=25), _np(300, 40, seed=26)
    mask = rng.random(300) < 0.5
    from repro.kernels import autotune

    base = None
    for tq, tn in autotune.CANDIDATES:
        d, i = ops.masked_exact_topk(
            jnp.asarray(Q), jnp.asarray(X), jnp.asarray(mask), 7,
            backend="pallas", tile_q=tq, tile_n=tn,
        )
        d, i = np.asarray(d), np.asarray(i)
        if base is None:
            base = (d, i)
        else:
            np.testing.assert_array_equal(i, base[1])
            np.testing.assert_allclose(
                np.where(np.isinf(d), 0.0, d),
                np.where(np.isinf(base[0]), 0.0, base[0]),
                rtol=2e-4, atol=2e-3,
            )


# ---------------------------------------------------------------------------
# device-copy caching (identity-keyed)
# ---------------------------------------------------------------------------


class _FakeGraph:
    def __init__(self, vectors, n):
        self.vectors = vectors
        self.n = n


def test_device_vectors_cached_by_identity():
    from repro.kernels import device_cache

    g = _FakeGraph(_np(50, 8, seed=31), 40)
    a = device_cache.device_vectors(g)
    b = device_cache.device_vectors(g)
    assert a is b  # cache hit: same device buffer
    np.testing.assert_allclose(np.asarray(a), g.vectors[:40])


def test_device_vectors_staleness_same_length_swap():
    """Regression (the old cache keyed by n alone): swapping in a DIFFERENT
    array of the SAME length must invalidate the cached device copy."""
    from repro.kernels import device_cache

    g = _FakeGraph(_np(50, 8, seed=33), 50)
    a = device_cache.device_vectors(g)
    g.vectors = _np(50, 8, seed=34)  # same shape, new contents
    b = device_cache.device_vectors(g)
    assert a is not b
    np.testing.assert_allclose(np.asarray(b), g.vectors[:50])


def test_device_vectors_revalidates_on_n_change():
    from repro.kernels import device_cache

    vecs = _np(50, 8, seed=35)
    g = _FakeGraph(vecs, 30)
    a = device_cache.device_vectors(g)
    assert np.asarray(a).shape == (30, 8)
    g.n = 45  # same array grew its live prefix (insert_batch)
    b = device_cache.device_vectors(g)
    assert np.asarray(b).shape == (45, 8)
    np.testing.assert_allclose(np.asarray(b), vecs[:45])


@pytest.mark.parametrize("dtype", ["bf16", "int8"])
def test_device_vectors_quant_cached_per_dtype(dtype):
    from repro.kernels import device_cache

    g = _FakeGraph(_np(60, 16, seed=37), 60)
    s1, sc1 = device_cache.device_vectors_quant(g, dtype)
    s2, sc2 = device_cache.device_vectors_quant(g, dtype)
    assert s1 is s2 and sc1 == sc2
    f32 = device_cache.device_vectors(g)
    assert np.asarray(f32).dtype == np.float32  # separate attr per flavor
