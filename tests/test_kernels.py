"""Per-kernel validation: shape/dtype sweeps, Pallas(interpret) vs ref oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _np(*shape, seed=0, scale=1.0):
    return (np.random.default_rng(seed).normal(size=shape) * scale).astype(np.float32)


# ---------------------------------------------------------------------------
# exact distances (rerank kernel)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,n,d", [(1, 1, 1), (7, 33, 5), (37, 301, 100), (128, 256, 768), (3, 500, 17)])
@pytest.mark.parametrize("metric", ["l2", "ip"])
def test_rerank_matches_ref(q, n, d, metric):
    Q, X = _np(q, d, seed=1), _np(n, d, seed=2)
    got = ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), metric=metric, backend="pallas")
    want = ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), metric=metric, backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-3)


def test_rerank_topk_order():
    Q, X = _np(4, 16, seed=3), _np(100, 16, seed=4)
    d, i = ops.exact_topk(jnp.asarray(Q), jnp.asarray(X), 5, backend="pallas")
    full = np.asarray(ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="ref"))
    for qi in range(4):
        np.testing.assert_array_equal(
            np.sort(np.asarray(i)[qi]), np.sort(np.argsort(full[qi])[:5])
        )


@pytest.mark.parametrize("dtype", [np.float32, np.float16])
def test_rerank_dtypes(dtype):
    Q = _np(8, 32, seed=5).astype(dtype)
    X = _np(64, 32, seed=6).astype(dtype)
    got = ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="pallas")
    want = ref.l2_distances(jnp.asarray(Q, jnp.float32), jnp.asarray(X, jnp.float32))
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=5e-3, atol=5e-2)


# ---------------------------------------------------------------------------
# PQ ADC scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("q,n,m,K", [(1, 1, 1, 2), (5, 77, 8, 16), (16, 300, 48, 256), (2, 130, 4, 64)])
def test_pq_scan_matches_ref(q, n, m, K):
    rng = np.random.default_rng(7)
    luts = rng.normal(size=(q, m, K)).astype(np.float32)
    codes = rng.integers(0, K, size=(n, m)).astype(np.int32)
    got = ops.pq_scan(jnp.asarray(luts), jnp.asarray(codes), backend="pallas", tile_q=4, tile_n=32)
    want = ops.pq_scan(jnp.asarray(luts), jnp.asarray(codes), backend="ref")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-4)


def test_pq_scan_topk():
    rng = np.random.default_rng(8)
    luts = rng.normal(size=(3, 8, 32)).astype(np.float32)
    codes = rng.integers(0, 32, size=(50, 8)).astype(np.int32)
    d, i = ops.pq_scan_topk(jnp.asarray(luts), jnp.asarray(codes), 7, backend="pallas")
    full = np.asarray(ref.pq_adc_scores(jnp.asarray(luts), jnp.asarray(codes)))
    for qi in range(3):
        np.testing.assert_array_equal(np.sort(np.asarray(i)[qi]), np.sort(np.argsort(full[qi])[:7]))


# ---------------------------------------------------------------------------
# k-means assignment
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k,d", [(1, 1, 1), (100, 10, 8), (555, 100, 48), (1000, 257, 16)])
def test_kmeans_assign_matches_ref(n, k, d):
    X = _np(n, d, seed=9)
    C = _np(k, d, seed=10)
    ip, dp = ops.kmeans_assign(jnp.asarray(X), jnp.asarray(C), backend="pallas", tile_n=128, tile_k=32)
    ir, dr = ops.kmeans_assign(jnp.asarray(X), jnp.asarray(C), backend="ref")
    np.testing.assert_array_equal(np.asarray(ip), np.asarray(ir))
    np.testing.assert_allclose(np.asarray(dp), np.asarray(dr), rtol=2e-4, atol=2e-3)


# ---------------------------------------------------------------------------
# property-based sweeps
# ---------------------------------------------------------------------------

@pytest.mark.slow  # every drawn shape pays a fresh Pallas-interpret compile
@settings(max_examples=20, deadline=None)
@given(
    q=st.integers(1, 24),
    n=st.integers(1, 200),
    d=st.integers(1, 64),
)
def test_property_rerank(q, n, d):
    Q, X = _np(q, d, seed=q * 7 + n), _np(n, d, seed=d)
    got = np.asarray(
        ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="pallas")
    )
    want = np.asarray(ops.exact_distances(jnp.asarray(Q), jnp.asarray(X), backend="ref"))
    np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-3)
    # metric properties: non-negative, d(x,x)=0
    self_d = np.asarray(
        ops.exact_distances(jnp.asarray(X[:5]), jnp.asarray(X[:5]), backend="pallas")
    )
    assert np.all(self_d > -1e-2)
    np.testing.assert_allclose(np.diag(self_d), 0.0, atol=1e-2)


@pytest.mark.slow  # every drawn shape pays a fresh Pallas-interpret compile
@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 150),
    m=st.integers(1, 16),
    nbits=st.integers(1, 8),
)
def test_property_pq_scan(n, m, nbits):
    K = 1 << nbits
    rng = np.random.default_rng(n * 31 + m)
    luts = rng.normal(size=(3, m, K)).astype(np.float32)
    codes = rng.integers(0, K, size=(n, m)).astype(np.int32)
    got = np.asarray(ops.pq_scan(jnp.asarray(luts), jnp.asarray(codes), backend="pallas", tile_q=4, tile_n=32))
    want = np.asarray(ref.pq_adc_scores(jnp.asarray(luts), jnp.asarray(codes)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-4)
