"""vparquet columnar format: projection, row-group masks, range reads."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.lakehouse.vparquet import (
    ColumnSpec,
    VParquetReader,
    VParquetWriter,
    read_vector_column,
    write_vector_file,
)


def test_roundtrip_with_projection(tmp_store, rng):
    vecs = rng.normal(size=(1000, 16)).astype(np.float32)
    write_vector_file(tmp_store, "d/f.vpq", vecs, rows_per_group=128)
    r = VParquetReader.from_store(tmp_store, "d/f.vpq")
    assert r.num_rows == 1000
    assert r.num_row_groups == 8
    np.testing.assert_allclose(r.read_column("vec"), vecs)
    ids = r.read_column("id")
    np.testing.assert_array_equal(ids, np.arange(1000))


def test_row_group_mask_reads_only_target_bytes(tmp_store, rng):
    vecs = rng.normal(size=(4096, 32)).astype(np.float32)
    write_vector_file(tmp_store, "d/g.vpq", vecs, rows_per_group=512)
    tmp_store.metrics.reset()
    r = VParquetReader.from_store(tmp_store, "d/g.vpq")
    sub = r.read_column("vec", [3])
    np.testing.assert_allclose(sub, vecs[3 * 512 : 4 * 512])
    # bytes read ≈ one row group + footer, far less than the file
    assert tmp_store.metrics.bytes_read < vecs.nbytes / 4


def test_read_rows(tmp_store, rng):
    vecs = rng.normal(size=(300, 8)).astype(np.float32)
    write_vector_file(tmp_store, "d/h.vpq", vecs, rows_per_group=100)
    r = VParquetReader.from_store(tmp_store, "d/h.vpq")
    got = r.read_rows("vec", 2, [5, 50, 99])
    np.testing.assert_allclose(got, vecs[[205, 250, 299]])


def test_zstd_codec(tmp_store):
    pytest.importorskip("zstandard")
    vecs = np.zeros((5000, 64), np.float32)  # compressible
    n_plain = write_vector_file(tmp_store, "p.vpq", vecs)
    n_zstd = write_vector_file(tmp_store, "z.vpq", vecs, codec="zstd")
    assert n_zstd < n_plain / 10
    np.testing.assert_allclose(read_vector_column(tmp_store, "z.vpq"), vecs)


@settings(max_examples=20, deadline=None)
@given(
    n=st.integers(1, 500),
    d=st.integers(1, 32),
    rows_per_group=st.integers(1, 200),
)
def test_property_roundtrip(n, d, rows_per_group):
    rng = np.random.default_rng(n * 31 + d)
    vecs = rng.normal(size=(n, d)).astype(np.float32)
    w = VParquetWriter([ColumnSpec("vec", "float32", d)])
    for s in range(0, n, rows_per_group):
        w.write_row_group({"vec": vecs[s : s + rows_per_group]})
    data = w.finish()
    r = VParquetReader.from_bytes(data)
    assert r.num_rows == n
    np.testing.assert_allclose(r.read_column("vec"), vecs)
    # per-group reads concatenate to the whole
    parts = [r.read_column("vec", [g]) for g in range(r.num_row_groups)]
    np.testing.assert_allclose(np.concatenate(parts), vecs)


def test_attribute_columns_and_dictionary_encoding(tmp_store, rng):
    """String attribute columns dictionary-encode per file: stored ints are
    codes into the footer's value table; numeric attributes store raw."""
    vecs = rng.normal(size=(200, 8)).astype(np.float32)
    cat = np.asarray(["news", "games", "books", "games"] * 50)
    price = rng.integers(0, 100, size=200).astype(np.int64)
    write_vector_file(
        tmp_store, "a.vpq", vecs, rows_per_group=64,
        extra_columns={"category": cat, "price": price},
    )
    r = VParquetReader.from_store(tmp_store, "a.vpq")
    spec = r.columns["category"]
    assert spec.dtype == "int32"
    assert spec.dictionary == ["books", "games", "news"]  # sorted uniques
    codes = r.read_column("category")
    decoded = np.asarray(spec.dictionary, dtype=object)[codes]
    np.testing.assert_array_equal(decoded.astype(str), cat)
    assert r.columns["price"].dictionary is None
    np.testing.assert_array_equal(r.read_column("price"), price)
    # row-group projection of attribute columns works like any column
    np.testing.assert_array_equal(r.read_column("price", [1]), price[64:128])


def test_table_append_scan_attributes(tmp_store, rng):
    from repro.iceberg.catalog import RestCatalog
    from repro.lakehouse.table import LakehouseTable

    cat = RestCatalog(tmp_store)
    t = LakehouseTable(cat, "t")
    t.create(dim=8)
    vecs = rng.normal(size=(120, 8)).astype(np.float32)
    tags = np.asarray([f"t{i % 5}" for i in range(120)])
    price = rng.integers(0, 10, size=120).astype(np.int64)
    t.append_vectors(vecs, num_files=3, rows_per_group=32,
                     attributes={"tag": tags, "price": price})
    attrs = t.scan_attributes()
    _, locs = t.scan_vectors()
    assert len(attrs["tag"]) == len(locs) == 120
    # row alignment with scan_vectors: files are written by index split
    np.testing.assert_array_equal(attrs["price"], price)
    np.testing.assert_array_equal(attrs["tag"].astype(str), tags)
    assert set(t.attribute_schema()) == {"tag", "price"}
    with pytest.raises(ValueError):
        t.append_vectors(vecs, attributes={"short": price[:5]})
