"""The fresh-tail tier: appended rows are searchable WITHOUT a rebuild.

The stale-read window this closes: `append_vectors` → `probe` used to
silently drop every row committed after the index's base snapshot until
someone ran `refresh_index`.  Now the append commit records the new row
groups in a ``repro.fresh-tail-v1`` Puffin blob, the planner emits one
``ExactScan`` op per unindexed row group (synthetic negative ids), the
executors score them through the same masked kernels (predicates and
tombstones included), and the hits merge with the graph candidates — at
exact-oracle parity for the tail rows.

Lifecycle coverage: append → probe parity (filtered + unfiltered, single
+ batch), the plan artifact, the ``include_tail=False`` silent-drop
regression, k > live-rows sentinel hygiene, a fully-deleted tail,
compaction thresholds, time travel, and orphan-file GC of superseded
tail Puffins.
"""

import numpy as np
import pytest

from repro.iceberg.gc import expire_and_collect
from repro.lakehouse.table import LakehouseTable
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig

DIM = 16
CFG = dict(R=12, L=24, partitions_per_shard=2, build_passes=1, build_batch=128)


def _build(tmp_path, rng, *, n=480, attrs=False, num_executors=2):
    """Table + index over ``n`` base rows; returns (cluster, table, X, rep)."""
    c = make_local_cluster(str(tmp_path), num_executors=num_executors)
    t = LakehouseTable(c.catalog, "docs")
    t.create(dim=DIM)
    X = rng.normal(size=(n, DIM)).astype(np.float32)
    kw = {}
    if attrs:
        kw["attributes"] = {"cat": rng.integers(0, 4, size=n).astype(np.int64)}
    t.append_vectors(X, num_files=4, rows_per_group=64, **kw)
    rep = c.coordinator.create_index("docs", IndexConfig(name="idx", **CFG))
    return c, t, X, rep


def _append_tail(t, rng, n_tail, *, attrs=False, rows_per_group=64, loc=3.0):
    Y = rng.normal(loc=loc, size=(n_tail, DIM)).astype(np.float32)
    kw = {}
    if attrs:
        kw["attributes"] = {"cat": rng.integers(0, 4, size=n_tail).astype(np.int64)}
    t.append_vectors(Y, num_files=1, rows_per_group=rows_per_group,
                     file_prefix="tail", **kw)
    return Y


def _locs(hits):
    return {(h.file_path, h.row_group, h.row_offset) for h in hits}


def _recall(report, oracle):
    scores = [
        len(_locs(h) & _locs(o)) / max(len(_locs(o)), 1)
        for h, o in zip(report.hits, oracle.hits)
    ]
    return float(np.mean(scores))


def test_append_then_probe_serves_tail_without_refresh(tmp_path):
    """The tentpole: probe immediately after append (NO refresh) returns
    the appended rows at exact-oracle parity, the report carries the
    freshness accounting, and the plan has exactly one op per tail row
    group.  ``include_tail=False`` reproduces the pre-fix silent drop."""
    rng = np.random.default_rng(7)
    c, t, X, rep = _build(tmp_path, rng)
    Y = _append_tail(t, rng, 150)  # 150 rows / 64 per group = 3 row groups

    # queries dead-center on tail rows: the oracle's top hits live there
    Q = Y[:6] + 0.01 * rng.normal(size=(6, DIM)).astype(np.float32)
    oracle = c.coordinator.probe("docs", Q, 5, strategy="scan")
    pr = c.coordinator.probe("docs", Q, 5, strategy="diskann")

    assert pr.stale is True          # index binding is carried-forward
    assert pr.tail_rows == 150       # ... but the tail tier served them
    assert pr.unindexed_rows == 0    # the invariant: nothing dropped
    assert _recall(pr, oracle) == 1.0
    # every oracle hit in a tail file is present — the tail path is exact
    for h_pr, h_or in zip(pr.hits, oracle.hits):
        tail_truth = {loc for loc in _locs(h_or) if "tail" in loc[0]}
        assert tail_truth and tail_truth <= _locs(h_pr)

    # the plan artifact: one ExactScan per tail row group, negative ids
    assert pr.plan is not None
    for row in pr.plan.ops:
        assert sorted(sid for sid in row if sid < 0) == [-3, -2, -1]

    # batch path agrees
    prb = c.coordinator.probe_batch("docs", Q, 5, strategy="diskann")
    assert prb.tail_rows == 150 and prb.unindexed_rows == 0
    assert _recall(prb, oracle) == 1.0
    for row in prb.plan.ops:
        assert len([sid for sid in row if sid < 0]) == 3

    # regression: the pre-fix behavior drops the tail AND now says so
    pr_off = c.coordinator.probe(
        "docs", Q, 5, strategy="diskann", include_tail=False
    )
    assert pr_off.unindexed_rows == 150 and pr_off.tail_rows == 0
    assert pr_off.stale is True
    assert not any("tail" in h.file_path for hits in pr_off.hits for h in hits)
    assert _recall(pr_off, oracle) < 0.5  # the silent stale-read window

    # time travel: the pre-append snapshot never sees tail rows
    pr_old = c.coordinator.probe("docs", Q, 5, snapshot_id=rep.snapshot_id)
    assert pr_old.tail_rows == 0 and pr_old.unindexed_rows == 0
    assert not any("tail" in h.file_path for hits in pr_old.hits for h in hits)


def test_filtered_probe_covers_tail(tmp_path):
    """Predicates push into the tail scans through the same masked-kernel
    path: filtered probes stay at oracle parity with a tail present, and
    a zero-match predicate over the tail is clean (sentinel hygiene)."""
    rng = np.random.default_rng(11)
    c, t, X, rep = _build(tmp_path, rng, attrs=True)
    Y = _append_tail(t, rng, 120, attrs=True)
    Q = Y[:5] + 0.01 * rng.normal(size=(5, DIM)).astype(np.float32)

    for where in ("cat = 1", "cat >= 2"):
        oracle = c.coordinator.probe("docs", Q, 5, strategy="scan", filter=where)
        pr = c.coordinator.probe("docs", Q, 5, strategy="diskann", filter=where)
        assert pr.unindexed_rows == 0 and pr.tail_rows == 120
        assert _recall(pr, oracle) == 1.0

    # heterogeneous per-query filters through the batch path
    filters = ["cat = 0", None, "cat = 3", "cat >= 1", None]
    oracle = c.coordinator.probe_batch("docs", Q, 5, strategy="scan", filter=filters)
    prb = c.coordinator.probe_batch("docs", Q, 5, strategy="diskann", filter=filters)
    assert prb.unindexed_rows == 0
    assert _recall(prb, oracle) == 1.0

    # zero matches anywhere: no sentinel garbage leaks into hits
    pr0 = c.coordinator.probe("docs", Q, 5, strategy="diskann", filter="cat < 0")
    assert all(len(h) == 0 for h in pr0.hits)


def test_k_exceeds_live_rows_and_fully_deleted_tail(tmp_path):
    """Edge cases: k larger than the live row count must not surface
    (+inf, -1) kernel sentinels, and a tail whose only file is deleted
    must vanish from both the plan and the hits."""
    rng = np.random.default_rng(13)
    c, t, X, rep = _build(tmp_path, rng, n=240)
    Y = _append_tail(t, rng, 60)

    pr = c.coordinator.probe("docs", Y[:2], 1000, strategy="diskann")
    for hits in pr.hits:
        assert 0 < len(hits) <= len(X) + len(Y)
        assert len(_locs(hits)) == len(hits)  # no duplicate slots
        assert all(np.isfinite(h.distance) and h.row_offset >= 0 for h in hits)
        # the tail is scanned exactly: all 60 tail rows are reachable
        assert sum("tail" in h.file_path for h in hits) == 60
    prb = c.coordinator.probe_batch("docs", Y[:2], 1000, strategy="diskann")
    for hits in prb.hits:
        assert all(np.isfinite(h.distance) and h.row_offset >= 0 for h in hits)
        assert sum("tail" in h.file_path for h in hits) == 60

    # delete the tail's only file: the tier must drop it entirely
    doomed = [f.path for f in t.current_files() if "tail" in f.path]
    assert doomed
    t.delete_files(doomed)
    pr2 = c.coordinator.probe("docs", Y[:2], 5, strategy="diskann")
    assert pr2.tail_rows == 0 and pr2.unindexed_rows == 0
    assert not any("tail" in h.file_path for hits in pr2.hits for h in hits)


def test_compact_tail_threshold_and_fold(tmp_path):
    """The background compaction policy: below the row threshold the tail
    is left alone (probes keep serving it); crossing it (or forcing)
    folds the tail into the shards via the ordinary refresh commit,
    after which the binding is fresh and the tail is reset."""
    rng = np.random.default_rng(17)
    c, t, X, rep = _build(tmp_path, rng)
    # in-distribution tail: greedy insert wires such rows into the graph at
    # full recall (an isolated far-off cluster is a known insert-quality
    # limit of refresh_index itself, independent of the tail tier)
    Y = _append_tail(t, rng, 100, loc=0.0)

    assert c.coordinator.compact_tail("docs", "idx", threshold_rows=4096) is None
    assert c.coordinator.probe("docs", Y[:2], 5).tail_rows == 100  # untouched

    rr = c.coordinator.compact_tail("docs", "idx", threshold_rows=64)
    assert rr is not None and rr.inserted == 100
    snap = c.catalog.load_table("docs").current_snapshot()
    assert snap.statistics_file == rr.puffin_path
    assert snap.summary.get("ann.fresh-tail-file") is None

    Q = Y[:4] + 0.01 * rng.normal(size=(4, DIM)).astype(np.float32)
    pr = c.coordinator.probe("docs", Q, 5, strategy="diskann")
    assert pr.stale is False and pr.tail_rows == 0 and pr.unindexed_rows == 0
    oracle = c.coordinator.probe("docs", Q, 5, strategy="scan")
    assert _recall(pr, oracle) == 1.0  # folded rows now served by the graph

    # no tail → compaction is a no-op even when forced
    assert c.coordinator.compact_tail("docs", "idx", force=True) is None


def test_gc_reaps_orphaned_tail_puffins(tmp_path):
    """Tail Puffins follow the same lifecycle as index Puffins: referenced
    while any retained snapshot binds them (time travel keeps working),
    orphaned — and deletable — once those snapshots expire."""
    rng = np.random.default_rng(19)
    c, t, X, rep = _build(tmp_path, rng)
    _append_tail(t, rng, 90)
    tail_path = c.catalog.load_table("docs").current_snapshot().summary[
        "ann.fresh-tail-file"
    ]
    rr = c.coordinator.compact_tail("docs", "idx", force=True)
    assert rr is not None

    # append snapshot still retained → its tail blob is NOT an orphan
    meta = c.catalog.load_table("docs")
    keep_all = expire_and_collect(c.store, meta, keep_last=len(meta.snapshots))
    assert tail_path not in keep_all

    # expire everything but the compaction snapshot → tail blob orphaned
    orphans = expire_and_collect(
        c.store, meta, keep_last=1, delete=True, catalog=c.catalog,
        table_name="docs",
    )
    assert tail_path in orphans
    assert rr.puffin_path not in orphans
    with pytest.raises(Exception):
        c.store.stat(tail_path)  # actually deleted
    # the live index still probes after the sweep
    pr = c.coordinator.probe("docs", X[:2], 5, strategy="diskann")
    assert all(len(h) == 5 for h in pr.hits)
