"""Minimal, dependency-free stand-in for the ``hypothesis`` package.

The suite's property tests use a small slice of the hypothesis API:
``@settings(max_examples=N, deadline=None)``, ``@given(**strategies)`` and
the strategies ``integers``, ``floats``, ``lists``, ``binary`` and
``sampled_from``.  When the real package is installed, conftest.py leaves it
alone; when it is missing, this module is registered under
``sys.modules["hypothesis"]`` so the test modules collect and run unchanged.

Semantics: ``@given`` draws ``max_examples`` example dicts from a
numpy-seeded generator (deterministic per test name, so failures reproduce)
and calls the test once per example.  There is no shrinking and no coverage
feedback — this is a fallback sampler, not a replacement for hypothesis —
but every property still runs against a spread of random inputs.
"""

from __future__ import annotations

import functools
import inspect
import sys
import types
import zlib

import numpy as np

DEFAULT_MAX_EXAMPLES = 25


class SearchStrategy:
    """A strategy is just a draw(rng) callable here."""

    def __init__(self, draw_fn):
        self._draw = draw_fn

    def draw(self, rng):
        return self._draw(rng)


def integers(min_value, max_value):
    return SearchStrategy(lambda rng: int(rng.integers(min_value, max_value + 1)))


def floats(min_value, max_value):
    return SearchStrategy(lambda rng: float(rng.uniform(min_value, max_value)))


def binary(min_size=0, max_size=64):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return rng.integers(0, 256, size=n, dtype=np.uint8).tobytes()

    return SearchStrategy(draw)


def lists(element_strategy, min_size=0, max_size=8):
    def draw(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [element_strategy.draw(rng) for _ in range(n)]

    return SearchStrategy(draw)


def sampled_from(options):
    options = list(options)

    def draw(rng):
        return options[int(rng.integers(0, len(options)))]

    return SearchStrategy(draw)


def settings(max_examples=DEFAULT_MAX_EXAMPLES, deadline=None, **_ignored):
    def decorate(fn):
        fn._shim_max_examples = max_examples
        return fn

    return decorate


def given(**strategies):
    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            # @settings may sit above @given (it then marks the wrapper) or
            # below it (it then marks fn) — honor either order
            max_examples = getattr(
                wrapper,
                "_shim_max_examples",
                getattr(fn, "_shim_max_examples", DEFAULT_MAX_EXAMPLES),
            )
            # deterministic per-test stream: same examples on every run
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = np.random.default_rng(seed)
            for i in range(max_examples):
                drawn = {name: s.draw(rng) for name, s in strategies.items()}
                try:
                    fn(*args, **drawn, **kwargs)
                except _Unsatisfied:
                    continue
                except Exception as exc:  # annotate with the failing example
                    raise AssertionError(
                        f"property falsified on example {i}: {drawn!r}"
                    ) from exc

        # pytest must not treat the drawn kwargs as fixtures: expose a
        # signature holding only the params @given does not supply
        del wrapper.__wrapped__
        sig = inspect.signature(fn)
        wrapper.__signature__ = sig.replace(
            parameters=[p for n, p in sig.parameters.items() if n not in strategies]
        )
        return wrapper

    return decorate


def assume(condition):
    """Real hypothesis retries; the shim just skips the rest via exception."""
    if not condition:
        raise _Unsatisfied()


class _Unsatisfied(Exception):
    pass


def install():
    """Register the shim as ``hypothesis`` / ``hypothesis.strategies``."""
    mod = types.ModuleType("hypothesis")
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.SearchStrategy = SearchStrategy
    strategies = types.ModuleType("hypothesis.strategies")
    for name in ("integers", "floats", "binary", "lists", "sampled_from"):
        setattr(strategies, name, globals()[name])
    mod.strategies = strategies
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = strategies
