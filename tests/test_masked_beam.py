"""Masked beam search (PR 8): predicate-aware Vamana traversal.

Graph-level contract of ``VamanaGraph.search_masked`` — traversal expands
*through* masked nodes but admits only mask-passing ones, with the
``(+inf, -1)`` sentinel tail on under-delivery — plus the cluster-level
acceptance: on a shard too large for a masked linear scan
(> EXACT_SCAN_MAX_ROWS), filtered probes route to the ``MaskedBeam`` plan
op and hit exact-oracle-parity recall across a selectivity sweep, and the
fused exact-masked fallback still fires when the beam under-delivers.
"""

import numpy as np
import pytest

from repro.core.vamana import VamanaGraph, VamanaParams, build_vamana
from repro.lakehouse.table import LakehouseTable
from repro.runtime import planner
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig
from repro.runtime.planner import MaskedBeam

DIM = 16


# ---------------------------------------------------------------------------
# graph-level unit tests
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def graph():
    rng = np.random.default_rng(3)
    centers = rng.normal(size=(8, DIM)) * 3.0
    X = np.concatenate(
        [c + rng.normal(size=(150, DIM)) for c in centers]
    ).astype(np.float32)
    g = build_vamana(
        X, VamanaParams(R=16, L=32), passes=2, batch=128, with_pq=True, pq_m=4
    )
    return g, X


def _masked_oracle_ids(X, q, mask, k):
    d = np.sum((X - q) ** 2, axis=1)
    d = np.where(mask[: len(X)], d, np.inf)
    order = np.argsort(d)[:k]
    return order[np.isfinite(d[order])]


def _recall(got_ids, oracle_ids):
    if len(oracle_ids) == 0:
        return 1.0
    return len(set(got_ids[got_ids >= 0]) & set(oracle_ids)) / len(oracle_ids)


@pytest.mark.parametrize("frac", [0.5, 0.1])
def test_search_masked_recall_vs_masked_oracle(graph, frac):
    g, X = graph
    rng = np.random.default_rng(7)
    mask = rng.random(g.n) < frac
    Q = X[rng.choice(len(X), 32)] + 0.05 * rng.normal(size=(32, DIM)).astype(
        np.float32
    )
    dists, ids = g.search_masked(Q, 10, mask, L=64)
    recalls = [
        _recall(ids[i], _masked_oracle_ids(X, Q[i], mask, 10)) for i in range(32)
    ]
    assert np.mean(recalls) >= 0.9, np.mean(recalls)
    # every admitted id passes the mask; sentinel slots are (-1, +inf)
    finite = np.isfinite(dists)
    assert mask[ids[finite]].all()
    assert (ids[~finite] == -1).all()
    # rows come back ascending on the finite prefix
    for row in np.where(finite, dists, np.inf):
        assert (np.diff(row) >= 0).all()


def test_search_masked_zero_admissible_is_all_sentinels(graph):
    g, X = graph
    dists, ids = g.search_masked(X[:4], 10, np.zeros(g.n, bool), L=64)
    assert np.isinf(dists).all() and (ids == -1).all()


def test_search_masked_underdelivery_keeps_sentinel_tail(graph):
    """Fewer admissible nodes than k: finite slots hold only admissible ids
    and the tail stays (+inf, -1) — the contract the executor's fused
    exact-masked fallback keys on."""
    g, X = graph
    mask = np.zeros(g.n, bool)
    admissible = [5, 400, 900]
    mask[admissible] = True
    dists, ids = g.search_masked(X[:8], 10, mask, L=64)
    finite = np.isfinite(dists)
    assert finite.sum(axis=1).max() <= len(admissible)
    assert set(ids[finite].tolist()) <= set(admissible)
    assert (ids[~finite] == -1).all()


def test_search_masked_batch_invariance(graph):
    """Rows are independent: slicing the query block into odd batches must
    not change a single result — the parity pin between sequential probes
    and coalesced fragments."""
    g, X = graph
    rng = np.random.default_rng(9)
    mask = rng.random(g.n) < 0.3
    Q = X[rng.choice(len(X), 21)]
    d64, i64 = g.search_masked(Q, 10, mask, L=64, batch=64)
    d5, i5 = g.search_masked(Q, 10, mask, L=64, batch=5)
    np.testing.assert_array_equal(i64, i5)
    np.testing.assert_array_equal(d64, d5)


def test_search_masked_per_query_masks(graph):
    """mask_idx routes each query to its own mask row."""
    g, X = graph
    rng = np.random.default_rng(11)
    masks = np.stack([rng.random(g.n) < 0.4, rng.random(g.n) < 0.4])
    Q = X[:10]
    idx = np.arange(10) % 2
    _, ids = g.search_masked(Q, 10, masks, mask_idx=idx, L=64)
    for i in range(10):
        got = ids[i][ids[i] >= 0]
        assert masks[idx[i]][got].all()


def test_search_masked_pq_path_reranks_full_precision(graph):
    """ADC traversal + host rerank: admitted ids obey the mask and recall
    stays near the full-precision path."""
    g, X = graph
    rng = np.random.default_rng(13)
    mask = rng.random(g.n) < 0.5
    Q = X[rng.choice(len(X), 16)] + 0.05 * rng.normal(size=(16, DIM)).astype(
        np.float32
    )
    dists, ids = g.search_masked(Q, 10, mask, L=64, use_pq=True)
    finite = np.isfinite(dists)
    assert mask[ids[finite]].all()
    recalls = [
        _recall(ids[i], _masked_oracle_ids(X, Q[i], mask, 10)) for i in range(16)
    ]
    assert np.mean(recalls) >= 0.9, np.mean(recalls)
    # reranked distances are exact L2, not ADC approximations
    safe = np.clip(ids, 0, len(X) - 1)
    exact = np.sum((X[safe] - Q[:, None, :]) ** 2, axis=-1)
    np.testing.assert_allclose(
        np.where(finite, dists, 0.0), np.where(finite, exact, 0.0), rtol=1e-4
    )


def test_search_masked_respects_tombstones_via_mask(graph):
    """The caller folds tombstones into the mask (admissible = predicate
    AND NOT tombstoned) — a tombstoned id must never be admitted."""
    g, X = graph
    mask = np.ones(g.n, bool)
    dead = np.arange(0, g.n, 3)
    mask[dead] = False
    _, ids = g.search_masked(X[:8], 10, mask, L=64)
    got = ids[ids >= 0]
    assert not np.isin(got, dead).any()


# ---------------------------------------------------------------------------
# cluster-level: the MaskedBeam plan op on a big shard
# ---------------------------------------------------------------------------


def _locs(hits):
    return [(h.file_path, h.row_group, h.row_offset) for h in hits]


N_BIG = 5000  # > planner.EXACT_SCAN_MAX_ROWS: masked linear scans are out


@pytest.fixture(scope="module")
def bigshard_cluster(tmp_path_factory):
    """ONE shard above EXACT_SCAN_MAX_ROWS — the regime the MaskedBeam band
    exists for — with a uniform int attribute for selectivity control."""
    rng = np.random.default_rng(17)
    c = make_local_cluster(str(tmp_path_factory.mktemp("mbeam")), num_executors=2)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    centers = rng.normal(size=(10, DIM)) * 3.0
    X = np.concatenate(
        [ctr + rng.normal(size=(N_BIG // 10, DIM)) for ctr in centers]
    ).astype(np.float32)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(X, num_files=4, rows_per_group=250, attributes={"price": price})
    c.coordinator.create_index(
        "emb",
        IndexConfig(
            name="idx", num_shards=1, R=16, L=48,
            partitions_per_shard=4, build_passes=1,
        ),
    )
    return c, t, X, price


def _queries(X, n, seed):
    rng = np.random.default_rng(seed)
    picks = X[rng.choice(len(X), n)]
    return (picks + 0.05 * rng.normal(size=picks.shape)).astype(np.float32)


# (predicate, expected true fraction, expected to stay MaskedBeam at the
# executor): ~0.01 collapses to the exact scan in resolve — its passing set
# fits planner.SMALL_MATCH — but the *plan* is still mbeam-band evidence
SWEEP = [
    ("price < 50", 0.5, True),
    ("price < 10", 0.1, True),
    ("price < 1", 0.01, False),
]


@pytest.mark.parametrize("where,frac,stays_mbeam", SWEEP, ids=["0.5", "0.1", "0.01"])
def test_masked_beam_selectivity_sweep(bigshard_cluster, where, frac, stays_mbeam):
    c, t, X, price = bigshard_cluster
    true_frac = float((price < int(where.split("<")[1])).mean())
    assert true_frac == pytest.approx(frac, abs=0.05)
    Q = _queries(X, 16, seed=int(frac * 100))
    oracle = c.coordinator.probe_batch("emb", Q, 10, strategy="scan", filter=where)
    got = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="diskann", filter=where, L=256
    )
    # the big shard bands to MaskedBeam at every swept selectivity
    assert "mbeam" in got.filter_plan, got.filter_plan
    for row in got.plan.ops:
        assert all(isinstance(op, MaskedBeam) for op in row.values())
    recalls = [
        len(set(_locs(a)) & set(_locs(b))) / max(len(_locs(a)), 1)
        for a, b in zip(oracle.hits, got.hits)
    ]
    assert np.mean(recalls) >= 0.95, (where, np.mean(recalls))
    if stays_mbeam:
        # rows were answered by the traversal, not a scan; the beam pass
        # itself is not a masked-kernel dispatch — only fused fallbacks are
        assert got.masked_beam_rows == len(Q)
        assert got.masked_beam_fallbacks <= len(Q)
        assert got.kernel_dispatches <= got.probe_fragments
    else:
        # resolve collapsed the tiny passing set to the exact scan: full
        # parity, and no traversal rows to account
        assert got.masked_beam_rows == 0
        for a, b in zip(oracle.hits, got.hits):
            assert _locs(a) == _locs(b)


def test_masked_beam_probe_matches_batch(bigshard_cluster):
    """Sequential single probes and the coalesced batch interpret the same
    resolved op — identical hits."""
    c, t, X, price = bigshard_cluster
    Q = _queries(X, 6, seed=23)
    br = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="diskann", filter="price < 40", L=256
    )
    assert br.masked_beam_rows == len(Q)
    for i in range(len(Q)):
        pr = c.coordinator.probe(
            "emb", Q[i], 10, strategy="diskann", filter="price < 40", L=256
        )
        assert pr.masked_beam_rows == 1
        assert _locs(pr.hits[0]) == _locs(br.hits[i])


def test_masked_beam_heterogeneous_batch_shares_width_pools(bigshard_cluster):
    """Distinct predicates in one fragment pool by planner width; hits still
    match sequential probes."""
    c, t, X, price = bigshard_cluster
    Q = _queries(X, 4, seed=29)
    filters = ["price < 60", "price < 45", "price < 60", "price < 8"]
    br = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="diskann", filter=filters, L=256
    )
    assert br.masked_beam_rows == len(Q)
    for i in range(len(Q)):
        pr = c.coordinator.probe(
            "emb", Q[i], 10, strategy="diskann", filter=filters[i], L=256
        )
        assert _locs(pr.hits[0]) == _locs(br.hits[i])


def test_masked_beam_underdelivery_fallback_fires(bigshard_cluster, monkeypatch):
    """Regression: when the widened beam under-delivers, every starved row is
    re-answered by the fused exact-masked fallback — results stay
    oracle-exact and the fallback is visible in the report accounting."""
    c, t, X, price = bigshard_cluster
    Q = _queries(X, 8, seed=31)
    where = "price < 30"

    def _starved(self, queries, k, unique_masks, mask_idx=None, L=None,
                 batch=64, use_pq=False):
        q = queries.shape[0]
        return (
            np.full((q, int(k)), np.inf, np.float32),
            np.full((q, int(k)), -1, np.int64),
        )

    monkeypatch.setattr(VamanaGraph, "search_masked", _starved)
    br = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="diskann", filter=where, L=256
    )
    assert br.masked_beam_rows == len(Q)
    assert br.masked_beam_fallbacks == len(Q)
    # ONE fused exact-masked dispatch per fragment, not one per starved row
    assert br.kernel_dispatches == br.probe_fragments == 1
    monkeypatch.undo()
    oracle = c.coordinator.probe_batch("emb", Q, 10, strategy="scan", filter=where)
    for a, b in zip(oracle.hits, br.hits):
        assert _locs(a) == _locs(b)  # the fallback is exact

    # single-probe path fires the same fallback
    monkeypatch.setattr(VamanaGraph, "search_masked", _starved)
    pr = c.coordinator.probe(
        "emb", Q[0], 10, strategy="diskann", filter=where, L=256
    )
    assert pr.masked_beam_fallbacks == 1
    monkeypatch.undo()
    assert _locs(pr.hits[0]) == _locs(oracle.hits[0])


def test_masked_beam_above_mask_band_stays_postfilter(bigshard_cluster):
    """Selectivity above MASK_MAX_FRAC on the big shard keeps the
    over-fetched postfilter beam — MaskedBeam's widening would be wasted on
    a predicate nearly everything passes."""
    c, t, X, price = bigshard_cluster
    Q = _queries(X, 4, seed=37)
    br = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="diskann", filter="price < 95", L=256
    )
    assert "mbeam" not in br.filter_plan
    assert "postfilter" in br.filter_plan
    assert br.masked_beam_rows == 0
