"""Vamana graph: build invariants, recall, insert, tombstones, serialization."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from repro.core.blobs import ShardLocationMap, decode_shard_blob, encode_shard_blob
from repro.core.pq import encode, train_pq
from repro.core.vamana import (
    VamanaParams,
    _robust_prune,
    brute_force_topk,
    build_vamana,
    recall_at_k,
)
from conftest import clustered_vectors


@pytest.fixture(scope="module")
def built():
    rng = np.random.default_rng(0)
    X, _ = clustered_vectors(rng, n_clusters=16, per_cluster=100, dim=32)
    g = build_vamana(X, VamanaParams(R=24, L=48), seed=0, passes=2, batch=128)
    Q = X[rng.choice(len(X), 24)] + 0.1 * rng.normal(size=(24, 32)).astype(np.float32)
    return X, g, Q


def test_degree_bound(built):
    X, g, _ = built
    assert g.degrees().max() <= g.params.R


def test_no_self_loops_no_dups(built):
    X, g, _ = built
    for i in range(0, g.n, 97):
        row = g.adjacency[i]
        row = row[row >= 0]
        assert i not in row
        assert len(set(row.tolist())) == len(row)
    # all neighbor ids are valid
    assert g.adjacency[: g.n].max() < g.n


def test_reachability_from_medoid(built):
    """Beam search must reach (almost) every node — graph connectivity."""
    X, g, _ = built
    # BFS from medoid over the directed graph
    seen = np.zeros(g.n, bool)
    frontier = [g.medoid]
    seen[g.medoid] = True
    while frontier:
        nxt = []
        for u in frontier:
            for v in g.adjacency[u]:
                if v >= 0 and not seen[v]:
                    seen[v] = True
                    nxt.append(int(v))
        frontier = nxt
    assert seen.mean() > 0.99


def test_recall_vs_bruteforce(built):
    X, g, Q = built
    _, truth = brute_force_topk(X, Q, 10)
    _, got = g.search(Q, 10)
    assert recall_at_k(got, truth) >= 0.9


def test_pq_search_with_rerank(built):
    X, g, Q = built
    pq = train_pq(X, m=16, nbits=8, iters=6)
    g.attach_pq(pq, encode(pq, X))
    _, truth = brute_force_topk(X, Q, 10)
    _, got = g.search_pq(Q, 10)
    assert recall_at_k(got, truth) >= 0.75


def test_insert_then_search(built):
    X, g, Q = built
    rng = np.random.default_rng(5)
    target = Q[0]
    new = (target[None, :] + 0.01 * rng.normal(size=(20, 32))).astype(np.float32)
    ids = g.insert_batch(new)
    d, i = g.search(target[None, :], 10, L=96)
    overlap = set(i[0].tolist()) & set(ids.tolist())
    assert len(overlap) >= 5  # near-duplicates of the query must surface


def test_tombstones_filtered_but_traversable(built):
    X, g, Q = built
    _, before = g.search(Q[:4], 10)
    doomed = np.unique(before.ravel())[:10]
    g.tombstone(doomed)
    d, after = g.search(Q[:4], 10)
    assert not (set(after.ravel().tolist()) & set(doomed.tolist()))
    assert np.isfinite(d).all()  # still returns k live results
    g.tombstones[:] = False  # restore for other tests


def test_blob_roundtrip(built):
    X, g, Q = built
    n = g.n
    loc = ShardLocationMap(
        ["f/a.vpq", "f/b.vpq"],
        (np.arange(n) % 2).astype(np.uint32),
        (np.arange(n) % 5).astype(np.uint32),
        (np.arange(n) % 777).astype(np.uint32),
    )
    blob = encode_shard_blob(g, loc, include_vectors=True)
    g2, loc2 = decode_shard_blob(blob)
    np.testing.assert_array_equal(g2.adjacency[:n], g.adjacency[:n])
    np.testing.assert_allclose(g2.vectors[:n], g.vectors[:n])
    assert g2.medoid == g.medoid and g2.n == n
    assert loc2.lookup(123) == loc.lookup(123)
    # lean blob + override
    lean = encode_shard_blob(g, loc, include_vectors=False)
    assert len(lean) < len(blob) / 2
    g3, _ = decode_shard_blob(lean, vectors_override=g.vectors[:n])
    np.testing.assert_allclose(g3.vectors[:n], g.vectors[:n])
    # search results identical after roundtrip
    _, i1 = g.search(Q[:4], 5)
    _, i2 = g2.search(Q[:4], 5)
    np.testing.assert_array_equal(i1, i2)


# ---------------------------------------------------------------------------
# robust prune properties
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(10, 120),
    d=st.integers(2, 16),
    R=st.integers(2, 16),
    alpha=st.floats(1.0, 2.0),
)
def test_property_robust_prune(n, d, R, alpha):
    rng = np.random.default_rng(n * 13 + d)
    vectors = rng.normal(size=(n, d)).astype(np.float32)
    cand = np.arange(1, n, dtype=np.int32)
    out = _robust_prune(
        jnp.asarray(vectors),
        jnp.asarray(vectors[:1]),
        jnp.asarray(cand[None, :]),
        jnp.int32(n),
        R,
        float(alpha),
        "l2",
    )
    out = np.asarray(out)[0]
    picked = out[out >= 0]
    # degree bound
    assert len(picked) <= R
    # no duplicates
    assert len(set(picked.tolist())) == len(picked)
    # the overall nearest candidate is always kept
    d_p = np.sum((vectors[cand] - vectors[0]) ** 2, axis=1)
    nearest = cand[np.argmin(d_p)]
    assert nearest in picked
    # α-RNG property: every pruned candidate either has an α-witness among
    # the kept neighbors, or the degree budget R was exhausted first
    kept = set(picked.tolist())
    if len(picked) < R:
        for c in cand:
            if int(c) in kept:
                continue
            d_pc = np.sum((vectors[c] - vectors[0]) ** 2)
            ok = any(
                alpha * np.sum((vectors[c] - vectors[p]) ** 2) <= d_pc + 1e-3
                for p in picked
            )
            assert ok, f"candidate {c} pruned without an α-witness"
