"""SQL frontend: DDL parsing + vector query routing (paper §6, §8)."""

import numpy as np
import pytest

from repro.lakehouse.table import LakehouseTable
from repro.runtime.frontend import IndexDDLInfo, SqlError, SqlFrontend
from conftest import clustered_vectors


@pytest.fixture(scope="module")
def fe(tmp_path_factory):
    from repro.runtime.cluster import make_local_cluster

    rng = np.random.default_rng(0)
    c = make_local_cluster(str(tmp_path_factory.mktemp("sql")), num_executors=2)
    t = LakehouseTable(c.catalog, "docs")
    t.create(dim=16)
    X, _ = clustered_vectors(rng, n_clusters=8, per_cluster=100, dim=16)
    t.append_vectors(X, num_files=4, rows_per_group=128)
    return SqlFrontend(c.coordinator), X


def test_parse_create_with_options(fe):
    frontend, _ = fe
    stmt = frontend.parse(
        "CREATE VECTOR INDEX idx ON docs (vec) WITH (R=16, L=32, PQ_M=4, passes=1);"
    )
    assert isinstance(stmt, IndexDDLInfo)
    assert stmt.action == "create" and stmt.index_name == "idx"
    assert stmt.options["r"] == "16"


def test_parse_rejects_garbage(fe):
    frontend, _ = fe
    with pytest.raises(SqlError):
        frontend.parse("SELECT COUNT(*) FROM docs")


def test_ddl_and_query_roundtrip(fe):
    frontend, X = fe
    rep = frontend.execute(
        "CREATE VECTOR INDEX idx ON docs (vec) WITH (R=16, L=32, passes=1)"
    )
    assert rep.num_shards >= 1
    q = ",".join(str(float(v)) for v in X[0])
    hits = frontend.execute(f"SELECT * FROM docs ORDER BY L2_DISTANCE(vec, [{q}]) LIMIT 5")
    assert len(hits) == 5
    assert hits[0].distance < 1e-3  # the query point itself

    # threshold query: exact pruning bound, results all within the bound
    hits = frontend.execute(f"SELECT * FROM docs WHERE L2_DISTANCE(vec, [{q}]) < 2.0")
    assert hits, "neighbors within radius 2 exist (the point itself)"
    assert all(h.distance <= 4.0 + 1e-3 for h in hits)  # squared bound

    # refresh is a no-op right after build
    rr = frontend.execute("REFRESH INDEX idx ON docs")
    assert rr.noop

    # drop unbinds the statistics file
    frontend.execute("DROP INDEX idx ON docs")
    meta = frontend.coordinator.catalog.load_table("docs")
    assert meta.current_snapshot().statistics_file is None
