"""Training substrate: optimizer, loss descent, checkpoints, compression."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.iceberg.catalog import RestCatalog
from repro.models.model import build_model
from repro.launch.mesh import make_debug_mesh
from repro.training.checkpoint import CheckpointManager
from repro.training.compression import compress_with_feedback, dequantize_int8, quantize_int8
from repro.training.optimizer import adamw_init, adamw_update
from repro.training.train_loop import TrainStepConfig, make_train_step
from repro.data.pipeline import SyntheticTokens


def test_adamw_minimizes_quadratic():
    params = {"w": jnp.asarray([5.0, -3.0, 2.0])}
    opt = adamw_init(params)
    target = jnp.asarray([1.0, 1.0, 1.0])
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(params, grads, opt, lr=0.05, weight_decay=0.0)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=0.05)


def test_grad_clip_bounds_update():
    params = {"w": jnp.zeros(4)}
    opt = adamw_init(params)
    grads = {"w": jnp.full(4, 1e6)}
    _, _, stats = adamw_update(params, grads, opt, clip_norm=1.0)
    assert float(stats["grad_norm"]) > 1e5  # reported pre-clip


def test_loss_decreases_small_model():
    cfg = dataclasses.replace(reduced(get_config("qwen2.5-3b")), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_debug_mesh(1, 1)
    step, _ = make_train_step(
        model, mesh, cfg=TrainStepConfig(microbatches=1, lr=1e-3, remat=False)
    )
    opt = adamw_init(params)
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=32, batch_size=8, seed=7)
    losses = []
    for i in range(12):
        ids, labels = data.batch(i % 2)  # small repeating set -> memorizable
        params, opt, m = step(params, opt, jnp.asarray(ids), jnp.asarray(labels))
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.5, losses


# ---------------------------------------------------------------------------
# gradient compression
# ---------------------------------------------------------------------------

def test_quantize_roundtrip_error_bounded():
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(1000,)).astype(np.float32))
    q, scale = quantize_int8(x)
    err = np.abs(np.asarray(dequantize_int8(q, scale) - x))
    assert err.max() <= float(scale) / 2 + 1e-6


def test_error_feedback_is_unbiased_over_time():
    """With EF, the *accumulated* compressed sum tracks the true sum."""
    rng = np.random.default_rng(1)
    true_sum = np.zeros(64, np.float32)
    comp_sum = np.zeros(64, np.float32)
    err = jnp.zeros(64)
    for i in range(200):
        g = jnp.asarray(rng.normal(size=(64,)).astype(np.float32))
        q, scale, err = compress_with_feedback(g, err)
        comp_sum += np.asarray(dequantize_int8(q, scale))
        true_sum += np.asarray(g)
    drift = np.abs(comp_sum - true_sum).max()
    # residual error is bounded by one quantization step, not O(steps)
    assert drift < 0.2, drift


def test_compressed_psum_shard_map():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    from repro.training.compression import compressed_psum

    mesh = make_debug_mesh(1, 1)  # single device still exercises the path
    grads = {"a": jnp.arange(8.0), "b": jnp.ones((3, 3))}
    errors = jax.tree.map(jnp.zeros_like, grads)

    def f(g, e):
        return compressed_psum(g, e, "data")

    out, new_e = shard_map(
        f, mesh=mesh, in_specs=(P(), P()), out_specs=(P(), P()), check_rep=False
    )(grads, errors)
    np.testing.assert_allclose(np.asarray(out["a"]), np.arange(8.0), atol=0.05)


# ---------------------------------------------------------------------------
# checkpoints (snapshot-bound)
# ---------------------------------------------------------------------------

def test_checkpoint_save_restore_roundtrip(tmp_store):
    cat = RestCatalog(tmp_store)
    mgr = CheckpointManager(cat, async_save=False)
    state = {"w": jnp.arange(6.0).reshape(2, 3), "step_data": {"b": jnp.ones(4)}}
    mgr.save(10, state, metrics={"loss": 3.25})
    like = jax.tree.map(jnp.zeros_like, state)
    restored, step = mgr.restore(like)
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["w"]), np.asarray(state["w"]))


def test_checkpoint_resume_latest_and_time_travel(tmp_store):
    cat = RestCatalog(tmp_store)
    mgr = CheckpointManager(cat, async_save=False, keep_last=3)
    state = {"w": jnp.zeros(3)}
    for s in (1, 2, 3):
        mgr.save(s, {"w": jnp.full(3, float(s))})
    assert mgr.latest_step() == 3
    assert mgr.available_steps() == [1, 2, 3]
    restored, step = mgr.restore(state, step=2)
    assert step == 2 and float(restored["w"][0]) == 2.0


def test_checkpoint_async_and_crash_atomicity(tmp_store):
    cat = RestCatalog(tmp_store)
    mgr = CheckpointManager(cat, async_save=True)
    mgr.save(5, {"w": jnp.ones(8)})
    mgr.wait()
    assert mgr.latest_step() == 5
    # a "crashed" save = objects without a commit -> invisible + orphaned
    meta = cat.load_table("__checkpoints")
    tmp_store.put(f"{meta.location}/data/step-00000099/w.npy", b"junk")
    assert mgr.latest_step() == 5
    from repro.iceberg.gc import collect_orphans

    orphans = collect_orphans(tmp_store, cat.load_table("__checkpoints"))
    assert any("step-00000099" in o for o in orphans)


def test_checkpoint_retention(tmp_store):
    cat = RestCatalog(tmp_store)
    mgr = CheckpointManager(cat, async_save=False, keep_last=2)
    for s in range(5):
        mgr.save(s, {"w": jnp.full(2, float(s))})
    assert mgr.available_steps() == [3, 4]


def test_train_resume_after_crash(tmp_store):
    """checkpoint → 'crash' → restore → continue: loss trajectory intact."""
    cfg = dataclasses.replace(reduced(get_config("qwen2.5-3b")), num_layers=2)
    model = build_model(cfg)
    mesh = make_debug_mesh(1, 1)
    step, _ = make_train_step(model, mesh, cfg=TrainStepConfig(microbatches=1, lr=1e-3, remat=False))
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=16, batch_size=4, seed=3)
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    cat = RestCatalog(tmp_store)
    mgr = CheckpointManager(cat, async_save=False)
    for i in range(3):
        ids, labels = data.batch(i)
        params, opt, _ = step(params, opt, jnp.asarray(ids), jnp.asarray(labels))
    mgr.save(3, {"params": params, "opt": opt})
    ids, labels = data.batch(3)
    params4, opt4, m4 = step(params, opt, jnp.asarray(ids), jnp.asarray(labels))
    # crash + restore
    like = {"params": jax.tree.map(jnp.zeros_like, params4),
            "opt": jax.tree.map(jnp.zeros_like, opt4)}
    restored, s = mgr.restore(like)
    assert s == 3
    p2, o2, m4b = step(restored["params"], restored["opt"], jnp.asarray(ids), jnp.asarray(labels))
    assert abs(float(m4["loss"]) - float(m4b["loss"])) < 1e-4  # deterministic resume
