"""Executor ``_mask_cache``: LRU bounds and refresh-time eviction scope.

The cache maps (shard key, row count, predicate) → per-vector-id bool
mask.  Two contracts under test:

- insertion past the capacity (64) evicts least-recently-USED entries —
  a re-touched mask survives a flood of fresh predicates;
- ``_refresh_shard`` drops ONLY the refreshed shard's mask keys; other
  shards' cached masks (still valid — their row sets did not change)
  survive.
"""

import numpy as np
import pytest

from repro.core.blobs import ROUTING_BLOB_TYPE, decode_routing_blob
from repro.lakehouse.table import LakehouseTable
from repro.runtime import fragments as F
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig
from repro.runtime.predicates import parse_predicate

DIM = 8


@pytest.fixture(scope="module")
def cache_cluster(tmp_path_factory):
    rng = np.random.default_rng(0)
    c = make_local_cluster(str(tmp_path_factory.mktemp("maskcache")), num_executors=1)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    X = rng.normal(size=(300, DIM)).astype(np.float32)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(X, num_files=2, rows_per_group=80, attributes={"price": price})
    c.coordinator.create_index(
        "emb",
        IndexConfig(
            name="idx", R=12, L=32, num_shards=2,
            partitions_per_shard=2, build_passes=1,
        ),
    )
    # one filtered probe loads both shards into the executor's L1 and
    # caches one mask per shard
    c.coordinator.probe("emb", X[0], 5, strategy="diskann", filter="price < 50")
    return c, t, X


def test_mask_cache_lru_eviction_at_capacity(cache_cluster):
    c, t, X = cache_cluster
    ex = c.executors[0]
    assert ex._mask_cache_capacity == 64
    l1_key, (graph, locmap) = next(iter(ex._l1.items()))
    ex._mask_cache.clear()
    keep = parse_predicate("price < 0")
    ex._predicate_mask(locmap, graph.n, keep, l1_key)
    keep_key = (l1_key, graph.n, keep)
    assert keep_key in ex._mask_cache
    # flood with 70 fresh predicates, re-touching the protected one along
    # the way: LRU keeps the touched entry and bounds the cache at 64
    for i in range(70):
        ex._predicate_mask(locmap, graph.n, parse_predicate(f"price < {i + 1}"), l1_key)
        ex._predicate_mask(locmap, graph.n, keep, l1_key)  # touch => MRU
    assert len(ex._mask_cache) == 64
    assert keep_key in ex._mask_cache
    # the oldest untouched predicates were evicted, the newest survive
    assert (l1_key, graph.n, parse_predicate("price < 1")) not in ex._mask_cache
    assert (l1_key, graph.n, parse_predicate("price < 70")) in ex._mask_cache
    # an evicted predicate recomputes to the same mask (cache is transparent)
    m = ex._predicate_mask(locmap, graph.n, parse_predicate("price < 1"), l1_key)
    assert m.shape == (graph.n,) and m.dtype == bool


def test_refresh_evicts_only_refreshed_shards_masks(cache_cluster, tmp_path):
    c, t, X = cache_cluster
    ex = c.executors[0]
    meta, snap, path, reader = c.coordinator._resolve_index("emb")
    routing = decode_routing_blob(reader.read_first(ROUTING_BLOB_TYPE))
    assert len(routing.shards) == 2
    blobs = reader.blobs
    # cache one distinct mask per shard under the real shard keys
    shard_keys = []
    pred = parse_predicate("price BETWEEN 10 AND 60")
    for s in routing.shards:
        b = blobs[s.blob_index]
        cache_key = f"{path}#shard{s.shard_id}"
        graph, locmap, _ = ex._load_shard(
            path, b.offset, b.length, b.compression_codec, cache_key
        )
        skey = f"{cache_key}@{b.offset}"
        ex._predicate_mask(locmap, graph.n, pred, skey)
        shard_keys.append((skey, graph.n))
    assert all((sk, n, pred) in ex._mask_cache for sk, n in shard_keys)
    # refresh ONLY shard 0 (no data change needed — eviction is
    # unconditional: the refresh mutates the cached graph/locmap in place)
    s0 = routing.shards[0]
    b0 = blobs[s0.blob_index]
    ex.handle(
        F.RefreshTaskInfo(
            task_id="refresh-0",
            cache_key=f"{path}#shard{s0.shard_id}",
            shard_id=s0.shard_id,
            puffin_path=path,
            blob_offset=b0.offset,
            blob_length=b0.length,
            blob_codec=b0.compression_codec,
            added_files=[],
            removed_files=[],
            partition_centroids=routing.partition_centroids,
            shard_of_partition=routing.shard_of_partition,
            output_path=str(tmp_path / "shard0-refreshed.blob"),
        )
    )
    (sk0, n0), (sk1, n1) = shard_keys
    assert (sk0, n0, pred) not in ex._mask_cache  # refreshed shard: dropped
    assert (sk1, n1, pred) in ex._mask_cache  # other shard: survives
