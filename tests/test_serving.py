"""Serving: device-resident ANN probe, kNN-LM retrieval decode."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.core.vamana import VamanaParams, brute_force_topk, build_vamana, recall_at_k
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build_model
from repro.serving.device_index import DeviceAnnIndex, make_probe_fn
from repro.serving.serve_loop import ServeConfig, make_serve_fns
from conftest import clustered_vectors


@pytest.fixture(scope="module")
def device_index():
    rng = np.random.default_rng(0)
    X, _ = clustered_vectors(rng, n_clusters=8, per_cluster=125, dim=16)
    # two shards (single-device mesh still exercises shard_map semantics)
    half = len(X) // 2
    g1 = build_vamana(X[:half], VamanaParams(R=12, L=24), passes=1, batch=128)
    g2 = build_vamana(X[half:], VamanaParams(R=12, L=24), passes=1, batch=128)
    payloads = [np.arange(half), np.arange(half, len(X))]
    idx = DeviceAnnIndex.from_graphs([g1, g2], payloads=payloads)
    return X, idx


def test_device_probe_matches_host_search(device_index):
    X, idx = device_index
    mesh = make_debug_mesh(1, 1)
    # one device: both shards probed on it (leading dim = 2 shards over
    # data axis of size 1 -> sequential but same math)
    probe = make_probe_fn(mesh, k=10, L=24)
    rng = np.random.default_rng(1)
    Q = X[rng.choice(len(X), 8)] + 0.05 * rng.normal(size=(8, 16)).astype(np.float32)
    with mesh:
        d, ids = jax.jit(probe)(idx, jnp.asarray(Q))
    _, truth = brute_force_topk(X, Q, 10)
    rec = recall_at_k(np.asarray(ids), truth)
    assert rec >= 0.85, rec
    # distances sorted ascending
    d = np.asarray(d)
    assert (np.diff(d, axis=1) >= -1e-4).all()


def test_abstract_index_lowering(device_index):
    """The dry-run path: probe lowers+compiles from ShapeDtypeStructs."""
    mesh = make_debug_mesh(1, 1)
    probe = make_probe_fn(mesh, k=8, L=16)
    idx = DeviceAnnIndex.abstract(n_shards=1, cap=2048, dim=16, R=12)
    q = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    with mesh:
        compiled = jax.jit(probe).lower(idx, q).compile()
    assert compiled is not None


def test_knn_lm_decode_runs_and_mixes():
    cfg = dataclasses.replace(reduced(get_config("qwen2.5-3b")), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_debug_mesh(1, 1)
    rng = np.random.default_rng(2)
    # corpus in lm_head space with token payloads
    d = cfg.d_model
    corpus = rng.normal(size=(500, d)).astype(np.float32)
    tokens = rng.integers(0, cfg.vocab_size, size=500)
    g = build_vamana(corpus, VamanaParams(R=8, L=16), passes=1, batch=128)
    idx = DeviceAnnIndex.from_graphs([g], payloads=[tokens])
    probe = make_probe_fn(mesh, k=4, L=16)
    prefill, decode, sample, sh = make_serve_fns(
        model, mesh, cfg=ServeConfig(knn_lambda=0.5), retrieval=probe,
        index_template=idx, batch_hint=2, max_len_hint=16,
    )
    ids = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(2, 8)))
    cache = model.init_cache(2, 16)
    with mesh:
        _, cache = prefill(params, ids, cache)
        logits, cache = decode(params, ids[:, -1:], cache, jnp.int32(8), idx)
    assert bool(jnp.isfinite(logits).all())
    # λ=0 vs λ=0.5 must differ (retrieval actually contributes)
    prefill0, decode0, _, _ = make_serve_fns(
        model, mesh, cfg=ServeConfig(knn_lambda=0.0), retrieval=probe,
        index_template=idx, batch_hint=2, max_len_hint=16,
    )
    cache0 = model.init_cache(2, 16)
    with mesh:
        _, cache0 = prefill0(params, ids, cache0)
        logits0, _ = decode0(params, ids[:, -1:], cache0, jnp.int32(8), idx)
    assert float(jnp.abs(logits - logits0).max()) > 1e-4


def test_greedy_generation_loop():
    cfg = dataclasses.replace(reduced(get_config("chatglm3-6b")), num_layers=2)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    mesh = make_debug_mesh(1, 1)
    prefill, decode, sample, _ = make_serve_fns(model, mesh, batch_hint=1, max_len_hint=24)
    rng = np.random.default_rng(3)
    prompt = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(1, 8)))
    cache = model.init_cache(1, 24)
    with mesh:
        logits, cache = prefill(params, prompt, cache)
        tok = sample(logits, jax.random.PRNGKey(0))
        outs = [int(tok[0, 0])]
        for t in range(8, 16):
            logits, cache = decode(params, tok, cache, jnp.int32(t))
            tok = sample(logits, jax.random.PRNGKey(t))
            outs.append(int(tok[0, 0]))
    assert len(outs) == 9
    assert all(0 <= t < cfg.vocab_size for t in outs)
