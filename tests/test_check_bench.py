"""scripts/check_bench.py: the CI benchmark-regression gate.

The acceptance contract: the gate must demonstrably FAIL on an injected
regression (doctored JSON) and pass on a clean run — both through the pure
``check()`` function and the CLI entry point's exit codes.
"""

import copy
import importlib.util
import json
import pathlib

import pytest

_SCRIPT = pathlib.Path(__file__).resolve().parents[1] / "scripts" / "check_bench.py"
_spec = importlib.util.spec_from_file_location("check_bench", _SCRIPT)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def _clean_doc():
    return {
        "meta": {"bench": "bench_query_paths", "tiny": True},
        "rows": {
            "table2.scan": {"throughput_qps": 25.0, "recall": 1.0},
            "table2.diskann": {"throughput_qps": 5.2, "recall": 0.96},
            "table2.batched": {
                "throughput_qps": 130.0,
                "seq_qps": 19.0,
                "speedup": 6.8,
                "recall": 0.96,
                "parity_ok": True,
                "probe_fragments": 2,
            },
            "table2.filtered": {
                "throughput_qps": 60.0,
                "recall": 1.0,
                "shards_pruned": 1,
                "probe_fragments": 1,
                "unfiltered_fragments": 2,
            },
        },
    }


def test_clean_run_passes():
    doc = _clean_doc()
    assert check_bench.check(doc, copy.deepcopy(doc)) == []
    assert check_bench.check(doc, None) == []  # no baseline: absolute gates only


def test_throughput_regression_fails():
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered"]["throughput_qps"] = 60.0 * 0.7  # −30% > 20% budget
    failures = check_bench.check(cur, base)
    assert len(failures) == 1 and "table2.filtered" in failures[0]
    assert "throughput" in failures[0]


def test_throughput_within_budget_passes():
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered"]["throughput_qps"] = 60.0 * 0.85  # −15% < 20%
    assert check_bench.check(cur, base) == []


def test_ungated_row_throughput_is_informational_but_recall_is_not():
    """Beam-search-driven rows (the table rows and the batched row) are too
    timing-noisy to gate on wall clock — but their recall is deterministic
    and stays gated, and batched keeps its speedup gate."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.diskann"]["throughput_qps"] = 5.2 * 0.3  # huge, ignored
    cur["rows"]["table2.batched"]["throughput_qps"] = 130.0 * 0.3  # ignored too
    assert check_bench.check(cur, base) == []
    cur["rows"]["table2.diskann"]["recall"] = 0.90
    failures = check_bench.check(cur, base)
    assert any("table2.diskann" in f and "recall" in f for f in failures)


def test_baseline_row_missing_from_current_fails():
    """A row silently dropped from the bench output must fail the gate —
    otherwise deleting/renaming a row un-gates it."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    del cur["rows"]["table2.filtered"]
    failures = check_bench.check(cur, base)
    assert any("table2.filtered" in f and "missing" in f for f in failures)


def test_uniform_machine_slowdown_passes():
    """Every row slower by the same factor = a slower machine, not a
    regression: the median-ratio normalization must absorb it."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    for row in cur["rows"].values():
        if "throughput_qps" in row:
            row["throughput_qps"] *= 0.4  # 2.5x slower across the board
    assert check_bench.check(cur, base) == []


def test_single_row_regression_sticks_out_of_machine_factor():
    """One row regressing on an otherwise-identical machine is caught even
    though the median ratio stays ~1."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered"]["throughput_qps"] *= 0.5
    failures = check_bench.check(cur, base)
    assert any("table2.filtered" in f and "machine factor" in f for f in failures)


def test_any_recall_drop_fails():
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered"]["recall"] = 0.999  # tiny, still a drop
    failures = check_bench.check(cur, base)
    assert any("table2.filtered" in f and "recall" in f for f in failures)


def test_absolute_gates_without_baseline():
    cur = _clean_doc()
    cur["rows"]["table2.filtered"]["recall"] = 0.80  # below the 0.95 floor
    cur["rows"]["table2.batched"]["speedup"] = 0.9
    cur["rows"]["table2.batched"]["parity_ok"] = False
    failures = check_bench.check(cur, None)
    assert any("recall vs oracle" in f for f in failures)
    assert any("not above the sequential" in f for f in failures)
    assert any("diverge" in f for f in failures)


def test_zone_prune_gate():
    cur = _clean_doc()
    cur["rows"]["table2.filtered"]["shards_pruned"] = 0
    cur["rows"]["table2.filtered"]["probe_fragments"] = 2  # == unfiltered
    failures = check_bench.check(cur, None)
    assert any("zone-map pruning" in f for f in failures)


def test_new_row_without_baseline_entry_is_not_gated():
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.new_path"] = {"throughput_qps": 0.001, "recall": 0.1}
    assert check_bench.check(cur, base) == []


@pytest.mark.parametrize(
    "doctor,expected_exit",
    [
        (lambda rows: None, 0),  # untouched => clean
        (lambda rows: rows["table2.filtered"].__setitem__("throughput_qps", 1.0), 1),
        (lambda rows: rows["table2.batched"].__setitem__("recall", 0.5), 1),
    ],
)
def test_cli_exit_codes(tmp_path, capsys, doctor, expected_exit):
    base = _clean_doc()
    cur = copy.deepcopy(base)
    doctor(cur["rows"])
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    rc = check_bench.main([str(cur_p), "--baseline", str(base_p)])
    out = capsys.readouterr().out
    assert rc == expected_exit
    if expected_exit:
        assert "BENCH-REGRESSION:" in out
    else:
        assert "OK" in out


def test_cli_unreadable_input(tmp_path):
    missing = tmp_path / "nope.json"
    assert check_bench.main([str(missing), "--baseline", ""]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert check_bench.main([str(bad), "--baseline", ""]) == 2
