"""scripts/check_bench.py: the CI benchmark-regression gate.

The acceptance contract: the gate must demonstrably FAIL on an injected
regression (doctored JSON) and pass on a clean run — both through the pure
``check()`` function and the CLI entry point's exit codes.
"""

import copy
import importlib.util
import json
import pathlib

import pytest

_SCRIPT = pathlib.Path(__file__).resolve().parents[1] / "scripts" / "check_bench.py"
_spec = importlib.util.spec_from_file_location("check_bench", _SCRIPT)
check_bench = importlib.util.module_from_spec(_spec)
_spec.loader.exec_module(check_bench)


def _clean_doc():
    return {
        "meta": {"bench": "bench_query_paths", "tiny": True},
        "rows": {
            "table2.scan": {"throughput_qps": 25.0, "recall": 1.0},
            "table2.diskann": {"throughput_qps": 5.2, "recall": 0.96},
            "table2.batched": {
                "throughput_qps": 130.0,
                "seq_qps": 19.0,
                "speedup": 6.8,
                "recall": 0.96,
                "parity_ok": True,
                "probe_fragments": 2,
            },
            "table2.filtered": {
                "throughput_qps": 60.0,
                "recall": 1.0,
                "shards_pruned": 1,
                "probe_fragments": 1,
                "unfiltered_fragments": 2,
                "oracle_qps": 40.0,
                "speedup_vs_oracle": 1.5,
            },
            "table2.filtered_hetero": {
                "throughput_qps": 45.0,
                "grouped_qps": 20.0,
                "speedup_vs_grouped": 2.25,
                "recall": 1.0,
                "kernel_dispatches": 2,
                "grouped_dispatches": 16,
                "distinct_filters": 8,
                "parity_ok": True,
            },
            "table2.filtered_mixed_flavor": {
                "throughput_qps": 40.0,
                "recall": 1.0,
                "kernel_dispatches": 2,
                "split_dispatches": 4,
                "probe_fragments": 2,
                "speedup_vs_split": 1.4,
                "distinct_filters": 8,
                "parity_ok": True,
            },
            "table2.filtered_lowsel_bigshard": {
                "throughput_qps": 30.0,
                "postfilter_qps": 12.0,
                "speedup_vs_postfilter": 2.5,
                "recall": 1.0,
                "est_selectivity": 0.15,
                "shard_rows": 5000,
                "exact_scan_cap": 4096,
                "batch_queries": 8,
                "masked_beam_rows": 8,
                "masked_beam_fallbacks": 1,
                "postfilter_dispatches": 1,
                "kernel_dispatches": 1,
                "probe_fragments": 1,
                "plan_mbeam": True,
            },
            "table2.freshness": {
                "throughput_qps": 70.0,
                "recall": 0.98,
                "recall_without_tail": 0.48,
                "tail_rows": 128,
                "tail_row_groups": 1,
                "tail_plan_ops": 1,
                "unindexed_rows": 0,
                "stale": True,
                "oracle_qps": 350.0,
            },
            "table2.overload": {
                "throughput_qps": 50.0,
                "capacity_qps": 70.0,
                "offered_qps": 140.0,
                "overload_factor": 2.0,
                "well_hit_rate": 1.0,
                "well_attempts": 40,
                "well_served": 40,
                "well_rejected": 0,
                "abusive_attempts": 230,
                "abusive_admitted": 40,
                "abusive_rejected": 190,
                "deadline_misses": 0,
                "degraded_batches": 0,
                "queue_bounded": True,
            },
            "table2.zipfian": {
                "throughput_qps": 20000.0,
                "recall": 0.98,
                "zipf_s": 1.1,
                "pool_size": 16,
                "stream_len": 96,
                "semantic_hits": 72,
                "semantic_misses": 24,
                "semantic_hit_rate": 0.75,
                "shard_hits": 18,
                "shard_lookups": 40,
                "shard_hit_rate": 0.45,
                "warm_p50_ms": 0.1,
                "warm_p99_ms": 5.0,
                "cold_p50_ms": 120.0,
                "cold_p99_ms": 200.0,
                "parity_ok": True,
                "replay_cache_hits": 12,
                "invalidations": 54,
                "stale_hits": 0,
            },
        },
    }


def _kernels_doc():
    return {
        "meta": {"bench": "bench_kernels"},
        "rows": {
            "kernel.rerank": {"throughput_qps": 22.0},
            "kernel.masked_exact_topk": {"throughput_qps": 45.0},
            "kernel.masked_exact_topk_multi": {"throughput_qps": 65.0},
            "kernel.masked_pq_topk_multi": {"throughput_qps": 5.0},
            "kernel.unified_masked_topk": {"throughput_qps": 12.0, "parity_ok": True},
            "kernel.gather_rerank": {
                "throughput_qps": 900.0,
                "host_qps": 420.0,
                "speedup_vs_host": 2.1,
            },
            "host.gather_rerank": {"throughput_qps": 420.0},
            "kernel.masked_exact_topk_bf16": {
                "throughput_qps": 40.0,
                "speedup_vs_f32": 0.7,
                "recall_raw": 0.97,
                "recall_post_guard": 1.0,
                "quantized_native": False,
            },
            "kernel.masked_exact_topk_int8": {
                "throughput_qps": 38.0,
                "speedup_vs_f32": 0.65,
                "recall_raw": 0.90,
                "recall_post_guard": 1.0,
                "quantized_native": False,
            },
            "anchor.numpy_matmul": {"throughput_qps": 300.0},
        },
    }


def test_clean_run_passes():
    doc = _clean_doc()
    assert check_bench.check(doc, copy.deepcopy(doc)) == []
    assert check_bench.check(doc, None) == []  # no baseline: absolute gates only


def test_throughput_regression_fails():
    """Wall-clock baseline gating lives on the kernel rows: a single
    kernel row dropping past its budget fails."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["kernel.rerank"]["throughput_qps"] *= 0.5  # −50% > 35% budget
    failures = check_bench.check(cur, base)
    assert len(failures) == 1 and "kernel.rerank" in failures[0]
    assert "throughput" in failures[0]


def test_throughput_within_budget_passes():
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["kernel.rerank"]["throughput_qps"] *= 0.75  # −25% < 35%
    assert check_bench.check(cur, base) == []


def test_table2_rows_are_not_wall_clock_gated():
    """Every table2 row rides the scheduler, so its wall clock never
    gates against the baseline — only its same-window ratios and recall
    do.  A filtered-row throughput drop (and a sub-1 oracle ratio, normal
    at tiny scale) passes; its recall dropping fails."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered"]["throughput_qps"] *= 0.4
    cur["rows"]["table2.filtered"]["speedup_vs_oracle"] = 0.8
    assert check_bench.check(cur, base) == []
    cur["rows"]["table2.filtered"]["recall"] = 0.96
    failures = check_bench.check(cur, base)
    assert any("table2.filtered" in f and "recall" in f for f in failures)


def test_ungated_row_throughput_is_informational_but_recall_is_not():
    """Beam-search-driven rows (the table rows and the batched row) are too
    timing-noisy to gate on wall clock — but their recall is deterministic
    and stays gated, and batched keeps its speedup gate."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.diskann"]["throughput_qps"] = 5.2 * 0.3  # huge, ignored
    cur["rows"]["table2.batched"]["throughput_qps"] = 130.0 * 0.3  # ignored too
    assert check_bench.check(cur, base) == []
    cur["rows"]["table2.diskann"]["recall"] = 0.90
    failures = check_bench.check(cur, base)
    assert any("table2.diskann" in f and "recall" in f for f in failures)


def test_baseline_row_missing_from_current_fails():
    """A row silently dropped from the bench output must fail the gate —
    otherwise deleting/renaming a row un-gates it."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    del cur["rows"]["table2.filtered"]
    failures = check_bench.check(cur, base)
    assert any("table2.filtered" in f and "missing" in f for f in failures)


def test_uniform_machine_slowdown_passes():
    """Every row slower by the same factor = a slower machine, not a
    regression: the median-ratio normalization must absorb it."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    for row in cur["rows"].values():
        if "throughput_qps" in row:
            row["throughput_qps"] *= 0.4  # 2.5x slower across the board
    assert check_bench.check(cur, base) == []


def test_single_row_regression_sticks_out_of_machine_factor():
    """One row regressing on an otherwise-identical machine is caught even
    though the anchor-pinned factor stays ~1."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["kernel.masked_pq_topk_multi"]["throughput_qps"] *= 0.5
    failures = check_bench.check(cur, base)
    assert any(
        "kernel.masked_pq_topk_multi" in f and "machine factor" in f
        for f in failures
    )


def test_any_recall_drop_fails():
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered"]["recall"] = 0.999  # tiny, still a drop
    failures = check_bench.check(cur, base)
    assert any("table2.filtered" in f and "recall" in f for f in failures)


def test_absolute_gates_without_baseline():
    cur = _clean_doc()
    cur["rows"]["table2.filtered"]["recall"] = 0.80  # below the 0.95 floor
    cur["rows"]["table2.batched"]["speedup"] = 0.9
    cur["rows"]["table2.batched"]["parity_ok"] = False
    failures = check_bench.check(cur, None)
    assert any("recall vs oracle" in f for f in failures)
    assert any("not above the sequential" in f for f in failures)
    assert any("diverge" in f for f in failures)


def test_zone_prune_gate():
    cur = _clean_doc()
    cur["rows"]["table2.filtered"]["shards_pruned"] = 0
    cur["rows"]["table2.filtered"]["probe_fragments"] = 2  # == unfiltered
    failures = check_bench.check(cur, None)
    assert any("zone-map pruning" in f for f in failures)


def test_current_row_missing_from_baseline_fails():
    """Drift check, forward direction: a row the bench emits that the
    committed baseline lacks means the baseline is stale — the new row
    would otherwise silently exempt itself from every baseline-relative
    gate.  (Absolute gates still run on it; but the drift failure is what
    forces the baseline regeneration alongside the change.)"""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.new_path"] = {"throughput_qps": 100.0, "recall": 1.0}
    failures = check_bench.check(cur, base)
    assert any(
        "table2.new_path" in f and "missing from the committed baseline" in f
        for f in failures
    )
    # without a baseline there is nothing to drift from: absolute-only runs
    # (check_bench <file> --baseline '') must not fail on row presence
    assert check_bench.check(cur, None) == []


def test_zipfian_vacuous_stream_fails():
    """Guard: a stream no longer than the pool never repeats a query, so
    every hit-rate and parity number is vacuous."""
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["stream_len"] = 16  # == pool_size
    failures = check_bench.check(cur, None)
    assert any("table2.zipfian" in f and "never repeats" in f for f in failures)


def test_zipfian_vacuous_parity_pass_fails():
    """Guard: parity_ok proves nothing if the replay pass took zero
    shard-cache hits — it compared the uncached path with itself."""
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["replay_cache_hits"] = 0
    failures = check_bench.check(cur, None)
    assert any(
        "table2.zipfian" in f and "uncached path with itself" in f
        for f in failures
    )


def test_zipfian_zero_semantic_hit_rate_fails():
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["semantic_hit_rate"] = 0.0
    failures = check_bench.check(cur, None)
    assert any(
        "table2.zipfian" in f and "result cache never answered" in f
        for f in failures
    )


def test_zipfian_zero_shard_hit_rate_fails():
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["shard_hit_rate"] = 0.0
    failures = check_bench.check(cur, None)
    assert any(
        "table2.zipfian" in f and "always recomputed" in f for f in failures
    )


def test_zipfian_warm_not_faster_than_cold_fails():
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["warm_p50_ms"] = 150.0  # >= cold 120.0
    failures = check_bench.check(cur, None)
    assert any(
        "table2.zipfian" in f and "caches bought nothing" in f for f in failures
    )


def test_zipfian_recall_floor_fails():
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["recall"] = 0.90
    failures = check_bench.check(cur, None)
    assert any("table2.zipfian" in f and "recall" in f for f in failures)


def test_zipfian_parity_break_fails():
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["parity_ok"] = False
    failures = check_bench.check(cur, None)
    assert any(
        "table2.zipfian" in f and "changed results" in f for f in failures
    )


def test_zipfian_zero_invalidations_fails():
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["invalidations"] = 0
    failures = check_bench.check(cur, None)
    assert any(
        "table2.zipfian" in f and "not reaching the caches" in f
        for f in failures
    )


def test_zipfian_stale_hits_fail():
    """Any stale answer after the refresh commit fails — and so does a
    bench that forgot to record the field at all (default -1)."""
    cur = _clean_doc()
    cur["rows"]["table2.zipfian"]["stale_hits"] = 2
    failures = check_bench.check(cur, None)
    assert any("table2.zipfian" in f and "stale" in f for f in failures)
    del cur["rows"]["table2.zipfian"]["stale_hits"]
    failures = check_bench.check(cur, None)
    assert any("table2.zipfian" in f and "stale" in f for f in failures)


def test_zipfian_never_wall_clock_gated():
    """The zipfian row rides the scheduler like every table2 row: its
    absolute qps dropping vs the baseline must not gate."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.zipfian"]["throughput_qps"] *= 0.2
    assert check_bench.check(cur, base) == []


@pytest.mark.parametrize(
    "doctor,expected_exit",
    [
        (lambda rows: None, 0),  # untouched => clean
        (lambda rows: rows["table2.filtered"].__setitem__("recall", 0.5), 1),
        (lambda rows: rows["table2.batched"].__setitem__("recall", 0.5), 1),
    ],
)
def test_cli_exit_codes(tmp_path, capsys, doctor, expected_exit):
    base = _clean_doc()
    cur = copy.deepcopy(base)
    doctor(cur["rows"])
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    rc = check_bench.main([str(cur_p), "--baseline", str(base_p)])
    out = capsys.readouterr().out
    assert rc == expected_exit
    if expected_exit:
        assert "BENCH-REGRESSION:" in out
    else:
        assert "OK" in out


def test_cli_unreadable_input(tmp_path):
    missing = tmp_path / "nope.json"
    assert check_bench.main([str(missing), "--baseline", ""]) == 2
    bad = tmp_path / "bad.json"
    bad.write_text("{not json")
    assert check_bench.main([str(bad), "--baseline", ""]) == 2


def test_cli_empty_or_rowless_input_is_an_error(tmp_path, capsys):
    """A bench that crashed before writing its record must FAIL the gate,
    not pass vacuously: an empty file and a row-less document are both
    invocation errors (exit 2), never exit 0."""
    empty = tmp_path / "empty.json"
    empty.write_text("")
    assert check_bench.main([str(empty), "--baseline", ""]) == 2
    assert "empty" in capsys.readouterr().err
    rowless = tmp_path / "rowless.json"
    rowless.write_text(json.dumps({"meta": {"bench": "x"}, "rows": {}}))
    assert check_bench.main([str(rowless), "--baseline", ""]) == 2
    assert "no benchmark rows" in capsys.readouterr().err


# ---------------------------------------------------------------------------
# heterogeneous-filter row gates
# ---------------------------------------------------------------------------


def test_hetero_absolute_gates():
    """The mask-plane acceptance gates: worse-or-equal dispatch count than
    the per-group path, speedup <= 1, parity breakage, and recall below the
    floor each fail without any baseline."""
    cur = _clean_doc()
    h = cur["rows"]["table2.filtered_hetero"]
    h["kernel_dispatches"] = 16  # == grouped: coalescing win lost
    h["speedup_vs_grouped"] = 0.8
    h["parity_ok"] = False
    h["recall"] = 0.90
    failures = check_bench.check(cur, None)
    assert any("no fewer kernel dispatches" in f for f in failures)
    assert any("not above the per-predicate-group path" in f for f in failures)
    assert any("diverge from the per-predicate-group" in f for f in failures)
    assert any("table2.filtered_hetero" in f and "recall vs oracle" in f for f in failures)


def test_hetero_clean_row_passes():
    doc = _clean_doc()
    assert check_bench.check(doc, copy.deepcopy(doc)) == []


def test_mixed_flavor_absolute_gates():
    """The unified-kernel acceptance gates: more than one dispatch per
    shard, no dispatch win over the split path, a sub-1 fragment speedup,
    broken parity, and recall below the floor each fail without any
    baseline."""
    cur = _clean_doc()
    m = cur["rows"]["table2.filtered_mixed_flavor"]
    m["kernel_dispatches"] = 4  # == split: two dispatches per shard again
    m["speedup_vs_split"] = 0.9
    m["parity_ok"] = False
    m["recall"] = 0.90
    failures = check_bench.check(cur, None)
    assert any("exactly one kernel dispatch per shard" in f for f in failures)
    assert any("no fewer" in f and "split-flavor" in f for f in failures)
    assert any("not faster than the two-dispatch split-flavor" in f for f in failures)
    assert any("diverge from the split-flavor" in f for f in failures)
    assert any(
        "table2.filtered_mixed_flavor" in f and "recall vs oracle" in f
        for f in failures
    )


def test_mixed_flavor_one_dispatch_gate_is_exact():
    """kernel_dispatches must EQUAL probe_fragments: even fewer dispatches
    than fragments (a shard silently skipped) fails the gate."""
    cur = _clean_doc()
    cur["rows"]["table2.filtered_mixed_flavor"]["kernel_dispatches"] = 1
    failures = check_bench.check(cur, None)
    assert any("exactly one kernel dispatch per shard" in f for f in failures)


def test_hetero_gates_on_speedup_ratio_not_wall_clock():
    """filtered_hetero spans two scheduler waves, so its wall clock is as
    load-sensitive as the batched row's: a throughput drop alone must NOT
    fail (it is not baseline-throughput-gated), but the same-window
    speedup_vs_grouped ratio falling to 1 must."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered_hetero"]["throughput_qps"] *= 0.4
    cur["rows"]["table2.filtered_hetero"]["grouped_qps"] *= 0.4  # same window
    assert check_bench.check(cur, base) == []
    cur["rows"]["table2.filtered_hetero"]["speedup_vs_grouped"] = 0.97
    failures = check_bench.check(cur, base)
    assert any(
        "table2.filtered_hetero" in f and "not above the per-predicate-group" in f
        for f in failures
    )


# ---------------------------------------------------------------------------
# low-selectivity big-shard row gates (the MaskedBeam traversal)
# ---------------------------------------------------------------------------


def test_bigshard_absolute_gates():
    """The MaskedBeam acceptance gates: losing the paired timing to the
    replayed postfilter plan, recall below the floor, and dispatches beyond
    one fused fallback per fragment each fail without any baseline."""
    cur = _clean_doc()
    b = cur["rows"]["table2.filtered_lowsel_bigshard"]
    b["speedup_vs_postfilter"] = 0.8
    b["recall"] = 0.90
    b["kernel_dispatches"] = 3  # > probe_fragments: traversal leaked dispatches
    failures = check_bench.check(cur, None)
    assert any("not above" in f and "postfilter" in f for f in failures)
    assert any(
        "table2.filtered_lowsel_bigshard" in f and "recall vs oracle" in f
        for f in failures
    )
    assert any("ONE fused fallback per fragment" in f for f in failures)


def test_bigshard_gate_requires_a_big_shard():
    """A run whose shard shrank below the masked-scan cap (or whose rows
    never took the traversal) gates nothing — it must fail rather than
    pass vacuously."""
    cur = _clean_doc()
    cur["rows"]["table2.filtered_lowsel_bigshard"]["shard_rows"] = 2000
    failures = check_bench.check(cur, None)
    assert any("not above the masked-scan cap" in f for f in failures)
    cur = _clean_doc()
    cur["rows"]["table2.filtered_lowsel_bigshard"]["masked_beam_rows"] = 2
    failures = check_bench.check(cur, None)
    assert any("took the MaskedBeam traversal" in f for f in failures)
    cur = _clean_doc()
    cur["rows"]["table2.filtered_lowsel_bigshard"]["plan_mbeam"] = False
    failures = check_bench.check(cur, None)
    assert any("took the MaskedBeam traversal" in f for f in failures)


def test_bigshard_gate_rejects_all_fallback_runs():
    """If EVERY traversal row under-delivered into the exact fallback, the
    paired timing compares the fallback with itself — fail loudly."""
    cur = _clean_doc()
    cur["rows"]["table2.filtered_lowsel_bigshard"]["masked_beam_fallbacks"] = 8
    failures = check_bench.check(cur, None)
    assert any("fallback path with itself" in f for f in failures)


def test_bigshard_is_not_wall_clock_gated_but_recall_is():
    """Like every table2 row: wall clock is informational, recall and the
    same-window ratio gate."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered_lowsel_bigshard"]["throughput_qps"] *= 0.3
    cur["rows"]["table2.filtered_lowsel_bigshard"]["postfilter_qps"] *= 0.3
    assert check_bench.check(cur, base) == []
    cur["rows"]["table2.filtered_lowsel_bigshard"]["recall"] = 0.97
    failures = check_bench.check(cur, base)
    assert any(
        "table2.filtered_lowsel_bigshard" in f and "recall" in f
        for f in failures
    )


def test_bigshard_cli_doctored_json(tmp_path):
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.filtered_lowsel_bigshard"]["speedup_vs_postfilter"] = 0.5
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    assert check_bench.main([str(cur_p), "--baseline", str(base_p)]) == 1


# ---------------------------------------------------------------------------
# freshness row gates (the fresh-tail tier's stale-read window)
# ---------------------------------------------------------------------------


def test_freshness_absolute_gates():
    """The stale-read acceptance gates: recall below the floor with a tail
    present, silently-dropped unindexed rows, and a plan that does not
    carry one op per tail row group each fail without any baseline."""
    cur = _clean_doc()
    f = cur["rows"]["table2.freshness"]
    f["recall"] = 0.48  # the pre-fix silent-drop recall
    f["unindexed_rows"] = 128
    f["tail_plan_ops"] = 0
    failures = check_bench.check(cur, None)
    assert any(
        "table2.freshness" in x and "recall vs the fresh scan oracle" in x
        for x in failures
    )
    assert any("silently dropped" in x for x in failures)
    assert any("one-ExactScan-per-tail-row-group" in x for x in failures)


def test_freshness_gate_requires_a_tail():
    """A freshness row measured with no unindexed tail present gates
    nothing — the run must fail rather than pass vacuously."""
    cur = _clean_doc()
    cur["rows"]["table2.freshness"]["tail_rows"] = 0
    failures = check_bench.check(cur, None)
    assert any("exercised nothing" in x for x in failures)
    cur = _clean_doc()
    cur["rows"]["table2.freshness"]["stale"] = False
    failures = check_bench.check(cur, None)
    assert any("exercised nothing" in x for x in failures)


def test_freshness_recall_drop_vs_baseline_fails():
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.freshness"]["recall"] = 0.97  # above floor, below base
    failures = check_bench.check(cur, base)
    assert any("table2.freshness" in x and "recall" in x for x in failures)


def test_freshness_cli_doctored_json(tmp_path):
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.freshness"]["unindexed_rows"] = 64
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    assert check_bench.main([str(cur_p), "--baseline", str(base_p)]) == 1


# ---------------------------------------------------------------------------
# kernel-bench file (multi-file gating)
# ---------------------------------------------------------------------------


def test_kernel_rows_are_throughput_gated():
    """Every kernel.* row is throughput-gated (prefix rule), with the same
    median-ratio machine-factor normalization — including the multi-mask
    rows."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["kernel.masked_exact_topk_multi"]["throughput_qps"] *= 0.5
    failures = check_bench.check(cur, base)
    assert len(failures) == 1
    assert "kernel.masked_exact_topk_multi" in failures[0]
    assert "machine factor" in failures[0]
    # a uniform slowdown (slower CI runner) is absorbed by the factor —
    # the anchor row slows down with everything else
    uniform = copy.deepcopy(base)
    for row in uniform["rows"].values():
        row["throughput_qps"] *= 0.3
    assert check_bench.check(uniform, base) == []


def test_anchor_row_pins_the_machine_factor():
    """A uniform regression of EVERY kernel row would read as a slower
    machine under an all-rows median — the pure-numpy anchor row (which no
    repo change can slow down) pins the factor, so it is caught."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    for name, row in cur["rows"].items():
        if name.startswith("kernel."):
            row["throughput_qps"] *= 0.3  # anchor stays at baseline speed
    failures = check_bench.check(cur, base)
    gated = [n for n in base["rows"] if n.startswith("kernel.")]
    assert len(failures) == len(gated)
    assert all("machine factor 1.00" in f for f in failures)


def test_kernel_rows_use_wider_noise_budget():
    """Eager-matmul timing floats ±20% on shared runners even after the
    interleaved best-of measurement, so kernel rows gate at
    KERNEL_MAX_REGRESS (35%) instead of the default 20%: a −30% drop
    passes, a −50% drop fails (see test_throughput_regression_fails)."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["kernel.rerank"]["throughput_qps"] *= 0.70
    assert check_bench.check(cur, base) == []


def test_cli_multiple_bench_files(tmp_path):
    """One invocation gates several bench records, each against its own
    baseline; a regression in ANY file fails the run."""
    qp_base, qp_cur = _clean_doc(), _clean_doc()
    k_base, k_cur = _kernels_doc(), _kernels_doc()
    k_cur["rows"]["kernel.masked_pq_topk_multi"]["throughput_qps"] *= 0.4
    paths = {}
    for name, doc in [
        ("qp_cur", qp_cur), ("qp_base", qp_base), ("k_cur", k_cur), ("k_base", k_base)
    ]:
        p = tmp_path / f"{name}.json"
        p.write_text(json.dumps(doc))
        paths[name] = str(p)
    rc = check_bench.main([
        paths["qp_cur"], paths["k_cur"],
        "--baseline", paths["qp_base"], "--baseline", paths["k_base"],
    ])
    assert rc == 1
    # clean kernels file: whole invocation passes
    pathlib.Path(paths["k_cur"]).write_text(json.dumps(_kernels_doc()))
    rc = check_bench.main([
        paths["qp_cur"], paths["k_cur"],
        "--baseline", paths["qp_base"], "--baseline", paths["k_base"],
    ])
    assert rc == 0


def test_cli_mismatched_baseline_count(tmp_path):
    p = tmp_path / "cur.json"
    p.write_text(json.dumps(_clean_doc()))
    rc = check_bench.main([str(p), str(p), "--baseline", ""])
    assert rc == 2


# ---------------------------------------------------------------------------
# overload row (multi-tenant serving tier)
# ---------------------------------------------------------------------------


def test_overload_absolute_gates():
    """The serving-tier acceptance gates: a well-behaved tenant starved
    below a 0.9 deadline hit-rate, rejections landing on the wrong tenant,
    and an unbounded queue each fail without any baseline."""
    cur = _clean_doc()
    o = cur["rows"]["table2.overload"]
    o["well_hit_rate"] = 0.6
    o["well_rejected"] = 200
    o["abusive_rejected"] = 5
    o["queue_bounded"] = False
    failures = check_bench.check(cur, None)
    assert any(
        "table2.overload" in x and "hit-rate" in x for x in failures
    )
    assert any("wrong tenant is paying" in x for x in failures)
    assert any("backpressure is not holding" in x for x in failures)


def test_overload_gate_requires_actual_overload():
    """An overload row measured UNDER capacity gates nothing — the run
    must fail rather than pass vacuously."""
    cur = _clean_doc()
    cur["rows"]["table2.overload"]["overload_factor"] = 1.1
    failures = check_bench.check(cur, None)
    assert any("did not actually overload" in x for x in failures)


def test_overload_clean_row_passes_and_is_not_wall_clock_gated():
    """A clean overload row passes, and its throughput is informational:
    the row rides the scheduler, so wall clock never gates it even
    against a much faster baseline."""
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.overload"]["throughput_qps"] = 5.0  # 10x slower
    assert check_bench.check(cur, base) == []


def test_overload_cli_doctored_json(tmp_path):
    base = _clean_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["table2.overload"]["well_hit_rate"] = 0.2
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    assert check_bench.main([str(cur_p), "--baseline", str(base_p)]) == 1


# ---------------------------------------------------------------------------
# gather-rerank / quantized-scan / unified-parity gates (the kernel hot path)
# ---------------------------------------------------------------------------


def test_gather_rerank_speedup_gate():
    """The device pool rerank replaced the executor's NumPy host rerank; if
    its same-window paired timing ever loses to that comparator, the
    replacement regressed and the gate must fail — with or without a
    baseline."""
    cur = _kernels_doc()
    cur["rows"]["kernel.gather_rerank"]["speedup_vs_host"] = 0.9
    failures = check_bench.check(cur, None)
    assert any(
        "kernel.gather_rerank" in f and "host rerank" in f for f in failures
    )


def test_host_comparator_row_is_not_throughput_gated():
    """host.gather_rerank exists only as the same-window comparator for the
    paired ratio; its absolute wall clock dropping must not gate (the ratio
    is what gates)."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["host.gather_rerank"]["throughput_qps"] *= 0.2
    assert check_bench.check(cur, base) == []


def test_unified_kernel_parity_gate():
    """The single-dispatch unified kernel must return bit-identical hits to
    the split exact+ADC dispatches — a dispatch-count win that changes
    results is a correctness bug, not an optimization."""
    cur = _kernels_doc()
    cur["rows"]["kernel.unified_masked_topk"]["parity_ok"] = False
    failures = check_bench.check(cur, None)
    assert any(
        "kernel.unified_masked_topk" in f and "changed results" in f
        for f in failures
    )


def test_quantized_post_guard_recall_gate():
    """Reduced-precision scanning is only admissible because the
    full-precision gather-rerank guard restores recall: post-guard recall
    below the floor fails for each quantized flavor independently — and so
    does a bench that forgot to record the field (default 0.0)."""
    cur = _kernels_doc()
    cur["rows"]["kernel.masked_exact_topk_bf16"]["recall_post_guard"] = 0.90
    del cur["rows"]["kernel.masked_exact_topk_int8"]["recall_post_guard"]
    failures = check_bench.check(cur, None)
    assert any(
        "kernel.masked_exact_topk_bf16" in f and "guard" in f for f in failures
    )
    assert any(
        "kernel.masked_exact_topk_int8" in f and "guard" in f for f in failures
    )


def test_quantized_raw_recall_is_informational():
    """recall_raw (before the guard) is expected to dip — that is the whole
    reason the guard exists — so it must never gate on its own."""
    cur = _kernels_doc()
    cur["rows"]["kernel.masked_exact_topk_int8"]["recall_raw"] = 0.50
    assert check_bench.check(cur, None) == []


def test_quantized_speed_gate_is_backend_conditional():
    """On a native backend (TPU) a quantized scan that fails to beat f32 is
    a regression; on CPU the honest path dequantizes to f32, so only the
    0.5x plumbing floor gates.  The same 0.9x ratio must fail natively and
    pass non-natively."""
    for name in check_bench.QUANT_ROWS:
        native = _kernels_doc()
        native["rows"][name]["quantized_native"] = True
        native["rows"][name]["speedup_vs_f32"] = 0.9
        failures = check_bench.check(native, None)
        assert any(name in f and "native quantized scan" in f for f in failures)
        native["rows"][name]["speedup_vs_f32"] = 1.3
        assert check_bench.check(native, None) == []
        nonnative = _kernels_doc()
        nonnative["rows"][name]["speedup_vs_f32"] = 0.9  # above 0.5 floor
        assert check_bench.check(nonnative, None) == []
        nonnative["rows"][name]["speedup_vs_f32"] = 0.3  # below it
        failures = check_bench.check(nonnative, None)
        assert any(name in f and "plumbing floor" in f for f in failures)


def test_new_kernel_rows_are_throughput_gated():
    """The gather/quantized rows are kernel.* rows like any other: a
    wall-clock drop past the kernel budget fails against the baseline even
    when every same-window ratio stays healthy."""
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["kernel.gather_rerank"]["throughput_qps"] *= 0.5
    failures = check_bench.check(cur, base)
    assert any(
        "kernel.gather_rerank" in f and "machine factor" in f for f in failures
    )


def test_gather_rerank_cli_doctored_json(tmp_path):
    base = _kernels_doc()
    cur = copy.deepcopy(base)
    cur["rows"]["kernel.gather_rerank"]["speedup_vs_host"] = 0.8
    cur_p, base_p = tmp_path / "cur.json", tmp_path / "base.json"
    cur_p.write_text(json.dumps(cur))
    base_p.write_text(json.dumps(base))
    assert check_bench.main([str(cur_p), "--baseline", str(base_p)]) == 1
