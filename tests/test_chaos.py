"""Chaos suite: executor failures mid-wave and between waves.

The acceptance bar for the serving tier's failover path: killing an executor
while it HOLDS fragments (heartbeat goes dark mid-wave) must lose zero
queries — every submitted query returns hits at exact parity with a healthy
run, because the scheduler's lease monitor observes the death and re-dispatches
the in-flight fragments to a surviving lease holder.

Run explicitly via ``scripts/ci.sh --chaos`` (``pytest -m chaos``); the cases
are also part of the default tier-1 run (they are not slow-marked).
"""

import numpy as np
import pytest

pytestmark = pytest.mark.chaos


def _locs(hits):
    return [(h.file_path, h.row_group, h.row_offset) for h in hits]


def _dists(hits):
    return np.array([h.distance for h in hits])


def _assert_parity(healthy_hits, chaos_hits):
    assert len(healthy_hits) == len(chaos_hits)
    for a, b in zip(healthy_hits, chaos_hits):
        assert _locs(a) == _locs(b)
        np.testing.assert_allclose(_dists(a), _dists(b), rtol=1e-5, atol=1e-3)


def test_kill_executor_mid_wave_loses_no_queries(built_cluster):
    """Heartbeat dies while fragments are in flight; nothing is lost.

    ``kill_next(hold_s=...)`` makes the executor accept a fragment, go
    heartbeat-dead while holding it, and then drop the result.  The
    scheduler's mid-wave monitor must expire its leases and re-dispatch the
    held fragment to a survivor — the batch completes at exact parity with a
    healthy run and the re-dispatch is visible in scheduler stats."""
    c, t, X, centers, rep = built_cluster
    Q = X[:8]

    healthy = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")
    base_redispatch = c.coordinator.scheduler.stats.redispatches

    doomed = c.executors[1]
    try:
        doomed.kill_next(1, hold_s=0.05)
        chaos = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")
    finally:
        doomed.revive()

    _assert_parity(healthy.hits, chaos.hits)
    assert len(chaos.hits) == len(Q)
    assert c.coordinator.scheduler.stats.redispatches > base_redispatch
    # the dead executor held (and lost) its only task: it served nothing
    assert chaos.served_by, "probe report must carry placement provenance"
    assert all(not e.endswith(f"@{doomed.executor_id}") for e in chaos.served_by)


def test_kill_executor_mid_wave_through_micro_batcher(built_cluster):
    """The full serving path (submit → batch → wave) survives a mid-wave kill."""
    from repro.serving.serve_loop import ProbeMicroBatcher

    c, t, X, centers, rep = built_cluster
    Q = X[64:70]
    healthy = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")

    doomed = c.executors[2]
    try:
        doomed.kill_next(1, hold_s=0.05)
        with ProbeMicroBatcher(
            c.coordinator, "emb", max_batch=16, max_wait_s=0.02
        ) as mb:
            got = mb.probe_many([q for q in Q], k=5)
    finally:
        doomed.revive()

    _assert_parity(healthy.hits, got)


def test_kill_executor_between_waves_loses_no_queries(built_cluster):
    """An executor dead BEFORE the wave starts is simply never scheduled."""
    c, t, X, centers, rep = built_cluster
    Q = X[128:136]

    healthy = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")

    doomed = c.executors[0]
    try:
        doomed.kill()
        chaos = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")
    finally:
        doomed.revive()

    _assert_parity(healthy.hits, chaos.hits)
    assert all(not e.endswith(f"@{doomed.executor_id}") for e in chaos.served_by)


@pytest.mark.cache
def test_kill_executor_mid_wave_with_warm_shard_cache(built_cluster):
    """Chaos × cache: a mid-wave kill with a warm shard-probe cache.

    The cache is warmed with a SUBSET of the batch (so the chaos probe
    still dispatches live fragments that the doomed executor can hold and
    lose).  The re-dispatched wave may consult the cache freely — results
    must stay at exact parity with a healthy cache-off run, and no cache
    entry written during the chaos wave may attribute ``served_by`` to the
    dead executor (its held fragments were lost, never answered)."""
    from repro.serving.cache import ShardProbeCache

    c, t, X, centers, rep = built_cluster
    Q = X[200:208]

    healthy = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")

    cache = ShardProbeCache(max_bytes=8 << 20)
    doomed = c.executors[1]
    try:
        c.coordinator.probe_cache = cache
        # warm phase (healthy): only the first half of the batch
        c.coordinator.probe_batch("emb", Q[:4], 5, strategy="diskann")
        warm_keys = {k for k, _ in cache.entries_snapshot()}
        assert warm_keys, "warm phase must populate the cache"

        doomed.kill_next(1, hold_s=0.05)
        chaos = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")
    finally:
        doomed.revive()
        c.coordinator.probe_cache = None

    _assert_parity(healthy.hits, chaos.hits)
    # the warmed half was served from cache, the rest re-dispatched live
    assert chaos.shard_cache_hits > 0
    assert chaos.cache == "shard"
    assert all(not e.endswith(f"@{doomed.executor_id}") for e in chaos.served_by)
    # entries ADDED by the chaos wave came from the re-dispatch survivors,
    # never from the executor that died holding its fragment
    for key, entry in cache.entries_snapshot():
        if key not in warm_keys:
            assert entry.served_by != doomed.executor_id
