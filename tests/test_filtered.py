"""Filtered vector search: attribute predicates through the probe path.

Contracts under test:

- **oracle parity** — a filtered ``probe`` / ``probe_batch`` returns exactly
  the brute-force scan + post-filter oracle's top-k, across selectivities
  ~0.9 (over-fetched post-filter plan), ~0.3 (filter-aware masked beam) and
  ~0.01 (pre-filter exact scan);
- **zone-map pruning** — on an attribute-correlated layout, a
  high-selectivity predicate prunes whole shards before dispatch
  (``ProbeReport.shards_pruned`` / fewer ``probe_fragments``);
- **coalescing** — per-query predicates survive fragment coalescing, so
  filtered and unfiltered queries share one batch;
- **SQL + serving** — the WHERE grammar and the micro-batcher route the
  same predicates end to end.
"""

import numpy as np
import pytest

from repro.runtime.frontend import SqlFrontend, SqlError
from repro.runtime.predicates import (
    And,
    Eq,
    In,
    Or,
    PredicateError,
    Range,
    ZoneStats,
    parse_predicate,
)
from repro.serving.serve_loop import ProbeMicroBatcher


def _locs(hits):
    return [(h.file_path, h.row_group, h.row_offset) for h in hits]


# ---------------------------------------------------------------------------
# predicate IR unit tests (no cluster)
# ---------------------------------------------------------------------------


def test_parse_predicate_shapes():
    p = parse_predicate("category = 'news' AND price < 10 OR price >= 90")
    assert isinstance(p, Or)
    assert isinstance(p.children[0], And)
    assert p.children[0].children[0] == Eq("category", "news")
    assert p.children[0].children[1] == Range("price", hi=10, hi_inclusive=False)
    assert p.children[1] == Range("price", lo=90)
    assert parse_predicate("x IN (1, 2, 3)") == In("x", (1, 2, 3))
    assert parse_predicate("x BETWEEN 5 AND 9") == Range("x", lo=5, hi=9)
    assert parse_predicate("(a = 1 OR b = 2) AND c = 3").children[0] == Or(
        (Eq("a", 1), Eq("b", 2))
    )
    # equal texts parse to equal (and hashable) trees — coalescing groups rely on it
    assert hash(parse_predicate("a = 'x' AND b < 3")) == hash(
        parse_predicate("a = 'x' AND b < 3")
    )


def test_parse_predicate_rejects():
    for bad in ["", "price <", "price != 3", "category = ", "x BETWEEN 'a' AND 'b'",
                "price < 'cheap'", "x IN ()", "(a = 1"]:
        with pytest.raises(PredicateError):
            parse_predicate(bad)


def test_predicate_evaluate_and_dictionary():
    cat_codes = np.array([0, 1, 2, 1, 0], np.int32)
    price = np.array([5, 50, 95, 20, 70], np.int64)
    cols = {"category": cat_codes, "price": price}
    dicts = {"category": ["books", "games", "news"]}
    np.testing.assert_array_equal(
        Eq("category", "games").evaluate(cols, dicts), [False, True, False, True, False]
    )
    # value absent from the file's dictionary matches nothing
    assert not Eq("category", "zzz").evaluate(cols, dicts).any()
    np.testing.assert_array_equal(
        And((In("category", ("books", "news")), Range("price", hi=70))).evaluate(
            cols, dicts
        ),
        [True, False, False, False, True],
    )


def test_type_mismatch_is_conservative():
    """A string literal against a numeric column matches nothing (and never
    crashes the coordinator); numeric zones reject it outright."""
    price = {"price": np.array([1, 2, 3], np.int64)}
    assert not Eq("price", "cheap").evaluate(price).any()
    assert not In("price", ("a", "b")).evaluate(price).any()
    zones = {"price": ZoneStats(count=3, min=1, max=3)}
    assert Eq("price", "cheap").zone_may_match(zones) is False
    assert Eq("price", "cheap").estimate_fraction(zones) == 0.0
    # range over a string/dictionary column: matches nothing, prunes cleanly
    tags = {"tag": np.asarray(["a", "b", "c"])}
    assert not Range("tag", hi=5).evaluate(tags).any()
    dict_zones = {"tag": ZoneStats(count=3, values={"a": 1, "b": 2})}
    assert Range("tag", hi=5).zone_may_match(dict_zones) is False
    assert Range("tag", hi=5).estimate_fraction(dict_zones) == 0.0


def test_zone_pruning_logic():
    zones = {
        "price": ZoneStats(count=100, min=10, max=20),
        "category": ZoneStats(count=100, values={"a": 60, "b": 40}),
    }
    assert Range("price", hi=9, hi_inclusive=False).zone_may_match(zones) is False
    assert Range("price", hi=10).zone_may_match(zones) is True
    assert Range("price", lo=21).zone_may_match(zones) is False
    assert Eq("category", "c").zone_may_match(zones) is False
    assert Eq("category", "a").zone_may_match(zones) is True
    assert And((Eq("category", "a"), Range("price", lo=25))).zone_may_match(zones) is False
    assert Or((Eq("category", "c"), Eq("category", "b"))).zone_may_match(zones) is True
    # selectivity estimates: dict columns are exact, ranges interpolate
    assert Eq("category", "a").estimate_fraction(zones) == pytest.approx(0.6)
    assert Range("price", lo=10, hi=15).estimate_fraction(zones) == pytest.approx(0.5)
    # unknown column: conservatively matches
    assert Eq("other", 1).zone_may_match(zones) is True


# ---------------------------------------------------------------------------
# cluster fixtures
# ---------------------------------------------------------------------------

DIM = 16


@pytest.fixture(scope="module")
def filtered_cluster(tmp_path_factory):
    """Mildly-clustered corpus (connected shard graphs → beam paths are
    effectively exhaustive at generous L) with uncorrelated attributes —
    the oracle-parity fixture.  ``price`` is uniform on [0, 100) so WHERE
    fragments dial selectivity directly."""
    from repro.lakehouse.table import LakehouseTable
    from repro.runtime.cluster import make_local_cluster
    from repro.runtime.coordinator import IndexConfig

    rng = np.random.default_rng(0)
    c = make_local_cluster(str(tmp_path_factory.mktemp("filtered")), num_executors=3)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    centers = rng.normal(size=(8, DIM))  # scale 1: clusters overlap
    X = np.concatenate(
        [ctr + rng.normal(size=(120, DIM)) for ctr in centers]
    ).astype(np.float32)
    category = np.asarray([f"c{i}" for i in rng.integers(0, 8, size=len(X))])
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(
        X, num_files=4, rows_per_group=80,
        attributes={"category": category, "price": price},
    )
    rep = c.coordinator.create_index(
        "emb",
        IndexConfig(name="idx", R=24, L=48, partitions_per_shard=2, build_passes=2),
    )
    return c, t, X, category, price, rep


@pytest.fixture(scope="module")
def zoned_cluster(tmp_path_factory):
    """Strongly-clustered corpus written in cluster order with the category
    following the cluster — attribute-homogeneous row groups, so zone maps
    can prune whole shards."""
    from repro.lakehouse.table import LakehouseTable
    from repro.runtime.cluster import make_local_cluster
    from repro.runtime.coordinator import IndexConfig

    rng = np.random.default_rng(1)
    c = make_local_cluster(str(tmp_path_factory.mktemp("zoned")), num_executors=3)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    centers = rng.normal(size=(12, DIM)) * 4.0
    X = np.concatenate(
        [ctr + rng.normal(size=(100, DIM)) for ctr in centers]
    ).astype(np.float32)
    category = np.repeat([f"c{i}" for i in range(12)], 100)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(
        X, num_files=6, rows_per_group=100,
        attributes={"category": category, "price": price},
    )
    rep = c.coordinator.create_index(
        "emb",
        IndexConfig(name="idx", R=16, L=48, partitions_per_shard=3, build_passes=1),
    )
    return c, t, X, category, price, rep


def _queries(X, n, seed):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n)] + 0.05 * rng.normal(size=(n, DIM)).astype(
        np.float32
    )


# ---------------------------------------------------------------------------
# oracle parity across selectivities (the acceptance contract)
# ---------------------------------------------------------------------------

SELECTIVITY_CASES = [
    # (WHERE fragment, ~selectivity, plan the planner must choose)
    ("price < 90", 0.9, "postfilter"),
    ("price BETWEEN 20 AND 50", 0.3, "mask"),
    ("price < 1", 0.01, "prefilter"),
]


@pytest.mark.parametrize("where,sel,plan", SELECTIVITY_CASES)
def test_filtered_probe_matches_oracle(filtered_cluster, where, sel, plan):
    c, t, X, category, price, rep = filtered_cluster
    Q = _queries(X, 4, seed=7)
    oracle = c.coordinator.probe("emb", Q, 10, strategy="scan", filter=where)
    got = c.coordinator.probe("emb", Q, 10, strategy="diskann", filter=where, L=256)
    assert got.filtered and oracle.filtered
    assert plan in got.filter_plan
    assert got.est_selectivity == pytest.approx(sel, abs=0.12)
    for a, b in zip(oracle.hits, got.hits):
        assert _locs(a) == _locs(b)


@pytest.mark.parametrize("where,sel,plan", SELECTIVITY_CASES)
def test_filtered_probe_batch_matches_oracle(filtered_cluster, where, sel, plan):
    c, t, X, category, price, rep = filtered_cluster
    Q = _queries(X, 4, seed=11)
    oracle = c.coordinator.probe("emb", Q, 10, strategy="scan", filter=where)
    got = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=where, L=256)
    assert got.batch_size == len(Q) and got.filtered
    assert plan in got.filter_plan
    for a, b in zip(oracle.hits, got.hits):
        assert _locs(a) == _locs(b)


def test_filtered_scan_and_centroid_paths(filtered_cluster):
    """The coordinator-tier paths apply predicates in their masks: scan is
    the oracle itself; a full-fanout centroid probe must agree with it."""
    c, t, X, category, price, rep = filtered_cluster
    Q = _queries(X, 3, seed=13)
    where = "category IN ('c1', 'c2') AND price < 60"
    oracle = c.coordinator.probe("emb", Q, 8, strategy="scan", filter=where)
    cent = c.coordinator.probe("emb", Q, 8, strategy="centroid", n_probe=10**9, filter=where)
    for a, b in zip(oracle.hits, cent.hits):
        assert _locs(a) == _locs(b)
    # every returned row satisfies the predicate (cross-checked on raw data)
    attrs = t.scan_attributes()
    vecs_all, locs_all = t.scan_vectors()
    by_loc = {
        (l.file_path, l.row_group_id, l.row_offset): i for i, l in enumerate(locs_all)
    }
    for hits in cent.hits:
        for h in hits:
            i = by_loc[(h.file_path, h.row_group, h.row_offset)]
            assert attrs["category"][i] in ("c1", "c2") and attrs["price"][i] < 60


def test_filter_with_no_matches(filtered_cluster):
    c, t, X, category, price, rep = filtered_cluster
    got = c.coordinator.probe("emb", X[0], 5, filter="price > 1000")
    assert got.hits[0] == []
    gotb = c.coordinator.probe_batch("emb", X[:3], 5, filter="category = 'nope'")
    assert all(h == [] for h in gotb.hits)


def test_mixed_filtered_unfiltered_batch(filtered_cluster):
    """Per-query predicates survive fragment coalescing: a batch mixing
    filtered and unfiltered queries returns exactly what per-query probes
    return, while still coalescing to ≤ one fragment per shard."""
    c, t, X, category, price, rep = filtered_cluster
    Q = _queries(X, 5, seed=17)
    filters = [None, "price < 40", None, "category = 'c3'", "price < 40"]
    stats = c.coordinator.scheduler.stats
    offered0 = stats.probe_fragments_offered
    br = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann", filter=filters, L=256)
    offered = stats.probe_fragments_offered - offered0
    assert br.probe_fragments <= rep.num_shards  # coalescing still collapses
    assert offered == len(Q) * rep.num_shards
    seq = [
        c.coordinator.probe("emb", Q[i], 5, strategy="diskann", filter=filters[i], L=256).hits[0]
        for i in range(len(Q))
    ]
    for a, b in zip(seq, br.hits):
        assert _locs(a) == _locs(b)


def test_mixed_filter_centroid_batch_matches_sequential(filtered_cluster):
    """Regression: heterogeneous-filter batches on the CENTROID path must
    keep per-query file ownership — with a small n_probe, a query's hits
    may not include rows from files only another group member routed to."""
    c, t, X, category, price, rep = filtered_cluster
    Q = _queries(X, 4, seed=23)
    filters = ["price < 50", "price < 50", "price >= 50", None]
    br = c.coordinator.probe_batch(
        "emb", Q, 5, strategy="centroid", n_probe=2, filter=filters
    )
    seq = [
        c.coordinator.probe(
            "emb", Q[i], 5, strategy="centroid", n_probe=2, filter=filters[i]
        ).hits[0]
        for i in range(len(Q))
    ]
    for a, b in zip(seq, br.hits):
        assert _locs(a) == _locs(b)


def test_filtered_probe_on_mixed_schema_appends(filtered_cluster):
    """Regression: files appended WITHOUT an attribute column must not
    crash filtered probes — they simply contribute no matches on that
    column, identically on the oracle and index paths.  scan_attributes
    keeps row alignment by filling the gap."""
    from repro.lakehouse.table import LakehouseTable
    from repro.runtime.cluster import make_local_cluster
    from repro.runtime.coordinator import IndexConfig

    import tempfile

    rng = np.random.default_rng(31)
    c = make_local_cluster(tempfile.mkdtemp(), num_executors=2)
    t = LakehouseTable(c.catalog, "mix")
    t.create(dim=8)
    X1 = rng.normal(size=(200, 8)).astype(np.float32)
    t.append_vectors(X1, num_files=2, rows_per_group=64,
                     attributes={"price": rng.integers(0, 100, 200).astype(np.int64)})
    X2 = rng.normal(size=(100, 8)).astype(np.float32)
    t.append_vectors(X2, num_files=1, rows_per_group=64)  # no attributes
    c.coordinator.create_index(
        "mix", IndexConfig(name="i", R=12, L=24, partitions_per_shard=2, build_passes=1)
    )
    oracle = c.coordinator.probe("mix", X1[0], 5, strategy="scan", filter="price < 50")
    got = c.coordinator.probe("mix", X1[0], 5, strategy="diskann", filter="price < 50", L=128)
    assert _locs(got.hits[0]) == _locs(oracle.hits[0])
    # a predicate over a non-scalar column (the vector itself) matches
    # nothing — identically on both paths, instead of crashing executors
    assert c.coordinator.probe("mix", X1[0], 5, filter="vec = 1").hits[0] == []
    assert c.coordinator.probe("mix", X1[0], 5, strategy="scan", filter="vec = 1").hits[0] == []
    assert all("data-00002" not in h.file_path for h in got.hits[0])
    attrs = t.scan_attributes()
    _, locs_all = t.scan_vectors()
    assert len(attrs["price"]) == len(locs_all) == 300  # alignment held
    assert all(v is None for v in attrs["price"][-100:])  # gap filled, not dropped
    # object fill preserves exact int64 values (no float promotion)
    assert attrs["price"].dtype == object
    assert all(isinstance(v, (int, np.integer)) for v in attrs["price"][:200])


# ---------------------------------------------------------------------------
# zone-map pruning
# ---------------------------------------------------------------------------


def test_zonemap_prunes_shards(zoned_cluster):
    """High-selectivity predicate on the cluster-correlated attribute: the
    zone map must drop whole shards before dispatch, and the surviving
    plan must still return exactly the oracle's rows."""
    c, t, X, category, price, rep = zoned_cluster
    Q = _queries(X, 4, seed=3)
    where = "category = 'c5' AND price < 40"
    unfiltered = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann")
    got = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=where)
    assert got.filtered
    assert got.shards_pruned >= 1
    # per-(query, shard) fragments dropped before coalescing: every query
    # skips each zone-pruned shard
    assert got.fragments_pruned == got.shards_pruned * len(Q)
    assert got.probe_fragments < unfiltered.probe_fragments
    oracle = c.coordinator.probe("emb", Q, 10, strategy="scan", filter=where)
    for a, b in zip(oracle.hits, got.hits):
        assert _locs(a) == _locs(b)
    # single-query path prunes identically
    single = c.coordinator.probe("emb", Q[0], 10, strategy="diskann", filter=where)
    assert single.shards_pruned == got.shards_pruned
    assert _locs(single.hits[0]) == _locs(oracle.hits[0])


def test_zonemap_row_group_pruning_on_centroid_path(zoned_cluster):
    c, t, X, category, price, rep = zoned_cluster
    Q = _queries(X, 2, seed=5)
    where = "category = 'c2'"
    got = c.coordinator.probe(
        "emb", Q, 5, strategy="centroid", n_probe=10**9, filter=where
    )
    assert got.row_groups_pruned > 0  # zones skipped before any attribute read
    oracle = c.coordinator.probe("emb", Q, 5, strategy="scan", filter=where)
    for a, b in zip(oracle.hits, got.hits):
        assert _locs(a) == _locs(b)


def test_filtered_survives_refresh(tmp_path):
    """Append + REFRESH rebuilds the zone map against the new snapshot
    (reusing prior zones for unchanged files, scanning only the appended
    ones): filtered probes over the refreshed index still match the oracle
    and cover the new rows.  Own cluster — this test mutates the table."""
    from repro.core.blobs import ATTR_ZONEMAP_BLOB_TYPE
    from repro.lakehouse.table import LakehouseTable
    from repro.runtime.cluster import make_local_cluster
    from repro.runtime.coordinator import IndexConfig

    rng = np.random.default_rng(9)
    c = make_local_cluster(str(tmp_path), num_executors=2)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    centers = rng.normal(size=(6, DIM)) * 4.0
    X = np.concatenate(
        [ctr + rng.normal(size=(80, DIM)) for ctr in centers]
    ).astype(np.float32)
    t.append_vectors(
        X, num_files=3, rows_per_group=80,
        attributes={
            "category": np.repeat([f"c{i}" for i in range(6)], 80),
            "price": rng.integers(0, 100, size=len(X)).astype(np.int64),
        },
    )
    c.coordinator.create_index(
        "emb", IndexConfig(name="idx", R=16, L=32, partitions_per_shard=2, build_passes=1)
    )
    X_new = (X[:80] + 0.02 * rng.normal(size=(80, DIM))).astype(np.float32)
    t.append_vectors(
        X_new, num_files=1, rows_per_group=80,
        attributes={
            "category": np.asarray(["c_new"] * 80),
            "price": rng.integers(0, 100, size=80).astype(np.int64),
        },
    )
    rr = c.coordinator.refresh_index("emb", "idx")
    assert rr.inserted == 80
    meta, snap, path, reader = c.coordinator._resolve_index("emb")
    assert reader.blobs_of_type(ATTR_ZONEMAP_BLOB_TYPE)
    # the rebuilt map covers the appended file's category
    zm = c.coordinator._read_zonemap(reader, path)
    assert any(
        "c_new" in z.get("category").values
        for per_file in zm.zones.values()
        for z in per_file
        if z.get("category") is not None and z["category"].values
    )
    where = "category = 'c_new'"
    oracle = c.coordinator.probe("emb", X_new[0], 5, strategy="scan", filter=where)
    got = c.coordinator.probe("emb", X_new[0], 5, strategy="diskann", filter=where)
    assert _locs(got.hits[0]) == _locs(oracle.hits[0])
    assert len(got.hits[0]) == 5


# ---------------------------------------------------------------------------
# mask-aware kernel path (PR 3)
# ---------------------------------------------------------------------------


def test_mask_plan_calls_masked_kernels(filtered_cluster, monkeypatch):
    """The acceptance contract of the kernel path: a ``mask``-plan filtered
    probe goes through ops.masked_* (bitmask into the kernel) — no widened
    beam pool, no post-hoc NumPy filter.  The beam search must not run at
    all for that plan."""
    from repro.core.vamana import VamanaGraph
    from repro.kernels import ops as kops

    c, t, X, category, price, rep = filtered_cluster
    calls = {"masked": 0, "beam": 0}
    real = kops.masked_exact_topk

    def spy(*a, **kw):
        calls["masked"] += 1
        return real(*a, **kw)

    def no_beam(self, *a, **kw):
        calls["beam"] += 1
        raise AssertionError("beam search ran on a mask-plan filtered probe")

    monkeypatch.setattr(kops, "masked_exact_topk", spy)
    monkeypatch.setattr(VamanaGraph, "search", no_beam)
    monkeypatch.setattr(VamanaGraph, "search_pq", no_beam)
    Q = _queries(X, 3, seed=29)
    got = c.coordinator.probe(
        "emb", Q, 10, strategy="diskann", filter="price BETWEEN 20 AND 50"
    )
    assert "mask" in got.filter_plan or "prefilter" in got.filter_plan
    assert calls["masked"] >= 1 and calls["beam"] == 0
    oracle = c.coordinator.probe(
        "emb", Q, 10, strategy="scan", filter="price BETWEEN 20 AND 50"
    )
    for a, b in zip(oracle.hits, got.hits):
        assert _locs(a) == _locs(b)


@pytest.mark.parametrize("batched", [False, True])
def test_filtered_fewer_matches_than_k(filtered_cluster, batched):
    """match_count < k_eff: a predicate passing only a handful of rows must
    return exactly those rows (every one of them, ranked), not k — on the
    single-query and batched paths alike."""
    c, t, X, category, price, rep = filtered_cluster
    where = "price < 2"  # ~2% of ~960 rows => typically < 20 matches
    n_pass = int((price < 2).sum())
    assert 0 < n_pass < 25  # fixture sanity: genuinely fewer than k_eff
    Q = _queries(X, 3, seed=41)
    k = n_pass + 10  # ask for more than can exist
    oracle = c.coordinator.probe("emb", Q, k, strategy="scan", filter=where)
    if batched:
        got = c.coordinator.probe_batch("emb", Q, k, strategy="diskann", filter=where)
    else:
        got = c.coordinator.probe("emb", Q, k, strategy="diskann", filter=where)
    for a, b in zip(oracle.hits, got.hits):
        assert len(b) == n_pass  # all passing rows surfaced, nothing padded
        assert _locs(a) == _locs(b)


def test_exact_masked_short_delivery_backends():
    """Executor._exact_masked on a shard whose passing rows < k_eff: both
    kernel backends return exactly k_eff columns with (+inf, -1) sentinels
    past the passing count — batched and single-query."""
    import jax.numpy as jnp

    from repro.core.vamana import VamanaParams, build_vamana
    from repro.kernels import ops as kops

    rng = np.random.default_rng(2)
    X = rng.normal(size=(120, 8)).astype(np.float32)
    graph = build_vamana(X, VamanaParams(R=8, L=16), passes=1)
    live = np.zeros(graph.n, bool)
    live[[3, 50, 101]] = True
    for backend in ("pallas", "ref"):
        for Q in (X[:1], X[:5]):  # single-query and batched
            d, ids = kops.masked_exact_topk(
                jnp.asarray(Q), jnp.asarray(graph.vectors[: graph.n]),
                jnp.asarray(live), 10, backend=backend,
            )
            d, ids = np.asarray(d), np.asarray(ids)
            assert d.shape == (len(Q), 10)
            assert (ids[:, :3] >= 0).all() and (ids[:, 3:] == -1).all()
            assert np.isinf(d[:, 3:]).all()
            assert set(ids[:, :3].ravel()) <= {3, 50, 101}


def test_mask_cache_invalidated_on_refresh(tmp_path):
    """Regression (PR 3 bugfix): build → filtered probe → append+refresh →
    same filtered probe.  The refresh mutates the shard graph/locmap that
    the executor's L1 cache holds and changes the row set, so pre-refresh
    (shard, predicate) bitmasks must not survive — and a time-travel probe
    of the PRE-refresh snapshot must re-decode the pristine old blob rather
    than serve the mutated graph."""
    from repro.lakehouse.table import LakehouseTable
    from repro.runtime.cluster import make_local_cluster
    from repro.runtime.coordinator import IndexConfig

    rng = np.random.default_rng(77)
    c = make_local_cluster(str(tmp_path), num_executors=1)  # one executor => caches MUST be reused
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    X = rng.normal(size=(300, DIM)).astype(np.float32)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(X, num_files=2, rows_per_group=80, attributes={"price": price})
    c.coordinator.create_index(
        "emb", IndexConfig(name="idx", R=12, L=32, partitions_per_shard=2, build_passes=1)
    )
    where = "price < 30"
    old_snap = c.catalog.load_table("emb").current_snapshot().snapshot_id
    first = c.coordinator.probe("emb", X[0], 8, strategy="diskann", filter=where, L=128)
    oracle0 = c.coordinator.probe("emb", X[0], 8, strategy="scan", filter=where)
    assert _locs(first.hits[0]) == _locs(oracle0.hits[0])
    assert any(len(ex._mask_cache) for ex in c.executors)  # masks were cached
    # append rows matching the same predicate, then refresh
    X_new = (X[:60] + 0.01 * rng.normal(size=(60, DIM))).astype(np.float32)
    t.append_vectors(
        X_new, num_files=1, rows_per_group=80,
        attributes={"price": np.full(60, 5, np.int64)},  # all pass price < 30
    )
    rr = c.coordinator.refresh_index("emb", "idx")
    assert rr.inserted == 60
    # same filtered probe: must see the refreshed row set (oracle includes
    # the appended rows, which dominate — they duplicate existing vectors)
    oracle = c.coordinator.probe("emb", X[0], 8, strategy="scan", filter=where)
    got = c.coordinator.probe("emb", X[0], 8, strategy="diskann", filter=where, L=128)
    assert _locs(got.hits[0]) == _locs(oracle.hits[0])
    assert any("data-00002" in fp for fp, _, _ in _locs(got.hits[0]))  # new rows served
    # time-travel to the pre-refresh snapshot: the old shard blobs must be
    # re-decoded (not the refresh-mutated L1 objects), masks recomputed
    back = c.coordinator.probe(
        "emb", X[0], 8, strategy="diskann", filter=where, snapshot_id=old_snap, L=128
    )
    assert _locs(back.hits[0]) == _locs(oracle0.hits[0])


# ---------------------------------------------------------------------------
# SQL frontend + serving
# ---------------------------------------------------------------------------


def test_frontend_where_grammar(filtered_cluster):
    c, t, X, category, price, rep = filtered_cluster
    fe = SqlFrontend(c.coordinator)
    q = ",".join(str(float(v)) for v in X[3])
    hits = fe.execute(
        f"SELECT * FROM emb WHERE category = 'c1' AND price < 50 "
        f"ORDER BY L2_DISTANCE(vec, [{q}]) LIMIT 5"
    )
    oracle = c.coordinator.probe(
        "emb", X[3], 5, strategy="scan", filter="category = 'c1' AND price < 50"
    )
    assert _locs(hits) == _locs(oracle.hits[0])
    # unfiltered grammar unchanged; threshold grammar not shadowed by WHERE
    assert len(fe.execute(f"SELECT * FROM emb ORDER BY L2_DISTANCE(vec, [{q}]) LIMIT 5")) == 5
    with pytest.raises(SqlError):
        fe.execute(f"SELECT * FROM emb WHERE bogus ~ 3 ORDER BY L2_DISTANCE(vec, [{q}]) LIMIT 5")


def test_frontend_execute_many_mixed_filters(filtered_cluster):
    c, t, X, category, price, rep = filtered_cluster
    fe = SqlFrontend(c.coordinator)
    qs = [",".join(str(float(v)) for v in X[i]) for i in range(4)]
    sqls = [
        f"SELECT * FROM emb ORDER BY L2_DISTANCE(vec, [{qs[0]}]) LIMIT 5",
        f"SELECT * FROM emb WHERE price < 30 ORDER BY L2_DISTANCE(vec, [{qs[1]}]) LIMIT 5",
        f"SELECT * FROM emb WHERE category = 'c2' ORDER BY L2_DISTANCE(vec, [{qs[2]}]) LIMIT 5",
        f"SELECT * FROM emb ORDER BY L2_DISTANCE(vec, [{qs[3]}]) LIMIT 5",
    ]
    stats = c.coordinator.scheduler.stats
    d0 = stats.dispatched
    batched = fe.execute_many(sqls)
    frags_batched = stats.dispatched - d0
    single = [fe.execute(s) for s in sqls]
    for a, b in zip(single, batched):
        assert _locs(a) == _locs(b)
    frags_single = stats.dispatched - d0 - frags_batched
    assert frags_batched < frags_single  # one coalesced wave for the block


def test_micro_batcher_filtered_and_unfiltered_together(filtered_cluster):
    c, t, X, category, price, rep = filtered_cluster
    with ProbeMicroBatcher(c.coordinator, "emb", max_batch=8, max_wait_s=0.1) as mb:
        futs = [
            mb.submit(X[0], k=5),
            mb.submit(X[1], k=5, filter="price < 30"),
            mb.submit(X[2], k=5, filter="category = 'c1'"),
        ]
        got = [f.result() for f in futs]
    assert mb.stats.filtered_queries == 2
    assert mb.stats.batches <= 2  # they shared batch probes
    expect = [
        c.coordinator.probe("emb", X[0], 5).hits[0],
        c.coordinator.probe("emb", X[1], 5, filter="price < 30").hits[0],
        c.coordinator.probe("emb", X[2], 5, filter="category = 'c1'").hits[0],
    ]
    for a, b in zip(expect, got):
        assert _locs(a) == _locs(b)
