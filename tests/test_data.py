"""Data pipeline: determinism, host sharding, lakehouse-backed tokens."""

import numpy as np

from repro.data.pipeline import SyntheticTokens, TokenTableReader, write_token_table


def test_synthetic_deterministic_across_restarts():
    a = SyntheticTokens(vocab_size=1000, seq_len=16, batch_size=4, seed=1)
    b = SyntheticTokens(vocab_size=1000, seq_len=16, batch_size=4, seed=1)
    ids1, lab1 = a.batch(7)
    ids2, lab2 = b.batch(7)
    np.testing.assert_array_equal(ids1, ids2)
    np.testing.assert_array_equal(ids1[:, 1:], lab1[:, :-1])  # next-token shift


def test_synthetic_host_sharding_disjoint():
    h0 = SyntheticTokens(vocab_size=1000, seq_len=8, batch_size=2, seed=1, host_id=0, num_hosts=2)
    h1 = SyntheticTokens(vocab_size=1000, seq_len=8, batch_size=2, seed=1, host_id=1, num_hosts=2)
    ids0, _ = h0.batch(0)
    ids1, _ = h1.batch(0)
    assert not np.array_equal(ids0, ids1)


def test_synthetic_vocab_bound():
    d = SyntheticTokens(vocab_size=64, seq_len=32, batch_size=8, seed=2)
    ids, labels = d.batch(0)
    assert ids.min() >= 0 and ids.max() < 64


def test_codebook_stream_shape():
    d = SyntheticTokens(vocab_size=100, seq_len=8, batch_size=2, num_codebooks=4, seed=0)
    ids, labels = d.batch(0)
    assert ids.shape == (2, 8, 4) and labels.shape == (2, 8, 4)


def test_token_table_roundtrip(tmp_store):
    rng = np.random.default_rng(0)
    tokens = rng.integers(0, 5000, size=10_000).astype(np.int32)
    write_token_table(tmp_store, "tok/a.vpq", tokens, rows_per_group=2048)
    reader = TokenTableReader(tmp_store, ["tok/a.vpq"], seq_len=16, batch_size=4)
    batches = list(reader)
    assert len(batches) == 10_000 // (4 * 17)
    ids, labels = batches[0]
    np.testing.assert_array_equal(ids[:, 1:], labels[:, :-1])  # per-row shift
    flat = np.c_[ids, labels[:, -1:]].reshape(-1)
    np.testing.assert_array_equal(flat, tokens[: 4 * 17])
