"""Serving tier: admission control, deadlines, degradation, leases, metrics.

Unit tests run against injected clocks (no sleeps); micro-batcher behavior
tests run against a stub coordinator so they exercise the serving envelope
(admission → queue → deadline-aware drain → degradation → delivery) without
building an index.
"""

import queue as queue_mod
import threading
import time
from concurrent.futures import Future

import numpy as np
import pytest

from repro.serving.admission import (
    AdmissionController,
    AdmissionRejected,
    DeadlineExceeded,
    DegradationPolicy,
    DropOversample,
    ProbeParams,
    ShrinkK,
    SkipTail,
    TenantPolicy,
    TokenBucket,
)
from repro.serving.leases import LeaseTable
from repro.serving.metrics import MetricsRegistry
from repro.serving.serve_loop import ProbeMicroBatcher


class _FakeClock:
    def __init__(self, t: float = 0.0) -> None:
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


# -- token bucket / admission ---------------------------------------------

def test_token_bucket_burst_and_refill():
    clock = _FakeClock()
    bucket = TokenBucket(rate=10.0, burst=2.0, clock=clock)
    assert bucket.try_acquire()
    assert bucket.try_acquire()
    assert not bucket.try_acquire()  # burst exhausted, no time passed
    clock.advance(0.1)  # one token refills at 10/s
    assert bucket.try_acquire()
    assert not bucket.try_acquire()
    clock.advance(10.0)  # refill caps at burst, not rate*dt
    assert bucket.try_acquire()
    assert bucket.try_acquire()
    assert not bucket.try_acquire()


def test_admission_controller_per_tenant_budgets():
    clock = _FakeClock()
    metrics = MetricsRegistry()
    ctl = AdmissionController(
        {"abuser": TenantPolicy(rate_qps=1.0, burst=2.0)},
        clock=clock,
        metrics=metrics,
    )
    # unknown tenants fall back to the default policy (unlimited)
    assert all(ctl.admit("trusted") for _ in range(50))
    # the configured tenant burns its own bucket
    decisions = [ctl.admit("abuser") for _ in range(5)]
    assert decisions == [True, True, False, False, False]
    assert metrics.counter_value("admissions", "abuser") == 2
    assert metrics.counter_value("admission_rejected", "abuser") == 3
    assert metrics.counter_value("admission_rejected", "trusted") == 0
    clock.advance(1.0)
    assert ctl.admit("abuser")  # budget recovers at rate_qps


# -- degradation ladder ----------------------------------------------------

def test_degradation_ladder_arms_by_pressure():
    policy = DegradationPolicy()
    assert policy.plan(0.0) == ()
    assert [type(s) for s in policy.plan(0.6)] == [ShrinkK]
    assert [type(s) for s in policy.plan(0.8)] == [ShrinkK, DropOversample]
    assert [type(s) for s in policy.plan(1.0)] == [ShrinkK, DropOversample, SkipTail]


def test_degradation_apply_transforms_params_and_labels():
    policy = DegradationPolicy()
    params = ProbeParams(k=10)

    out, labels = policy.apply(params, 0.0)
    assert out == params and labels == ()

    out, labels = policy.apply(params, 1.0)
    assert out.k == 5
    assert out.oversample == 1
    assert out.include_tail is False
    assert labels == ("shrink_k(x0.5)", "drop_oversample(to=1)", "skip_tail")


def test_degradation_noop_steps_leave_no_label():
    # k already at the floor: ShrinkK changes nothing and must not claim to
    policy = DegradationPolicy(steps=(ShrinkK(min_k=1),))
    out, labels = policy.apply(ProbeParams(k=1), 1.0)
    assert out.k == 1 and labels == ()


# -- lease table -----------------------------------------------------------

def test_lease_table_grants_replicas_and_expires():
    clock = _FakeClock()
    lt = LeaseTable(ttl=1.0, replicas=2, clock=clock)
    lease = lt.ensure("s1", ["a", "b", "c"])
    assert len(lease.holders) == 2
    primary = lease.holders[0]
    assert lt.valid_holders("s1") == lease.holders

    # renewal extends only the renewed holder
    clock.advance(0.6)
    lt.renew(primary)
    clock.advance(0.6)  # the other holder's lease (t=0 + 1.0) has lapsed
    valid = lt.valid_holders("s1")
    assert valid == [primary]

    # ensure tops back up to replicas, aging out the lapsed holder
    lease = lt.ensure("s1", ["a", "b", "c"])
    assert len(lease.holders) == 2
    assert primary in lease.holders
    assert lt.metrics.counter_value("lease_expiries") >= 1


def test_lease_table_expire_holder_is_immediate():
    clock = _FakeClock()
    lt = LeaseTable(ttl=100.0, replicas=2, clock=clock)
    lease = lt.ensure("s1", ["a", "b"])
    dead = lease.holders[0]
    assert lt.expire_holder(dead) == 1
    assert dead not in lt.valid_holders("s1")
    assert lt.holder_load(dead) == 0
    # re-ensure replaces the dead holder without advancing the clock
    lease = lt.ensure("s1", ["a", "b"])
    assert len(lease.holders) == 2 and dead in lease.holders


def test_lease_table_hot_shard_gains_extra_holder():
    clock = _FakeClock()
    lt = LeaseTable(ttl=100.0, replicas=2, hot_dispatches=10, clock=clock)
    for _ in range(10):
        lease = lt.ensure("hot", ["a", "b", "c", "d"])
    assert len(lease.holders) == 2  # not hot yet (dispatches == threshold)
    lease = lt.ensure("hot", ["a", "b", "c", "d"])
    assert len(lease.holders) == 3  # crossed hot_dispatches: +1 replica
    snap = lt.snapshot()["hot"]
    assert snap["dispatches"] == 11 and len(snap["valid"]) == 3


def test_lease_table_spreads_load_least_leased_first():
    clock = _FakeClock()
    lt = LeaseTable(ttl=100.0, replicas=1, clock=clock)
    holders = [lt.ensure(f"s{i}", ["a", "b", "c"]).holders[0] for i in range(6)]
    # 6 single-replica shards over 3 candidates: perfectly balanced
    assert sorted(holders.count(e) for e in "abc") == [2, 2, 2]


# -- metrics ---------------------------------------------------------------

def test_metrics_histogram_percentiles_and_snapshot():
    reg = MetricsRegistry()
    h = reg.histogram("latency_ms", "tenant-a", window=100)
    for v in range(1, 101):  # 1..100
        h.observe(float(v))
    assert h.percentile(50) == pytest.approx(50.0, abs=1.0)
    assert h.percentile(99) == pytest.approx(99.0, abs=1.0)
    assert h.count == 100
    reg.counter("served", "tenant-a").inc(7)
    snap = reg.snapshot()
    assert snap["served[tenant-a]"] == 7.0
    assert snap["latency_ms[tenant-a].count"] == 100.0
    assert snap["latency_ms[tenant-a].p99"] >= snap["latency_ms[tenant-a].p50"]


def test_metrics_histogram_window_slides():
    h = MetricsRegistry().histogram("x", window=10)
    for v in range(1000):
        h.observe(float(v))
    # percentiles reflect the recent window, lifetime count keeps the total
    assert h.percentile(50) >= 990.0
    assert h.count == 1000


# -- micro-batcher behavior (stub coordinator) -----------------------------

class _StubReport:
    def __init__(self, n):
        self.hits = [[("hit", i)] for i in range(n)]
        self.kernel_dispatches = 1
        self.tail_rows = 0
        self.degraded = ()


class _StubCoordinator:
    """Records probe_batch calls; optionally blocks on a gate or sleeps."""

    def __init__(self, *, service_s=0.0, gate=None, tail_rows=0, compact_exc=None):
        self.calls = []
        self.reports = []
        self.compact_calls = []
        self.service_s = service_s
        self.gate = gate
        self.tail_rows = tail_rows
        self.compact_exc = compact_exc
        self.entered = threading.Event()

    def probe_batch(self, table, queries, k, **kw):
        self.entered.set()
        if self.gate is not None:
            self.gate.wait(timeout=30.0)
        if self.service_s:
            time.sleep(self.service_s)
        self.calls.append((table, np.asarray(queries).shape, k, dict(kw)))
        rep = _StubReport(len(queries))
        rep.tail_rows = self.tail_rows
        self.reports.append(rep)
        return rep

    def compact_tail(self, table, index_name, threshold_rows):
        self.compact_calls.append((table, index_name, threshold_rows))
        if self.compact_exc is not None:
            raise self.compact_exc


def test_submit_admission_rejected_creates_no_future():
    coord = _StubCoordinator()
    with ProbeMicroBatcher(
        coord,
        "t",
        max_batch=8,
        max_wait_s=0.001,
        tenant_policies={"abuser": TenantPolicy(rate_qps=0.001, burst=2.0)},
    ) as mb:
        q = np.zeros(4, np.float32)
        f1 = mb.submit(q, k=3, tenant="abuser")
        f2 = mb.submit(q, k=3, tenant="abuser")
        with pytest.raises(AdmissionRejected):
            mb.submit(q, k=3, tenant="abuser")
        # trusted traffic is untouched by the abuser's empty bucket
        f3 = mb.submit(q, k=3, tenant="trusted")
        assert f1.result(timeout=5) and f2.result(timeout=5) and f3.result(timeout=5)
    assert mb.stats.admission_rejected == 1
    assert mb.metrics.counter_value("admission_rejected", "abuser") == 1
    assert mb.metrics.counter_value("served", "trusted") == 1


def test_deadline_expired_in_queue_never_dispatched():
    gate = threading.Event()
    coord = _StubCoordinator(gate=gate)
    with ProbeMicroBatcher(coord, "t", max_batch=1, max_wait_s=0.0) as mb:
        q = np.zeros(4, np.float32)
        f_slow = mb.submit(q, k=3)  # drained; blocks inside probe_batch
        assert coord.entered.wait(timeout=5)
        f_doomed = mb.submit(q, k=3, deadline_ms=20)
        time.sleep(0.08)  # deadline passes while still queued
        gate.set()
        assert f_slow.result(timeout=5)
        with pytest.raises(DeadlineExceeded):
            f_doomed.result(timeout=5)
    assert mb.stats.deadline_misses == 1
    assert len(coord.calls) == 1  # the expired query was never dispatched
    assert mb.metrics.counter_value("deadline_misses", "default") == 1


def test_late_completion_refused_not_served_late():
    coord = _StubCoordinator(service_s=0.08)
    with ProbeMicroBatcher(coord, "t", max_batch=4, max_wait_s=0.0) as mb:
        fut = mb.submit(np.zeros(4, np.float32), k=3, deadline_ms=20)
        with pytest.raises(DeadlineExceeded):
            fut.result(timeout=5)
    # it WAS dispatched (alive at drain time) but the result came back late
    assert len(coord.calls) == 1
    assert mb.stats.deadline_misses == 1
    assert mb.metrics.counter_value("served", "default") == 0


def test_deadline_ordering_earliest_first():
    gate = threading.Event()
    coord = _StubCoordinator(gate=gate)
    with ProbeMicroBatcher(coord, "t", max_batch=8, max_wait_s=0.05) as mb:
        q = np.zeros(4, np.float32)
        blocker = mb.submit(q, k=3)  # occupies the drainer
        assert coord.entered.wait(timeout=5)
        loose = mb.submit(q, k=3, deadline_ms=10_000)
        tight = mb.submit(q, k=3, deadline_ms=1_000)
        free = mb.submit(q, k=3)
        gate.set()
        for f in (blocker, loose, tight, free):
            f.result(timeout=5)
    # second batch flushed earliest-deadline-first, deadline-free last
    assert coord.calls[1][1][0] == 3  # the three queued queries batched
    assert len(coord.calls) == 2


def test_degradation_force_on_shrinks_and_labels():
    coord = _StubCoordinator()
    with ProbeMicroBatcher(
        coord, "t", max_batch=4, max_wait_s=0.0, force_degrade="on"
    ) as mb:
        fut = mb.submit(np.zeros(4, np.float32), k=10)
        assert fut.result(timeout=5)
    (table, shape, k, kwargs) = coord.calls[0]
    assert k == 5  # ShrinkK halved the requested k
    assert kwargs["oversample"] == 1  # DropOversample
    assert kwargs["include_tail"] is False  # SkipTail
    assert coord.reports[0].degraded == (
        "shrink_k(x0.5)",
        "drop_oversample(to=1)",
        "skip_tail",
    )
    assert mb.stats.degraded_batches == 1
    assert mb.stats.degraded_queries == 1
    assert mb.metrics.counter_value("degraded:skip_tail") == 1


def test_force_degrade_off_is_bit_for_bit_legacy():
    """With force_degrade='off' an attached policy changes NOTHING about the
    coordinator call — same k, same kwargs as a policy-free batcher."""
    q = np.arange(4, dtype=np.float32)
    legacy = _StubCoordinator()
    with ProbeMicroBatcher(legacy, "t", max_batch=4, max_wait_s=0.0) as mb:
        for _ in range(3):
            mb.submit(q, k=7).result(timeout=5)

    armed = _StubCoordinator()
    with ProbeMicroBatcher(
        armed,
        "t",
        max_batch=4,
        max_wait_s=0.0,
        degradation=DegradationPolicy(),
        force_degrade="off",
        max_queue=2,  # pressure exists; "off" must still ignore it
    ) as mb2:
        for _ in range(3):
            mb2.submit(q, k=7).result(timeout=5)

    assert armed.calls == legacy.calls
    assert mb2.stats.degraded_batches == 0


def test_force_degrade_validation():
    with pytest.raises(ValueError):
        ProbeMicroBatcher(_StubCoordinator(), "t", force_degrade="sometimes")


# -- satellite: exact rejection accounting under concurrent submit ---------

def test_concurrent_submit_full_queue_exact_accounting():
    """≥8 threads hammer a max_queue=4 batcher while the drainer is wedged:
    exactly 4 submissions fit, every other attempt raises queue.Full, and
    stats.rejected equals the refusals exactly — no lost or double counts."""
    gate = threading.Event()
    coord = _StubCoordinator(gate=gate)
    mb = ProbeMicroBatcher(
        coord, "t", max_batch=1, max_wait_s=0.0, max_queue=4
    ).start()
    try:
        q = np.zeros(4, np.float32)
        wedge = mb.submit(q, k=3)  # drained immediately; blocks in probe_batch
        assert coord.entered.wait(timeout=5)

        n_threads, per_thread = 8, 6
        start = threading.Barrier(n_threads)
        futures, fulls = [], []
        lock = threading.Lock()

        def hammer():
            start.wait()
            for _ in range(per_thread):
                try:
                    f = mb.submit(q, k=3)
                    with lock:
                        futures.append(f)
                except queue_mod.Full:
                    with lock:
                        fulls.append(1)

        threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(timeout=10)

        attempts = n_threads * per_thread
        assert len(futures) == 4  # exactly the queue capacity
        assert len(fulls) == attempts - 4
        assert mb.stats.rejected == len(fulls)

        gate.set()  # unwedge: every accepted submission must still be served
        assert wedge.result(timeout=5)
        for f in futures:
            assert f.result(timeout=5)
        assert mb.stats.queries == 1 + len(futures)
    finally:
        gate.set()
        mb.stop()


# -- satellite: background compaction failures are recorded ----------------

def test_background_compaction_error_recorded_not_swallowed():
    coord = _StubCoordinator(
        tail_rows=64, compact_exc=RuntimeError("disk full (injected)")
    )
    with ProbeMicroBatcher(
        coord, "t", max_batch=4, max_wait_s=0.0, compact_tail_over=32, index_name="idx"
    ) as mb:
        assert mb.submit(np.zeros(4, np.float32), k=3).result(timeout=5)
        # wait out the doomed background compaction, then prove serving is fine
        deadline = time.monotonic() + 10
        while time.monotonic() < deadline:
            t = mb._compact_thread
            if t is not None and t.is_alive():
                t.join(timeout=10)
            if mb.stats.compaction_errors:
                break
            time.sleep(0.005)
        coord.tail_rows = 0  # disarm the trigger for the follow-up probe
        assert mb.submit(np.zeros(4, np.float32), k=3).result(timeout=5)
    assert coord.compact_calls == [("t", "idx", 32)]
    assert mb.stats.compactions == 1
    assert mb.stats.compaction_errors == 1
    assert mb.stats.last_compaction_error == "RuntimeError: disk full (injected)"
    assert mb.metrics.counter_value("compaction_errors") == 1


# -- overload: a well-behaved tenant survives an abusive one ---------------

def test_overload_two_tenants_well_behaved_protected():
    """Offered load ≫ capacity from an abusive tenant: admission control
    makes the abuser absorb the rejections while the well-behaved tenant's
    deadline hit-rate stays ≥ 0.9 and the queue stays bounded."""
    coord = _StubCoordinator(service_s=0.01)
    with ProbeMicroBatcher(
        coord,
        "t",
        max_batch=8,
        max_wait_s=0.002,
        max_queue=32,
        tenant_policies={"abuser": TenantPolicy(rate_qps=50.0, burst=4.0)},
    ) as mb:
        q = np.zeros(4, np.float32)
        abusive_outcomes = {"admitted": 0, "rejected": 0}

        def flood():
            for _ in range(200):
                try:
                    mb.submit(q, k=5, tenant="abuser", deadline_ms=2000)
                    abusive_outcomes["admitted"] += 1
                except (AdmissionRejected, queue_mod.Full):
                    abusive_outcomes["rejected"] += 1

        flooder = threading.Thread(target=flood)
        flooder.start()
        well_futs = []
        for _ in range(30):
            well_futs.append(mb.submit(q, k=5, tenant="well", deadline_ms=2000))
            time.sleep(0.005)
        flooder.join(timeout=10)

        well_ok = 0
        for f in well_futs:
            try:
                f.result(timeout=10)
                well_ok += 1
            except Exception:
                pass

    hit_rate = well_ok / len(well_futs)
    assert hit_rate >= 0.9, f"well-behaved hit rate {hit_rate:.2f}"
    # the abuser absorbed the rejections, not the well-behaved tenant
    assert abusive_outcomes["rejected"] > 100
    assert mb.stats.admission_rejected == abusive_outcomes["rejected"] or (
        mb.stats.admission_rejected > 100  # queue.Full counted separately
    )
    assert mb.metrics.counter_value("admission_rejected", "well") == 0
    # bounded queue: nothing ever sat beyond max_queue
    assert mb._queue.qsize() <= 32
