"""Probe-plan IR (runtime/planner.py): the acceptance contract of PR 5.

- plan ops are golden-serializable and round-trip through ``ProbeReport``;
- planner.resolve is the ONLY flavor classifier (executor.py is grep-clean
  of selectivity thresholds);
- a coalesced fragment mixing exact and PQ flavors with heterogeneous
  predicates completes in exactly ONE kernel dispatch per shard, with hits
  bit-identical to the ``force_group_loop`` path AND the two-dispatch
  ``force_split_flavors`` path;
- an unfiltered query riding a MIXED fragment gets a shared Beam op (or a
  size-capped ExactScan below EXACT_SCAN_MAX_ROWS) — never an uncapped
  O(N·D) all-ones row.
"""

import json
import pathlib

import numpy as np
import pytest

from repro.lakehouse.table import LakehouseTable
from repro.runtime import planner
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig
from repro.runtime.planner import (
    Beam,
    ExactScan,
    MaskedBeam,
    PlanOp,
    PostfilterBeam,
    ProbePlan,
    PQScan,
    Skip,
    op_from_json,
)

DIM = 16


def _locs(hits):
    return [(h.file_path, h.row_group, h.row_offset) for h in hits]


def _locs_d(hits):
    return [(h.file_path, h.row_group, h.row_offset, h.distance) for h in hits]


# ---------------------------------------------------------------------------
# op selection + resolution (pure unit tests)
# ---------------------------------------------------------------------------


def test_band_op_golden():
    """The three selectivity bands map to their ops, with evidence and
    pool sizes attached."""
    assert planner.band_op(0.05, k=10, oversample=4, use_pq=True) == ExactScan(
        k=40, est_frac=0.05
    )
    assert planner.band_op(0.5, k=10, oversample=4, use_pq=True) == PQScan(
        pool=160, k=40, est_frac=0.5
    )
    # mask band without PQ codes: the exact kernel scan
    assert planner.band_op(0.5, k=10, oversample=4, use_pq=False) == ExactScan(
        k=40, est_frac=0.5
    )
    op = planner.band_op(0.9, k=10, oversample=4, use_pq=True)
    assert isinstance(op, PostfilterBeam)
    # postfilter band: 1/0.9 < MIN_OVERFETCH, so the 2x floor applies
    assert op.pool == 80 and op.k == 40 and op.est_frac == pytest.approx(0.9)


def test_band_op_big_shard_routes_to_masked_beam():
    """Above EXACT_SCAN_MAX_ROWS every masked linear scan is an O(N·D) hole:
    both the prefilter and mask bands route to MaskedBeam, widened by
    ~1/est_frac and clamped at MASKED_BEAM_MAX_WIDEN."""
    big = planner.EXACT_SCAN_MAX_ROWS + 1
    assert planner.band_op(0.5, k=10, oversample=4, use_pq=True, shard_rows=big) == (
        MaskedBeam(width=80, k=40, est_frac=0.5)
    )
    # prefilter-band fraction on a big shard: still the traversal, width
    # clamped at 4x even though 1/0.05 = 20x
    assert planner.band_op(0.05, k=10, oversample=4, use_pq=True, shard_rows=big) == (
        MaskedBeam(width=160, k=40, est_frac=0.05)
    )
    # above MASK_MAX_FRAC the over-fetched postfilter beam stays cheaper
    assert isinstance(
        planner.band_op(0.9, k=10, oversample=4, use_pq=True, shard_rows=big),
        PostfilterBeam,
    )
    # AT the cap (not above) the scan bands still apply
    assert planner.band_op(
        0.5, k=10, oversample=4, use_pq=True,
        shard_rows=planner.EXACT_SCAN_MAX_ROWS,
    ) == PQScan(pool=160, k=40, est_frac=0.5)
    # no shard-size evidence (default_filtered_op path): never MaskedBeam
    assert planner.band_op(0.5, k=10, oversample=4, use_pq=True) == PQScan(
        pool=160, k=40, est_frac=0.5
    )


def test_masked_beam_width_clamps():
    k_eff = 40
    assert planner.masked_beam_width(10, 4, 1.0) == k_eff  # no widening
    assert planner.masked_beam_width(10, 4, 0.5) == 2 * k_eff
    assert planner.masked_beam_width(10, 4, 0.25) == 4 * k_eff
    assert planner.masked_beam_width(10, 4, 0.01) == 4 * k_eff  # ceiling
    assert planner.masked_beam_width(10, 4, 0.0) == 4 * k_eff  # no div-zero


def test_postfilter_pool_clamps():
    k_eff = 40
    # band-planned shards only reach PostfilterBeam above MASK_MAX_FRAC,
    # so the 2x floor is their operative size; the sub-floor fractions
    # below exercise the sizing for hand-authored/replayed plans
    assert planner.postfilter_pool(10, 4, 1.0) == 2 * k_eff  # floor
    assert planner.postfilter_pool(10, 4, 0.8) == 2 * k_eff  # still floor
    assert planner.postfilter_pool(10, 4, 0.3) == int(round(k_eff / 0.3))
    assert planner.postfilter_pool(10, 4, 0.01) == 4 * k_eff  # ceiling


def test_resolve_zero_and_small_matches():
    op = planner.band_op(0.5, k=10, oversample=4, use_pq=True)
    assert planner.resolve(
        op, match_count=0, k=10, oversample=4, has_pq=True
    ) == Skip(reason="no-match")
    # small passing set: exact scan whatever the band, k_eff = match
    small = planner.resolve(op, match_count=30, k=10, oversample=4, has_pq=True)
    assert small == ExactScan(k=30, est_frac=0.5)
    post = planner.band_op(0.9, k=10, oversample=4, use_pq=True)
    assert isinstance(
        planner.resolve(post, match_count=100, k=10, oversample=4, has_pq=True),
        ExactScan,
    )  # 100 <= max(4*40, 64)


def test_resolve_pins_pq_pool_and_degrades_without_codes():
    op = planner.band_op(0.5, k=10, oversample=4, use_pq=True)
    big = planner.resolve(op, match_count=500, k=10, oversample=4, has_pq=True)
    assert big == PQScan(pool=160, k=40, est_frac=0.5)
    # every not-small match count resolves to the SAME pool (the parity pin)
    bigger = planner.resolve(op, match_count=5000, k=10, oversample=4, has_pq=True)
    assert bigger.pool == big.pool == 160
    no_pq = planner.resolve(op, match_count=500, k=10, oversample=4, has_pq=False)
    assert no_pq == ExactScan(k=40, est_frac=0.5)


def test_resolve_masked_beam():
    big = planner.EXACT_SCAN_MAX_ROWS + 1
    op = planner.band_op(0.1, k=10, oversample=4, use_pq=False, shard_rows=big)
    assert op == MaskedBeam(width=160, k=40, est_frac=0.1)
    # zero and small passing sets collapse before the traversal branch
    assert planner.resolve(
        op, match_count=0, k=10, oversample=4, has_pq=False
    ) == Skip(reason="no-match")
    assert planner.resolve(
        op, match_count=100, k=10, oversample=4, has_pq=False
    ) == ExactScan(k=40, est_frac=0.1)
    # a not-small passing set keeps the traversal with its planned width —
    # and k pinned at k_eff, the fused-fallback parity requirement
    kept = planner.resolve(op, match_count=500, k=10, oversample=4, has_pq=False)
    assert kept == MaskedBeam(width=160, k=40, est_frac=0.1)
    # hand-authored/replayed widths cap at the actual match count (never
    # below k_eff): admitting more than the passing set is meaningless
    hand = MaskedBeam(width=1000, k=40, est_frac=0.1)
    assert planner.resolve(
        hand, match_count=300, k=10, oversample=4, has_pq=False
    ) == MaskedBeam(width=300, k=40, est_frac=0.1)
    assert planner.resolve(
        MaskedBeam(width=10, k=40, est_frac=0.1),
        match_count=500, k=10, oversample=4, has_pq=False,
    ).width == 40  # floor: at least k_eff


def test_resolve_passes_beam_and_skip_through():
    assert planner.resolve(
        Beam(width=40), match_count=0, k=10, oversample=4, has_pq=True
    ) == Beam(width=40)
    assert planner.resolve(
        Skip(), match_count=7, k=10, oversample=4, has_pq=True
    ) == Skip()


def test_plan_unfiltered_caps_the_all_ones_scan():
    """The PR-4 regression fix: an unfiltered query on a MIXED fragment is
    an all-ones kernel row only below EXACT_SCAN_MAX_ROWS; past the cap it
    routes to a shared beam, and unmixed fragments always beam."""
    small = planner.plan_unfiltered(1000, mixed=True, k=10, oversample=4)
    assert small == ExactScan(k=40, est_frac=1.0)
    big = planner.plan_unfiltered(
        planner.EXACT_SCAN_MAX_ROWS + 1, mixed=True, k=10, oversample=4
    )
    assert big == Beam(width=40)
    assert planner.plan_unfiltered(100, mixed=False, k=10, oversample=4) == Beam(
        width=40
    )


# ---------------------------------------------------------------------------
# serialization: golden op JSON + ProbePlan round-trip
# ---------------------------------------------------------------------------

GOLDEN_OPS = [
    (Skip(reason="zone-pruned"), {"op": "Skip", "reason": "zone-pruned"}),
    (Beam(width=40), {"op": "Beam", "width": 40}),
    (
        ExactScan(k=40, est_frac=0.05),
        {"op": "ExactScan", "k": 40, "est_frac": 0.05, "dtype": "f32"},
    ),
    (
        ExactScan(k=40, est_frac=0.05, dtype="int8"),
        {"op": "ExactScan", "k": 40, "est_frac": 0.05, "dtype": "int8"},
    ),
    (
        PQScan(pool=160, k=40, est_frac=0.5),
        {"op": "PQScan", "pool": 160, "k": 40, "est_frac": 0.5},
    ),
    (
        PostfilterBeam(pool=80, k=40, est_frac=0.9),
        {"op": "PostfilterBeam", "pool": 80, "k": 40, "est_frac": 0.9},
    ),
    (
        MaskedBeam(width=160, k=40, est_frac=0.1),
        {"op": "MaskedBeam", "width": 160, "k": 40, "est_frac": 0.1},
    ),
]


@pytest.mark.parametrize("op,golden", GOLDEN_OPS, ids=lambda x: type(x).__name__)
def test_golden_op_serialization(op, golden):
    if not isinstance(op, PlanOp):
        pytest.skip("golden literal side of the pair")
    assert op.to_json() == golden
    assert op_from_json(golden) == op
    # through an actual JSON string, as a log line would carry it
    assert op_from_json(json.loads(json.dumps(op.to_json()))) == op


def test_exact_scan_json_back_compat():
    """Plans serialized before the ``dtype`` field existed deserialize to
    the f32 default — replay of old captured plans keeps working."""
    old = {"op": "ExactScan", "k": 40, "est_frac": 0.05}
    assert op_from_json(old) == ExactScan(k=40, est_frac=0.05, dtype="f32")


def test_probe_plan_round_trip():
    plan = ProbePlan(
        k=10,
        oversample=4,
        use_pq=True,
        ops=[
            {0: ExactScan(k=40, est_frac=0.05), 1: Skip()},
            {0: PQScan(pool=160, k=40, est_frac=0.5), 1: Beam(width=40)},
        ],
        est_selectivity=0.275,
        pruned_shards=(1,),
    )
    back = ProbePlan.from_json(json.loads(json.dumps(plan.to_json())))
    assert back == plan
    assert back.op_for(1, 0) == PQScan(pool=160, k=40, est_frac=0.5)
    assert back.kernel_eligible(1, 0) and not back.kernel_eligible(1, 1)
    assert "prefilter" in plan.summary() and "pruned" in plan.summary()


def test_executor_is_grep_clean_of_thresholds():
    """Acceptance: runtime/planner.py is the only module that chooses plan
    ops — executor.py must carry no selectivity thresholds or flavor
    classification of its own."""
    src = (
        pathlib.Path(__file__).resolve().parents[1]
        / "src" / "repro" / "runtime" / "executor.py"
    ).read_text()
    for needle in ("MAX_FRAC", "_plan_flavor", "def _pq_pool", "max(4 *", "max(4*"):
        assert needle not in src, f"threshold logic leaked into executor.py: {needle}"


# ---------------------------------------------------------------------------
# plans as report artifacts (integration, PQ index for mixed flavors)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def mixed_cluster(tmp_path_factory):
    """PQ index whose shards are large enough that a mid-selectivity mask
    plan takes the ADC flavor while a tight predicate stays exact — the
    mixed-flavor fragment the unified kernel collapses to one dispatch."""
    rng = np.random.default_rng(2)
    c = make_local_cluster(str(tmp_path_factory.mktemp("planner")), num_executors=2)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    centers = rng.normal(size=(6, DIM))
    X = np.concatenate(
        [ctr + rng.normal(size=(220, DIM)) for ctr in centers]
    ).astype(np.float32)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(
        X, num_files=4, rows_per_group=110, attributes={"price": price}
    )
    rep = c.coordinator.create_index(
        "emb",
        IndexConfig(
            name="idx", R=16, L=48, pq_m=8, pq_nbits=8,
            partitions_per_shard=2, build_passes=1,
        ),
    )
    return c, t, X, price, rep


# alternating tight (exact flavor) and wide (ADC flavor) predicates — all
# distinct: est selectivities ~0.02-0.05 and ~0.55-0.70
MIXED_FILTERS = [
    f"price < {2 + i // 2}" if i % 2 == 0 else f"price < {55 + 5 * (i // 2)}"
    for i in range(8)
]


def _set_flag(c, name, flag):
    for ex in c.executors:
        setattr(ex, name, flag)


def _reset_dispatches(c):
    for ex in c.executors:
        ex.masked_kernel_dispatches = 0


def test_mixed_flavor_fragment_is_one_dispatch_per_shard(mixed_cluster):
    """THE tentpole acceptance: exact-flavor and PQ-flavor queries with
    heterogeneous predicates in one coalesced fragment cost exactly ONE
    kernel dispatch per shard (the unified kernel), with hits bit-identical
    to the force_group_loop path and to the two-dispatch split-flavor
    path."""
    c, t, X, price, rep = mixed_cluster
    rng = np.random.default_rng(4)
    Q = X[rng.choice(len(X), 8)] + 0.05 * rng.normal(size=(8, DIM)).astype(np.float32)
    assert len(set(MIXED_FILTERS)) == 8
    # warm masks + jit
    c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=MIXED_FILTERS)

    _reset_dispatches(c)
    br = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="diskann", filter=MIXED_FILTERS
    )
    # the plan grid must genuinely mix flavors on at least one shard
    flavors = {
        type(br.plan.op_for(qi, sid)).__name__
        for qi in range(8)
        for sid in br.plan.ops[qi]
    }
    assert {"ExactScan", "PQScan"} <= flavors, br.plan.to_json()
    assert br.probe_fragments >= 1
    assert br.kernel_dispatches == br.probe_fragments  # ONE dispatch per shard
    assert sum(ex.masked_kernel_dispatches for ex in c.executors) == br.kernel_dispatches

    # two-dispatch split-flavor path: same hits, one dispatch per flavor
    _set_flag(c, "force_split_flavors", True)
    try:
        _reset_dispatches(c)
        bs = c.coordinator.probe_batch(
            "emb", Q, 10, strategy="diskann", filter=MIXED_FILTERS
        )
    finally:
        _set_flag(c, "force_split_flavors", False)
    assert bs.kernel_dispatches == 2 * bs.probe_fragments
    for a, b in zip(br.hits, bs.hits):
        assert _locs_d(a) == _locs_d(b)

    # legacy per-predicate-group loop: one dispatch per distinct predicate
    _set_flag(c, "force_group_loop", True)
    try:
        _reset_dispatches(c)
        bg = c.coordinator.probe_batch(
            "emb", Q, 10, strategy="diskann", filter=MIXED_FILTERS
        )
    finally:
        _set_flag(c, "force_group_loop", False)
    assert bg.kernel_dispatches == len(MIXED_FILTERS) * bg.probe_fragments
    for a, b in zip(br.hits, bg.hits):
        assert _locs_d(a) == _locs_d(b)  # bit-identical, distances included

    # and exact parity vs the brute-force oracle (every plan is exact or
    # ADC + full-precision rerank over >= 4*k_eff pools at this scale)
    oracle = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="scan", filter=MIXED_FILTERS
    )
    recall = np.mean([
        len(set(_locs(a)) & set(_locs(b))) / max(len(_locs(a)), 1)
        for a, b in zip(oracle.hits, br.hits)
    ])
    assert recall >= 0.95


def test_report_plan_round_trips_and_matches_summary(mixed_cluster):
    """The plan artifact on ProbeReport: serializable, replayable, and its
    summary is exactly the report's filter_plan string."""
    c, t, X, price, rep = mixed_cluster
    br = c.coordinator.probe_batch(
        "emb", X[:4], 10, strategy="diskann", filter=MIXED_FILTERS[:4]
    )
    assert br.plan is not None
    assert br.plan.k == 10 and br.plan.use_pq
    assert len(br.plan.ops) == 4  # one op row per query
    back = ProbePlan.from_json(json.loads(json.dumps(br.plan.to_json())))
    assert back == br.plan
    # single-probe plans round-trip too (one pseudo-query row)
    pr = c.coordinator.probe("emb", X[0], 10, strategy="diskann", filter="price < 60")
    assert pr.plan is not None and len(pr.plan.ops) == 1
    assert ProbePlan.from_json(pr.plan.to_json()) == pr.plan
    assert pr.plan.summary() == pr.filter_plan


def test_golden_plan_scenarios(mixed_cluster):
    """Representative (selectivity, flavor) scenarios produce the expected
    op types in the report plan."""
    c, t, X, price, rep = mixed_cluster
    cases = [
        ("price < 2", ExactScan),       # ~2%: prefilter band
        ("price < 60", PQScan),         # ~60% on a PQ index: ADC band
        ("price < 95", PostfilterBeam), # ~95%: over-fetched postfilter
    ]
    for where, op_type in cases:
        pr = c.coordinator.probe("emb", X[0], 10, strategy="diskann", filter=where)
        ops_row = pr.plan.ops[0]
        assert ops_row, where
        assert all(isinstance(op, op_type) for op in ops_row.values()), (
            where, pr.plan.to_json(),
        )


def test_unfiltered_rows_in_mixed_batch_get_planned_ops(mixed_cluster):
    """A batch mixing filtered and unfiltered queries: the unfiltered rows
    appear in the plan grid with a planner op — the size-capped ExactScan
    on these small shards — and with EXACT_SCAN_MAX_ROWS forced to 0 they
    route to a shared Beam instead, still matching sequential probes."""
    c, t, X, price, rep = mixed_cluster
    rng = np.random.default_rng(6)
    Q = X[rng.choice(len(X), 4)] + 0.05 * rng.normal(size=(4, DIM)).astype(np.float32)
    filters = [None, "price < 60", None, "price < 3"]
    br = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann", filter=filters)
    for qi in (0, 2):
        row_ops = list(br.plan.ops[qi].values())
        assert row_ops and all(
            op == ExactScan(k=20, est_frac=1.0) for op in row_ops
        ), br.plan.to_json()
    seq = [
        c.coordinator.probe(
            "emb", Q[i], 5, strategy="diskann", filter=filters[i]
        ).hits[0]
        for i in range(4)
    ]
    for a, b in zip(seq, br.hits):
        assert _locs(a) == _locs(b)

    # shards "too big" for the all-ones scan: unfiltered rows become Beam
    import repro.runtime.planner as planner_mod

    old = planner_mod.EXACT_SCAN_MAX_ROWS
    planner_mod.EXACT_SCAN_MAX_ROWS = 0
    try:
        bb = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann", filter=filters)
    finally:
        planner_mod.EXACT_SCAN_MAX_ROWS = old
    for qi in (0, 2):
        assert all(
            isinstance(op, Beam) for op in bb.plan.ops[qi].values()
        ), bb.plan.to_json()
    for a, b in zip(seq, bb.hits):
        assert _locs(a) == _locs(b)  # beam == the sequential unfiltered probe


def test_postfilter_rows_share_pooled_beam_with_fused_fallback(mixed_cluster):
    """Heterogeneous postfilter-planned predicates no longer loop per
    predicate group: rows group by planner pool (one beam pass here), and
    results still match both the group loop and sequential probes."""
    c, t, X, price, rep = mixed_cluster
    rng = np.random.default_rng(8)
    Q = X[rng.choice(len(X), 4)] + 0.05 * rng.normal(size=(4, DIM)).astype(np.float32)
    filters = [f"price < {90 + i}" for i in range(4)]  # all postfilter band
    br = c.coordinator.probe_batch(
        "emb", Q, 5, strategy="diskann", filter=filters, L=256
    )
    assert "postfilter" in br.filter_plan
    _set_flag(c, "force_group_loop", True)
    try:
        bg = c.coordinator.probe_batch(
            "emb", Q, 5, strategy="diskann", filter=filters, L=256
        )
    finally:
        _set_flag(c, "force_group_loop", False)
    for a, b in zip(br.hits, bg.hits):
        assert _locs_d(a) == _locs_d(b)
    seq = [
        c.coordinator.probe(
            "emb", Q[i], 5, strategy="diskann", filter=filters[i], L=256
        ).hits[0]
        for i in range(4)
    ]
    for a, b in zip(seq, br.hits):
        assert _locs(a) == _locs(b)


def test_histogram_feeds_range_selectivity(mixed_cluster):
    """The attr-zonemap blob carries per-file int histograms and the range
    estimator uses them: on this uniform column the estimate lands within
    a few percent of the true fraction (the span guess would too — the
    histogram's value shows on skew, unit-tested below)."""
    c, t, X, price, rep = mixed_cluster
    true_frac = float((price < 30).mean())
    pr = c.coordinator.probe("emb", X[0], 10, strategy="diskann", filter="price < 30")
    assert pr.est_selectivity == pytest.approx(true_frac, abs=0.05)


def test_histogram_estimate_conditions_on_row_group_range():
    """The file-level histogram must be conditioned on each row group's own
    [min, max]: on a sorted column, a row group whose whole value range
    passes the predicate estimates ~1.0 (like the old span estimator did),
    not the file-wide fraction."""
    from repro.runtime.predicates import ColumnHistogram, Range, ZoneStats

    sorted_col = np.arange(1000, dtype=np.int64) // 10  # 0..99, sorted
    hist = ColumnHistogram.build(sorted_col)
    pred = Range("c", hi=9)  # first ~10% of the file
    rg_first = {"c": ZoneStats(count=100, min=0, max=9, hist=hist)}
    rg_last = {"c": ZoneStats(count=100, min=90, max=99, hist=hist)}
    assert pred.estimate_fraction(rg_first) == pytest.approx(1.0, abs=0.05)
    assert pred.estimate_fraction(rg_last) == 0.0  # zone_may_match says no
    whole = {"c": ZoneStats(count=1000, min=0, max=99, hist=hist)}
    assert pred.estimate_fraction(whole) == pytest.approx(0.10, abs=0.03)


def test_histogram_estimate_respects_strict_int_bounds():
    """'price < 1' passes only value 0: a column concentrated AT the
    excluded boundary must not count that mass (int columns, so a strict
    bound shifts by exactly one)."""
    from repro.runtime.predicates import ColumnHistogram, Range, ZoneStats

    # values 0..15 with the default 16 bins: one value per bin, so the
    # boundary mass is fully separable (wider value ranges only blur this
    # by within-bin interpolation, they cannot re-count the excluded bin)
    col = np.concatenate([
        np.zeros(50, np.int64), np.ones(900, np.int64),
        np.full(50, 15, np.int64),
    ])
    hist = ColumnHistogram.build(col)
    z = {"c": ZoneStats(count=1000, min=0, max=15, hist=hist)}
    true_frac = 0.05  # only the zeros pass price < 1
    est = Range("c", hi=1, hi_inclusive=False).estimate_fraction(z)
    assert est == pytest.approx(true_frac, abs=0.02)
    # inclusive keeps the boundary mass
    est_inc = Range("c", hi=1).estimate_fraction(z)
    assert est_inc == pytest.approx(0.95, abs=0.02)
    # strict lower bound mirrors: price > 1 excludes the concentrated mass
    est_gt = Range("c", lo=1, lo_inclusive=False).estimate_fraction(z)
    assert est_gt == pytest.approx(0.05, abs=0.02)


def test_histogram_estimate_beats_span_on_skew():
    from repro.runtime.predicates import ColumnHistogram, Range, ZoneStats

    rng = np.random.default_rng(0)
    skewed = np.minimum((rng.exponential(3.0, size=4000)).astype(np.int64), 99)
    hist = ColumnHistogram.build(skewed)
    z_hist = {"c": ZoneStats(count=4000, min=0, max=99, hist=hist)}
    z_span = {"c": ZoneStats(count=4000, min=0, max=99)}
    pred = Range("c", hi=10)
    true_frac = float((skewed <= 10).mean())  # ~0.95 on this skew
    est_hist = pred.estimate_fraction(z_hist)
    est_span = pred.estimate_fraction(z_span)  # ~0.10: wildly off
    assert abs(est_hist - true_frac) < 0.1
    assert abs(est_hist - true_frac) < abs(est_span - true_frac)
    # histogram round-trips through the zone-map blob codec
    from repro.core import blobs as B
    from repro.core.blobs import AttrZoneMap

    zm = AttrZoneMap(columns={"c": "int"}, zones={"f1": [z_hist]})
    back = B.decode_zonemap_blob(B.encode_zonemap_blob(zm))
    assert back.zones["f1"][0]["c"].hist == hist
    assert pred.estimate_fraction(back.zones["f1"][0]) == pytest.approx(est_hist)


# ---------------------------------------------------------------------------
# replayable plans: probe_batch(replay_plan=...) skips planning entirely
# ---------------------------------------------------------------------------


def test_probe_batch_replay_plan_skips_planner_at_parity(mixed_cluster, monkeypatch):
    """A captured ``ProbePlan`` round-trips through JSON and replays through
    ``probe_batch(replay_plan=...)`` with the planner booby-trapped: no
    re-planning, no zone-map consultation — and the hits are identical to
    the freshly planned probe (the plan IS the planning)."""
    c, t, X, price, rep = mixed_cluster
    Q = np.stack([X[i] for i in range(8)])
    fresh = c.coordinator.probe_batch(
        "emb", Q, 5, strategy="diskann", filter=MIXED_FILTERS
    )
    assert fresh.plan is not None

    wire = json.dumps(fresh.plan.to_json())  # e.g. persisted next to a report
    plan = ProbePlan.from_json(json.loads(wire))

    def _no_planning(*a, **k):
        raise AssertionError("plan_filtered must not run under replay")

    monkeypatch.setattr(planner, "plan_filtered", _no_planning)
    replay = c.coordinator.probe_batch(
        "emb", Q, 5, strategy="diskann", filter=MIXED_FILTERS, replay_plan=plan
    )
    assert replay.filter_plan == "replay"
    assert replay.est_selectivity == pytest.approx(fresh.est_selectivity)
    assert replay.shards_pruned == fresh.shards_pruned
    for a, b in zip(fresh.hits, replay.hits):
        assert _locs(a) == _locs(b)
        np.testing.assert_allclose(
            [h.distance for h in a],
            [h.distance for h in b],
            rtol=1e-5,
            atol=1e-3,
        )


def test_replay_plan_validates_shape_and_strategy(mixed_cluster):
    c, t, X, price, rep = mixed_cluster
    Q = np.stack([X[i] for i in range(4)])
    fresh = c.coordinator.probe_batch(
        "emb", Q, 5, strategy="diskann", filter=MIXED_FILTERS[:4]
    )
    plan = ProbePlan.from_json(fresh.plan.to_json())
    with pytest.raises(ValueError):  # k mismatch
        c.coordinator.probe_batch(
            "emb", Q, 7, strategy="diskann", filter=MIXED_FILTERS[:4], replay_plan=plan
        )
    with pytest.raises(ValueError):  # row-count mismatch
        c.coordinator.probe_batch(
            "emb", Q[:2], 5, strategy="diskann",
            filter=MIXED_FILTERS[:2], replay_plan=plan,
        )
    with pytest.raises(ValueError):  # plans only exist for the index path
        c.coordinator.probe_batch(
            "emb", Q, 5, strategy="scan", filter=MIXED_FILTERS[:4], replay_plan=plan
        )


# ---------------------------------------------------------------------------
# per-shard histogram merge: shard-level selectivity evidence
# ---------------------------------------------------------------------------


def test_column_histogram_merge_unions_files():
    from repro.runtime.predicates import ColumnHistogram

    lo_half = ColumnHistogram.build(np.arange(0, 50, dtype=np.int64))
    hi_half = ColumnHistogram.build(np.arange(50, 100, dtype=np.int64))
    merged = ColumnHistogram.merge([lo_half, hi_half])
    assert merged.lo == 0.0 and merged.hi == 99.0
    assert sum(merged.counts) == pytest.approx(100.0, rel=1e-6)
    # mass sits in both halves, roughly evenly on this uniform data
    assert merged.fraction_between(None, 49) == pytest.approx(0.5, abs=0.05)
    assert merged.fraction_between(50, None) == pytest.approx(0.5, abs=0.05)
    # degenerate cases: single histogram passes through bit-for-bit
    assert ColumnHistogram.merge([lo_half]) is lo_half
    assert ColumnHistogram.merge([]) is None


def test_shard_zones_merge_file_histograms():
    """A shard spanning two files with disjoint value ranges must expose a
    merged histogram: estimating against either file's own histogram would
    attribute ALL of the shard's mass to that file's range."""
    from repro.core.blobs import AttrZoneMap
    from repro.runtime.predicates import ColumnHistogram, Range, ZoneStats

    cheap = np.arange(0, 50, dtype=np.int64).repeat(20)  # 1000 rows, 0..49
    dear = np.arange(50, 100, dtype=np.int64).repeat(20)  # 1000 rows, 50..99
    h_cheap = ColumnHistogram.build(cheap)
    h_dear = ColumnHistogram.build(dear)
    zm = AttrZoneMap(
        columns={"price": "int"},
        zones={
            "fa": [{"price": ZoneStats(count=1000, min=0, max=49, hist=h_cheap)}],
            "fb": [{"price": ZoneStats(count=1000, min=50, max=99, hist=h_dear)}],
        },
        shard_membership={0: [("fa", 0), ("fb", 0)], 1: [("fa", 0)]},
    )

    pred = Range("price", hi=49)  # passes exactly file fa's rows
    both = zm.shard_zones(0)
    merged_hist = both[0]["price"].hist
    assert merged_hist is both[1]["price"].hist  # one shard-level histogram
    assert merged_hist.lo == 0.0 and merged_hist.hi == 99.0
    # per-zone estimates stay conditioned on each row group's own range
    assert pred.estimate_fraction(both[0]) == pytest.approx(1.0, abs=0.05)
    assert pred.estimate_fraction(both[1]) == 0.0
    # shard-level fraction over the merged evidence: half the shard's rows
    assert merged_hist.fraction_between(None, 49) == pytest.approx(0.5, abs=0.05)

    # single-file shard keeps its file histogram bit-for-bit (no re-binning)
    solo = zm.shard_zones(1)
    assert solo[0]["price"].hist is h_cheap
