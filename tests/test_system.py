"""End-to-end system behaviour: the paper's full lifecycle in one scenario.

Create table → ingest embeddings → CREATE INDEX (3-stage distributed build
into a Puffin file, snapshot-bound) → probe (tiered strategies) → append +
delete data → REFRESH INDEX (manifest diff, greedy insert, tombstones,
metadata-only commit) → time travel to the old index → orphan GC of the
superseded Puffin.
"""

import numpy as np

from repro.core.blobs import ROUTING_BLOB_TYPE, SHARD_BLOB_TYPE, decode_routing_blob
from repro.core.vamana import brute_force_topk
from repro.iceberg.gc import expire_and_collect
from repro.iceberg.puffin import PuffinReader
from repro.lakehouse.table import LakehouseTable
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig
from conftest import clustered_vectors


def test_full_lifecycle(tmp_path):
    rng = np.random.default_rng(0)
    c = make_local_cluster(str(tmp_path), num_executors=3)
    t = LakehouseTable(c.catalog, "docs")
    t.create(dim=24)
    X, centers = clustered_vectors(rng, n_clusters=12, per_cluster=90, dim=24)
    t.append_vectors(X, num_files=6, rows_per_group=128)

    # -- CREATE INDEX ------------------------------------------------------
    rep = c.coordinator.create_index(
        "docs", IndexConfig(name="docs_vec", R=16, L=32, pq_m=12, pq_nbits=8,
                            partitions_per_shard=2, build_passes=1)
    )
    assert rep.vector_count == len(X)
    # the Puffin file is bound to the snapshot
    meta = c.catalog.load_table("docs")
    assert meta.current_snapshot().statistics_file == rep.puffin_path
    # the file is a valid Puffin with routing + centroid + shard blobs
    reader = PuffinReader(
        c.store.stat(rep.puffin_path).size, c.store.range_reader(rep.puffin_path)
    )
    routing = decode_routing_blob(reader.read_first(ROUTING_BLOB_TYPE))
    assert routing.num_shards == rep.num_shards
    assert routing.base_snapshot_id == rep.base_snapshot_id
    assert len(reader.blobs_of_type(SHARD_BLOB_TYPE)) == rep.num_shards

    # -- probe --------------------------------------------------------------
    Q = X[rng.choice(len(X), 10)]
    _, truth = brute_force_topk(X, Q, 5)
    pr = c.coordinator.probe("docs", Q, 5, strategy="diskann")
    assert len(pr.hits) == 10 and all(len(h) == 5 for h in pr.hits)
    # warm-cache probe: shard blobs served from executor caches, so the
    # object store sees only footer/routing + rerank row groups.  (The
    # probe-vs-scan byte ratio of paper Table 2 is measured at scale in
    # benchmarks/bench_query_paths.py — at this toy size rerank row groups
    # approach the whole table.)
    pr_warm = c.coordinator.probe("docs", Q, 5, strategy="diskann")
    assert pr_warm.bytes_read < pr.bytes_read
    assert pr_warm.cache_hits == pr_warm.shards_probed

    # -- data churn + REFRESH ------------------------------------------------
    Y = (centers[0] + rng.normal(size=(160, 24))).astype(np.float32)
    t.append_vectors(Y, num_files=2, file_prefix="new")
    doomed = t.current_files()[0].path
    t.delete_files([doomed])
    rr = c.coordinator.refresh_index("docs", "docs_vec")
    assert rr.inserted == 160 and rr.tombstoned > 0
    meta = c.catalog.load_table("docs")
    assert meta.current_snapshot().statistics_file == rr.puffin_path
    assert rr.puffin_path != rep.puffin_path  # new object, old superseded

    # refreshed index serves the new data and hides the deleted file
    pr2 = c.coordinator.probe("docs", Y[:5], 5, strategy="diskann")
    flat = [h for hits in pr2.hits for h in hits]
    assert any("new" in h.file_path for h in flat)
    assert not any(h.file_path == doomed for h in flat)

    # -- time travel: the old snapshot still probes the old index -----------
    pr_old = c.coordinator.probe("docs", Q, 5, snapshot_id=rep.snapshot_id)
    assert len(pr_old.hits) == 10

    # -- GC: expiring old snapshots orphans the superseded Puffin -----------
    orphans = expire_and_collect(c.store, c.catalog.load_table("docs"), keep_last=1, delete=False)
    assert rep.puffin_path in orphans
    assert rr.puffin_path not in orphans


def test_refresh_then_committed_expire_collects_old_puffin(tmp_path):
    """Regression (refresh_index ↔ gc interplay): after a REFRESH commit the
    superseded index Puffin must be collectible — and actually deletable —
    via a *committed* expiration.  The uncommitted form left the catalog
    serving expired snapshots whose backing objects were gone (time travel
    crashed with NoSuchKey after delete=True)."""
    rng = np.random.default_rng(2)
    c = make_local_cluster(str(tmp_path), num_executors=2)
    t = LakehouseTable(c.catalog, "docs")
    t.create(dim=8)
    X = rng.normal(size=(400, 8)).astype(np.float32)
    t.append_vectors(X, num_files=3, rows_per_group=64)
    rep = c.coordinator.create_index(
        "docs", IndexConfig(name="v", R=12, L=24, partitions_per_shard=2, build_passes=1)
    )
    t.append_vectors(rng.normal(size=(100, 8)).astype(np.float32), num_files=1)
    rr = c.coordinator.refresh_index("docs", "v")
    assert rr.puffin_path != rep.puffin_path

    # committed expiration: the catalog's served metadata agrees with storage
    orphans = expire_and_collect(
        c.store, c.catalog.load_table("docs"), keep_last=1, delete=True,
        catalog=c.catalog, table_name="docs",
    )
    assert rep.puffin_path in orphans       # superseded index reaped
    assert rr.puffin_path not in orphans    # live index untouched
    meta = c.catalog.load_table("docs")
    assert len(meta.snapshots) == 1         # expiration is visible to readers
    assert meta.current_snapshot().statistics_file == rr.puffin_path

    # the refreshed index still probes after the sweep deleted the orphans
    pr = c.coordinator.probe("docs", X[:3], 5, strategy="diskann")
    assert all(len(h) == 5 for h in pr.hits)
