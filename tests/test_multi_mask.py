"""Per-query mask planes: heterogeneous-filter batches in ONE kernel call.

The PR acceptance contract: a coalesced fragment whose queries all land on
kernel-backed plans (prefilter / mask / unfiltered-in-a-mixed-fragment)
issues exactly ONE masked-kernel dispatch per shard regardless of how many
distinct predicates the batch carries — counted via
``Executor.masked_kernel_dispatches`` / ``ProbeReport.kernel_dispatches``
— with per-query results identical to the legacy per-predicate-group loop
(``Executor.force_group_loop=True`` re-enables it for comparison).
"""

import numpy as np
import pytest

from repro.lakehouse.table import LakehouseTable
from repro.runtime import fragments as F
from repro.runtime import planner
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig
from repro.serving.serve_loop import ProbeMicroBatcher

DIM = 16


def _locs(hits):
    return [(h.file_path, h.row_group, h.row_offset) for h in hits]


def _locs_d(hits):
    return [(h.file_path, h.row_group, h.row_offset, h.distance) for h in hits]


def _reset_dispatch_counters(c):
    for ex in c.executors:
        ex.masked_kernel_dispatches = 0


def _set_group_loop(c, flag: bool):
    for ex in c.executors:
        ex.force_group_loop = flag


def _queries(X, n, seed):
    rng = np.random.default_rng(seed)
    return X[rng.choice(len(X), n)] + 0.05 * rng.normal(size=(n, DIM)).astype(
        np.float32
    )


@pytest.fixture(scope="module")
def plane_cluster(tmp_path_factory):
    """Full-precision (no PQ) index: every kernel-backed plan takes the
    exact flavor, so an all-kernel fragment is exactly one dispatch."""
    rng = np.random.default_rng(0)
    c = make_local_cluster(str(tmp_path_factory.mktemp("plane")), num_executors=2)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    centers = rng.normal(size=(8, DIM))
    X = np.concatenate(
        [ctr + rng.normal(size=(150, DIM)) for ctr in centers]
    ).astype(np.float32)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(
        X, num_files=4, rows_per_group=100, attributes={"price": price}
    )
    rep = c.coordinator.create_index(
        "emb",
        IndexConfig(name="idx", R=16, L=48, partitions_per_shard=2, build_passes=1),
    )
    return c, t, X, price, rep


@pytest.fixture(scope="module")
def pq_plane_cluster(tmp_path_factory):
    """PQ index with shards big enough that mid-selectivity mask plans take
    the ADC flavor (match_count > max(4·k_eff, 64))."""
    rng = np.random.default_rng(1)
    c = make_local_cluster(str(tmp_path_factory.mktemp("pqplane")), num_executors=2)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=DIM)
    centers = rng.normal(size=(6, DIM))
    X = np.concatenate(
        [ctr + rng.normal(size=(220, DIM)) for ctr in centers]
    ).astype(np.float32)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    t.append_vectors(
        X, num_files=4, rows_per_group=110, attributes={"price": price}
    )
    rep = c.coordinator.create_index(
        "emb",
        IndexConfig(
            name="idx", R=16, L=48, pq_m=8, pq_nbits=8,
            partitions_per_shard=2, build_passes=1,
        ),
    )
    return c, t, X, price, rep


HETERO_FILTERS = [f"price < {5 + 9 * i}" for i in range(8)]  # est 0.05 .. 0.68


def test_hetero_batch_is_one_dispatch_per_shard(plane_cluster):
    """8 distinct predicates in one batch: the mask-plane path answers each
    coalesced fragment with exactly ONE kernel call, where the per-group
    loop pays one call per distinct predicate — and the hits (including
    distances) are identical between the two paths and exact vs the
    brute-force oracle."""
    c, t, X, price, rep = plane_cluster
    Q = _queries(X, 8, seed=3)
    assert len(set(HETERO_FILTERS)) == 8
    # warm: masks computed and cached on first touch (both paths share them)
    c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=HETERO_FILTERS)

    _reset_dispatch_counters(c)
    br = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="diskann", filter=HETERO_FILTERS
    )
    assert br.probe_fragments >= 1
    assert br.kernel_dispatches == br.probe_fragments  # ONE call per shard
    assert sum(ex.masked_kernel_dispatches for ex in c.executors) == br.kernel_dispatches

    _set_group_loop(c, True)
    try:
        _reset_dispatch_counters(c)
        bg = c.coordinator.probe_batch(
            "emb", Q, 10, strategy="diskann", filter=HETERO_FILTERS
        )
    finally:
        _set_group_loop(c, False)
    # legacy path: one kernel call per distinct predicate per shard
    assert bg.kernel_dispatches == len(HETERO_FILTERS) * bg.probe_fragments
    assert bg.kernel_dispatches > br.kernel_dispatches
    for a, b in zip(br.hits, bg.hits):
        assert _locs_d(a) == _locs_d(b)  # byte-identical to the group loop
    # every plan is an exact kernel scan, so hits match the oracle exactly
    oracle = c.coordinator.probe_batch(
        "emb", Q, 10, strategy="scan", filter=HETERO_FILTERS
    )
    for a, b in zip(oracle.hits, br.hits):
        assert _locs(a) == _locs(b)


def test_hetero_pq_batch_is_one_dispatch_per_shard(pq_plane_cluster):
    """On a PQ index, mid-selectivity mask plans all take the ADC flavor:
    still one multi-mask kernel call per shard, and byte-identical to the
    per-group path (per-query pool truncation keeps the rerank pools the
    same)."""
    c, t, X, price, rep = pq_plane_cluster
    Q = _queries(X, 8, seed=5)
    filters = [f"price < {30 + 5 * i}" for i in range(8)]  # est 0.30 .. 0.65
    c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=filters)

    _reset_dispatch_counters(c)
    br = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=filters)
    assert br.kernel_dispatches == br.probe_fragments
    assert "mask" in br.filter_plan

    _set_group_loop(c, True)
    try:
        bg = c.coordinator.probe_batch("emb", Q, 10, strategy="diskann", filter=filters)
    finally:
        _set_group_loop(c, False)
    assert bg.kernel_dispatches == len(set(filters)) * bg.probe_fragments
    for a, b in zip(br.hits, bg.hits):
        assert _locs_d(a) == _locs_d(b)


def test_mixed_kernel_and_postfilter_batch_matches_sequential(plane_cluster):
    """A batch mixing unfiltered, mask-planned, and postfilter-planned
    queries: kernel rows ride the plane, the beam group loop survives only
    for the postfilter queries — and every query returns exactly what its
    sequential probe returns."""
    c, t, X, price, rep = plane_cluster
    Q = _queries(X, 5, seed=7)
    filters = [None, "price < 30", "price < 95", "price < 48", None]
    br = c.coordinator.probe_batch(
        "emb", Q, 5, strategy="diskann", filter=filters, L=256
    )
    assert "postfilter" in br.filter_plan
    seq = [
        c.coordinator.probe(
            "emb", Q[i], 5, strategy="diskann", filter=filters[i], L=256
        ).hits[0]
        for i in range(len(Q))
    ]
    for a, b in zip(seq, br.hits):
        assert _locs(a) == _locs(b)


def test_single_probe_report_counts_dispatches(plane_cluster):
    c, t, X, price, rep = plane_cluster
    got = c.coordinator.probe("emb", X[0], 5, strategy="diskann", filter="price < 30")
    assert got.kernel_dispatches >= 1
    unf = c.coordinator.probe("emb", X[0], 5, strategy="diskann")
    assert unf.kernel_dispatches == 0  # pure beam path


def test_coalesced_fragment_keeps_hetero_filters_together(plane_cluster):
    """Fragment layer: the coalesce key ignores predicates, so per-(query,
    shard) fragments with 8 distinct predicates still merge to ≤ one
    fragment per shard and the merged fragment carries the aligned filter
    list."""
    c, t, X, price, rep = plane_cluster
    Q = _queries(X, 8, seed=9)
    tasks = [
        F.BatchProbeTaskInfo(
            task_id=f"t{qi}",
            shard_id=0,
            puffin_path="p",
            blob_offset=0,
            blob_length=1,
            queries=Q[qi : qi + 1],
            query_index=np.array([qi], np.int64),
            filters=[HETERO_FILTERS[qi]],
            plan_ops=[planner.default_filtered_op(10, 4, use_pq=False)],
        )
        for qi in range(8)
    ]
    merged = F.coalesce_batch_probes(tasks)
    assert len(merged) == 1
    assert merged[0].filters == HETERO_FILTERS
    assert merged[0].queries.shape == (8, DIM)
    assert len(merged[0].plan_ops) == 8  # row-aligned ops ride the merge


def test_micro_batcher_hetero_submissions_share_kernel_calls(plane_cluster):
    """Serving: concurrent submissions with distinct predicates no longer
    need filter-homogeneous batches — the drained batch costs one kernel
    call per shard, surfaced via stats.kernel_dispatches."""
    c, t, X, price, rep = plane_cluster
    # warm the masks so the measured batch is steady-state
    c.coordinator.probe_batch(
        "emb", X[:4], 5, strategy="diskann", filter=HETERO_FILTERS[:4]
    )
    with ProbeMicroBatcher(c.coordinator, "emb", max_batch=8, max_wait_s=0.1) as mb:
        futs = [
            mb.submit(X[i], k=5, filter=HETERO_FILTERS[i]) for i in range(4)
        ]
        got = [f.result() for f in futs]
    assert mb.stats.filtered_queries == 4
    assert 0 < mb.stats.kernel_dispatches <= mb.stats.batches * rep.num_shards
    for i, hits in enumerate(got):
        expect = c.coordinator.probe("emb", X[i], 5, filter=HETERO_FILTERS[i]).hits[0]
        assert _locs(expect) == _locs(hits)
