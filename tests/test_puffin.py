"""Puffin container: spec conformance, roundtrips, range-read access."""

import struct

import pytest
from hypothesis import given, settings, strategies as st

from repro.iceberg.puffin import (
    MAGIC,
    PuffinError,
    PuffinReader,
    PuffinWriter,
    preferred_codec,
    read_footer,
)

_zstd_only = pytest.mark.skipif(
    preferred_codec() != "zstd", reason="zstandard not installed"
)


def _file(blobs, **kw):
    w = PuffinWriter(**kw)
    metas = [w.add_blob(payload, **meta) for payload, meta in blobs]
    return w.finish(), metas


def test_layout_magic_and_footer():
    data, _ = _file([(b"hello", dict(type="t1"))])
    assert data[:4] == MAGIC
    assert data[-4:] == MAGIC
    # footer payload length field
    (ln,) = struct.unpack("<i", data[-12:-8])
    assert 0 < ln < len(data)


def test_roundtrip_multiple_blobs():
    data, _ = _file(
        [
            (b"a" * 1000, dict(type="flockdb-ann-routing-v1", properties={"x": "1"})),
            (b"b" * 5000, dict(type="flockdb-ann-index-v1", snapshot_id=42)),
            (b"c" * 10, dict(type="unknown-type")),
        ]
    )
    r = PuffinReader.from_bytes(data)
    assert [b.type for b in r.blobs] == [
        "flockdb-ann-routing-v1",
        "flockdb-ann-index-v1",
        "unknown-type",
    ]
    assert r.read_blob(r.blobs[0]) == b"a" * 1000
    assert r.read_blob(r.blobs[1]) == b"b" * 5000
    assert r.blobs[1].snapshot_id == 42
    assert r.blobs[0].properties == {"x": "1"}


@pytest.mark.parametrize("codec", [None, pytest.param("zstd", marks=_zstd_only), "zlib"])
def test_compression_codecs(codec):
    payload = b"z" * 100_000
    data, metas = _file([(payload, dict(type="t", compression=codec))])
    if codec:
        assert metas[0].length < len(payload)
    r = PuffinReader.from_bytes(data)
    assert r.read_first("t") == payload


def test_range_read_access_pattern():
    """Reader must touch only the footer + requested blob ranges."""
    data, _ = _file(
        [(b"x" * 100_000, dict(type="big")), (b"y" * 10, dict(type="small"))]
    )
    reads = []

    def tracked(off, ln):
        reads.append((off, ln))
        return data[off : off + ln]

    r = PuffinReader(len(data), tracked)
    footer_bytes = sum(ln for _, ln in reads)
    assert footer_bytes < 1000  # header magic + footer only
    r.read_first("small")
    assert reads[-1][1] == 10  # exactly the small blob's stored length


def test_unknown_blob_types_ignored():
    data, _ = _file([(b"q", dict(type="future-type-v9"))])
    r = PuffinReader.from_bytes(data)
    assert r.blobs_of_type("flockdb-ann-index-v1") == []


def test_corrupt_magic_rejected():
    data, _ = _file([(b"p", dict(type="t"))])
    with pytest.raises(PuffinError):
        PuffinReader.from_bytes(b"XXXX" + data[4:])
    with pytest.raises(PuffinError):
        PuffinReader.from_bytes(data[:-4] + b"XXXX")


def test_compressed_footer():
    data, _ = _file([(b"p" * 100, dict(type="t"))], compress_footer=True)
    r = PuffinReader.from_bytes(data)
    assert r.read_first("t") == b"p" * 100


def test_precompressed_blob_passthrough():
    zstandard = pytest.importorskip("zstandard")

    payload = b"w" * 50_000
    stored = zstandard.ZstdCompressor().compress(payload)
    w = PuffinWriter()
    w.add_blob(stored, type="t", compression="zstd", precompressed=True)
    data = w.finish()
    r = PuffinReader.from_bytes(data)
    assert r.read_first("t") == payload


@settings(max_examples=25, deadline=None)
@given(
    payloads=st.lists(st.binary(min_size=0, max_size=2048), min_size=1, max_size=6),
    codec=st.sampled_from([None, preferred_codec()]),
)
def test_property_roundtrip(payloads, codec):
    w = PuffinWriter()
    for i, p in enumerate(payloads):
        w.add_blob(p, type=f"t{i}", compression=codec, properties={"i": str(i)})
    data = w.finish()
    r = PuffinReader.from_bytes(data)
    assert len(r.blobs) == len(payloads)
    for i, p in enumerate(payloads):
        assert r.read_first(f"t{i}") == p
