"""Puffin container: spec conformance, roundtrips, range-read access."""

import struct

import pytest
from hypothesis import given, settings, strategies as st

from repro.iceberg.puffin import (
    MAGIC,
    PuffinError,
    PuffinReader,
    PuffinWriter,
    preferred_codec,
)

_zstd_only = pytest.mark.skipif(
    preferred_codec() != "zstd", reason="zstandard not installed"
)


def _file(blobs, **kw):
    w = PuffinWriter(**kw)
    metas = [w.add_blob(payload, **meta) for payload, meta in blobs]
    return w.finish(), metas


def test_layout_magic_and_footer():
    data, _ = _file([(b"hello", dict(type="t1"))])
    assert data[:4] == MAGIC
    assert data[-4:] == MAGIC
    # footer payload length field
    (ln,) = struct.unpack("<i", data[-12:-8])
    assert 0 < ln < len(data)


def test_roundtrip_multiple_blobs():
    data, _ = _file(
        [
            (b"a" * 1000, dict(type="flockdb-ann-routing-v1", properties={"x": "1"})),
            (b"b" * 5000, dict(type="flockdb-ann-index-v1", snapshot_id=42)),
            (b"c" * 10, dict(type="unknown-type")),
        ]
    )
    r = PuffinReader.from_bytes(data)
    assert [b.type for b in r.blobs] == [
        "flockdb-ann-routing-v1",
        "flockdb-ann-index-v1",
        "unknown-type",
    ]
    assert r.read_blob(r.blobs[0]) == b"a" * 1000
    assert r.read_blob(r.blobs[1]) == b"b" * 5000
    assert r.blobs[1].snapshot_id == 42
    assert r.blobs[0].properties == {"x": "1"}


@pytest.mark.parametrize("codec", [None, pytest.param("zstd", marks=_zstd_only), "zlib"])
def test_compression_codecs(codec):
    payload = b"z" * 100_000
    data, metas = _file([(payload, dict(type="t", compression=codec))])
    if codec:
        assert metas[0].length < len(payload)
    r = PuffinReader.from_bytes(data)
    assert r.read_first("t") == payload


def test_range_read_access_pattern():
    """Reader must touch only the footer + requested blob ranges."""
    data, _ = _file(
        [(b"x" * 100_000, dict(type="big")), (b"y" * 10, dict(type="small"))]
    )
    reads = []

    def tracked(off, ln):
        reads.append((off, ln))
        return data[off : off + ln]

    r = PuffinReader(len(data), tracked)
    footer_bytes = sum(ln for _, ln in reads)
    assert footer_bytes < 1000  # header magic + footer only
    r.read_first("small")
    assert reads[-1][1] == 10  # exactly the small blob's stored length


def test_unknown_blob_types_ignored():
    data, _ = _file([(b"q", dict(type="future-type-v9"))])
    r = PuffinReader.from_bytes(data)
    assert r.blobs_of_type("flockdb-ann-index-v1") == []


def test_corrupt_magic_rejected():
    data, _ = _file([(b"p", dict(type="t"))])
    with pytest.raises(PuffinError):
        PuffinReader.from_bytes(b"XXXX" + data[4:])
    with pytest.raises(PuffinError):
        PuffinReader.from_bytes(data[:-4] + b"XXXX")


def test_compressed_footer():
    data, _ = _file([(b"p" * 100, dict(type="t"))], compress_footer=True)
    r = PuffinReader.from_bytes(data)
    assert r.read_first("t") == b"p" * 100


def test_precompressed_blob_passthrough():
    zstandard = pytest.importorskip("zstandard")

    payload = b"w" * 50_000
    stored = zstandard.ZstdCompressor().compress(payload)
    w = PuffinWriter()
    w.add_blob(stored, type="t", compression="zstd", precompressed=True)
    data = w.finish()
    r = PuffinReader.from_bytes(data)
    assert r.read_first("t") == payload


@settings(max_examples=25, deadline=None)
@given(
    payloads=st.lists(st.binary(min_size=0, max_size=2048), min_size=1, max_size=6),
    codec=st.sampled_from([None, preferred_codec()]),
)
def test_property_roundtrip(payloads, codec):
    w = PuffinWriter()
    for i, p in enumerate(payloads):
        w.add_blob(p, type=f"t{i}", compression=codec, properties={"i": str(i)})
    data = w.finish()
    r = PuffinReader.from_bytes(data)
    assert len(r.blobs) == len(payloads)
    for i, p in enumerate(payloads):
        assert r.read_first(f"t{i}") == p


def _tiny_shard():
    import numpy as np
    from repro.core.blobs import ShardLocationMap
    from repro.core.vamana import VamanaParams, build_vamana

    rng = np.random.default_rng(0)
    vecs = rng.normal(size=(48, 8)).astype(np.float32)
    graph = build_vamana(vecs, VamanaParams(R=8, L=16, alpha=1.2, metric="l2"),
                         passes=1, batch=48)
    n = graph.n
    locmap = ShardLocationMap(
        ["f0"],
        np.zeros(n, np.uint32),
        np.zeros(n, np.uint32),
        np.arange(n, dtype=np.uint32),
    )
    return graph, locmap


@pytest.mark.parametrize("force_zlib", [False, True],
                         ids=["env-codec", "zlib-fallback"])
def test_shard_blob_codec_roundtrip(monkeypatch, force_zlib):
    """DANN shard blobs roundtrip under the environment codec (zstd when
    available) AND under the zlib fallback path the module falls back to
    when zstandard is absent."""
    import zlib

    import numpy as np
    from repro.core import blobs as B

    if force_zlib:
        monkeypatch.setattr(B, "_c", lambda b: zlib.compress(b, 6))
        monkeypatch.setattr(B, "_d", zlib.decompress)
    graph, locmap = _tiny_shard()
    blob = B.encode_shard_blob(graph, locmap, include_vectors=True)
    g2, lm2 = B.decode_shard_blob(blob)
    assert g2.n == graph.n and g2.medoid == graph.medoid
    np.testing.assert_allclose(g2.vectors[: graph.n], graph.vectors[: graph.n])
    np.testing.assert_array_equal(g2.adjacency[: graph.n], graph.adjacency[: graph.n])
    assert lm2.file_paths == locmap.file_paths
    np.testing.assert_array_equal(lm2.row_offset, locmap.row_offset)


@pytest.mark.parametrize("force_zlib", [False, True],
                         ids=["env-codec", "zlib-fallback"])
def test_zonemap_blob_codec_roundtrip(monkeypatch, force_zlib):
    import zlib

    from repro.core import blobs as B
    from repro.runtime.predicates import ZoneStats

    if force_zlib:
        monkeypatch.setattr(B, "_c", lambda b: zlib.compress(b, 6))
        monkeypatch.setattr(B, "_d", zlib.decompress)
    zm = B.AttrZoneMap(
        columns={"price": "int", "category": "dict"},
        zones={
            "f0": [
                {"price": ZoneStats(count=10, min=1, max=9),
                 "category": ZoneStats(count=10, values={"a": 4, "b": 6})},
                {"price": ZoneStats(count=5, min=50, max=99),
                 "category": ZoneStats(count=5, values={"c": 5})},
            ]
        },
        shard_membership={0: [("f0", 0)], 1: [("f0", 0), ("f0", 1)]},
    )
    zm2 = B.decode_zonemap_blob(B.encode_zonemap_blob(zm))
    assert zm2.columns == zm.columns
    assert zm2.shard_membership == zm.shard_membership
    assert zm2.zones["f0"][0]["category"].values == {"a": 4, "b": 6}
    assert zm2.zones["f0"][1]["price"].min == 50
    assert zm2.shard_zones(1) == zm.zones["f0"]
    assert zm2.shard_zones(9) is None
