"""Batched multi-query probe pipeline (probe_batch + coalescing).

The contract under test: ``probe_batch(Q)`` returns, per query, exactly the
hits of ``probe(q)`` — same locations in the same order, same distances —
while the scheduler dispatches at most ONE shard-probe fragment per shard
for the whole batch (instead of B × shards).
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.runtime.frontend import SqlFrontend
from repro.serving.serve_loop import ProbeMicroBatcher


def _locs(hits):
    return [(h.file_path, h.row_group, h.row_offset) for h in hits]


def _dists(hits):
    return np.asarray([h.distance for h in hits], np.float64)


def _assert_same_hits(seq_hits, batch_hits):
    """Per query: identical ordered locations, distances to float tolerance.

    The batched rerank scores a different candidate-matrix shape, and the
    f32 ``q² − 2qx + x²`` expansion has an absolute noise floor of roughly
    ``|q|² · eps`` (~1e-4 at this data scale), so distances are compared to
    1e-3 absolute while locations must match exactly."""
    assert len(seq_hits) == len(batch_hits)
    for a, b in zip(seq_hits, batch_hits):
        assert _locs(a) == _locs(b)
        np.testing.assert_allclose(_dists(a), _dists(b), rtol=1e-5, atol=1e-3)


@pytest.mark.parametrize("strategy,kw", [
    ("diskann", {}),
    ("centroid", {"n_probe": 4}),
    ("scan", {}),
])
def test_batch_equals_sequential(built_cluster, strategy, kw):
    c, t, X, centers, rep = built_cluster
    rng = np.random.default_rng(7)
    Q = X[rng.choice(len(X), 6)] + 0.05 * rng.normal(size=(6, 32)).astype(np.float32)
    seq = [c.coordinator.probe("emb", Q[i], 5, strategy=strategy, **kw).hits[0]
           for i in range(len(Q))]
    br = c.coordinator.probe_batch("emb", Q, 5, strategy=strategy, **kw)
    assert br.batch_size == len(Q)
    _assert_same_hits(seq, br.hits)


# k ≤ 8 keeps k·oversample ≤ L=32, so every draw reuses one beam-search
# compilation instead of jit-compiling per distinct pool size
@settings(max_examples=8, deadline=None)
@given(
    b=st.integers(1, 6),
    k=st.integers(1, 8),
    strategy=st.sampled_from(["centroid", "diskann"]),
    seed=st.integers(0, 10_000),
)
def test_property_batch_equals_sequential(built_cluster, b, k, strategy, seed):
    """Property: for any batch size, k, and probe strategy, the batched
    pipeline is indistinguishable from per-query probes."""
    c, t, X, centers, rep = built_cluster
    rng = np.random.default_rng(seed)
    Q = X[rng.choice(len(X), b)] + 0.05 * rng.normal(size=(b, 32)).astype(np.float32)
    seq = [c.coordinator.probe("emb", Q[i], k, strategy=strategy).hits[0]
           for i in range(b)]
    br = c.coordinator.probe_batch("emb", Q, k, strategy=strategy)
    _assert_same_hits(seq, br.hits)


def test_batch_probe_coalesces_fragments(built_cluster):
    """B queries × S shards of per-(query, shard) fragments must reach the
    executors as ≤ S coalesced fragments."""
    c, t, X, centers, rep = built_cluster
    stats = c.coordinator.scheduler.stats
    B = 16
    Q = X[:B]
    offered0 = stats.probe_fragments_offered
    coalesced0 = stats.probe_fragments_coalesced
    br = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")
    assert 1 <= br.probe_fragments <= rep.num_shards
    offered = stats.probe_fragments_offered - offered0
    assert offered == B * rep.num_shards  # full routing: one per (query, shard)
    assert stats.probe_fragments_coalesced - coalesced0 == offered - br.probe_fragments
    assert all(len(h) == 5 for h in br.hits)


def test_batch_probe_shard_routing(built_cluster):
    """n_route restricts each query to the shards owning its nearest
    partitions; results still return k hits per query."""
    c, t, X, centers, rep = built_cluster
    Q = X[:6]
    br = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann", n_route=1)
    assert br.probe_fragments <= rep.num_shards
    assert all(len(h) == 5 for h in br.hits)
    # routed probes read no more than the full-fanout probe
    full = c.coordinator.probe_batch("emb", Q, 5, strategy="diskann")
    assert br.probe_fragments <= full.probe_fragments


def test_micro_batcher_matches_direct(built_cluster):
    c, t, X, centers, rep = built_cluster
    Q = X[:8]
    direct = c.coordinator.probe_batch("emb", Q, 5).hits
    with ProbeMicroBatcher(c.coordinator, "emb", max_batch=8, max_wait_s=0.1) as mb:
        hits = mb.probe_many(Q, k=5)
    _assert_same_hits(direct, hits)
    assert mb.stats.queries == len(Q)
    # concurrent submissions actually coalesced into few batch probes
    assert mb.stats.batches <= 2
    assert mb.stats.max_batch_seen >= 4


def test_frontend_execute_many_batches(built_cluster):
    c, t, X, centers, rep = built_cluster
    fe = SqlFrontend(c.coordinator)
    qs = [",".join(str(float(v)) for v in X[i]) for i in range(5)]
    sqls = [f"SELECT * FROM emb ORDER BY L2_DISTANCE(vec, [{q}]) LIMIT 5" for q in qs]
    stats = c.coordinator.scheduler.stats
    d0 = stats.dispatched
    batched = fe.execute_many(sqls)
    frags_batched = stats.dispatched - d0
    d0 = stats.dispatched
    single = [fe.execute(s) for s in sqls]
    frags_single = stats.dispatched - d0
    _assert_same_hits(single, batched)
    assert frags_batched < frags_single  # the whole block shared one wave


def test_frontend_batcher_attachment(built_cluster):
    c, t, X, centers, rep = built_cluster
    q = ",".join(str(float(v)) for v in X[3])
    sql = f"SELECT * FROM emb ORDER BY L2_DISTANCE(vec, [{q}]) LIMIT 4"
    plain = SqlFrontend(c.coordinator).execute(sql)
    with ProbeMicroBatcher(c.coordinator, "emb", max_wait_s=0.01) as mb:
        via_batcher = SqlFrontend(c.coordinator, batcher=mb).execute(sql)
    _assert_same_hits([plain], [via_batcher])


def test_micro_batcher_adaptive_sizing():
    """Adaptive sizing unit contract: a full drain with backlog doubles
    max_batch (up to the cap), a light drain with an idle queue halves it
    (down to the floor), steady state holds."""
    from concurrent.futures import Future

    from repro.runtime.coordinator import ProbeReport

    class _StubCoordinator:
        def probe_batch(self, table, queries, k, **kw):
            return ProbeReport(
                hits=[[] for _ in range(queries.shape[0])],
                strategy="stub", files_scanned=0, bytes_read=0,
            )

    mb = ProbeMicroBatcher(
        _StubCoordinator(), "t", max_batch=8, adaptive=True,
        min_batch=2, max_batch_cap=64,
    )
    mb._adapt(8, 4)
    assert mb.max_batch == 16 and mb.stats.grows == 1
    mb._adapt(16, 1)
    assert mb.max_batch == 32
    mb._adapt(20, 0)            # steady state: no resize
    assert mb.max_batch == 32
    mb._adapt(4, 0)
    assert mb.max_batch == 16 and mb.stats.shrinks == 1
    for _ in range(4):
        mb._adapt(1, 0)
    assert mb.max_batch == 2     # floored at min_batch
    mb.max_batch = 64
    mb._adapt(64, 10)
    assert mb.max_batch == 64    # capped at max_batch_cap

    # end-to-end: a pre-filled backlog grows the window on the first drains
    mb2 = ProbeMicroBatcher(
        _StubCoordinator(), "t", max_batch=4, adaptive=True,
        min_batch=2, max_batch_cap=64, max_wait_s=0.01,
    )
    from repro.serving.serve_loop import _Submission

    futs = []
    for i in range(40):
        f = Future()
        mb2._queue.put(_Submission(np.zeros(4, np.float32), 5, None, f))
        futs.append(f)
    with mb2:
        for f in futs:
            assert f.result(timeout=5.0) == []
    assert mb2.stats.grows >= 1
    assert mb2.max_batch > 4


def test_micro_batcher_bounded_queue_fails_fast():
    """max_queue backpressure: a submit that finds the queue full raises
    queue.Full immediately (counted in stats.rejected) instead of growing
    the backlog — probes already queued are unaffected."""
    import queue as queue_mod
    import threading

    from repro.runtime.coordinator import ProbeReport

    gate = threading.Event()
    entered = threading.Event()

    class _SlowCoordinator:
        def probe_batch(self, table, queries, k, **kw):
            entered.set()
            gate.wait(timeout=5.0)
            return ProbeReport(
                hits=[[] for _ in range(queries.shape[0])],
                strategy="stub", files_scanned=0, bytes_read=0,
            )

    mb = ProbeMicroBatcher(
        _SlowCoordinator(), "t", max_batch=1, max_wait_s=0.0, max_queue=2,
    )
    q = np.zeros(4, np.float32)
    with mb:
        f0 = mb.submit(q, k=5)          # drained, blocks inside probe_batch
        assert entered.wait(timeout=5.0)
        f1, f2 = mb.submit(q, k=5), mb.submit(q, k=5)  # fill the queue
        with pytest.raises(queue_mod.Full):
            mb.submit(q, k=5)
        assert mb.stats.rejected == 1
        gate.set()
        for f in (f0, f1, f2):
            assert f.result(timeout=5.0) == []
    assert mb.stats.queries == 3        # the rejected probe never ran


def test_micro_batcher_background_tail_compaction():
    """compact_tail_over: a drained batch reporting that many tail rows
    kicks off exactly one background Coordinator.compact_tail, off the
    serving path; below the threshold nothing happens."""
    import threading

    from repro.runtime.coordinator import ProbeReport

    compacted = threading.Event()

    class _TailCoordinator:
        def __init__(self, tail_rows):
            self.tail_rows = tail_rows
            self.calls = []

        def probe_batch(self, table, queries, k, **kw):
            return ProbeReport(
                hits=[[] for _ in range(queries.shape[0])],
                strategy="stub", files_scanned=0, bytes_read=0,
                tail_rows=self.tail_rows,
            )

        def compact_tail(self, table, index, *, threshold_rows):
            self.calls.append((table, index, threshold_rows))
            compacted.set()

    q = np.zeros(4, np.float32)
    coord = _TailCoordinator(tail_rows=128)
    with ProbeMicroBatcher(
        coord, "t", max_wait_s=0.01, compact_tail_over=100, index_name="idx"
    ) as mb:
        assert mb.submit(q, k=5).result(timeout=5.0) == []
        assert compacted.wait(timeout=5.0)
    assert coord.calls == [("t", "idx", 100)]
    assert mb.stats.compactions == 1

    # below the threshold the policy stays quiet
    coord2 = _TailCoordinator(tail_rows=10)
    with ProbeMicroBatcher(
        coord2, "t", max_wait_s=0.01, compact_tail_over=100, index_name="idx"
    ) as mb2:
        assert mb2.submit(q, k=5).result(timeout=5.0) == []
    assert coord2.calls == [] and mb2.stats.compactions == 0

    # the policy needs to know which index to fold into
    with pytest.raises(ValueError):
        ProbeMicroBatcher(coord, "t", compact_tail_over=100)
