"""Distributed runtime: build/probe/refresh protocols + fault tolerance.

These are the paper's §5–§7 protocols end-to-end, plus the scale-out
machinery from DESIGN.md §6: executor failure reassignment, straggler
speculation, elasticity, concurrent-refresh arbitration, tombstone-driven
shard rebuild."""

import numpy as np

from repro.core.vamana import brute_force_topk
from repro.lakehouse.table import LakehouseTable
from repro.runtime.coordinator import IndexConfig
from conftest import BUILT_CFG as CFG, clustered_vectors

# the shared session-scoped ``built_cluster`` fixture lives in conftest.py


def _recall(table, X, hits_lists, truth_ids):
    vecs_all, locs_all = table.scan_vectors()
    truth_locs = [
        {(locs_all[i].file_path, locs_all[i].row_group_id, locs_all[i].row_offset) for i in row}
        for row in truth_ids
    ]
    scores = []
    for hits, truth in zip(hits_lists, truth_locs):
        got = {(h.file_path, h.row_group, h.row_offset) for h in hits}
        scores.append(len(got & truth) / len(truth))
    return float(np.mean(scores))


def test_build_covers_all_vectors(built_cluster):
    c, t, X, centers, rep = built_cluster
    assert rep.vector_count == len(X)
    assert rep.num_shards == 3
    assert c.store.exists(rep.puffin_path)


def test_probe_strategies_and_recall(built_cluster):
    c, t, X, centers, rep = built_cluster
    rng = np.random.default_rng(1)
    Q = X[rng.choice(len(X), 12)]
    _, truth = brute_force_topk(X, Q, 10)
    pr_scan = c.coordinator.probe("emb", Q, 10, strategy="scan")
    assert _recall(t, X, pr_scan.hits, truth) == 1.0
    pr_dk = c.coordinator.probe("emb", Q, 10, strategy="diskann")
    assert _recall(t, X, pr_dk.hits, truth) >= 0.85
    pr_cent = c.coordinator.probe("emb", Q, 10, strategy="centroid", n_probe=4)
    assert _recall(t, X, pr_cent.hits, truth) >= 0.8
    # warm-cache index path reads less object-store data than the scan path
    # (cold probes pay the one-time shard-blob download, amortized at scale
    # — paper Table 2's warm column; measured at scale in bench_query_paths)
    pr_warm = c.coordinator.probe("emb", Q, 10, strategy="diskann")
    assert pr_warm.bytes_read < pr_scan.bytes_read


def test_probe_cache_warm(built_cluster):
    c, t, X, centers, rep = built_cluster
    Q = X[:4]
    c.coordinator.probe("emb", Q, 5, strategy="diskann")
    pr = c.coordinator.probe("emb", Q, 5, strategy="diskann")
    assert pr.cache_hits == pr.shards_probed  # L1/SSD cache hit on all shards


def test_executor_failure_reassignment(tmp_path):
    from repro.runtime.cluster import make_local_cluster

    rng = np.random.default_rng(2)
    c = make_local_cluster(str(tmp_path), num_executors=3, max_attempts=5)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=16)
    X, _ = clustered_vectors(rng, n_clusters=8, per_cluster=60, dim=16)
    t.append_vectors(X, num_files=6)
    # one executor dies mid-wave: its fragments must be reassigned
    c.executors[1].fail_next(1)
    rep = c.coordinator.create_index("emb", IndexConfig(name="idx", **CFG))
    assert rep.vector_count == len(X)
    assert c.coordinator.scheduler.stats.reassigned >= 1


def test_dead_executor_probe_survives(built_cluster):
    c, t, X, centers, rep = built_cluster
    # a heartbeat-dead executor is excluded proactively; the probe succeeds
    c.executors[0].kill()
    try:
        pr = c.coordinator.probe("emb", X[:2], 5, strategy="diskann")
        assert len(pr.hits) == 2
    finally:
        c.executors[0].revive()
    # mid-flight failures (dispatched then died) are reassigned: make every
    # executor fail its next task — all first attempts die, retries succeed
    before = c.coordinator.scheduler.stats.reassigned
    for ex in c.executors:
        ex.fail_next(1)
    pr = c.coordinator.probe("emb", X[:2], 5, strategy="diskann")
    assert len(pr.hits) == 2
    assert c.coordinator.scheduler.stats.reassigned > before


def test_straggler_speculation(tmp_path):
    from repro.runtime.cluster import make_local_cluster

    rng = np.random.default_rng(3)
    c = make_local_cluster(str(tmp_path), num_executors=3, enable_speculation=True)
    c.coordinator.scheduler.speculation_factor = 2.0
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=16)
    X, _ = clustered_vectors(rng, n_clusters=8, per_cluster=60, dim=16)
    t.append_vectors(X, num_files=6)
    c.coordinator.create_index("emb", IndexConfig(name="idx", **CFG))
    # warm up first (jit compile + caches) so the wave's median latency is
    # small; then a 2 s straggler is far beyond speculation_factor × median
    c.coordinator.probe("emb", X[:2], 5, strategy="diskann")
    c.executors[2].delay_next(2.0)
    pr = c.coordinator.probe("emb", X[:2], 5, strategy="diskann")
    assert len(pr.hits) == 2
    assert c.coordinator.scheduler.stats.speculative >= 1


def test_elastic_scale_out_and_in(built_cluster):
    c, t, X, centers, rep = built_cluster
    ex = c.add_executor()  # new empty-cache executor joins
    pr = c.coordinator.probe("emb", X[:2], 5, strategy="diskann")
    assert len(pr.hits) == 2
    c.remove_executor(ex.executor_id)
    pr = c.coordinator.probe("emb", X[:2], 5, strategy="diskann")
    assert len(pr.hits) == 2


def test_refresh_insert_and_tombstone(built_cluster):
    c, t, X, centers, rep = built_cluster
    rng = np.random.default_rng(4)
    Y = (centers[3] + rng.normal(size=(150, 32))).astype(np.float32)
    t.append_vectors(Y, num_files=1, file_prefix="delta")
    doomed = t.current_files()[0].path
    t.delete_files([doomed])
    rr = c.coordinator.refresh_index("emb", "idx")
    assert rr.inserted == 150
    assert rr.tombstoned > 0
    # new vectors findable; deleted file gone
    Q = Y[:6]
    pr = c.coordinator.probe("emb", Q, 8, strategy="diskann")
    flat = [h for hits in pr.hits for h in hits]
    assert any("delta" in h.file_path for h in flat)
    assert not any(h.file_path == doomed for h in flat)
    # no-op refresh detected
    rr2 = c.coordinator.refresh_index("emb", "idx")
    assert rr2.noop


def test_tombstone_threshold_triggers_shard_rebuild(tmp_path):
    from repro.runtime.cluster import make_local_cluster

    rng = np.random.default_rng(5)
    c = make_local_cluster(str(tmp_path), num_executors=2)
    t = LakehouseTable(c.catalog, "emb")
    t.create(dim=16)
    X, _ = clustered_vectors(rng, n_clusters=4, per_cluster=120, dim=16)
    t.append_vectors(X, num_files=4)
    # R/L match CFG so the jit'd beam-search compilations are shared with
    # the rest of the suite (a distinct L would recompile per shape)
    c.coordinator.create_index("emb", IndexConfig(name="idx", R=16, L=32,
                                                  partitions_per_shard=2, build_passes=1))
    # delete half the files -> some shard crosses the 20% tombstone ratio
    files = [f.path for f in t.current_files()]
    t.delete_files(files[:2])
    rr = c.coordinator.refresh_index("emb", "idx")
    assert rr.tombstoned > 0
    assert rr.shards_rebuilt >= 1
    # post-rebuild probe still correct on remaining data
    vecs, locs = t.scan_vectors()
    pr = c.coordinator.probe("emb", vecs[:4], 5, strategy="diskann")
    assert all(len(h) == 5 for h in pr.hits)


def test_time_travel_probe(built_cluster):
    c, t, X, centers, rep = built_cluster
    pr = c.coordinator.probe("emb", X[:2], 5, snapshot_id=rep.snapshot_id)
    assert len(pr.hits) == 2
