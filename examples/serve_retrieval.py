"""End-to-end driver: retrieval-augmented serving with a snapshot-bound index.

    PYTHONPATH=src python examples/serve_retrieval.py

The paper's kind is a serving-infrastructure paper, so the end-to-end driver
serves: a small LM handles batched decode requests while a kNN-LM probe
against the Puffin-backed index (built from lakehouse embeddings through the
full §5 protocol) interpolates its output distribution.  Reports decode
throughput with and without retrieval.
"""

import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.core.blobs import SHARD_BLOB_TYPE, decode_shard_blob
from repro.iceberg.puffin import PuffinReader
from repro.lakehouse.table import LakehouseTable
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build_model
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig
from repro.serving.device_index import DeviceAnnIndex, make_probe_fn
from repro.serving.serve_loop import ServeConfig, make_serve_fns


def main() -> None:
    rng = np.random.default_rng(0)
    cfg = dataclasses.replace(reduced(get_config("qwen2.5-3b")), num_layers=4)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    mesh = make_debug_mesh(1, 1)
    d = cfg.d_model

    # -- 1. embedding corpus lives in the lakehouse --------------------------
    print("== corpus -> lakehouse -> CREATE INDEX ==")
    cluster = make_local_cluster(tempfile.mkdtemp(), num_executors=2)
    table = LakehouseTable(cluster.catalog, "memories")
    table.create(dim=d)
    # corpus: lm_head-space embeddings of corpus tokens (kNN-LM keys)
    corpus_tokens = rng.integers(0, cfg.vocab_size, size=4000).astype(np.int64)
    head = np.asarray(params["lm_head"], np.float32)  # (d, V)
    corpus_vecs = head[:, corpus_tokens].T + 0.01 * rng.normal(size=(4000, d)).astype(np.float32)
    table.append_vectors(corpus_vecs.astype(np.float32), num_files=4)
    rep = cluster.coordinator.create_index(
        "memories", IndexConfig(name="mem_idx", R=16, L=32,
                                partitions_per_shard=2, build_passes=1, build_batch=256),
    )
    print(f"  index built: {rep.num_shards} shards, bound to snapshot {rep.snapshot_id}")

    # -- 2. upload the snapshot's shards into device HBM ---------------------
    reader = PuffinReader(
        cluster.store.stat(rep.puffin_path).size, cluster.store.range_reader(rep.puffin_path)
    )
    graphs, payloads = [], []
    for bm in reader.blobs_of_type(SHARD_BLOB_TYPE):
        g, locmap = decode_shard_blob(reader.read_blob(bm))
        graphs.append(g)
        # payload: the corpus token of each indexed vector (kNN-LM value).
        # row offsets were assigned per shard; recover via global row id.
        rows = locmap.row_offset[: g.n].astype(np.int64) + 1000 * locmap.file_idx[: g.n]
        payloads.append(corpus_tokens[np.clip(rows, 0, len(corpus_tokens) - 1)])
    index = DeviceAnnIndex.from_graphs(graphs, payloads=payloads)
    probe = make_probe_fn(mesh, k=8, L=32)

    # -- 3. batched serving, with and without retrieval ----------------------
    B, prompt_len, gen_len = 8, 16, 32
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size, size=(B, prompt_len)))

    def run(retrieval, label):
        serve_cfg = ServeConfig(knn_lambda=0.3 if retrieval else 0.0)
        prefill, decode, sample, _ = make_serve_fns(
            model, mesh, cfg=serve_cfg,
            retrieval=probe if retrieval else None,
            index_template=index if retrieval else None,
            batch_hint=B, max_len_hint=prompt_len + gen_len,
        )
        cache = model.init_cache(B, prompt_len + gen_len)
        with mesh:
            logits, cache = prefill(params, prompts, cache)
            tok = sample(logits, jax.random.PRNGKey(0))
            t0 = time.perf_counter()
            for t in range(prompt_len, prompt_len + gen_len):
                if retrieval:
                    logits, cache = decode(params, tok, cache, jnp.int32(t), index)
                else:
                    logits, cache = decode(params, tok, cache, jnp.int32(t))
                tok = sample(logits, jax.random.PRNGKey(t))
            jax.block_until_ready(tok)
        dt = time.perf_counter() - t0
        tps = B * gen_len / dt
        print(f"  {label:22s} {tps:8.1f} tok/s  ({dt/gen_len*1e3:.1f} ms/step, batch {B})")
        return tok

    print("== batched serving ==")
    run(False, "decode")
    run(True, "decode + kNN-LM probe")
    print("done.")


if __name__ == "__main__":
    main()
