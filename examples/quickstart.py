"""Quickstart: the paper's full index lifecycle in ~60 seconds on a laptop.

    PYTHONPATH=src python examples/quickstart.py

Creates a lakehouse table of embeddings, builds a Puffin-backed Vamana index
(3-stage distributed build over 4 in-process executors), probes it with all
three strategies, appends + deletes data, refreshes the index incrementally,
and shows time travel + orphan GC.
"""

import tempfile

import numpy as np

from repro.core.vamana import brute_force_topk
from repro.iceberg.gc import expire_and_collect
from repro.lakehouse.table import LakehouseTable
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig


def main() -> None:
    rng = np.random.default_rng(0)
    cluster = make_local_cluster(tempfile.mkdtemp(), num_executors=4)
    table = LakehouseTable(cluster.catalog, "documents")
    dim = 64
    table.create(dim=dim)

    print("== ingest ==")
    centers = rng.normal(size=(32, dim)) * 4
    X = np.concatenate([c + rng.normal(size=(400, dim)) for c in centers]).astype(np.float32)
    rng.shuffle(X)
    meta = table.append_vectors(X, num_files=16, rows_per_group=512)
    print(f"  {len(X)} vectors in {len(table.current_files())} parquet files, "
          f"snapshot {meta.current_snapshot_id}")

    print("== CREATE INDEX (3-stage distributed build) ==")
    rep = cluster.coordinator.create_index(
        "documents",
        IndexConfig(name="docs_idx", R=24, L=48, pq_m=16, pq_nbits=8,
                    partitions_per_shard=4, build_passes=1, build_batch=256),
    )
    print(f"  shards={rep.num_shards} vectors={rep.vector_count} "
          f"puffin={rep.total_bytes/1e6:.1f}MB")
    print(f"  stage0(sample+kmeans)={rep.stage0_seconds:.1f}s "
          f"stage1(parallel build)={rep.stage1_seconds:.1f}s "
          f"stage2(assemble+commit)={rep.stage2_seconds:.1f}s")
    print(f"  bound to snapshot via statistics-file: {rep.puffin_path}")

    print("== probe ==")
    Q = X[rng.choice(len(X), 16)] + 0.05 * rng.normal(size=(16, dim)).astype(np.float32)
    _, truth = brute_force_topk(X, Q, 10)
    vecs_all, locs_all = table.scan_vectors()
    tl = [{(locs_all[i].file_path, locs_all[i].row_group_id, locs_all[i].row_offset)
           for i in row} for row in truth]
    for strategy, kw in (("scan", {}), ("centroid", {"n_probe": 4}), ("diskann", {})):
        pr = cluster.coordinator.probe("documents", Q, 10, strategy=strategy, use_pq=False, **kw) if strategy == "diskann" else cluster.coordinator.probe("documents", Q, 10, strategy=strategy, **kw)
        rec = np.mean([
            len({(h.file_path, h.row_group, h.row_offset) for h in hits} & t) / len(t)
            for hits, t in zip(pr.hits, tl)
        ])
        print(f"  {strategy:9s} recall@10={rec:.3f} files={pr.files_scanned:3d} "
              f"S3_bytes={pr.bytes_read/1e6:7.2f}MB")

    print("== churn + REFRESH INDEX ==")
    Y = (centers[3] + rng.normal(size=(800, dim))).astype(np.float32)
    table.append_vectors(Y, num_files=2, file_prefix="delta")
    doomed = table.current_files()[0].path
    table.delete_files([doomed])
    rr = cluster.coordinator.refresh_index("documents", "docs_idx")
    print(f"  inserted={rr.inserted} tombstoned={rr.tombstoned} "
          f"rebuilt={rr.shards_rebuilt} in {rr.seconds:.1f}s (metadata-only commit)")

    print("== time travel ==")
    pr_old = cluster.coordinator.probe("documents", Q[:2], 5, snapshot_id=rep.snapshot_id)
    print(f"  probe AS OF old snapshot: {len(pr_old.hits)} result sets (old index version)")

    print("== orphan GC ==")
    orphans = expire_and_collect(
        cluster.store, cluster.catalog.load_table("documents"), keep_last=1, delete=True
    )
    print(f"  reclaimed {len(orphans)} objects (superseded Puffin + shard blobs)")
    print("done.")


if __name__ == "__main__":
    main()
