"""Fault-tolerance tour: kill executors, inject stragglers, scale elastically.

    PYTHONPATH=src python examples/fault_tolerance_demo.py

Runs the paper's distributed build/probe protocols while the fleet degrades:
an executor dies mid-build (fragments reassigned), another straggles during
probe (speculative backup task wins), the pool scales out and a fresh
executor serves from cold caches — all without client-visible failures.
"""

import tempfile

import numpy as np

from repro.lakehouse.table import LakehouseTable
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig


def main() -> None:
    rng = np.random.default_rng(0)
    cluster = make_local_cluster(tempfile.mkdtemp(), num_executors=4,
                                 enable_speculation=True, max_attempts=5)
    cluster.coordinator.scheduler.speculation_factor = 2.0
    table = LakehouseTable(cluster.catalog, "emb")
    dim = 48
    table.create(dim=dim)
    centers = rng.normal(size=(16, dim)) * 4
    X = np.concatenate([c + rng.normal(size=(250, dim)) for c in centers]).astype(np.float32)
    rng.shuffle(X)
    table.append_vectors(X, num_files=8, rows_per_group=512)

    print("== build with one executor failing mid-wave ==")
    cluster.executors[1].fail_next(1)
    rep = cluster.coordinator.create_index(
        "emb", IndexConfig(name="idx", R=16, L=32, partitions_per_shard=2,
                           build_passes=1, build_batch=256),
    )
    st = cluster.coordinator.scheduler.stats
    print(f"  built {rep.num_shards} shards / {rep.vector_count} vectors "
          f"(reassigned={st.reassigned}, failures_seen={st.failures_seen})")

    print("== probe with a dead executor ==")
    cluster.executors[0].kill()
    pr = cluster.coordinator.probe("emb", X[:4], 5, strategy="diskann")
    print(f"  {len(pr.hits)} result sets despite ex-0 down "
          f"(reassigned={cluster.coordinator.scheduler.stats.reassigned})")
    cluster.executors[0].revive()

    print("== probe with a straggler (speculative backup) ==")
    cluster.executors[2].delay_next(3.0)
    pr = cluster.coordinator.probe("emb", X[:4], 5, strategy="diskann")
    print(f"  done; speculative launches so far: "
          f"{cluster.coordinator.scheduler.stats.speculative}")

    print("== elastic scale-out: fresh executor, cold caches ==")
    ex = cluster.add_executor()
    pr = cluster.coordinator.probe("emb", X[:4], 5, strategy="diskann")
    print(f"  {ex.executor_id} joined; probe ok "
          f"(hits={ex.cache_hits}, misses={ex.cache_misses})")
    cluster.remove_executor(ex.executor_id)
    print("  scaled back in — executor state was only a cache. done.")


if __name__ == "__main__":
    main()
