"""Fault-tolerant training with snapshot-bound checkpoints.

    PYTHONPATH=src python examples/train_checkpointed.py [--steps 40]

Trains a small qwen2.5-family model on the synthetic pipeline with the full
production train step (microbatched grad accumulation, remat, AdamW), saving
async checkpoints through the Iceberg-style catalog; then simulates a crash
and resumes from the latest committed snapshot, verifying the loss
trajectory continues exactly.  (The production-size version of this loop is
``repro.launch.train``; the 100M+ configs are exercised via the dry-run.)
"""

import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced
from repro.data.pipeline import SyntheticTokens
from repro.iceberg.catalog import RestCatalog
from repro.lakehouse.objectstore import ObjectStore
from repro.launch.mesh import make_debug_mesh
from repro.models.model import build_model
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import adamw_init
from repro.training.train_loop import TrainStepConfig, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--d-model", type=int, default=256)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        reduced(get_config("qwen2.5-3b")),
        num_layers=args.layers, d_model=args.d_model, d_ff=args.d_model * 4,
        num_heads=8, num_kv_heads=2, head_dim=args.d_model // 8, vocab_size=2048,
    )
    model = build_model(cfg)
    mesh = make_debug_mesh(1, 1)
    step, _ = make_train_step(
        model, mesh, cfg=TrainStepConfig(microbatches=2, lr=1e-3, remat=True)
    )
    params = model.init(jax.random.PRNGKey(0))
    opt = adamw_init(params)
    print(f"model: {model.param_count(params)/1e6:.1f}M params "
          f"({cfg.num_layers}L × d{cfg.d_model})")

    store = ObjectStore(tempfile.mkdtemp())
    mgr = CheckpointManager(RestCatalog(store), async_save=True, keep_last=3)
    data = SyntheticTokens(vocab_size=cfg.vocab_size, seq_len=64, batch_size=8, seed=0)

    crash_at = args.steps // 2
    t0 = time.time()
    for i in range(crash_at + 3):
        ids, labels = data.batch(i)
        params, opt, m = step(params, opt, jnp.asarray(ids), jnp.asarray(labels))
        if i % 5 == 0 or i == crash_at:
            mgr.save(i, {"params": params, "opt": opt}, metrics={"loss": m["loss"]})
            print(f"  step {i:3d} loss {float(m['loss']):.3f} "
                  f"gnorm {float(m['grad_norm']):.2f}  [checkpointed]")
        else:
            print(f"  step {i:3d} loss {float(m['loss']):.3f}")
    mgr.wait()

    print(f"== simulated crash after step {crash_at + 2}; resuming from catalog ==")
    like = {"params": jax.tree.map(jnp.zeros_like, params),
            "opt": jax.tree.map(jnp.zeros_like, opt)}
    restored, at = mgr.restore(like)
    params, opt = restored["params"], restored["opt"]
    print(f"  restored committed step {at} "
          f"(available: {mgr.available_steps()})")
    for i in range(at + 1, args.steps):
        ids, labels = data.batch(i)
        params, opt, m = step(params, opt, jnp.asarray(ids), jnp.asarray(labels))
        if i % 5 == 0:
            print(f"  step {i:3d} loss {float(m['loss']):.3f}")
    print(f"done in {time.time()-t0:.0f}s — final loss {float(m['loss']):.3f}")


if __name__ == "__main__":
    main()
