"""Filtered vector search: predicate-aware probes and the probe-plan IR.

    PYTHONPATH=src python examples/filtered_search.py

Builds a single large shard (above the planner's EXACT_SCAN_MAX_ROWS cap)
with an attribute column, then sweeps predicate selectivity to show the
planner picking a different op per band — the predicate-aware MaskedBeam
traversal at low/mid selectivity, the over-fetched PostfilterBeam when
nearly everything passes — and inspects the ``ProbeReport.plan`` artifact:
selectivity evidence, per-shard ops, traversal/fallback accounting, and a
JSON round-trip replayed through ``probe_batch(replay_plan=...)``.
"""

import json
import tempfile

import numpy as np

from repro.lakehouse.table import LakehouseTable
from repro.runtime.cluster import make_local_cluster
from repro.runtime.coordinator import IndexConfig
from repro.runtime.planner import ProbePlan


def recall(oracle_hits, got_hits):
    loc = lambda hits: {(h.file_path, h.row_group, h.row_offset) for h in hits}
    return np.mean([
        len(loc(a) & loc(b)) / max(len(loc(a)), 1)
        for a, b in zip(oracle_hits, got_hits)
    ])


def main() -> None:
    rng = np.random.default_rng(0)
    cluster = make_local_cluster(tempfile.mkdtemp(), num_executors=2)
    table = LakehouseTable(cluster.catalog, "products")
    dim = 32
    table.create(dim=dim)

    print("== ingest: 5000 vectors with a uniform int `price` attribute ==")
    centers = rng.normal(size=(10, dim)) * 3.0
    X = np.concatenate(
        [c + rng.normal(size=(500, dim)) for c in centers]
    ).astype(np.float32)
    price = rng.integers(0, 100, size=len(X)).astype(np.int64)
    table.append_vectors(X, num_files=4, rows_per_group=250,
                         attributes={"price": price})

    # ONE shard of 5000 rows: too big for a masked linear scan, so filtered
    # probes must either traverse the graph predicate-aware (MaskedBeam) or
    # over-fetch and post-filter (PostfilterBeam)
    print("== CREATE INDEX (single 5000-row shard) ==")
    rep = cluster.coordinator.create_index(
        "products",
        IndexConfig(name="idx", num_shards=1, R=24, L=48,
                    partitions_per_shard=4, build_passes=1, build_batch=256),
    )
    print(f"  shards={rep.num_shards} vectors={rep.vector_count}")

    Q = X[rng.choice(len(X), 16)] + 0.05 * rng.normal(size=(16, dim)).astype(
        np.float32
    )

    print("== selectivity sweep: one predicate, three plan bands ==")
    for where in ("price < 5", "price < 30", "price < 95"):
        oracle = cluster.coordinator.probe_batch(
            "products", Q, 10, strategy="scan", filter=where
        )
        pr = cluster.coordinator.probe_batch(
            "products", Q, 10, strategy="diskann", filter=where, L=128
        )
        print(f"  {where:12s} est_frac={pr.est_selectivity:.2f} "
              f"plan[{pr.filter_plan}] recall@10={recall(oracle.hits, pr.hits):.3f} "
              f"mbeam_rows={pr.masked_beam_rows} "
              f"fallbacks={pr.masked_beam_fallbacks} "
              f"kernel_dispatches={pr.kernel_dispatches}")

    print("== the plan is an artifact: serialize, then replay ==")
    fresh = cluster.coordinator.probe_batch(
        "products", Q, 10, strategy="diskann", filter="price < 30", L=128
    )
    wire = json.dumps(fresh.plan.to_json())  # e.g. persisted next to a report
    print(f"  plan JSON: {len(wire)} bytes, ops for query 0: "
          f"{[op.to_json() for op in fresh.plan.ops[0].values()]}")
    replay = cluster.coordinator.probe_batch(
        "products", Q, 10, strategy="diskann", filter="price < 30", L=128,
        replay_plan=ProbePlan.from_json(json.loads(wire)),
    )
    same = all(
        [(h.file_path, h.row_group, h.row_offset) for h in a]
        == [(h.file_path, h.row_group, h.row_offset) for h in b]
        for a, b in zip(fresh.hits, replay.hits)
    )
    print(f"  replayed plan ({replay.filter_plan}): identical hits = {same}")

    print("== single probe: per-query report carries the same plan ==")
    one = cluster.coordinator.probe(
        "products", Q[0], 10, strategy="diskann", filter="price < 30", L=128
    )
    print(f"  filter_plan={one.filter_plan} "
          f"est={one.est_selectivity:.2f} hits={len(one.hits[0])}")
    print("done.")


if __name__ == "__main__":
    main()
