"""Per-tenant admission control and typed degradation policies.

Two pressure valves for the serving tier, applied in order:

1. **Admission** (:class:`AdmissionController`) — each tenant gets a token
   bucket (:class:`TokenBucket`) sized by its :class:`TenantPolicy`.  A
   ``submit`` that finds the bucket empty is rejected *at the door* with
   :class:`AdmissionRejected` before it can occupy queue space — an abusive
   tenant burns its own budget, not the shared queue.

2. **Degradation** (:class:`DegradationPolicy`) — once admitted, a drain
   under pressure trades answer quality for latency through an ordered list
   of typed steps (:class:`ShrinkK`, :class:`DropOversample`,
   :class:`SkipTail`), each armed at its own pressure threshold.  Steps
   transform a :class:`ProbeParams` and leave a label trail so degraded
   answers are never silent (``ProbeReport.degraded``).

Deadlines are enforced by the micro-batcher (drop-before-dispatch and
reject-after-late-completion) with :class:`DeadlineExceeded`; the exception
type lives here with the other serving-tier refusals.

Pure stdlib — no jax, no runtime imports; unit-testable with an injected
clock.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field, replace
from typing import Callable, Dict, List, Optional, Tuple

from repro.serving.metrics import MetricsRegistry


class AdmissionRejected(RuntimeError):
    """Raised by ``submit`` when a tenant's token bucket is empty."""

    def __init__(self, tenant: str) -> None:
        super().__init__(f"tenant {tenant!r} over admission rate; probe rejected")
        self.tenant = tenant


class DeadlineExceeded(RuntimeError):
    """The query's deadline passed before its result could be served.

    Set on the submission Future either when the drainer drops an
    already-expired query (never dispatched) or when a probe completes
    after the deadline (computed but refused — never served silently
    late)."""

    def __init__(self, tenant: str, overrun_s: float) -> None:
        super().__init__(
            f"deadline exceeded for tenant {tenant!r} by {overrun_s * 1e3:.1f} ms"
        )
        self.tenant = tenant
        self.overrun_s = overrun_s


class TokenBucket:
    """Classic token bucket: ``rate`` tokens/s refill, ``burst`` capacity."""

    def __init__(
        self,
        rate: float,
        burst: float,
        clock: Callable[[], float] = time.monotonic,
    ) -> None:
        if rate <= 0:
            raise ValueError("rate must be > 0")
        self.rate = float(rate)
        self.burst = max(1.0, float(burst))
        self._clock = clock
        self._lock = threading.Lock()
        self._tokens = self.burst
        self._last = clock()

    def try_acquire(self, n: float = 1.0) -> bool:
        now = self._clock()
        with self._lock:
            self._tokens = min(self.burst, self._tokens + (now - self._last) * self.rate)
            self._last = now
            if self._tokens >= n:
                self._tokens -= n
                return True
            return False


@dataclass(frozen=True)
class TenantPolicy:
    """Admission budget for one tenant.  ``rate_qps=None`` means unlimited
    (the tenant always admits — useful as a trusted-tenant default)."""

    rate_qps: Optional[float] = None
    burst: float = 16.0


class AdmissionController:
    """Token-bucket admission per tenant.

    ``policies`` maps tenant name → :class:`TenantPolicy`; tenants not in
    the map fall back to ``default`` (unlimited unless configured).  All
    decisions are counted per tenant in the attached registry
    (``admissions[t]`` / ``admission_rejected[t]``).
    """

    def __init__(
        self,
        policies: Optional[Dict[str, TenantPolicy]] = None,
        *,
        default: TenantPolicy = TenantPolicy(),
        clock: Callable[[], float] = time.monotonic,
        metrics: Optional[MetricsRegistry] = None,
    ) -> None:
        self.policies = dict(policies or {})
        self.default = default
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._clock = clock
        self._lock = threading.Lock()
        self._buckets: Dict[str, Optional[TokenBucket]] = {}

    def _bucket(self, tenant: str) -> Optional[TokenBucket]:
        with self._lock:
            if tenant not in self._buckets:
                policy = self.policies.get(tenant, self.default)
                self._buckets[tenant] = (
                    TokenBucket(policy.rate_qps, policy.burst, self._clock)
                    if policy.rate_qps is not None
                    else None  # unlimited
                )
            return self._buckets[tenant]

    def admit(self, tenant: str) -> bool:
        bucket = self._bucket(tenant)
        ok = bucket is None or bucket.try_acquire()
        name = "admissions" if ok else "admission_rejected"
        self.metrics.counter(name, tenant).inc()
        return ok


# -- degradation ----------------------------------------------------------

@dataclass(frozen=True)
class ProbeParams:
    """The knobs a degradation step may turn, in probe_batch terms."""

    k: int
    oversample: Optional[int] = None  # None → the index's configured value
    include_tail: bool = True


@dataclass(frozen=True)
class DegradeStep:
    """One typed quality/latency trade, armed at ``at_pressure`` ∈ [0, 1]."""

    at_pressure: float = 1.0

    def label(self) -> str:
        return type(self).__name__.lower()

    def apply(self, params: ProbeParams) -> ProbeParams:  # pragma: no cover
        raise NotImplementedError


@dataclass(frozen=True)
class ShrinkK(DegradeStep):
    """Halve (by ``factor``) the requested k, floored at ``min_k`` — the
    caller still gets its strongest neighbors, just fewer of them."""

    at_pressure: float = 0.5
    factor: float = 0.5
    min_k: int = 1

    def label(self) -> str:
        return f"shrink_k(x{self.factor:g})"

    def apply(self, params: ProbeParams) -> ProbeParams:
        k = max(self.min_k, int(params.k * self.factor))
        return replace(params, k=min(k, params.k))


@dataclass(frozen=True)
class DropOversample(DegradeStep):
    """Rerank only ``to``× k candidates instead of the index's configured
    oversample — cheaper stage B at a small recall cost."""

    at_pressure: float = 0.75
    to: int = 1

    def label(self) -> str:
        return f"drop_oversample(to={self.to})"

    def apply(self, params: ProbeParams) -> ProbeParams:
        return replace(params, oversample=max(1, self.to))


@dataclass(frozen=True)
class SkipTail(DegradeStep):
    """Skip the exact fresh-tail scan: serve from the indexed snapshot only
    (results may miss rows appended since the last index refresh)."""

    at_pressure: float = 0.9

    def label(self) -> str:
        return "skip_tail"

    def apply(self, params: ProbeParams) -> ProbeParams:
        return replace(params, include_tail=False)


def default_degradation_steps() -> Tuple[DegradeStep, ...]:
    return (ShrinkK(), DropOversample(), SkipTail())


@dataclass(frozen=True)
class DegradationPolicy:
    """Ordered degradation ladder: at pressure ``p`` every step with
    ``at_pressure <= p`` applies, mildest first."""

    steps: Tuple[DegradeStep, ...] = field(default_factory=default_degradation_steps)

    def plan(self, pressure: float) -> Tuple[DegradeStep, ...]:
        armed = [s for s in self.steps if pressure >= s.at_pressure]
        return tuple(sorted(armed, key=lambda s: s.at_pressure))

    def apply(
        self, params: ProbeParams, pressure: float
    ) -> Tuple[ProbeParams, Tuple[str, ...]]:
        """Run the armed steps over ``params``; returns the degraded params
        and the label trail (empty when nothing applied)."""
        labels: List[str] = []
        for step in self.plan(pressure):
            new = step.apply(params)
            if new != params:
                labels.append(step.label())
                params = new
        return params, tuple(labels)
