"""Serving-tier observability: thread-safe counters and latency histograms.

The serving tier (admission control, deadline scheduling, lease failover)
emits its accounting through a :class:`MetricsRegistry` — a flat namespace
of named :class:`Counter`\\ s and :class:`Histogram`\\ s, optionally labeled
by tenant (``admissions[tenant-a]``).  Everything is in-process and cheap:
counters are a lock + int, histograms keep a bounded window of recent
observations so per-tenant p50/p99 stay O(window) to compute and O(1) to
record.

Nothing here imports jax or the runtime — the registry is safe to use from
any layer (scheduler, lease table, micro-batcher) without import cycles.
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Deque, Dict, Optional


class Counter:
    """Monotonic thread-safe counter."""

    __slots__ = ("_lock", "_value")

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._value = 0

    def inc(self, n: int = 1) -> None:
        with self._lock:
            self._value += n

    @property
    def value(self) -> int:
        with self._lock:
            return self._value


class Histogram:
    """Sliding-window histogram over the most recent ``window`` observations.

    Percentiles are computed over the window (nearest-rank), which is what a
    serving dashboard wants: recent latency, not lifetime latency.  ``count``
    and ``total`` are lifetime aggregates.
    """

    __slots__ = ("_lock", "_window", "_count", "_total")

    def __init__(self, window: int = 2048) -> None:
        self._lock = threading.Lock()
        self._window: Deque[float] = deque(maxlen=max(1, window))
        self._count = 0
        self._total = 0.0

    def observe(self, value: float) -> None:
        with self._lock:
            self._window.append(float(value))
            self._count += 1
            self._total += float(value)

    @property
    def count(self) -> int:
        with self._lock:
            return self._count

    @property
    def total(self) -> float:
        with self._lock:
            return self._total

    def percentile(self, p: float) -> float:
        """Nearest-rank percentile (``p`` in [0, 100]) over the window;
        0.0 when nothing has been observed."""
        with self._lock:
            data = sorted(self._window)
        if not data:
            return 0.0
        rank = min(len(data) - 1, max(0, round((p / 100.0) * (len(data) - 1))))
        return data[int(rank)]


class MetricsRegistry:
    """Named counters/histograms with an optional per-tenant label.

    ``registry.counter("admissions", tenant="a")`` returns (creating on
    first use) the counter registered under ``admissions[a]``; without a
    tenant the bare name is the key.  :meth:`snapshot` flattens everything
    into a plain dict for logs / reports / assertions.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: Dict[str, Counter] = {}
        self._histograms: Dict[str, Histogram] = {}

    @staticmethod
    def _key(name: str, tenant: Optional[str]) -> str:
        return f"{name}[{tenant}]" if tenant is not None else name

    def counter(self, name: str, tenant: Optional[str] = None) -> Counter:
        key = self._key(name, tenant)
        with self._lock:
            c = self._counters.get(key)
            if c is None:
                c = self._counters[key] = Counter()
            return c

    def histogram(
        self, name: str, tenant: Optional[str] = None, *, window: int = 2048
    ) -> Histogram:
        key = self._key(name, tenant)
        with self._lock:
            h = self._histograms.get(key)
            if h is None:
                h = self._histograms[key] = Histogram(window)
            return h

    def counter_value(self, name: str, tenant: Optional[str] = None) -> int:
        key = self._key(name, tenant)
        with self._lock:
            c = self._counters.get(key)
        return c.value if c is not None else 0

    def snapshot(self) -> Dict[str, float]:
        """Flat, JSON-able view: every counter's value plus each histogram's
        ``.count`` / ``.p50`` / ``.p99``."""
        with self._lock:
            counters = dict(self._counters)
            hists = dict(self._histograms)
        out: Dict[str, float] = {k: float(c.value) for k, c in counters.items()}
        for k, h in hists.items():
            out[f"{k}.count"] = float(h.count)
            out[f"{k}.p50"] = h.percentile(50)
            out[f"{k}.p99"] = h.percentile(99)
        return out
