"""Serving substrate: prefill/decode steps, device-resident ANN probe,
retrieval-augmented decoding, and the multi-tenant probe serving tier
(micro-batcher + admission control + leases + metrics).

The model-serving symbols (``make_serve_fns`` etc.) pull in jax and the
model stack, so they load lazily — the light serving-tier modules
(:mod:`repro.serving.leases`, :mod:`repro.serving.metrics`,
:mod:`repro.serving.admission`) stay importable from the runtime layer
without that weight.
"""

_LAZY = {
    "make_serve_fns": ("repro.serving.serve_loop", "make_serve_fns"),
    "ServeConfig": ("repro.serving.serve_loop", "ServeConfig"),
    "ProbeMicroBatcher": ("repro.serving.serve_loop", "ProbeMicroBatcher"),
    "MicroBatchStats": ("repro.serving.serve_loop", "MicroBatchStats"),
    "DeviceAnnIndex": ("repro.serving.device_index", "DeviceAnnIndex"),
    "make_probe_fn": ("repro.serving.device_index", "make_probe_fn"),
    "ShardProbeCache": ("repro.serving.cache", "ShardProbeCache"),
    "SemanticResultCache": ("repro.serving.cache", "SemanticResultCache"),
}

__all__ = sorted(_LAZY)


def __getattr__(name: str):
    try:
        module_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}") from None
    import importlib

    return getattr(importlib.import_module(module_name), attr)
