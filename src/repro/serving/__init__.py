"""Serving substrate: prefill/decode steps, device-resident ANN probe,
retrieval-augmented decoding (the paper's index fused into serve_step)."""

from repro.serving.serve_loop import make_serve_fns, ServeConfig  # noqa: F401
from repro.serving.device_index import DeviceAnnIndex, make_probe_fn  # noqa: F401
