"""Serving: pjit'd prefill/decode steps + retrieval-augmented decoding.

``make_serve_fns`` builds jit'd ``prefill_step`` and ``serve_step`` with
shardings from the logical rules.  With ``retrieval=`` an ANN probe
(:func:`repro.serving.device_index.make_probe_fn`) is fused into the decode
step: the last-layer hidden state queries the snapshot-bound index and the
retrieved neighbor tokens interpolate the output distribution (kNN-LM) —
the paper's index as a first-class serving feature.

:class:`ProbeMicroBatcher` is the front door for concurrent probe traffic:
callers ``submit()`` single queries from any thread; a drainer collects a
micro-batch (bounded by ``max_batch`` / ``max_wait_s``) and issues ONE
``Coordinator.probe_batch`` call, so coordinator routing, fragment
dispatch, and kernel launches amortize across whatever concurrency the
serving tier sees.
"""

from __future__ import annotations

import functools
import math
import queue as queue_mod
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding

from repro.models.model import Model, param_shapes
from repro.models.sharding import DEFAULT_RULES, LogicalRules, logical_to_sharding, spec_for
from repro.runtime.coordinator import ProbeReport
from repro.serving.admission import (
    AdmissionController,
    AdmissionRejected,
    DeadlineExceeded,
    DegradationPolicy,
    ProbeParams,
    TenantPolicy,
)
from repro.serving.device_index import DeviceAnnIndex
from repro.serving.metrics import MetricsRegistry


@dataclass
class MicroBatchStats:
    batches: int = 0
    queries: int = 0
    max_batch_seen: int = 0
    filtered_queries: int = 0
    # adaptive sizing: how often the drainer grew / shrank max_batch
    grows: int = 0
    shrinks: int = 0
    # masked top-k kernel calls the drained probes cost (mask-plane path:
    # one per scoring flavor per shard per batch, however many distinct
    # predicates the concurrent submitters carried)
    kernel_dispatches: int = 0
    # submissions refused because the bounded queue was full (fail-fast
    # backpressure — the caller saw queue.Full, no Future was created)
    rejected: int = 0
    # background fresh-tail compactions this batcher kicked off
    compactions: int = 0
    # ... and how many of those failed in the background (the daemon used
    # to swallow exceptions silently; now the last failure is recorded)
    compaction_errors: int = 0
    last_compaction_error: str = ""
    # serving tier: submissions refused at the door by per-tenant token
    # buckets (the caller saw AdmissionRejected, no Future was created)
    admission_rejected: int = 0
    # queries whose deadline passed — dropped before dispatch or refused
    # after a late completion; their Future got DeadlineExceeded, they were
    # never served silently late
    deadline_misses: int = 0
    # batches / queries served with a degraded (labeled) answer
    degraded_batches: int = 0
    degraded_queries: int = 0
    # serving-tier cache hierarchy (serving/cache.py): queries answered at
    # the door by the semantic result cache (no admission token, no
    # dispatch) vs queries that went through to a probe ...
    semantic_hits: int = 0
    semantic_misses: int = 0
    # ... Stage-A (query, shard) fragments the coordinator's shard-probe
    # cache answered across this batcher's drained probes ...
    shard_cache_hits: int = 0
    # ... semantic entries dropped because a refresh/compaction committed a
    # new snapshot (mirrors the attached cache's invalidation total), and
    # entries evicted by the semantic cache's byte budget while inserting
    # this batcher's answers (the shard cache's counters live on the cache)
    cache_invalidations: int = 0
    cache_evictions: int = 0


@dataclass
class _Submission:
    """One queued probe: the query plus its serving-tier envelope."""

    query: np.ndarray
    k: int
    filter: object
    fut: Future
    tenant: str = "default"
    deadline: Optional[float] = None  # monotonic seconds, None = no deadline
    submitted: float = field(default_factory=time.monotonic)


class ProbeMicroBatcher:
    """Drain a queue of concurrent single-query probes into ``probe_batch``.

    Usage::

        with ProbeMicroBatcher(coordinator, "docs", max_batch=64) as mb:
            fut = mb.submit(q, k=10)        # from any number of threads
            fut2 = mb.submit(q2, k=10, filter="category = 'news'")
            hits = fut.result()             # per-query ProbeHit list
            hits_lists = mb.probe_many(Q, k=10)   # sync convenience

    The drainer waits ``max_wait_s`` after the first pending request (or
    until ``max_batch`` accumulate), groups requests by ``k`` (a batch probe
    shares one k), and resolves each Future with its query's hits.  Filtered
    and unfiltered submissions batch together: per-query predicates ride the
    same ``probe_batch`` call, and a batch does NOT need filter-homogeneous
    traffic to hit the kernel fast path — the executors answer a coalesced
    fragment's kernel-planned queries with one multi-mask kernel call per
    shard however many distinct predicates the submitters carried
    (``stats.kernel_dispatches`` counts the calls).  Errors propagate to
    every Future in the failed batch.

    With ``adaptive=True`` the drainer resizes ``max_batch`` from observed
    queue depth instead of holding the configured constant: a full drain
    that leaves requests queued doubles it (up to ``max_batch_cap``), and a
    drain well under the current size with an idle queue halves it (down to
    ``min_batch``) — deeper backlog buys more coalescing, light traffic
    keeps latency low.

    ``max_queue`` bounds the submission queue: when set, a ``submit`` that
    finds it full fails fast with :class:`queue.Full` instead of queueing
    unboundedly (``stats.rejected`` counts the refusals) — backpressure the
    caller can see, instead of a probe latency that silently grows with the
    backlog.  Unset, the queue is unbounded (the legacy behavior).

    ``compact_tail_over`` (with ``index_name``) turns on the background
    fresh-tail compaction policy: when a drained batch reports at least
    that many tail rows (appended-but-unindexed, served via the exact tail
    tier), a daemon thread folds the tail into the Vamana shards with
    :meth:`Coordinator.compact_tail` — serving traffic keeps flowing
    against the stale-but-tail-served snapshot until the refresh commits.
    A failed background compaction is recorded in
    ``stats.compaction_errors`` / ``stats.last_compaction_error`` instead
    of vanishing with the daemon thread.

    **Multi-tenant serving.**  Each submission carries ``(tenant,
    deadline_ms)``.  With an :class:`AdmissionController` attached (pass
    ``admission=`` or the ``tenant_policies=`` convenience), a tenant over
    its token-bucket rate is refused at the door with
    :class:`AdmissionRejected` — before it can occupy queue space
    (``stats.admission_rejected``).  The drainer is deadline-aware:
    already-expired queries are dropped with :class:`DeadlineExceeded`
    (``stats.deadline_misses``) and never dispatched, earlier deadlines
    flush first, and a result that completes past its deadline is likewise
    refused — never served silently late.  Per-tenant latency histograms
    (p50/p99) and decision counters live in ``self.metrics``.

    **Degradation.**  With a :class:`DegradationPolicy` attached, a drain
    under pressure (queue depth vs. capacity, and batch-latency EMA vs. the
    tightest pending deadline) trades answer quality for latency through
    the policy's typed steps — shrink k, drop the rerank oversample, skip
    the fresh-tail scan — instead of queueing unboundedly.  Degraded
    answers are labeled on the report (``ProbeReport.degraded``) and
    counted (``stats.degraded_batches``).  ``force_degrade`` is the
    operator override: ``"auto"`` (pressure-driven), ``"on"`` (every step,
    always), ``"off"`` (policy ignored — behavior is bit-for-bit the
    pre-degradation serving path).

    Caveat: the coordinator's per-probe I/O accounting
    (``ProbeReport.bytes_read``) resets a store-global counter, so byte
    attribution is best-effort when OTHER threads probe the same
    coordinator concurrently with the drainer; hits are unaffected.
    """

    def __init__(
        self,
        coordinator,
        table_name: str,
        *,
        strategy: str = "auto",
        max_batch: int = 64,
        max_wait_s: float = 0.002,
        adaptive: bool = False,
        min_batch: int = 4,
        max_batch_cap: int = 512,
        max_queue: Optional[int] = None,
        compact_tail_over: Optional[int] = None,
        index_name: Optional[str] = None,
        admission: Optional[AdmissionController] = None,
        tenant_policies: Optional[Dict[str, TenantPolicy]] = None,
        degradation: Optional[DegradationPolicy] = None,
        force_degrade: str = "auto",
        metrics: Optional[MetricsRegistry] = None,
        semantic_cache=None,
        **probe_kwargs,
    ) -> None:
        self.coordinator = coordinator
        self.table_name = table_name
        self.strategy = strategy
        self.max_batch = max_batch
        self.max_wait_s = max_wait_s
        self.adaptive = adaptive
        self.min_batch = max(1, min_batch)
        self.max_batch_cap = max(max_batch, max_batch_cap)
        if compact_tail_over is not None and index_name is None:
            raise ValueError("compact_tail_over requires index_name")
        self.compact_tail_over = compact_tail_over
        self.index_name = index_name
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if admission is None and tenant_policies is not None:
            admission = AdmissionController(tenant_policies, metrics=self.metrics)
        self.admission = admission
        if force_degrade not in ("off", "auto", "on"):
            raise ValueError(f"force_degrade must be off/auto/on, got {force_degrade!r}")
        if degradation is None and force_degrade == "on":
            degradation = DegradationPolicy()
        self.degradation = degradation
        self.force_degrade = force_degrade
        # optional whole-answer SemanticResultCache (serving/cache.py):
        # consulted in submit() BEFORE admission — a hit costs no token
        self.semantic_cache = semantic_cache
        if semantic_cache is not None and semantic_cache.metrics is None:
            semantic_cache.metrics = self.metrics
        self.probe_kwargs = probe_kwargs
        self.stats = MicroBatchStats()
        self._stats_lock = threading.Lock()
        self._max_queue = max_queue
        self._latency_ema = 0.0  # EMA of drained-batch service time (s)
        self._queue: "queue_mod.Queue" = queue_mod.Queue(maxsize=max_queue or 0)
        self._thread: Optional[threading.Thread] = None
        self._compact_thread: Optional[threading.Thread] = None
        self._stop = threading.Event()

    # -- lifecycle --------------------------------------------------------
    def start(self) -> "ProbeMicroBatcher":
        if self._thread is None:
            if self.semantic_cache is not None and hasattr(
                self.coordinator, "register_result_cache"
            ):
                # push invalidation: a refresh/compaction commit moves the
                # semantic cache's snapshot watermark at the commit itself,
                # closing the window where a hit could serve a pre-commit
                # answer before any post-commit report is drained
                self.coordinator.register_result_cache(
                    self.table_name, self.semantic_cache
                )
            self._stop.clear()
            self._thread = threading.Thread(target=self._drain_loop, daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        if self.semantic_cache is not None and hasattr(
            self.coordinator, "unregister_result_cache"
        ):
            self.coordinator.unregister_result_cache(
                self.table_name, self.semantic_cache
            )
        if self._thread is not None:
            self._stop.set()
            self._thread.join(timeout=5.0)
            self._thread = None
        if self._compact_thread is not None:
            self._compact_thread.join(timeout=30.0)
            self._compact_thread = None
        # requests enqueued before stop() but never drained must not strand
        # their waiters — fail them loudly
        while True:
            try:
                sub = self._queue.get_nowait()
            except queue_mod.Empty:
                break
            if not sub.fut.done():
                sub.fut.set_exception(RuntimeError("micro-batcher stopped"))

    def __enter__(self) -> "ProbeMicroBatcher":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- submission -------------------------------------------------------
    def submit(
        self,
        query,
        k: int = 10,
        filter=None,
        *,
        tenant: str = "default",
        deadline_ms: Optional[float] = None,
    ) -> Future:
        """Enqueue one query; the Future resolves to its ProbeHit list.
        ``filter`` (a Predicate or SQL WHERE fragment) makes it a filtered
        probe — it shares the batch with unfiltered submissions.

        ``tenant`` attributes the query for admission control and per-tenant
        latency metrics; with an admission controller attached, a tenant
        over its rate gets :class:`AdmissionRejected` here (counted in
        ``stats.admission_rejected``; no Future is created).

        ``deadline_ms`` is a relative deadline: if the result cannot be
        delivered within that many milliseconds the Future fails with
        :class:`DeadlineExceeded` (``stats.deadline_misses``) — expired
        queries are dropped before dispatch, and late completions are
        refused rather than served silently late.

        With ``max_queue`` set, a full queue raises :class:`queue.Full`
        immediately (fail-fast backpressure; counted in
        ``stats.rejected``) instead of blocking or queueing unboundedly."""
        if self._thread is None:
            raise RuntimeError("micro-batcher is not running (call start())")
        q = np.asarray(query, np.float32).reshape(-1)
        if self.semantic_cache is not None:
            # semantic result cache: answered at the door — the hit consumes
            # NO admission token (the tenant didn't use any compute), skips
            # the queue, and resolves the Future immediately
            entry = self.semantic_cache.lookup(tenant, q, k, filter)
            if entry is not None:
                with self._stats_lock:
                    self.stats.semantic_hits += 1
                self.metrics.counter("served", tenant).inc()
                self.metrics.histogram("latency_ms", tenant).observe(0.0)
                fut = Future()
                fut.set_result(list(entry.hits))
                return fut
            with self._stats_lock:
                self.stats.semantic_misses += 1
        if self.admission is not None and not self.admission.admit(tenant):
            with self._stats_lock:
                self.stats.admission_rejected += 1
            raise AdmissionRejected(tenant)
        now = time.monotonic()
        sub = _Submission(
            query=q,
            k=k,
            filter=filter,
            fut=Future(),
            tenant=tenant,
            deadline=now + deadline_ms / 1e3 if deadline_ms is not None else None,
            submitted=now,
        )
        try:
            self._queue.put_nowait(sub)
        except queue_mod.Full:
            with self._stats_lock:
                self.stats.rejected += 1
            self.metrics.counter("queue_rejected", tenant).inc()
            raise
        return sub.fut

    def probe_many(
        self,
        queries,
        k: int = 10,
        filter=None,
        *,
        tenant: str = "default",
        deadline_ms: Optional[float] = None,
    ) -> List[list]:
        """Submit a block of queries and wait for all results (in order)."""
        futs = [
            self.submit(q, k, filter=filter, tenant=tenant, deadline_ms=deadline_ms)
            for q in queries
        ]
        return [f.result() for f in futs]

    # -- drainer ----------------------------------------------------------
    def _drain_loop(self) -> None:
        while not self._stop.is_set():
            try:
                first = self._queue.get(timeout=0.05)
            except queue_mod.Empty:
                continue
            pending = [first]
            deadline = time.monotonic() + self.max_wait_s
            while len(pending) < self.max_batch:
                remaining = deadline - time.monotonic()
                if remaining <= 0:
                    break
                try:
                    pending.append(self._queue.get(timeout=remaining))
                except queue_mod.Empty:
                    break
            self._flush(pending)
            if self.adaptive:
                self._adapt(len(pending), self._queue.qsize())

    def _adapt(self, drained: int, queue_depth: int) -> None:
        """Resize ``max_batch`` from observed load: a full drain with
        requests still queued means the window is too small (double it); a
        drain well under the window with an idle queue means it is too
        large (halve it).  Bounded by [min_batch, max_batch_cap]."""
        if drained >= self.max_batch and queue_depth > 0:
            grown = min(self.max_batch * 2, self.max_batch_cap)
            if grown > self.max_batch:
                self.max_batch = grown
                self.stats.grows += 1
        elif queue_depth == 0 and drained <= self.max_batch // 4:
            shrunk = max(self.max_batch // 2, self.min_batch)
            if shrunk < self.max_batch:
                self.max_batch = shrunk
                self.stats.shrinks += 1

    # -- deadline / pressure accounting -----------------------------------
    def _miss_deadline(self, sub: _Submission, now: float) -> None:
        with self._stats_lock:
            self.stats.deadline_misses += 1
        self.metrics.counter("deadline_misses", sub.tenant).inc()
        if not sub.fut.done():
            sub.fut.set_exception(
                DeadlineExceeded(sub.tenant, now - (sub.deadline or now))
            )

    def _pressure(self, pending: List[_Submission], now: float) -> float:
        """Serving pressure in [0, 1]: how full the queue is (drained batch
        + still-queued backlog vs. capacity), escalated when the observed
        batch service time (EMA) eats into the tightest pending deadline."""
        cap = self._max_queue if self._max_queue else 4 * self.max_batch
        p = min(1.0, (len(pending) + self._queue.qsize()) / max(1, cap))
        if self._latency_ema > 0.0:
            headrooms = [s.deadline - now for s in pending if s.deadline is not None]
            if headrooms:
                tightest = max(min(headrooms), 1e-6)
                p = max(p, min(1.0, self._latency_ema / tightest))
        return p

    def _flush(self, pending: list) -> None:
        now = time.monotonic()
        # deadline-aware: already-expired queries are rejected, not served
        # late; the survivors flush earliest-deadline-first (stable within
        # equal deadlines, deadline-free queries keep arrival order last)
        live: List[_Submission] = []
        for sub in pending:
            if sub.deadline is not None and now >= sub.deadline:
                self._miss_deadline(sub, now)
            else:
                live.append(sub)
        if not live:
            return
        live.sort(key=lambda s: s.deadline if s.deadline is not None else math.inf)
        degrade = self.degradation is not None and self.force_degrade != "off"
        pressure = 0.0
        if degrade:
            pressure = 1.0 if self.force_degrade == "on" else self._pressure(live, now)
        by_k: Dict[int, List[_Submission]] = {}
        for sub in live:
            by_k.setdefault(sub.k, []).append(sub)
        for k, items in by_k.items():
            queries = np.stack([s.query for s in items])
            filters = [s.filter for s in items]
            any_filtered = any(f is not None for f in filters)
            labels: Tuple[str, ...] = ()
            probe_kwargs = self.probe_kwargs
            k_eff = k
            if degrade:
                params, labels = self.degradation.apply(
                    ProbeParams(
                        k=k,
                        include_tail=self.probe_kwargs.get("include_tail", True),
                    ),
                    pressure,
                )
                if labels:
                    k_eff = params.k
                    probe_kwargs = dict(self.probe_kwargs)
                    probe_kwargs["include_tail"] = params.include_tail
                    if params.oversample is not None:
                        probe_kwargs["oversample"] = params.oversample
            try:
                report = self.coordinator.probe_batch(
                    self.table_name,
                    queries,
                    k_eff,
                    strategy=self.strategy,
                    filter=filters if any_filtered else None,
                    **probe_kwargs,
                )
            except Exception as exc:  # propagate to every waiter
                for s in items:
                    s.fut.set_exception(exc)
                continue
            if labels:
                report.degraded = labels
                for name in labels:
                    self.metrics.counter(f"degraded:{name}").inc()
            done = time.monotonic()
            batch_s = done - now
            self._latency_ema = (
                batch_s
                if self._latency_ema == 0.0
                else 0.8 * self._latency_ema + 0.2 * batch_s
            )
            with self._stats_lock:
                self.stats.batches += 1
                self.stats.queries += len(items)
                self.stats.filtered_queries += sum(
                    1 for f in filters if f is not None
                )
                self.stats.kernel_dispatches += report.kernel_dispatches
                self.stats.shard_cache_hits += getattr(report, "shard_cache_hits", 0)
                self.stats.max_batch_seen = max(self.stats.max_batch_seen, len(items))
                if labels:
                    self.stats.degraded_batches += 1
                    self.stats.degraded_queries += len(items)
            # semantic cache maintenance: the report's snapshot id is the
            # invalidation watermark (a refresh/compaction commit changes
            # it, evicting every answer computed against the old snapshot).
            # Answers are cacheable at the k they were ACTUALLY served at —
            # a shrink_k-degraded answer is keyed under its degraded k_eff
            # so it can never satisfy a later full-k query; other
            # degradation steps (drop_oversample, skip_tail) lower quality
            # at the same k, so those answers are not cached at all.
            cacheable = self.semantic_cache is not None and all(
                lbl.startswith("shrink_k") for lbl in labels
            )
            if self.semantic_cache is not None:
                # belt-and-braces pull path (commits through OTHER
                # coordinators have no hook into this cache); the stats
                # field mirrors the cache's own total either way
                self.semantic_cache.observe_snapshot(
                    getattr(report, "snapshot_id", None)
                )
                with self._stats_lock:
                    self.stats.cache_invalidations = (
                        self.semantic_cache.stats.invalidations
                    )
            for s, hits in zip(items, report.hits):
                # the deadline covers delivery, not just dispatch: a result
                # that completed late is refused, never served silently late
                if s.deadline is not None and done > s.deadline:
                    self._miss_deadline(s, done)
                    continue
                self.metrics.histogram("latency_ms", s.tenant).observe(
                    (done - s.submitted) * 1e3
                )
                self.metrics.counter("served", s.tenant).inc()
                s.fut.set_result(hits)
                if cacheable:
                    ev = self.semantic_cache.put(
                        s.tenant,
                        s.query,
                        k_eff,
                        s.filter,
                        hits,
                        snapshot_id=getattr(report, "snapshot_id", None),
                        report=ProbeReport(
                            hits=[hits],
                            strategy=report.strategy,
                            files_scanned=0,
                            bytes_read=0,
                            cache="semantic",
                            snapshot_id=getattr(report, "snapshot_id", None),
                            degraded=labels,
                        ),
                    )
                    if ev:
                        with self._stats_lock:
                            self.stats.cache_evictions += ev
            self._maybe_compact(report)

    def _maybe_compact(self, report) -> None:
        """Background fresh-tail compaction: when a drained batch served at
        least ``compact_tail_over`` tail rows, fold the tail into the graph
        shards off the serving path.  At most one compaction runs at a
        time; the refresh commit resets the tail, so the trigger naturally
        disarms until enough new appends accumulate.  A compaction that
        fails in the background is recorded in ``stats.compaction_errors``
        / ``stats.last_compaction_error`` — daemon-thread failures must not
        vanish silently."""
        if self.compact_tail_over is None:
            return
        if report.tail_rows < self.compact_tail_over:
            return
        if self._compact_thread is not None and self._compact_thread.is_alive():
            return
        self.stats.compactions += 1

        def _run() -> None:
            try:
                self.coordinator.compact_tail(
                    self.table_name,
                    self.index_name,
                    threshold_rows=self.compact_tail_over,
                )
            except Exception as exc:  # noqa: BLE001 — record, don't crash serving
                with self._stats_lock:
                    self.stats.compaction_errors += 1
                    self.stats.last_compaction_error = f"{type(exc).__name__}: {exc}"
                self.metrics.counter("compaction_errors").inc()

        self._compact_thread = threading.Thread(target=_run, daemon=True)
        self._compact_thread.start()


@dataclass
class ServeConfig:
    knn_lambda: float = 0.25  # kNN-LM interpolation weight
    knn_temperature: float = 1.0
    greedy: bool = True
    param_dtype: str = "bfloat16"  # serving params are bf16 (no masters)


def make_serve_fns(
    model: Model,
    mesh: Mesh,
    rules: Optional[LogicalRules] = None,
    cfg: ServeConfig = ServeConfig(),
    retrieval: Optional[Callable] = None,  # probe fn from make_probe_fn
    index_template: Optional[DeviceAnnIndex] = None,  # structure for shardings
    batch_hint: int = 1,
    max_len_hint: int = 1,
):
    rules = rules or DEFAULT_RULES
    # serving rules: batch shards over (pod, data) — pods are replica groups
    param_sharding = logical_to_sharding(
        model.axes, rules, mesh, shapes_tree=param_shapes(model)
    )
    cache_ax = model.cache_axes(batch_hint, max_len_hint)
    cache_shapes = jax.eval_shape(lambda: model.init_cache(batch_hint, max_len_hint))
    cache_sharding = jax.tree_util.tree_map(
        lambda ax, shp: NamedSharding(mesh, spec_for(ax, rules, mesh, dim_sizes=shp.shape)),
        cache_ax,
        cache_shapes,
        is_leaf=lambda x: isinstance(x, tuple),
    )
    ids_rank = 3 if model.cfg.num_codebooks else 2
    batch_logical = ("batch", "seq") + (("codebook",) if ids_rank == 3 else ())
    ids_sharding = NamedSharding(
        mesh,
        spec_for(batch_logical, rules, mesh, dim_sizes=(batch_hint, 1) + ((model.cfg.num_codebooks,) if ids_rank == 3 else ())),
    )

    def prefill_step(params, ids, cache):
        logits, cache = model.prefill(params, ids, cache)
        return logits, cache

    def serve_step(params, ids, cache, pos, index=None):
        """One decode step: logits for the new token (+ cache update),
        optionally kNN-LM-interpolated against the ANN index."""
        logits, cache = model.decode(params, ids, cache, pos)
        if retrieval is not None and index is not None:
            # Query vector: the probability-weighted lm_head embedding of the
            # output distribution ("soft embedding", dim = d_model).  The
            # corpus index is built in the same space, so query and keys are
            # commensurate regardless of architecture family.
            probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
            if model.cfg.num_codebooks:
                q = jnp.einsum("bscv,cdv->bsd", probs.astype(params["lm_head"].dtype),
                               params["lm_head"].transpose(0, 1, 2))
                q = q[:, 0]
            else:
                q = jnp.einsum("bsv,dv->bsd", probs.astype(params["lm_head"].dtype),
                               params["lm_head"])[:, 0]
            dists, neigh_tokens = retrieval(index, q)  # (B,k), (B,k)
            # scatter neighbor tokens into a vocab distribution
            w = jax.nn.softmax(-dists / cfg.knn_temperature, axis=-1)  # (B,k)
            V = logits.shape[-1]
            knn_probs = jnp.zeros((q.shape[0], V), jnp.float32)
            knn_probs = knn_probs.at[
                jnp.arange(q.shape[0])[:, None], jnp.clip(neigh_tokens, 0, V - 1)
            ].add(w * (neigh_tokens >= 0))
            if model.cfg.num_codebooks:
                base = probs[:, 0]
                mixed = (1 - cfg.knn_lambda) * base + cfg.knn_lambda * knn_probs[:, None, :]
                logits = jnp.log(jnp.maximum(mixed, 1e-20))[:, None]
            else:
                base = probs[:, 0]
                mixed = (1 - cfg.knn_lambda) * base + cfg.knn_lambda * knn_probs
                logits = jnp.log(jnp.maximum(mixed, 1e-20))[:, None, :]
        return logits, cache

    def sample(logits, key):
        if cfg.greedy:
            return jnp.argmax(logits, axis=-1)
        return jax.random.categorical(key, logits.astype(jnp.float32), axis=-1)

    jit_prefill = jax.jit(
        prefill_step,
        in_shardings=(param_sharding, ids_sharding, cache_sharding),
        out_shardings=(None, cache_sharding),
        donate_argnums=(2,),
    )
    if retrieval is not None:
        if index_template is None:
            raise ValueError("retrieval requires index_template for shardings")
        idx_sharding = index_template.shardings(mesh)
        jit_decode = jax.jit(
            serve_step,
            in_shardings=(param_sharding, ids_sharding, cache_sharding, None, idx_sharding),
            out_shardings=(None, cache_sharding),
            donate_argnums=(2,),
        )
    else:
        jit_decode = jax.jit(
            functools.partial(serve_step, index=None),
            in_shardings=(param_sharding, ids_sharding, cache_sharding, None),
            out_shardings=(None, cache_sharding),
            donate_argnums=(2,),
        )

    class Shardings:
        params = param_sharding
        cache = cache_sharding
        ids = ids_sharding

    return jit_prefill, jit_decode, sample, Shardings
