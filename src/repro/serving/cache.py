"""Serving-tier cache hierarchy: snapshot-keyed, byte-bounded, two layers.

Real query streams are Zipfian — a handful of hot queries dominates — but
a compute-disaggregated engine recomputes every mask, shard probe, and
rerank from scratch on every wave.  With stateless executors and all
durable state in object storage, the compute side is the only place a
cache can live (the SHINE / d-HNSW move).  The snapshot id gives us an
exact, zero-cost invalidation token: index + data are a pure function of
the snapshot, so an entry keyed by snapshot id can never be stale for
that snapshot, and a refresh/compaction commit (which installs a NEW
random id) invalidates by key mismatch alone.

Two layers:

- :class:`ShardProbeCache` — cross-batch Stage-A cache owned by the
  coordinator.  Key: ``(table, snapshot_id, shard_id, predicate, probe
  params, plan op, query digest)``; value: that shard's candidate list
  (ids + approximate distances).  A hit skips mask evaluation AND the
  kernel dispatch for that (query, shard) fragment; the cached
  candidates re-merge through the unchanged Stage-A merge, so final hits
  are bit-identical to the uncached path by construction.

- :class:`SemanticResultCache` — whole-answer cache in front of
  ``ProbeMicroBatcher.submit`` (the redisvl ``SessionManager`` shape):
  answer from a prior result when the L2 distance between query vectors
  is under a per-index threshold, with an exact-duplicate fast path.
  Entries are scoped per tenant and per ``(k, filter)``, and carry the
  snapshot id they were computed against; a snapshot-id change observed
  on any later report evicts every entry from the old snapshot.

Both caches are thread-safe bounded LRUs with byte-size accounting and
hit/miss/eviction/invalidation counters, optionally mirrored into a
:class:`repro.serving.metrics.MetricsRegistry`.

Snapshot ids are *random* (``new_snapshot_id``), not monotone —
invalidation is always "id changed", never an ordering comparison, which
is also what keeps time travel safe: a probe of an old snapshot carries
the old id in its keys and can never alias a newer snapshot's entries.
"""

from __future__ import annotations

import hashlib
import threading
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

import numpy as np

__all__ = [
    "CacheStats",
    "SemanticCacheEntry",
    "SemanticResultCache",
    "ShardCacheEntry",
    "ShardProbeCache",
    "query_digest",
]


def query_digest(vec: np.ndarray) -> bytes:
    """Content digest of one query vector (float32 bytes, exact)."""
    q = np.ascontiguousarray(vec, dtype=np.float32)
    return hashlib.sha1(q.tobytes()).digest()


@dataclass
class CacheStats:
    """Counters one cache layer maintains (also mirrored to metrics)."""

    hits: int = 0
    misses: int = 0
    evictions: int = 0      # LRU byte-budget pressure
    invalidations: int = 0  # snapshot-id change

    def snapshot(self) -> Dict[str, int]:
        return {
            "hits": self.hits,
            "misses": self.misses,
            "evictions": self.evictions,
            "invalidations": self.invalidations,
        }


@dataclass
class ShardCacheEntry:
    """One shard's Stage-A candidate list for one (query, predicate, params)."""

    candidates: List[Any]  # List[fragments.ProbeCandidate]
    table_name: str
    snapshot_id: int
    served_by: str         # executor that computed the fragment originally
    nbytes: int


def _candidates_nbytes(candidates: List[Any]) -> int:
    # ProbeCandidate: file_path str + row_group/row_offset/vec_id/shard_id
    # ints + one float; ~64 bytes of payload plus the path.
    n = 64  # entry overhead
    for c in candidates:
        n += 64 + len(getattr(c, "file_path", ""))
    return n


class ShardProbeCache:
    """Cross-batch Stage-A shard-probe cache (coordinator-side).

    Bounded LRU with byte accounting.  Keys are opaque tuples built by the
    coordinator — ``(table, snapshot_id, shard_id, predicate, (k, L,
    use_pq, oversample), plan_op, query_digest)`` — so a hit is only ever
    possible for the *same* snapshot, predicate, search parameters, and
    exact query vector, which is what makes re-merging the cached
    candidates bit-identical to recomputing them.
    """

    def __init__(self, max_bytes: int = 16 << 20, metrics: Any = None):
        self.max_bytes = int(max_bytes)
        self.metrics = metrics  # MetricsRegistry or None
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, ShardCacheEntry]" = OrderedDict()
        self._total_bytes = 0

    # -- introspection -------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return self._total_bytes

    def entries_snapshot(self) -> List[Tuple[tuple, ShardCacheEntry]]:
        """Copy of (key, entry) pairs, LRU → MRU order (for tests)."""
        with self._lock:
            return list(self._entries.items())

    # -- core ----------------------------------------------------------
    def _count(self, name: str, n: int = 1) -> None:
        if self.metrics is not None and n:
            self.metrics.counter(f"shard_cache_{name}").inc(n)

    def get(self, key: tuple) -> Optional[ShardCacheEntry]:
        with self._lock:
            ent = self._entries.get(key)
            if ent is None:
                self.stats.misses += 1
            else:
                self._entries.move_to_end(key)
                self.stats.hits += 1
        self._count("hits" if ent is not None else "misses")
        return ent

    def put(
        self,
        key: tuple,
        candidates: List[Any],
        *,
        table_name: str,
        snapshot_id: int,
        served_by: str,
    ) -> int:
        """Insert one shard's candidate list; returns evictions caused."""
        nbytes = _candidates_nbytes(candidates)
        if nbytes > self.max_bytes:
            return 0  # would evict the whole cache for one entry
        evicted = 0
        with self._lock:
            old = self._entries.pop(key, None)
            if old is not None:
                self._total_bytes -= old.nbytes
            self._entries[key] = ShardCacheEntry(
                candidates=list(candidates),
                table_name=table_name,
                snapshot_id=int(snapshot_id),
                served_by=served_by,
                nbytes=nbytes,
            )
            self._total_bytes += nbytes
            while self._total_bytes > self.max_bytes and self._entries:
                _, victim = self._entries.popitem(last=False)
                self._total_bytes -= victim.nbytes
                evicted += 1
            self.stats.evictions += evicted
        self._count("evictions", evicted)
        return evicted

    def invalidate(self, table_name: str, current_snapshot_id: int) -> int:
        """Drop every entry for ``table_name`` whose snapshot id differs
        from the just-committed one.  Ids are random, so this is a pure
        identity check — never an ordering comparison.  Returns the count.
        """
        dropped = 0
        with self._lock:
            stale = [
                k
                for k, e in self._entries.items()
                if e.table_name == table_name
                and e.snapshot_id != int(current_snapshot_id)
            ]
            for k in stale:
                ent = self._entries.pop(k)
                self._total_bytes -= ent.nbytes
                dropped += 1
            self.stats.invalidations += dropped
        self._count("invalidations", dropped)
        return dropped

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._total_bytes = 0


@dataclass
class SemanticCacheEntry:
    """One cached whole answer, scoped to (tenant, k, filter)."""

    tenant: str
    query: np.ndarray       # float32, flat — kept for the distance check
    digest: bytes           # exact-duplicate fast path
    k: int
    filter_key: Any
    snapshot_id: Optional[int]
    hits: List[Any]         # the served per-query hit list
    report: Any = None      # minimal ProbeReport with cache="semantic"
    nbytes: int = 0
    served_hits: int = field(default=0)  # times this entry answered a query


class SemanticResultCache:
    """Whole-answer cache keyed by query *meaning*, not just bytes.

    ``lookup`` first tries the exact-duplicate digest, then scans the
    (tenant, k, filter) scope for a cached query vector within
    ``distance_threshold`` (L2).  Entries only serve while their snapshot
    id matches the watermark — the snapshot id carried by the most recent
    probe report ``observe_snapshot`` saw.  When the watermark changes
    (refresh/compaction committed), every entry from another snapshot is
    evicted and counted as an invalidation.
    """

    def __init__(
        self,
        max_bytes: int = 8 << 20,
        distance_threshold: float = 0.0,
        metrics: Any = None,
    ):
        self.max_bytes = int(max_bytes)
        self.distance_threshold = float(distance_threshold)
        self.metrics = metrics
        self.stats = CacheStats()
        self._lock = threading.Lock()
        self._entries: "OrderedDict[tuple, SemanticCacheEntry]" = OrderedDict()
        self._scopes: Dict[tuple, "OrderedDict[tuple, None]"] = {}
        self._total_bytes = 0
        self._watermark: Optional[int] = None

    # -- introspection -------------------------------------------------
    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return self._total_bytes

    @property
    def watermark(self) -> Optional[int]:
        with self._lock:
            return self._watermark

    # -- helpers -------------------------------------------------------
    @staticmethod
    def _scope(tenant: str, k: int, filter_key: Any) -> tuple:
        return (tenant, int(k), filter_key)

    def _count(self, name: str, n: int = 1, tenant: Optional[str] = None) -> None:
        if self.metrics is not None and n:
            self.metrics.counter(f"semantic_cache_{name}", tenant).inc(n)

    def _drop_locked(self, key: tuple) -> None:
        ent = self._entries.pop(key, None)
        if ent is None:
            return
        self._total_bytes -= ent.nbytes
        scope = self._scopes.get(self._scope(ent.tenant, ent.k, ent.filter_key))
        if scope is not None:
            scope.pop(key, None)
            if not scope:
                self._scopes.pop(self._scope(ent.tenant, ent.k, ent.filter_key), None)

    # -- core ----------------------------------------------------------
    def observe_snapshot(self, snapshot_id: Optional[int]) -> int:
        """Feed the snapshot id a fresh probe report resolved against.

        First sighting pins the watermark; a *changed* id evicts every
        entry from another snapshot and moves the watermark.  Returns the
        number of entries invalidated.
        """
        if snapshot_id is None:
            return 0
        sid = int(snapshot_id)
        dropped = 0
        with self._lock:
            if self._watermark == sid:
                return 0
            self._watermark = sid
            stale = [
                k for k, e in self._entries.items() if e.snapshot_id != sid
            ]
            for k in stale:
                self._drop_locked(k)
                dropped += 1
            self.stats.invalidations += dropped
        self._count("invalidations", dropped)
        return dropped

    def lookup(
        self, tenant: str, query: np.ndarray, k: int, filter_key: Any
    ) -> Optional[SemanticCacheEntry]:
        q = np.ascontiguousarray(query, dtype=np.float32).reshape(-1)
        dig = hashlib.sha1(q.tobytes()).digest()
        scope_key = self._scope(tenant, k, filter_key)
        with self._lock:
            wm = self._watermark
            exact = (scope_key, dig)
            ent = self._entries.get(exact)
            if ent is not None and (wm is None or ent.snapshot_id == wm):
                self._entries.move_to_end(exact)
                ent.served_hits += 1
                self.stats.hits += 1
                hit = ent
            else:
                hit = None
                if self.distance_threshold > 0.0:
                    scope = self._scopes.get(scope_key)
                    if scope:
                        best = None
                        best_d = self.distance_threshold
                        for key in scope:
                            cand = self._entries[key]
                            if wm is not None and cand.snapshot_id != wm:
                                continue
                            if cand.query.shape != q.shape:
                                continue
                            d = float(np.linalg.norm(cand.query - q))
                            if d <= best_d:
                                best, best_d = key, d
                        if best is not None:
                            self._entries.move_to_end(best)
                            hit = self._entries[best]
                            hit.served_hits += 1
                            self.stats.hits += 1
                if hit is None:
                    self.stats.misses += 1
        self._count("hits" if hit is not None else "misses", tenant=tenant)
        return hit

    def put(
        self,
        tenant: str,
        query: np.ndarray,
        k: int,
        filter_key: Any,
        hits: List[Any],
        *,
        snapshot_id: Optional[int],
        report: Any = None,
    ) -> int:
        """Cache one served answer under the k it was *actually* answered
        at (a degraded ``shrink_k`` answer is keyed by its degraded k, so
        it can never satisfy a later full-k query).  Returns evictions.
        """
        try:
            hash(filter_key)
        except TypeError:
            return 0  # unhashable filter — not cacheable, never wrong
        q = np.ascontiguousarray(query, dtype=np.float32).reshape(-1)
        dig = hashlib.sha1(q.tobytes()).digest()
        nbytes = q.nbytes + 128 + _candidates_nbytes(hits)
        if nbytes > self.max_bytes:
            return 0
        scope_key = self._scope(tenant, k, filter_key)
        key = (scope_key, dig)
        evicted = 0
        with self._lock:
            self._drop_locked(key)
            ent = SemanticCacheEntry(
                tenant=tenant,
                query=q.copy(),
                digest=dig,
                k=int(k),
                filter_key=filter_key,
                snapshot_id=None if snapshot_id is None else int(snapshot_id),
                hits=list(hits),
                report=report,
                nbytes=nbytes,
            )
            self._entries[key] = ent
            self._scopes.setdefault(scope_key, OrderedDict())[key] = None
            self._total_bytes += nbytes
            while self._total_bytes > self.max_bytes and self._entries:
                victim_key = next(iter(self._entries))
                self._drop_locked(victim_key)
                evicted += 1
            self.stats.evictions += evicted
        self._count("evictions", evicted, tenant=tenant)
        return evicted

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._scopes.clear()
            self._total_bytes = 0
