"""Leased shard assignment for stateless executors.

The coordinator's shard→executor placement becomes explicit, expiring
state: a :class:`LeaseTable` maps each shard key (the fragment's
``cache_key`` — puffin path + shard id) to an ordered set of lease holders
with per-holder expiry times.  Executors renew their leases by
heartbeating through the scheduler's poll loop; a holder that stops
heartbeating (crash, kill, network partition) simply ages out after
``ttl`` — or is lapsed immediately via :meth:`expire_holder` when the
scheduler observes the death first.

Because executors are stateless (every shard byte lives in the object
store behind the snapshot), a lease is *permission to serve*, not
ownership of data: re-granting a lapsed lease to a survivor is always
safe — the replacement re-reads the shard from the Puffin blob and
produces the identical answer.  The table therefore optimizes for cache
affinity, not correctness:

- **Replication** — ``ensure`` tops every lease up to ``replicas``
  holders (primary first), so a single death never leaves a shard
  without a warm candidate.
- **Hot-shard replication** — shards whose dispatch count crosses
  ``hot_dispatches`` get one extra holder (up to ``max_holders``), so a
  hot shard's traffic can spread instead of serializing behind one
  executor's cache.

Pure stdlib; unit-testable with an injected clock.
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set

from repro.serving.metrics import MetricsRegistry


@dataclass
class Lease:
    """One shard's lease: ordered holders (primary first) + expiries."""

    shard_key: str
    holders: List[str] = field(default_factory=list)
    expires: Dict[str, float] = field(default_factory=dict)
    dispatches: int = 0

    def valid_holders(self, now: float) -> List[str]:
        return [h for h in self.holders if self.expires.get(h, 0.0) > now]


class LeaseTable:
    """Expiring shard→executors assignment with replication.

    All methods are thread-safe; the scheduler calls :meth:`renew` from its
    poll loop (driven by live-executor heartbeats), :meth:`ensure` at
    dispatch time, and :meth:`expire_holder` the moment a dispatch observes
    ``ExecutorDead``.
    """

    def __init__(
        self,
        *,
        ttl: float = 0.5,
        replicas: int = 2,
        hot_dispatches: int = 32,
        max_holders: int = 4,
        clock: Callable[[], float] = time.monotonic,
        metrics: Optional[MetricsRegistry] = None,
    ) -> None:
        self.ttl = float(ttl)
        self.replicas = max(1, replicas)
        self.hot_dispatches = hot_dispatches
        self.max_holders = max(self.replicas, max_holders)
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self._clock = clock
        self._lock = threading.Lock()
        self._leases: Dict[str, Lease] = {}
        self._by_holder: Dict[str, Set[str]] = {}

    # -- grant / renew / expire -------------------------------------------
    def ensure(
        self,
        shard_key: str,
        candidates: List[str],
        *,
        now: Optional[float] = None,
    ) -> Lease:
        """Grant or top up the lease for ``shard_key`` from ``candidates``
        (live executor ids).  Tops holders up to ``replicas`` (+1 once the
        shard runs hot), preferring the least-leased candidates so load
        spreads.  Counts the dispatch for hotness tracking."""
        now = self._clock() if now is None else now
        with self._lock:
            lease = self._leases.get(shard_key)
            if lease is None:
                lease = self._leases[shard_key] = Lease(shard_key)
            lease.dispatches += 1
            target = self.replicas + (1 if lease.dispatches > self.hot_dispatches else 0)
            target = min(target, self.max_holders, max(1, len(candidates)))
            valid = set(lease.valid_holders(now))
            # age out lapsed holders (keeps the primary slot meaningful)
            for h in list(lease.holders):
                if h not in valid:
                    lease.holders.remove(h)
                    lease.expires.pop(h, None)
                    self._by_holder.get(h, set()).discard(shard_key)
                    self.metrics.counter("lease_expiries").inc()
            fresh = [c for c in candidates if c not in valid]
            fresh.sort(key=lambda c: len(self._by_holder.get(c, ())))
            for c in fresh[: max(0, target - len(lease.holders))]:
                lease.holders.append(c)
                lease.expires[c] = now + self.ttl
                self._by_holder.setdefault(c, set()).add(shard_key)
                self.metrics.counter("lease_grants").inc()
            return lease

    def renew(self, executor_id: str, *, now: Optional[float] = None) -> None:
        """Heartbeat: extend every lease this executor holds."""
        now = self._clock() if now is None else now
        with self._lock:
            for key in self._by_holder.get(executor_id, ()):  # pragma: no branch
                lease = self._leases.get(key)
                if lease is not None and executor_id in lease.expires:
                    lease.expires[executor_id] = now + self.ttl
            self.metrics.counter("lease_renewals").inc()

    def expire_holder(self, executor_id: str) -> int:
        """Lapse every lease held by ``executor_id`` immediately (the
        scheduler observed its death before the TTL did).  Returns how many
        leases lapsed."""
        with self._lock:
            keys = self._by_holder.pop(executor_id, set())
            lapsed = 0
            for key in keys:
                lease = self._leases.get(key)
                if lease is not None and executor_id in lease.holders:
                    lease.holders.remove(executor_id)
                    lease.expires.pop(executor_id, None)
                    lapsed += 1
            if lapsed:
                self.metrics.counter("lease_expiries").inc(lapsed)
            return lapsed

    # -- queries ----------------------------------------------------------
    def now(self) -> float:
        return self._clock()

    def valid_holders(self, shard_key: str, *, now: Optional[float] = None) -> List[str]:
        now = self._clock() if now is None else now
        with self._lock:
            lease = self._leases.get(shard_key)
            return lease.valid_holders(now) if lease is not None else []

    def holder_load(self, executor_id: str) -> int:
        with self._lock:
            return len(self._by_holder.get(executor_id, ()))

    def snapshot(self) -> Dict[str, Dict[str, object]]:
        """JSON-able view of the table (for logs / debugging / tests)."""
        now = self._clock()
        with self._lock:
            return {
                key: {
                    "holders": list(lease.holders),
                    "valid": lease.valid_holders(now),
                    "dispatches": lease.dispatches,
                }
                for key, lease in self._leases.items()
            }
