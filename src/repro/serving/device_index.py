"""Device-resident sharded ANN index — the probe path on the TPU mesh.

This is the TPU-native rendering of the paper's Stage-A probe (DESIGN.md §2):
each ``data``-axis slice owns one Vamana shard as dense arrays in HBM
(vectors, padded adjacency, medoid); a probe is a ``shard_map`` over the
``data`` axis running the jittable beam search per shard, followed by an
``all_gather`` + global ``top_k`` merge (Stage C).  The executor/SSD path in
:mod:`repro.runtime` and this device path share the same graph semantics —
blobs decoded from a Puffin file can be uploaded straight into a
:class:`DeviceAnnIndex`.

For decode-time retrieval (kNN-LM), :func:`make_probe_fn` returns a function
that can be fused into ``serve_step`` under the same mesh.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.vamana import _beam_search


@functools.partial(
    jax.tree_util.register_dataclass,
    data_fields=["vectors", "adjacency", "medoids", "counts", "payload"],
    meta_fields=[],
)
@dataclass
class DeviceAnnIndex:
    """Sharded index arrays.  Leading dim = shard (maps onto 'data' axis)."""

    vectors: jnp.ndarray  # (n_shards, cap, D) f32|bf16
    adjacency: jnp.ndarray  # (n_shards, cap, R) int32
    medoids: jnp.ndarray  # (n_shards,) int32
    counts: jnp.ndarray  # (n_shards,) int32 valid nodes per shard
    payload: Optional[jnp.ndarray] = None  # (n_shards, cap) int32 e.g. token ids

    @property
    def n_shards(self) -> int:
        return self.vectors.shape[0]

    def shardings(self, mesh: Mesh, shard_axes: Tuple[str, ...] = ("data",)):
        spec = P(shard_axes if len(shard_axes) > 1 else shard_axes[0])
        s = NamedSharding(mesh, spec)
        return DeviceAnnIndex(
            vectors=s, adjacency=s, medoids=s, counts=s,
            payload=s if self.payload is not None else None,
        )

    @staticmethod
    def from_graphs(graphs, payloads=None, dtype=jnp.float32) -> "DeviceAnnIndex":
        """Pack host VamanaGraphs (equal capacity) into device arrays."""
        cap = max(g.vectors.shape[0] for g in graphs)
        R = max(g.adjacency.shape[1] for g in graphs)
        D = graphs[0].dim
        n = len(graphs)
        vecs = np.zeros((n, cap, D), np.float32)
        adj = np.full((n, cap, R), -1, np.int32)
        meds = np.zeros(n, np.int32)
        counts = np.zeros(n, np.int32)
        pl = None
        if payloads is not None:
            pl = np.zeros((n, cap), np.int32)
        for i, g in enumerate(graphs):
            c = g.vectors.shape[0]
            vecs[i, :c] = g.vectors
            adj[i, :c, : g.adjacency.shape[1]] = g.adjacency
            meds[i] = g.medoid
            counts[i] = g.n
            if payloads is not None:
                pl[i, : len(payloads[i])] = payloads[i]
        return DeviceAnnIndex(
            vectors=jnp.asarray(vecs, dtype),
            adjacency=jnp.asarray(adj),
            medoids=jnp.asarray(meds),
            counts=jnp.asarray(counts),
            payload=jnp.asarray(pl) if pl is not None else None,
        )

    @staticmethod
    def abstract(n_shards: int, cap: int, dim: int, R: int, dtype=jnp.bfloat16, with_payload: bool = True):
        """ShapeDtypeStructs for dry-run lowering (no allocation)."""
        return DeviceAnnIndex(
            vectors=jax.ShapeDtypeStruct((n_shards, cap, dim), dtype),
            adjacency=jax.ShapeDtypeStruct((n_shards, cap, R), jnp.int32),
            medoids=jax.ShapeDtypeStruct((n_shards,), jnp.int32),
            counts=jax.ShapeDtypeStruct((n_shards,), jnp.int32),
            payload=jax.ShapeDtypeStruct((n_shards, cap), jnp.int32) if with_payload else None,
        )


def make_probe_fn(
    mesh: Mesh,
    *,
    k: int,
    L: int = 32,
    metric: str = "l2",
    oversample: int = 2,
    shard_axes: Tuple[str, ...] = ("data",),
):
    """Build the shard_map'd Stage-A+C probe.

    ``shard_axes`` controls shard ownership: ("data",) gives one shard per
    data slice (replicated across model — fine for small indexes);
    ("data", "model") flattens both axes so a billion-vector index holds one
    ~4M-vector shard per chip (6 GB of bf16 vectors + 1 GB adjacency at
    768 d, R=64 — the paper's §9 configuration on a v5e-256 pod).

    Returned fn: (index, queries (B, D) replicated) ->
        (dists (B, k), payload_or_ids (B, k)) globally merged.
    """
    max_iters = int(1.3 * L) + 8
    k_local = min(k * oversample, L)
    has_pod = "pod" in mesh.axis_names

    def local_probe(vectors, adjacency, medoid, count, payload, queries):
        # shapes inside shard_map: (S_local, cap, D), (S_local, cap, R),
        # (S_local,), (S_local,), (S_local, cap).  S_local > 1 when there are
        # more shards than data slices (tests; small deployments) — vmap the
        # beam search over the local shard dim.
        cap = vectors.shape[1]

        def one_shard(vecs, adj, cnt, med, pl_tab):
            ids, dists, _, _ = _beam_search(
                vecs.astype(jnp.float32), adj, cnt, med,
                queries.astype(jnp.float32), L, max_iters, metric, False,
            )
            neg, idx = jax.lax.top_k(-dists, k_local)
            lids = jnp.take_along_axis(ids, idx, axis=1)
            pl = jnp.where(lids < cap, pl_tab[jnp.clip(lids, 0, cap - 1)], -1)
            return -neg, pl

        d_s, p_s = jax.vmap(one_shard)(vectors, adjacency, count, medoid, payload)
        # (S_local, B, k_local) -> (B, S_local*k_local)
        local_d = d_s.transpose(1, 0, 2).reshape(queries.shape[0], -1)
        pl = p_s.transpose(1, 0, 2).reshape(queries.shape[0], -1)
        # Stage C merge: gather candidates over every shard axis, global top-k
        all_d, all_p = local_d, pl
        gather_axes = shard_axes + (("pod",) if has_pod else ())
        for ax in gather_axes:
            all_d = jax.lax.all_gather(all_d, ax, axis=1, tiled=True)
            all_p = jax.lax.all_gather(all_p, ax, axis=1, tiled=True)
        negg, gi = jax.lax.top_k(-all_d, k)
        return -negg, jnp.take_along_axis(all_p, gi, axis=1)

    from jax.experimental.shard_map import shard_map

    pspec_sharded = P(shard_axes if len(shard_axes) > 1 else shard_axes[0])
    pspec_none = P()
    in_specs = (
        pspec_sharded,  # vectors
        pspec_sharded,  # adjacency
        pspec_sharded,  # medoids
        pspec_sharded,  # counts
        pspec_sharded,  # payload
        pspec_none,  # queries replicated
    )
    out_specs = (pspec_none, pspec_none)

    def probe(index: DeviceAnnIndex, queries: jnp.ndarray):
        payload = index.payload if index.payload is not None else index.adjacency[:, :, 0]
        return shard_map(
            local_probe,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=out_specs,
            check_rep=False,
        )(index.vectors, index.adjacency, index.medoids, index.counts, payload, queries)

    return probe
