"""Mamba-2 (SSD) block — chunked state-space scan (zamba2 backbone).

Per head (head dim P, state dim N):

    h_t = exp(Δ_t A) h_{t-1} + Δ_t · (B_t ⊗ x_t)        h ∈ R^{N×P}
    y_t = C_tᵀ h_t + D ⊙ x_t

with scalar A < 0 per head (Mamba-2's key simplification), Δ_t = softplus(dt),
and a depthwise causal conv (kernel 4) on x/B/C before the scan.

Chunked computation (chunk C): cumulative log-decays within a chunk give an
attention-like lower-triangular intra-chunk term plus an inter-chunk carried
state — the SSD duality from the paper.  All decay math in f32 log space.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamBuilder

CONV_K = 4


def add_mamba2_params(b: ParamBuilder, path: str, cfg, layer_axes=()) -> None:
    d = cfg.d_model
    inner = cfg.ssm_expand * d
    H = cfg.ssm_heads_eff  # inner // P
    N = cfg.ssm_state
    la = tuple([None] * len(layer_axes))
    import numpy as _np

    s_in = 1.0 / _np.sqrt(d)
    b.add(f"{path}/w_x", layer_axes + (d, inner), la + ("embed", "mlp"), scale=s_in)
    b.add(f"{path}/w_z", layer_axes + (d, inner), la + ("embed", "mlp"), scale=s_in)
    b.add(f"{path}/w_B", layer_axes + (d, N), la + ("embed", "ssm_state"), scale=s_in)
    b.add(f"{path}/w_C", layer_axes + (d, N), la + ("embed", "ssm_state"), scale=s_in)
    b.add(f"{path}/w_dt", layer_axes + (d, H), la + ("embed", "ssm_heads"), scale=s_in)
    b.add(f"{path}/dt_bias", layer_axes + (H,), la + ("ssm_heads",), init="zeros")
    b.add(f"{path}/A_log", layer_axes + (H,), la + ("ssm_heads",), init="zeros")
    b.add(f"{path}/D_skip", layer_axes + (H,), la + ("ssm_heads",), init="ones")
    b.add(f"{path}/conv_x", layer_axes + (CONV_K, inner), la + ("conv", "mlp"), scale=0.5)
    b.add(f"{path}/conv_B", layer_axes + (CONV_K, N), la + ("conv", "ssm_state"), scale=0.5)
    b.add(f"{path}/conv_C", layer_axes + (CONV_K, N), la + ("conv", "ssm_state"), scale=0.5)
    b.add(f"{path}/norm_scale", layer_axes + (inner,), la + ("mlp",), init="ones")
    b.add(f"{path}/w_out", layer_axes + (inner, d), la + ("mlp", "embed"), scale=1.0 / _np.sqrt(inner))


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, carry: jnp.ndarray):
    """Depthwise causal conv, kernel CONV_K.

    x: (B,S,Ch), w: (K,Ch), carry: (B,K-1,Ch) previous tokens.
    Returns (y (B,S,Ch), new_carry (B,K-1,Ch))."""
    B, S, Ch = x.shape
    full = jnp.concatenate([carry.astype(x.dtype), x], axis=1)  # (B, S+K-1, Ch)
    y = jnp.zeros_like(x)
    for k in range(CONV_K):
        y = y + full[:, k : k + S, :] * w[k][None, None, :].astype(x.dtype)
    new_carry = full[:, S:, :] if False else full[:, -(CONV_K - 1) :, :]
    return jax.nn.silu(y), new_carry


def _project(p, x):
    z = jnp.einsum("bsd,di->bsi", x, p["w_z"].astype(x.dtype))
    xin = jnp.einsum("bsd,di->bsi", x, p["w_x"].astype(x.dtype))
    Braw = jnp.einsum("bsd,dn->bsn", x, p["w_B"].astype(x.dtype))
    Craw = jnp.einsum("bsd,dn->bsn", x, p["w_C"].astype(x.dtype))
    dt = jax.nn.softplus(
        jnp.einsum("bsd,dh->bsh", x, p["w_dt"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32)
    )  # (B,S,H)
    return z, xin, Braw, Craw, dt


def _gated_norm(y, z, scale):
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + 1e-6) * scale.astype(jnp.float32)).astype(y.dtype)


def mamba2_chunked(
    p: dict,
    x: jnp.ndarray,  # (B,S,D)
    conv_state: Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray],  # per-stream (B,K-1,·)
    ssm_state: jnp.ndarray,  # (B,H,N,P) f32
    *,
    chunk: int = 128,
) -> Tuple[jnp.ndarray, tuple, jnp.ndarray]:
    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:  # largest divisor ≤ requested chunk
        chunk -= 1
    z, xin, Braw, Craw, dt = _project(p, x)
    xin, cx = _causal_conv(xin, p["conv_x"], conv_state[0])
    Bc, cb = _causal_conv(Braw, p["conv_B"], conv_state[1])
    Cc, cc = _causal_conv(Craw, p["conv_C"], conv_state[2])
    inner = xin.shape[-1]
    H = p["A_log"].shape[-1]
    P = inner // H
    N = Bc.shape[-1]
    xh = xin.reshape(B, S, H, P)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))  # (H,) negative
    la = dt * A[None, None, :]  # (B,S,H) log-decay per token
    nC = S // chunk

    def toc(a, shape):  # (B,S,...) -> (nC,B,chunk,...)
        return a.reshape((B, nC, chunk) + shape).transpose((1, 0, 2) + tuple(range(3, 3 + len(shape))))

    xc_ = toc(xh, (H, P))
    Bc_ = toc(Bc, (N,))
    Cc_ = toc(Cc, (N,))
    dtc = toc(dt, (H,))
    lac = toc(la, (H,))

    def step(h_prev, inp):
        xb, Bb, Cb, dtb, lab = inp  # (B,chunk,H,P), (B,chunk,N), ., (B,chunk,H)
        xb32 = xb.astype(jnp.float32)
        Bb32 = Bb.astype(jnp.float32)
        Cb32 = Cb.astype(jnp.float32)
        cum = jnp.cumsum(lab, axis=1)  # (B,chunk,H) inclusive
        # intra-chunk: y_i += C_i · Σ_{j<=i} exp(cum_i - cum_j) Δ_j B_j x_jᵀ
        scores = jnp.einsum("bin,bjn->bij", Cb32, Bb32)  # (B,chunk,chunk)
        decay = cum[:, :, None, :] - cum[:, None, :, :]  # (B,i,j,H)
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        # mask the exponent BEFORE exp: in the untaken (j>i) region the
        # exponent is positive and would overflow/NaN the backward pass.
        decay = jnp.where(tri[None, :, :, None], decay, -jnp.inf)
        gate = jnp.exp(decay)  # (B,i,j,H), exponents ≤ 0 in the taken region
        w = scores[..., None] * gate * dtb[:, None, :, :]  # (B,i,j,H)
        y_intra = jnp.einsum("bijh,bjhp->bihp", w, xb32)
        # inter-chunk: y_i += C_i · exp(cum_i) h_prev
        y_inter = jnp.einsum("bin,bhnp,bih->bihp", Cb32, h_prev, jnp.exp(cum))
        # carry: h_new = exp(cum_last) h_prev + Σ_j exp(cum_last-cum_j) Δ_j B_j x_jᵀ
        cl = cum[:, -1, :]  # (B,H)
        carry_gate = jnp.exp(cl[:, None, :] - cum) * dtb  # (B,chunk,H)
        h_new = jnp.exp(cl)[:, :, None, None] * h_prev + jnp.einsum(
            "bjh,bjn,bjhp->bhnp", carry_gate, Bb32, xb32
        )
        return h_new, y_intra + y_inter

    ssm_state, ys = jax.lax.scan(step, ssm_state.astype(jnp.float32), (xc_, Bc_, Cc_, dtc, lac))
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, S, H, P)
    y = y + p["D_skip"].astype(jnp.float32)[None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(B, S, inner).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
    return out, (cx, cb, cc), ssm_state


def mamba2_decode(
    p: dict,
    x: jnp.ndarray,  # (B,1,D)
    conv_state: tuple,
    ssm_state: jnp.ndarray,  # (B,H,N,P)
):
    """Single-token step: O(H·N·P) state update."""
    B = x.shape[0]
    z, xin, Braw, Craw, dt = _project(p, x)
    xin, cx = _causal_conv(xin, p["conv_x"], conv_state[0])
    Bc, cb = _causal_conv(Braw, p["conv_B"], conv_state[1])
    Cc, cc = _causal_conv(Craw, p["conv_C"], conv_state[2])
    inner = xin.shape[-1]
    H = p["A_log"].shape[-1]
    P = inner // H
    xh = xin.reshape(B, 1, H, P).astype(jnp.float32)[:, 0]
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    la = dt[:, 0] * A[None, :]  # (B,H)
    decay = jnp.exp(la)
    dB = dt[:, 0][:, :, None] * Bc[:, 0].astype(jnp.float32)[:, None, :]  # (B,H,N)
    h_new = decay[:, :, None, None] * ssm_state + jnp.einsum("bhn,bhp->bhnp", dB, xh)
    y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(jnp.float32), h_new)
    y = y + p["D_skip"].astype(jnp.float32)[None, :, None] * xh
    y = y.reshape(B, 1, inner).astype(x.dtype)
    y = _gated_norm(y, z, p["norm_scale"])
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"].astype(x.dtype))
    return out, (cx, cb, cc), h_new


def mamba2_ref(p: dict, x: jnp.ndarray, conv_state: tuple, ssm_state: jnp.ndarray):
    """Token-by-token oracle for property tests."""

    def step(carry, xt):
        cs, hs = carry
        out, cs2, hs2 = mamba2_decode(p, xt[:, None, :], cs, hs)
        return (cs2, hs2), out[:, 0]

    (cs, hs), outs = jax.lax.scan(
        step, (conv_state, ssm_state.astype(jnp.float32)), x.transpose(1, 0, 2)
    )
    return outs.transpose(1, 0, 2), cs, hs


def init_mamba2_state(cfg, batch: int):
    inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads_eff
    P = inner // H
    N = cfg.ssm_state
    conv = (
        jnp.zeros((batch, CONV_K - 1, inner), jnp.float32),
        jnp.zeros((batch, CONV_K - 1, N), jnp.float32),
        jnp.zeros((batch, CONV_K - 1, N), jnp.float32),
    )
    return conv, jnp.zeros((batch, H, N, P), jnp.float32)
