"""Model assembly: one scan-over-layers decoder skeleton, four families.

``build_model(cfg, tp=...)`` returns a :class:`Model` with pure functions:

- ``init(key)``            → params pytree (f32 masters)
- ``axes``                 → parallel pytree of logical-axis tuples
- ``forward(params, ids)`` → logits  (training; full-sequence mixers)
- ``init_cache(B, max_len)``→ serving cache pytree (+ its logical axes)
- ``prefill(params, ids, cache)`` → (logits_last, cache)
- ``decode(params, ids_1, cache, pos)`` → (logits, cache)

Scan-over-layers keeps the HLO one-layer-sized for 40+ layer configs (the
dry-run compile-time bound).  KV heads are padded up to the tensor-parallel
degree when ``cfg.pad_kv_to_tp`` (DESIGN.md §5) so GQA caches shard cleanly
at TP=16; the padding cost is visible in the roofline — and is the target of
one of the hillclimbs.
"""

from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import layers as L
from repro.models import mamba2 as M2
from repro.models import moe as MOE
from repro.models import rwkv6 as R6
from repro.models.layers import ParamBuilder


def _compute_dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


@dataclass
class Model:
    cfg: ModelConfig
    kv_eff: int  # kv heads after TP padding
    init: Callable[[jax.Array], Any]
    axes: Any  # logical-axes pytree (matches params structure)
    forward: Callable  # (params, ids) -> (logits, aux_loss)
    init_cache: Callable  # (batch, max_len) -> cache
    cache_axes: Callable  # (batch, max_len) -> logical-axes pytree for cache
    prefill: Callable  # (params, ids, cache) -> (logits_last, cache)
    decode: Callable  # (params, ids, cache, pos) -> (logits, cache)

    def param_count(self, params=None) -> int:
        if params is None:
            shapes = jax.eval_shape(self.init, jax.random.PRNGKey(0))
            return sum(int(np.prod(s.shape)) for s in jax.tree_util.tree_leaves(shapes))
        return L.param_count(params)


# ---------------------------------------------------------------------------
# parameter init (all families)
# ---------------------------------------------------------------------------


def _init_params(cfg: ModelConfig, kv_eff: int, key: Optional[jax.Array], abstract: bool = False):
    b = ParamBuilder(key, dtype=jnp.float32, abstract=abstract)
    d, V = cfg.d_model, cfg.vocab_size
    nl = cfg.num_layers
    import dataclasses as _dc

    cfg_kv = _dc.replace(cfg, num_kv_heads=kv_eff)
    # embeddings
    if cfg.num_codebooks:
        b.add("embed", (cfg.num_codebooks, V, d), ("codebook", "vocab", "embed"), scale=0.02)
        b.add("lm_head", (cfg.num_codebooks, d, V), ("codebook", "embed", "vocab"), scale=0.02)
        # learned positions sized for the largest assigned serving shape (32k)
        b.add("pos_embed", (32768, d), (None, "embed"), scale=0.02)
    else:
        b.add("embed", (V, d), ("vocab", "embed"), scale=0.02)
        b.add("lm_head", (d, V), ("embed", "vocab"), scale=0.02)
    # per-layer stacks: leading "layers" dim on every per-layer param
    layer_axes = (nl,)

    def la_path(p: str) -> str:
        return f"layers/{p}"

    if cfg.ssm == "rwkv6":
        L.add_norm_params(b, la_path("ln_att"), d, cfg.norm, layer_axes)
        R6.add_rwkv6_params(b, la_path("tmix"), cfg, layer_axes)
        L.add_norm_params(b, la_path("ln_ffn"), d, cfg.norm, layer_axes)
        # channel-mix (token-shifted relu^2 FFN with receptance gate)
        la = (None,)
        b.add(la_path("cmix/wk"), layer_axes + (d, cfg.d_ff), la + ("embed", "mlp"), scale=1.0 / np.sqrt(d))
        b.add(la_path("cmix/wv"), layer_axes + (cfg.d_ff, d), la + ("mlp", "embed"), scale=1.0 / np.sqrt(cfg.d_ff))
        b.add(la_path("cmix/wr"), layer_axes + (d, d), la + ("embed", None), scale=1.0 / np.sqrt(d))
        b.add(la_path("cmix/mu_k"), layer_axes + (d,), la + ("embed",), init="zeros")
        b.add(la_path("cmix/mu_r"), layer_axes + (d,), la + ("embed",), init="zeros")
    elif cfg.ssm == "mamba2":
        L.add_norm_params(b, la_path("ln"), d, cfg.norm, layer_axes)
        M2.add_mamba2_params(b, la_path("mixer"), cfg, layer_axes)
        if cfg.shared_attn_every:
            # zamba2 shared attention + mlp block (params NOT stacked)
            L.add_norm_params(b, "shared/ln_att", d, cfg.norm)
            L.add_attention_params(b, "shared/attn", cfg_kv, (), kv_heads=kv_eff)
            L.add_norm_params(b, "shared/ln_mlp", d, cfg.norm)
            L.add_mlp_params(b, "shared/mlp", cfg)
    else:
        L.add_norm_params(b, la_path("ln_att"), d, cfg.norm, layer_axes)
        L.add_attention_params(b, la_path("attn"), cfg_kv, layer_axes, kv_heads=kv_eff)
        L.add_norm_params(b, la_path("ln_mlp"), d, cfg.norm, layer_axes)
        if cfg.num_experts:
            MOE.add_moe_params(b, la_path("moe"), cfg, layer_axes)
        else:
            L.add_mlp_params(b, la_path("mlp"), cfg, layer_axes)
    L.add_norm_params(b, "final_norm", d, cfg.norm)
    return b.params, b.axes


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------


def _embed(params, cfg: ModelConfig, ids: jnp.ndarray, dtype) -> jnp.ndarray:
    if cfg.num_codebooks:
        # ids: (B, S, CB)
        tables = params["embed"].astype(dtype)  # (CB, V, D)
        parts = [tables[cb][ids[..., cb]] for cb in range(cfg.num_codebooks)]
        x = functools.reduce(jnp.add, parts)
        S = ids.shape[1]
        x = x + params["pos_embed"][:S][None, :, :].astype(dtype)
        return x
    return params["embed"].astype(dtype)[ids]


def _embed_decode(params, cfg: ModelConfig, ids: jnp.ndarray, pos, dtype) -> jnp.ndarray:
    if cfg.num_codebooks:
        tables = params["embed"].astype(dtype)
        parts = [tables[cb][ids[..., cb]] for cb in range(cfg.num_codebooks)]
        x = functools.reduce(jnp.add, parts)
        pe = jax.lax.dynamic_index_in_dim(params["pos_embed"], pos, axis=0)
        return x + pe.astype(dtype)[None, :, :]
    return params["embed"].astype(dtype)[ids]


def _head(params, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if cfg.num_codebooks:
        return jnp.einsum("bsd,cdv->bscv", x, params["lm_head"].astype(x.dtype))
    return jnp.einsum("bsd,dv->bsv", x, params["lm_head"].astype(x.dtype))


# ---------------------------------------------------------------------------
# attention-family blocks (dense / moe / shared)
# ---------------------------------------------------------------------------


def _attn_full(p, cfg: ModelConfig, x, q_offset: int = 0):
    q, k, v = L._project_qkv(p, cfg, x)
    if cfg.rope != "none":
        S = x.shape[1]
        pos = q_offset + jnp.arange(S)
        frac = cfg.rope_frac if cfg.rope == "partial" else 1.0
        q = L.apply_rope(q, jnp.broadcast_to(pos, (x.shape[0], S)), frac, cfg.rope_theta)
        k = L.apply_rope(k, jnp.broadcast_to(pos, (x.shape[0], S)), frac, cfg.rope_theta)
    window = cfg.window if cfg.attention == "swa" else 0
    attn = (
        L.flash_attention_sparse if cfg.attn_impl == "sparse" else L.flash_attention
    )
    out = attn(q, k, v, q_offset=q_offset, causal=True, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), (k, v)


def _attn_decode(p, cfg: ModelConfig, x, k_cache, v_cache, cache_positions, pos):
    q, k, v = L._project_qkv(p, cfg, x)
    if cfg.rope != "none":
        frac = cfg.rope_frac if cfg.rope == "partial" else 1.0
        posb = jnp.broadcast_to(pos[None], (x.shape[0], 1))
        q = L.apply_rope(q, posb, frac, cfg.rope_theta)
        k = L.apply_rope(k, posb, frac, cfg.rope_theta)
    W = k_cache.shape[1]
    slot = pos % W
    k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), slot, axis=1)
    window = cfg.window if cfg.attention == "swa" else 0
    out = L.decode_attention(q, k_cache, v_cache, cache_positions, pos, window=window)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), k_cache, v_cache


# ---------------------------------------------------------------------------
# family: dense / moe transformer
# ---------------------------------------------------------------------------


def _make_transformer(cfg: ModelConfig, kv_eff: int) -> Dict[str, Callable]:
    dtype = _compute_dtype(cfg)
    import dataclasses as _dc

    cfg_kv = _dc.replace(cfg, num_kv_heads=kv_eff)

    def block_train(lp, x, q_offset=0):
        h = L.apply_norm(cfg.norm, lp["ln_att"], x)
        att, _ = _attn_full(lp["attn"], cfg_kv, h, q_offset)
        x = x + att
        h = L.apply_norm(cfg.norm, lp["ln_mlp"], x)
        if cfg.num_experts:
            out, aux = MOE.moe_block(
                lp["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
                capacity_factor=cfg.capacity_factor,
            )
        else:
            out, aux = L.mlp_block(lp["mlp"], h, cfg.mlp), 0.0
        return x + out, aux

    def forward(params, ids):
        x = _embed(params, cfg, ids, dtype)

        def body(carry, lp):
            x, aux = carry
            x, a = block_train(lp, x)
            return (x, aux + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), params["layers"])
        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        return _head(params, cfg, x), aux / cfg.num_layers

    def init_cache(batch: int, max_len: int):
        W = min(max_len, cfg.window) if cfg.attention == "swa" and cfg.window else max_len
        shape = (cfg.num_layers, batch, W, kv_eff, cfg.head_dim)
        cache_dt = jnp.dtype(cfg.cache_dtype) if cfg.cache_dtype else dtype
        return {
            "k": jnp.zeros(shape, cache_dt),
            "v": jnp.zeros(shape, cache_dt),
            "positions": jnp.full((W,), -1, jnp.int32),
        }

    def cache_axes(batch: int, max_len: int):
        ax = ("layers", "cache_batch", "cache_seq", "cache_heads", "head_dim")
        return {"k": ax, "v": ax, "positions": ("cache_seq",)}

    def prefill(params, ids, cache):
        """Run the full prompt, filling the cache; returns last-token logits."""
        x = _embed(params, cfg, ids, dtype)
        S = ids.shape[1]
        W = cache["k"].shape[2]

        def body(x, lp):
            h = L.apply_norm(cfg.norm, lp["ln_att"], x)
            att, (k, v) = _attn_full(lp["attn"], cfg_kv, h, 0)
            x = x + att
            h = L.apply_norm(cfg.norm, lp["ln_mlp"], x)
            if cfg.num_experts:
                out, _ = MOE.moe_block(
                    lp["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k,
                    capacity_factor=cfg.capacity_factor,
                )
            else:
                out = L.mlp_block(lp["mlp"], h, cfg.mlp)
            # keep the last W positions of k/v for the cache
            k_keep = k[:, -W:].astype(dtype)
            v_keep = v[:, -W:].astype(dtype)
            return x + out, (k_keep, v_keep)

        x, (ks, vs) = jax.lax.scan(body, x, params["layers"])
        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        logits = _head(params, cfg, x[:, -1:, :])
        # ring layout: position p lives at slot p % W
        W_ = cache["k"].shape[2]
        kept = jnp.arange(W_)
        pos_of_slot = jnp.where(
            S >= W_,
            # slots hold positions S-W .. S-1 at slot p%W
            (S - W_) + (kept - (S - W_) % W_ + W_) % W_,
            jnp.where(kept < S, kept, -1),
        )
        # scatter kept k/v into ring order
        src_idx = jnp.clip(pos_of_slot - (S - W_ if S >= W_ else 0), 0, W_ - 1)
        k_ring = jnp.take(ks, src_idx, axis=2)
        v_ring = jnp.take(vs, src_idx, axis=2)
        k_ring = jnp.where((pos_of_slot >= 0)[None, None, :, None, None], k_ring, 0)
        v_ring = jnp.where((pos_of_slot >= 0)[None, None, :, None, None], v_ring, 0)
        return logits, {"k": k_ring, "v": v_ring, "positions": pos_of_slot}

    def decode(params, ids, cache, pos):
        x = _embed_decode(params, cfg, ids, pos, dtype)
        W = cache["k"].shape[2]
        positions = cache["positions"]
        positions = positions.at[pos % W].set(pos)

        def body(x, inputs):
            lp, kc, vc = inputs
            h = L.apply_norm(cfg.norm, lp["ln_att"], x)
            att, kc, vc = _attn_decode(lp["attn"], cfg_kv, h, kc, vc, positions, pos)
            x = x + att
            h = L.apply_norm(cfg.norm, lp["ln_mlp"], x)
            if cfg.num_experts:
                # decode is weight-read-bound: dense dispatch is exact (no
                # capacity drops) and its extra FLOPs are negligible at S=1.
                out = MOE.moe_block_dense_ref(
                    lp["moe"], h, num_experts=cfg.num_experts, top_k=cfg.top_k
                )
            else:
                out = L.mlp_block(lp["mlp"], h, cfg.mlp)
            return x + out, (kc, vc)

        x, (k_new, v_new) = jax.lax.scan(body, x, (params["layers"], cache["k"], cache["v"]))
        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        logits = _head(params, cfg, x)
        return logits, {"k": k_new, "v": v_new, "positions": positions}

    return dict(
        forward=forward, init_cache=init_cache, cache_axes=cache_axes,
        prefill=prefill, decode=decode,
    )


# ---------------------------------------------------------------------------
# family: rwkv6
# ---------------------------------------------------------------------------


def _make_rwkv(cfg: ModelConfig) -> Dict[str, Callable]:
    dtype = _compute_dtype(cfg)
    H, N = cfg.ssm_heads_eff, cfg.head_dim

    def cmix(lp, x, x_prev):
        xs = R6._token_shift(x, x_prev)
        xk = R6._mix(x, xs, lp["mu_k"])
        xr = R6._mix(x, xs, lp["mu_r"])
        k = jnp.square(jax.nn.relu(jnp.einsum("bsd,df->bsf", xk, lp["wk"].astype(x.dtype))))
        r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", xr, lp["wr"].astype(x.dtype)))
        return r * jnp.einsum("bsf,fd->bsd", k, lp["wv"].astype(x.dtype)), x[:, -1, :]

    def block(lp, x, carry, chunked=True):
        xp_att, xp_ffn, st = carry
        h = L.apply_norm(cfg.norm, lp["ln_att"], x)
        if chunked:
            att, xp_att2, st2 = R6.rwkv6_chunked(lp["tmix"], h, xp_att, st)
        else:
            att, xp_att2, st2 = R6.rwkv6_decode(lp["tmix"], h, xp_att, st)
        x = x + att
        h = L.apply_norm(cfg.norm, lp["ln_ffn"], x)
        ff, xp_ffn2 = cmix(lp["cmix"], h, xp_ffn)
        return x + ff, (xp_att2, xp_ffn2, st2)

    def _zero_carry(batch):
        return (
            jnp.zeros((cfg.num_layers, batch, cfg.d_model), dtype),
            jnp.zeros((cfg.num_layers, batch, cfg.d_model), dtype),
            jnp.zeros((cfg.num_layers, batch, H, N, N), jnp.float32),
        )

    def forward(params, ids):
        B = ids.shape[0]
        x = _embed(params, cfg, ids, dtype)
        carry0 = _zero_carry(B)

        def body(x, inputs):
            lp, ca, cf, st = inputs
            x, _ = block(lp, x, (ca, cf, st))
            return x, None

        x, _ = jax.lax.scan(body, x, (params["layers"],) + carry0)
        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        return _head(params, cfg, x), jnp.float32(0.0)

    def init_cache(batch: int, max_len: int):
        ca, cf, st = _zero_carry(batch)
        return {"x_att": ca, "x_ffn": cf, "wkv": st}

    def cache_axes(batch: int, max_len: int):
        return {
            "x_att": ("layers", "cache_batch", "embed"),
            "x_ffn": ("layers", "cache_batch", "embed"),
            "wkv": ("layers", "cache_batch", "ssm_heads", None, None),
        }

    def _run(params, ids, cache, chunked, pos=None):
        x = (
            _embed(params, cfg, ids, dtype)
            if chunked
            else _embed_decode(params, cfg, ids, pos, dtype)
        )

        def body(x, inputs):
            lp, ca, cf, st = inputs
            x, (ca2, cf2, st2) = block(lp, x, (ca, cf, st), chunked=chunked)
            return x, (ca2, cf2, st2)

        x, (ca, cf, st) = jax.lax.scan(
            body, x, (params["layers"], cache["x_att"], cache["x_ffn"], cache["wkv"])
        )
        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        take_last = x[:, -1:, :] if chunked else x
        logits = _head(params, cfg, take_last)
        return logits, {"x_att": ca, "x_ffn": cf, "wkv": st}

    def prefill(params, ids, cache):
        return _run(params, ids, cache, chunked=True)

    def decode(params, ids, cache, pos):
        return _run(params, ids, cache, chunked=False, pos=pos)

    return dict(
        forward=forward, init_cache=init_cache, cache_axes=cache_axes,
        prefill=prefill, decode=decode,
    )


# ---------------------------------------------------------------------------
# family: mamba2 (+ zamba2 hybrid shared attention)
# ---------------------------------------------------------------------------


def _make_mamba(cfg: ModelConfig, kv_eff: int) -> Dict[str, Callable]:
    dtype = _compute_dtype(cfg)
    import dataclasses as _dc

    cfg_kv = _dc.replace(cfg, num_kv_heads=kv_eff)
    every = cfg.shared_attn_every
    n_shared = (cfg.num_layers // every) if every else 0
    inner = cfg.ssm_expand * cfg.d_model
    H = cfg.ssm_heads_eff
    P = inner // H
    N = cfg.ssm_state
    K = M2.CONV_K

    def _zero_states(batch):
        return (
            jnp.zeros((cfg.num_layers, batch, K - 1, inner), dtype),
            jnp.zeros((cfg.num_layers, batch, K - 1, N), dtype),
            jnp.zeros((cfg.num_layers, batch, K - 1, N), dtype),
            jnp.zeros((cfg.num_layers, batch, H, N, P), jnp.float32),
        )

    def _shared_attn_train(params, x, q_offset=0):
        sp = params["shared"]
        h = L.apply_norm(cfg.norm, sp["ln_att"], x)
        att, _ = _attn_full(sp["attn"], cfg_kv, h, q_offset)
        x = x + att
        h = L.apply_norm(cfg.norm, sp["ln_mlp"], x)
        return x + L.mlp_block(sp["mlp"], h, cfg.mlp)

    # Layer groups: the shared attention block runs after every full group
    # of ``every`` mamba layers.  Grouped scans (instead of a lax.cond inside
    # one big scan) keep the dead branch out of the compiled body and make
    # FLOP accounting exact — the shared block is compiled/counted once per
    # invocation, not once per layer.
    if every:
        _bounds = list(range(0, cfg.num_layers, every)) + [cfg.num_layers]
        _bounds = sorted(set(_bounds))
    else:
        _bounds = [0, cfg.num_layers]

    def _group_slices(tree):
        return [
            jax.tree.map(lambda a: a[lo:hi], tree)
            for lo, hi in zip(_bounds[:-1], _bounds[1:])
        ]

    def forward(params, ids):
        B = ids.shape[0]
        x = _embed(params, cfg, ids, dtype)
        xs = (params["layers"],) + _zero_states(B)

        def body(x, inputs):
            lp, cx_i, cb_i, cc_i, st_i = inputs
            h = L.apply_norm(cfg.norm, lp["ln"], x)
            out, _, _ = M2.mamba2_chunked(lp["mixer"], h, (cx_i, cb_i, cc_i), st_i)
            return x + out, None

        for gi, xs_g in enumerate(_group_slices(xs)):
            x, _ = jax.lax.scan(body, x, xs_g)
            lo, hi = _bounds[gi], _bounds[gi + 1]
            if every and (hi - lo) == every:
                x = _shared_attn_train(params, x)
        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        return _head(params, cfg, x), jnp.float32(0.0)

    def init_cache(batch: int, max_len: int):
        cx, cb, cc, st = _zero_states(batch)
        cache = {"conv_x": cx, "conv_B": cb, "conv_C": cc, "ssm": st}
        if every:
            cache["shared_k"] = jnp.zeros(
                (n_shared, batch, max_len, kv_eff, cfg.head_dim), dtype
            )
            cache["shared_v"] = jnp.zeros_like(cache["shared_k"])
            cache["positions"] = jnp.full((max_len,), -1, jnp.int32)
        return cache

    def cache_axes(batch: int, max_len: int):
        ax = {
            "conv_x": ("layers", "cache_batch", "conv", "mlp"),
            "conv_B": ("layers", "cache_batch", "conv", "ssm_state"),
            "conv_C": ("layers", "cache_batch", "conv", "ssm_state"),
            "ssm": ("layers", "cache_batch", "ssm_heads", "ssm_state", None),
        }
        if every:
            ax["shared_k"] = (None, "cache_batch", "cache_seq", "cache_heads", "head_dim")
            ax["shared_v"] = (None, "cache_batch", "cache_seq", "cache_heads", "head_dim")
            ax["positions"] = ("cache_seq",)
        return ax

    def _shared_attn_decode(params, x, cache, sl_idx, positions, pos):
        sp = params["shared"]
        h = L.apply_norm(cfg.norm, sp["ln_att"], x)
        kc = jax.lax.dynamic_index_in_dim(cache["shared_k"], sl_idx, 0, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(cache["shared_v"], sl_idx, 0, keepdims=False)
        att, kc, vc = _attn_decode(sp["attn"], cfg_kv, h, kc, vc, positions, pos)
        x = x + att
        h = L.apply_norm(cfg.norm, sp["ln_mlp"], x)
        x = x + L.mlp_block(sp["mlp"], h, cfg.mlp)
        return x, kc, vc

    def _shared_prefill(params, x, sk, sv, gi):
        sp = params["shared"]
        h = L.apply_norm(cfg.norm, sp["ln_att"], x)
        att, (k, v) = _attn_full(sp["attn"], cfg_kv, h, 0)
        x = x + att
        h = L.apply_norm(cfg.norm, sp["ln_mlp"], x)
        x = x + L.mlp_block(sp["mlp"], h, cfg.mlp)
        W = sk.shape[2]
        k_keep = k[:, -W:].astype(sk.dtype)
        v_keep = v[:, -W:].astype(sv.dtype)
        padlen = W - k_keep.shape[1]
        if padlen:
            k_keep = jnp.pad(k_keep, ((0, 0), (0, padlen), (0, 0), (0, 0)))
            v_keep = jnp.pad(v_keep, ((0, 0), (0, padlen), (0, 0), (0, 0)))
        sk = sk.at[gi].set(k_keep)
        sv = sv.at[gi].set(v_keep)
        return x, sk, sv

    def _run(params, ids, cache, chunked, pos=None):
        x = (
            _embed(params, cfg, ids, dtype)
            if chunked
            else _embed_decode(params, cfg, ids, pos, dtype)
        )
        if every:
            positions = cache["positions"]
            if not chunked:
                W = cache["shared_k"].shape[2]
                positions = positions.at[pos % W].set(pos)
            sk, sv = cache["shared_k"], cache["shared_v"]
        mix_fn = M2.mamba2_chunked if chunked else M2.mamba2_decode

        def body(x, inputs):
            lp, cx_i, cb_i, cc_i, st_i = inputs
            h = L.apply_norm(cfg.norm, lp["ln"], x)
            out, (cx2, cb2, cc2), st2 = mix_fn(lp["mixer"], h, (cx_i, cb_i, cc_i), st_i)
            return x + out, (cx2, cb2, cc2, st2)

        xs = (
            params["layers"],
            cache["conv_x"],
            cache["conv_B"],
            cache["conv_C"],
            cache["ssm"],
        )
        group_outs = []
        for gi, xs_g in enumerate(_group_slices(xs)):
            x, ys = jax.lax.scan(body, x, xs_g)
            group_outs.append(ys)
            lo, hi = _bounds[gi], _bounds[gi + 1]
            if every and (hi - lo) == every:
                if chunked:
                    x, sk, sv = _shared_prefill(params, x, sk, sv, gi)
                else:
                    x, kc, vc = _shared_attn_decode(
                        params, x, {"shared_k": sk, "shared_v": sv}, gi, positions, pos
                    )
                    sk = sk.at[gi].set(kc)
                    sv = sv.at[gi].set(vc)
        cx2, cb2, cc2, st2 = (
            jnp.concatenate([g[i] for g in group_outs], axis=0) for i in range(4)
        )
        x = L.apply_norm(cfg.norm, params["final_norm"], x)
        take_last = x[:, -1:, :] if chunked else x
        logits = _head(params, cfg, take_last)
        new_cache = {"conv_x": cx2, "conv_B": cb2, "conv_C": cc2, "ssm": st2}
        if every:
            new_cache["shared_k"] = sk
            new_cache["shared_v"] = sv
            if chunked:
                S = ids.shape[1]
                W = sk.shape[2]
                slots = jnp.arange(W)
                new_cache["positions"] = jnp.where(slots < min(S, W), slots, -1)
            else:
                new_cache["positions"] = positions
        return logits, new_cache

    def prefill(params, ids, cache):
        return _run(params, ids, cache, chunked=True)

    def decode(params, ids, cache, pos):
        return _run(params, ids, cache, chunked=False, pos=pos)

    return dict(
        forward=forward, init_cache=init_cache, cache_axes=cache_axes,
        prefill=prefill, decode=decode,
    )


# ---------------------------------------------------------------------------
# public factory
# ---------------------------------------------------------------------------


def build_model(cfg: ModelConfig, *, tp: int = 1) -> Model:
    # Pad KV heads up to the TP degree so GQA caches shard cleanly — only
    # when the padding keeps a valid grouping (KV | H) and is a true
    # replication (kv | tp).  Archs like phi4-mini (H=24, tp=16) keep their
    # native kv and fall back to replicated attention sharding instead
    # (recorded per-arch in the dry-run; a hillclimb target).
    kv_eff = cfg.num_kv_heads
    if (
        cfg.pad_kv_to_tp
        and cfg.attention != "none"
        and tp > cfg.num_kv_heads
        and cfg.num_heads % tp == 0
        and tp % cfg.num_kv_heads == 0
    ):
        kv_eff = tp
    if cfg.ssm == "rwkv6":
        fns = _make_rwkv(cfg)
    elif cfg.ssm == "mamba2":
        fns = _make_mamba(cfg, kv_eff)
    else:
        fns = _make_transformer(cfg, kv_eff)
    axes = _init_params(cfg, kv_eff, None, abstract=True)[1]
    return Model(
        cfg=cfg,
        kv_eff=kv_eff,
        init=lambda key: _init_params(cfg, kv_eff, key)[0],
        axes=axes,
        forward=fns["forward"],
        init_cache=fns["init_cache"],
        cache_axes=fns["cache_axes"],
        prefill=fns["prefill"],
        decode=fns["decode"],
    )


def param_shapes(model: Model):
    """ShapeDtypeStruct tree of the params (no allocation)."""
    return _init_params(model.cfg, model.kv_eff, None, abstract=True)[0]
