"""Shared layer library: norms, RoPE, attention (flash + decode), MLPs.

All functions are pure (params pytree in, arrays out) and scan-friendly.
Param construction goes through :class:`ParamBuilder`, which records a
parallel pytree of logical-axis tuples consumed by
:func:`repro.models.sharding.logical_to_sharding`.

Attention is implemented blockwise (online-softmax over KV chunks inside a
``lax.scan``) so 32k-token prefill compiles to O(S·chunk) memory instead of
an S×S score tensor, and supports causal + sliding-window masks.  Decode
attends one query position against a (optionally ring-buffered) cache.
"""

from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# parameter construction
# ---------------------------------------------------------------------------


class ParamBuilder:
    """Collects params + logical axes as parallel nested dicts.

    ``abstract=True`` records ShapeDtypeStructs instead of allocating — used
    to derive the axes/shape trees for sharding and dry-runs without paying
    for a 132 B-parameter init."""

    def __init__(self, key: Optional[jax.Array], dtype=jnp.float32, abstract: bool = False):
        self.key = key
        self.dtype = dtype
        self.abstract = abstract
        self.params: Dict[str, Any] = {}
        self.axes: Dict[str, Any] = {}

    def _split(self) -> jax.Array:
        self.key, sub = jax.random.split(self.key)
        return sub

    def add(self, path: str, shape: Tuple[int, ...], axes: Tuple[Optional[str], ...],
            init: str = "normal", scale: Optional[float] = None) -> None:
        assert len(shape) == len(axes), (path, shape, axes)
        if self.abstract:
            arr = jax.ShapeDtypeStruct(shape, self.dtype)
        elif init == "zeros":
            arr = jnp.zeros(shape, self.dtype)
        elif init == "ones":
            arr = jnp.ones(shape, self.dtype)
        elif init == "normal":
            if scale is None:
                # fan-in init, skipping leading stacked-layer dims (their
                # axes entries are None) and the output dim
                prefix = 0
                for a in axes:
                    if a is None:
                        prefix += 1
                    else:
                        break
                fan_in = max(1, int(np.prod(shape[prefix:-1])))
                scale = 1.0 / np.sqrt(fan_in)
            arr = (jax.random.normal(self._split(), shape, jnp.float32) * scale).astype(self.dtype)
        else:
            raise ValueError(init)
        self._set(self.params, path, arr)
        self._set(self.axes, path, tuple(axes))

    @staticmethod
    def _set(tree: dict, path: str, value) -> None:
        parts = path.split("/")
        for p in parts[:-1]:
            tree = tree.setdefault(p, {})
        tree[parts[-1]] = value


def param_count(params) -> int:
    return sum(int(np.prod(x.shape)) for x in jax.tree_util.tree_leaves(params))


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def rmsnorm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(dt)


def layernorm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return (y * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(dt)


def apply_norm(norm_kind: str, p: dict, x: jnp.ndarray) -> jnp.ndarray:
    if norm_kind == "rmsnorm":
        return rmsnorm(x, p["scale"])
    return layernorm(x, p["scale"], p["bias"])


def add_norm_params(b: ParamBuilder, path: str, d: int, norm_kind: str, layer_axes=()) -> None:
    b.add(f"{path}/scale", layer_axes + (d,), tuple([None] * len(layer_axes)) + ("embed",), init="ones")
    if norm_kind == "layernorm":
        b.add(f"{path}/bias", layer_axes + (d,), tuple([None] * len(layer_axes)) + ("embed",), init="zeros")


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, frac: float, theta: float) -> jnp.ndarray:
    rot = int(head_dim * frac) // 2 * 2
    return 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, frac: float, theta: float) -> jnp.ndarray:
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * frac) // 2 * 2
    if rot == 0:
        return x
    freqs = rope_freqs(hd, frac, theta)  # (rot/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, rot/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, rot/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., 0::2], x_rot[..., 1::2]
    y1 = x1 * cos - x2 * sin
    y2 = x1 * sin + x2 * cos
    y = jnp.stack([y1, y2], axis=-1).reshape(x_rot.shape)
    return jnp.concatenate([y.astype(x.dtype), x_pass], axis=-1)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------


def add_attention_params(b: ParamBuilder, path: str, cfg, layer_axes=(), kv_heads=None) -> None:
    d = cfg.d_model
    H, hd = cfg.num_heads, cfg.head_dim
    KV = kv_heads if kv_heads is not None else cfg.num_kv_heads
    la = tuple([None] * len(layer_axes))
    import numpy as _np

    s_in = 1.0 / _np.sqrt(d)
    b.add(f"{path}/wq", layer_axes + (d, H, hd), la + ("embed", "heads", "head_dim"), scale=s_in)
    b.add(f"{path}/wk", layer_axes + (d, KV, hd), la + ("embed", "kv_heads", "head_dim"), scale=s_in)
    b.add(f"{path}/wv", layer_axes + (d, KV, hd), la + ("embed", "kv_heads", "head_dim"), scale=s_in)
    b.add(f"{path}/wo", layer_axes + (H, hd, d), la + ("heads", "head_dim", "embed"), scale=1.0 / _np.sqrt(H * hd))
    if cfg.qkv_bias:
        b.add(f"{path}/bq", layer_axes + (H, hd), la + ("heads", "head_dim"), init="zeros")
        b.add(f"{path}/bk", layer_axes + (KV, hd), la + ("kv_heads", "head_dim"), init="zeros")
        b.add(f"{path}/bv", layer_axes + (KV, hd), la + ("kv_heads", "head_dim"), init="zeros")
    if cfg.qk_norm:
        b.add(f"{path}/q_norm", layer_axes + (hd,), la + ("head_dim",), init="ones")
        b.add(f"{path}/k_norm", layer_axes + (hd,), la + ("head_dim",), init="ones")


def _project_qkv(p: dict, cfg, x: jnp.ndarray):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    if "q_norm" in p:
        q = rmsnorm(q, p["q_norm"])
        k = rmsnorm(k, p["k_norm"])
    return q, k, v


def flash_attention(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    *,
    q_offset: int = 0,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 1024,
) -> jnp.ndarray:
    """Blockwise online-softmax attention with GQA-grouped einsums.

    Memory per step is O(q_chunk × kv_chunk); the KV loop is a lax.scan so
    the HLO stays one-block-sized regardless of sequence length.
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)

    def process_q_chunk(qi, q_blk):
        # q_blk: (B, q_chunk, KV, G, hd)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)

        def kv_step(carry, ki):
            m, l, acc = carry
            k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
            v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
            kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
            s = jnp.einsum(
                "bqkgd,bskd->bkgqs", q_blk, k_blk, preferred_element_type=jnp.float32
            ) * scale  # (B, KV, G, q_chunk, kv_chunk)
            mask = jnp.ones((q_chunk, kv_chunk), bool)
            if causal:
                mask &= kv_pos[None, :] <= q_pos[:, None]
            if window:
                mask &= kv_pos[None, :] > q_pos[:, None] - window
            s = jnp.where(mask, s, -jnp.inf)
            blk_max = jnp.max(s, axis=-1)  # (B,KV,G,qc)
            new_m = jnp.maximum(m, blk_max)
            # guard fully-masked rows
            new_m_safe = jnp.where(jnp.isfinite(new_m), new_m, 0.0)
            p = jnp.exp(s - new_m_safe[..., None])
            p = jnp.where(mask, p, 0.0)
            corr = jnp.exp(jnp.where(jnp.isfinite(m), m - new_m_safe, -jnp.inf))
            corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
            l = l * corr + jnp.sum(p, axis=-1)
            acc = acc * corr[..., None] + jnp.einsum(
                "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk,
                preferred_element_type=jnp.float32,
            )
            return (new_m, l, acc), None

        m0 = jnp.full((B, KV, G, q_chunk), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, q_chunk), jnp.float32)
        acc0 = jnp.zeros((B, KV, G, q_chunk, hd), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, acc0), jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-20)[..., None]
        # (B, KV, G, qc, hd) -> (B, qc, KV, G, hd)
        return out.transpose(0, 3, 1, 2, 4)

    q_blocks = qg.reshape(B, nq, q_chunk, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    out_blocks = jax.lax.map(
        lambda args: process_q_chunk(args[0], args[1]),
        (jnp.arange(nq), q_blocks),
    )  # (nq, B, qc, KV, G, hd)
    out = out_blocks.transpose(1, 0, 2, 3, 4, 5).reshape(B, Sq, KV, G, hd)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def flash_attention_sparse(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Skv, KV, hd)
    v: jnp.ndarray,  # (B, Skv, KV, hd)
    *,
    q_offset: int = 0,
    causal: bool = True,
    window: int = 0,
    q_chunk: int = 512,
    kv_chunk: int = 512,
) -> jnp.ndarray:
    """Block-sparse flash attention: only *visible* (q-block, kv-block) pairs
    are computed.

    The dense variant (:func:`flash_attention`) computes every kv block per
    q block and masks afterwards — paying the full S² FLOPs even for causal
    (2× waste) and sliding-window (S/W× waste) attention.  Here the block
    schedule is computed statically: a ``lax.scan`` over the visible pairs
    with per-q-block online-softmax accumulators.  FLOPs drop to the true
    masked work (plus boundary-block slack ≤ one block row), and the jaxpr
    FLOP accounting is exact (static trip count).
    """
    B, Sq, H, hd = q.shape
    _, Skv, KV, _ = k.shape
    G = H // KV
    q_chunk = min(q_chunk, Sq)
    kv_chunk = min(kv_chunk, Skv)
    assert Sq % q_chunk == 0 and Skv % kv_chunk == 0
    nq, nk = Sq // q_chunk, Skv // kv_chunk
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, Sq, KV, G, hd)

    # static visibility schedule
    pairs = []
    for qi in range(nq):
        q_lo = q_offset + qi * q_chunk
        q_hi = q_lo + q_chunk - 1
        for ki in range(nk):
            kv_lo = ki * kv_chunk
            kv_hi = kv_lo + kv_chunk - 1
            if causal and kv_lo > q_hi:
                continue  # entirely in the future
            if window and kv_hi <= q_lo - window:
                continue  # entirely outside the window
            pairs.append((qi, ki))
    pairs_arr = jnp.asarray(pairs, jnp.int32)  # (P, 2)

    m0 = jnp.full((nq, B, KV, G, q_chunk), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((nq, B, KV, G, q_chunk), jnp.float32)
    acc0 = jnp.zeros((nq, B, KV, G, q_chunk, hd), jnp.float32)

    def step(carry, pair):
        m, l, acc = carry
        qi, ki = pair[0], pair[1]
        q_blk = jax.lax.dynamic_slice_in_dim(qg, qi * q_chunk, q_chunk, axis=1)
        k_blk = jax.lax.dynamic_slice_in_dim(k, ki * kv_chunk, kv_chunk, axis=1)
        v_blk = jax.lax.dynamic_slice_in_dim(v, ki * kv_chunk, kv_chunk, axis=1)
        q_pos = q_offset + qi * q_chunk + jnp.arange(q_chunk)
        kv_pos = ki * kv_chunk + jnp.arange(kv_chunk)
        s = jnp.einsum(
            "bqkgd,bskd->bkgqs", q_blk, k_blk, preferred_element_type=jnp.float32
        ) * scale
        mask = jnp.ones((q_chunk, kv_chunk), bool)
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask, s, -jnp.inf)
        m_q = m[qi]
        blk_max = jnp.max(s, axis=-1)
        new_m = jnp.maximum(m_q, blk_max)
        new_m_safe = jnp.where(jnp.isfinite(new_m), new_m, 0.0)
        p = jnp.exp(s - new_m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.exp(jnp.where(jnp.isfinite(m_q), m_q - new_m_safe, -jnp.inf))
        corr = jnp.where(jnp.isfinite(corr), corr, 0.0)
        new_l = l[qi] * corr + jnp.sum(p, axis=-1)
        new_acc = acc[qi] * corr[..., None] + jnp.einsum(
            "bkgqs,bskd->bkgqd", p.astype(v_blk.dtype), v_blk,
            preferred_element_type=jnp.float32,
        )
        m = m.at[qi].set(new_m)
        l = l.at[qi].set(new_l)
        acc = acc.at[qi].set(new_acc)
        return (m, l, acc), None

    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, acc0), pairs_arr)
    out = acc / jnp.maximum(l, 1e-20)[..., None]  # (nq, B, KV, G, qc, hd)
    out = out.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq, KV, G, hd)
    return out.reshape(B, Sq, H, hd).astype(q.dtype)


def decode_attention(
    q: jnp.ndarray,  # (B, 1, H, hd)
    k_cache: jnp.ndarray,  # (B, W, KV, hd)  (positions already roped)
    v_cache: jnp.ndarray,  # (B, W, KV, hd)
    cache_positions: jnp.ndarray,  # (W,) int32 absolute positions, -1 = empty
    pos: jnp.ndarray,  # () int32 current position
    window: int = 0,
) -> jnp.ndarray:
    B, _, H, hd = q.shape
    KV = k_cache.shape[2]
    G = H // KV
    scale = 1.0 / np.sqrt(hd)
    qg = q.reshape(B, KV, G, hd)
    # low-precision cache storage (e.g. f8) is upcast after the HBM read
    k_cache = k_cache.astype(q.dtype)
    v_cache = v_cache.astype(q.dtype)
    s = jnp.einsum("bkgd,bskd->bkgs", qg, k_cache, preferred_element_type=jnp.float32) * scale
    valid = (cache_positions >= 0) & (cache_positions <= pos)
    if window:
        valid &= cache_positions > pos - window
    s = jnp.where(valid[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", p.astype(v_cache.dtype), v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, hd).astype(q.dtype)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def add_mlp_params(b: ParamBuilder, path: str, cfg, layer_axes=()) -> None:
    d, ff = cfg.d_model, cfg.d_ff
    la = tuple([None] * len(layer_axes))
    import numpy as _np

    s_in, s_out = 1.0 / _np.sqrt(d), 1.0 / _np.sqrt(ff)
    if cfg.mlp == "swiglu":
        b.add(f"{path}/wi_gate", layer_axes + (d, ff), la + ("embed", "mlp"), scale=s_in)
        b.add(f"{path}/wi_up", layer_axes + (d, ff), la + ("embed", "mlp"), scale=s_in)
        b.add(f"{path}/wo", layer_axes + (ff, d), la + ("mlp", "embed"), scale=s_out)
    else:  # squared_relu | gelu
        b.add(f"{path}/wi", layer_axes + (d, ff), la + ("embed", "mlp"), scale=s_in)
        b.add(f"{path}/wo", layer_axes + (ff, d), la + ("mlp", "embed"), scale=s_out)


def mlp_block(p: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        g = jnp.einsum("bsd,df->bsf", x, p["wi_gate"].astype(x.dtype))
        u = jnp.einsum("bsd,df->bsf", x, p["wi_up"].astype(x.dtype))
        h = jax.nn.silu(g) * u
    elif kind == "squared_relu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        h = jnp.square(jax.nn.relu(h))
    elif kind == "gelu":
        h = jnp.einsum("bsd,df->bsf", x, p["wi"].astype(x.dtype))
        h = jax.nn.gelu(h)
    else:
        raise ValueError(kind)
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
