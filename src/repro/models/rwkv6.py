"""RWKV-6 (Finch) time-mix — chunked parallel scan with data-dependent decay.

Per head (size N): state ``S ∈ R^{N×N}`` (key-dim × value-dim), inputs
r_t, k_t, v_t ∈ R^N, data-dependent decay w_t ∈ (0,1)^N, bonus u ∈ R^N:

    o_t = r_tᵀ (S_{t-1} + diag(u) k_t v_tᵀ)
    S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

Chunked form (chunk C): with A_i = Π_{t≤i} w_t (within-chunk cumulative
decay, f32),

    inter:  o_i += (r_i ⊙ A_{i-1})ᵀ S_prev
    intra:  o_i += Σ_{j<i} ((r_i ⊙ A_{i-1}/A_j)·k_j) v_j + ((r_i⊙u)·k_i) v_i
    carry:  S_new = diag(A_last) S_prev + Σ_j (A_last/A_j ⊙ k_j) v_jᵀ

giving O(T/C · (C² N + C N²)) work — sub-quadratic in T.  Decay products are
computed in log space and chunks kept short (default 32) for stability.

Simplifications vs the released Finch (recorded in DESIGN.md): decay is
data-dependent via a two-layer projection (theirs uses a LoRA with tanh);
token-shift mixing coefficients are learned-static (theirs adds a
data-dependent LoRA term).  The state-space semantics (the paper-relevant
part — O(1) decode state) are unchanged.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamBuilder


def add_rwkv6_params(b: ParamBuilder, path: str, cfg, layer_axes=()) -> None:
    d = cfg.d_model
    H, N = cfg.ssm_heads_eff, cfg.head_dim
    la = tuple([None] * len(layer_axes))
    lora = 64
    import numpy as _np

    s_in = 1.0 / _np.sqrt(d)
    for name in ("wr", "wk", "wv", "wg"):
        b.add(f"{path}/{name}", layer_axes + (d, H, N), la + ("embed", "ssm_heads", "head_dim"), scale=s_in)
    b.add(f"{path}/wo", layer_axes + (H, N, d), la + ("ssm_heads", "head_dim", "embed"), scale=1.0 / _np.sqrt(H * N))
    # data-dependent decay projection (two-layer)
    b.add(f"{path}/w_lora_a", layer_axes + (d, lora), la + ("embed", None), scale=s_in)
    b.add(f"{path}/w_lora_b", layer_axes + (lora, H, N), la + (None, "ssm_heads", "head_dim"), scale=0.05)
    b.add(f"{path}/w_base", layer_axes + (H, N), la + ("ssm_heads", "head_dim"), init="zeros")
    b.add(f"{path}/u_bonus", layer_axes + (H, N), la + ("ssm_heads", "head_dim"), scale=0.5)
    # static token-shift mix coefficients per projection
    for name in ("mu_r", "mu_k", "mu_v", "mu_w", "mu_g"):
        b.add(f"{path}/{name}", layer_axes + (d,), la + ("embed",), init="zeros")


def _token_shift(x: jnp.ndarray, x_prev: jnp.ndarray) -> jnp.ndarray:
    """shift(x)_t = x_{t-1}; x_prev supplies position -1 (decode carry)."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, x_shift, mu):
    m = jax.nn.sigmoid(mu.astype(jnp.float32)).astype(x.dtype)
    return x + m * (x_shift - x)


def _project(p, x, xs):
    """Compute r,k,v,g,(log w) from mixed inputs.  Shapes: (B,S,H,N)."""
    r = jnp.einsum("bsd,dhn->bshn", _mix(x, xs, p["mu_r"]), p["wr"].astype(x.dtype))
    k = jnp.einsum("bsd,dhn->bshn", _mix(x, xs, p["mu_k"]), p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhn->bshn", _mix(x, xs, p["mu_v"]), p["wv"].astype(x.dtype))
    g = jnp.einsum("bsd,dhn->bshn", _mix(x, xs, p["mu_g"]), p["wg"].astype(x.dtype))
    wx = _mix(x, xs, p["mu_w"])
    h = jnp.tanh(jnp.einsum("bsd,dl->bsl", wx, p["w_lora_a"].astype(x.dtype)))
    w_raw = p["w_base"].astype(jnp.float32) + jnp.einsum(
        "bsl,lhn->bshn", h, p["w_lora_b"].astype(x.dtype)
    ).astype(jnp.float32)
    # log-decay in (-inf, 0):  log w = -softplus(w_raw) - eps
    log_w = -jax.nn.softplus(w_raw) - 1e-4
    return r, k, v, g, log_w


def rwkv6_chunked(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    x_prev: jnp.ndarray,  # (B, D) token-shift carry
    state: jnp.ndarray,  # (B, H, N, N) wkv state carry
    *,
    chunk: int = 32,
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Training/prefill form.  Returns (out (B,S,D), x_last (B,D), state)."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    while S % chunk:  # largest power-of-two-ish divisor ≤ requested chunk
        chunk -= 1
    xs = _token_shift(x, x_prev)
    r, k, v, g, log_w = _project(p, x, xs)
    H, N = r.shape[2], r.shape[3]
    u = p["u_bonus"].astype(jnp.float32)
    nC = S // chunk

    def to_chunks(a):  # (B,S,H,N) -> (nC, B, H, C, N)
        return a.reshape(B, nC, chunk, H, N).transpose(1, 0, 3, 2, 4)

    rc, kc, vc, lwc = map(to_chunks, (r, k, v, log_w))

    def step(S_prev, inputs):
        rb, kb, vb, lwb = inputs  # (B,H,C,N)
        rb32 = rb.astype(jnp.float32)
        kb32 = kb.astype(jnp.float32)
        vb32 = vb.astype(jnp.float32)
        A = jnp.cumsum(lwb, axis=2)  # log cumulative decay incl. self
        A_prev = A - lwb  # exclusive (A_{i-1})
        r_t = rb32 * jnp.exp(A_prev)  # r_i ⊙ A_{i-1}  (exponent ≤ 0: safe)
        # inter-chunk: (B,H,C,N) @ (B,H,N,N)
        o_inter = jnp.einsum("bhcn,bhnm->bhcm", r_t, S_prev)
        # intra-chunk scores via *pairwise* decay differences: for j < i the
        # exponent A_{i-1} - A_j = Σ_{t=j+1..i-1} log w_t ≤ 0, so exp never
        # overflows (the factored r/A_i · k·A_j^{-1} form does at strong decay).
        tri = jnp.tril(jnp.ones((chunk, chunk), bool), k=-1)
        expo = A_prev[:, :, :, None, :] - A[:, :, None, :, :]  # (B,H,i,j,N)
        expo = jnp.where(tri[None, None, :, :, None], expo, -jnp.inf)
        gate = jnp.exp(expo)
        s = jnp.einsum("bhin,bhjn,bhijn->bhij", rb32, kb32, gate)  # (B,H,C,C)
        o_intra = jnp.einsum("bhcd,bhdm->bhcm", s, vb32)
        # diagonal bonus term
        diag = jnp.einsum("bhcn,bhcn->bhc", rb32 * u[None, :, None, :], kb32)
        o_diag = diag[..., None] * vb32
        # state carry
        A_last = A[:, :, -1:, :]  # (B,H,1,N)
        decay_chunk = jnp.exp(A_last[:, :, 0, :])  # (B,H,N)
        k_carry = kb32 * jnp.exp(A_last - A)  # k_j ⊙ A_last/A_j
        S_new = decay_chunk[..., None] * S_prev + jnp.einsum(
            "bhcn,bhcm->bhnm", k_carry, vb32
        )
        return S_new, (o_inter + o_intra + o_diag)

    state, outs = jax.lax.scan(step, state.astype(jnp.float32), (rc, kc, vc, lwc))
    # outs: (nC, B, H, C, N) -> (B, S, H, N)
    o = outs.transpose(1, 0, 3, 2, 4).reshape(B, S, H, N)
    o = o.astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bshn,hnd->bsd", o, p["wo"].astype(x.dtype))
    return out, x[:, -1, :], state


def rwkv6_decode(
    p: dict,
    x: jnp.ndarray,  # (B, 1, D)
    x_prev: jnp.ndarray,  # (B, D)
    state: jnp.ndarray,  # (B, H, N, N)
) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Single-token step: O(H·N²) — the O(1)-in-T decode the paper-assigned
    long_500k cell relies on."""
    B, _, D = x.shape
    xs = x_prev[:, None, :]
    r, k, v, g, log_w = _project(p, x, xs)
    H, N = r.shape[2], r.shape[3]
    u = p["u_bonus"].astype(jnp.float32)
    r32 = r[:, 0].astype(jnp.float32)  # (B,H,N)
    k32 = k[:, 0].astype(jnp.float32)
    v32 = v[:, 0].astype(jnp.float32)
    w = jnp.exp(log_w[:, 0])  # (B,H,N)
    kv = jnp.einsum("bhn,bhm->bhnm", k32, v32)
    o = jnp.einsum("bhn,bhnm->bhm", r32, state + u[None, :, :, None] * kv)
    state = w[..., None] * state + kv
    o = o[:, None, :, :].astype(x.dtype) * jax.nn.silu(g)
    out = jnp.einsum("bshn,hnd->bsd", o.reshape(B, 1, H, N), p["wo"].astype(x.dtype))
    return out, x[:, -1, :], state


def rwkv6_ref(p: dict, x: jnp.ndarray, x_prev: jnp.ndarray, state: jnp.ndarray):
    """Step-by-step oracle (lax.scan over single tokens) for property tests."""
    B, S, D = x.shape

    def step(carry, xt):
        xp, st = carry
        out, xp2, st2 = rwkv6_decode(p, xt[:, None, :], xp, st)
        return (xp2, st2), out[:, 0]

    (xp, st), outs = jax.lax.scan(step, (x_prev, state.astype(jnp.float32)), x.transpose(1, 0, 2))
    return outs.transpose(1, 0, 2), xp, st
