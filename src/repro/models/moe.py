"""Mixture-of-Experts block — GShard-style dispatch/combine einsums.

Design notes (DESIGN.md §5):
- top-k routing with renormalized gates (mixtral/dbrx convention);
- tokens are re-grouped into fixed-size groups (``group_size``) so the
  dispatch one-hot is (G, S_g, E, C) with C = S_g·topk/E·cf — keeping both
  memory and the dispatch einsum FLOPs at ~2 % of expert FLOPs;
- experts are sharded over the ``model`` ("expert" logical) axis; XLA SPMD
  inserts the all-to-alls at the dispatch/combine einsums;
- capacity-factor token dropping (dropped tokens pass through the residual),
  plus the standard load-balancing auxiliary loss.

HLO FLOPs therefore track *active* FLOPs × capacity factor, which keeps the
MODEL_FLOPS/HLO_FLOPs roofline ratio honest for the MoE archs.
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import ParamBuilder


def add_moe_params(b: ParamBuilder, path: str, cfg, layer_axes=()) -> None:
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    la = tuple([None] * len(layer_axes))
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(ff)
    b.add(f"{path}/router", layer_axes + (d, E), la + ("embed", "expert"), scale=s_in)
    b.add(f"{path}/wi_gate", layer_axes + (E, d, ff), la + ("expert", "expert_embed", "expert_mlp"), scale=s_in)
    b.add(f"{path}/wi_up", layer_axes + (E, d, ff), la + ("expert", "expert_embed", "expert_mlp"), scale=s_in)
    b.add(f"{path}/wo", layer_axes + (E, ff, d), la + ("expert", "expert_mlp", "expert_embed"), scale=s_out)


def moe_block(
    p: dict,
    x: jnp.ndarray,  # (B, S, D)
    *,
    num_experts: int,
    top_k: int,
    capacity_factor: float = 1.25,
    group_size: int = 1024,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (output (B,S,D), aux_loss ())."""
    B, S, D = x.shape
    E, K = num_experts, top_k
    tokens = x.reshape(B * S, D)
    T = B * S
    gsz = min(group_size, T)
    assert T % gsz == 0, (T, gsz)
    G = T // gsz
    xg = tokens.reshape(G, gsz, D)

    logits = jnp.einsum("gsd,de->gse", xg, p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)  # (G, S, E)
    gate_vals, gate_idx = jax.lax.top_k(probs, K)  # (G, S, K)
    gate_vals = gate_vals / jnp.maximum(
        jnp.sum(gate_vals, axis=-1, keepdims=True), 1e-9
    )
    # mask (G, S, E, K): expert e selected as the k-th choice
    sel = jax.nn.one_hot(gate_idx, E, dtype=jnp.float32)  # (G,S,K,E)
    sel = sel.transpose(0, 1, 3, 2)  # (G,S,E,K)
    combine_w = jnp.einsum("gsek,gsk->gse", sel, gate_vals)  # (G,S,E)

    # load-balancing aux loss (Switch): E * sum_e f_e * p_e
    density = jnp.mean(sel.sum(axis=-1), axis=1)  # (G, E) fraction routed
    router_prob = jnp.mean(probs, axis=1)  # (G, E)
    aux = E * jnp.mean(jnp.sum(density * router_prob, axis=-1))

    C = int(np.ceil(gsz * K * capacity_factor / E))
    # position of each token within its expert's capacity buffer, by k-th
    # choice priority then sequence order
    mask = sel  # (G,S,E,K)
    # flatten choice priority into the scan order: iterate k outer, s inner
    mask_k = mask.transpose(0, 3, 1, 2)  # (G,K,S,E)
    pos_k = jnp.cumsum(mask_k.reshape(G, K * gsz, E), axis=1) - 1.0
    pos = pos_k.reshape(G, K, gsz, E).transpose(0, 2, 3, 1)  # (G,S,E,K)
    within = (pos < C) & (mask > 0)
    pos = jnp.where(within, pos, 0.0)
    disp_k = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=x.dtype) * within[..., None]
    dispatch = disp_k.sum(axis=3)  # (G,S,E,C)
    combine = dispatch.astype(jnp.float32) * combine_w[..., None]  # (G,S,E,C)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch, xg)  # (E,G,C,D)
    g = jnp.einsum("egcd,edf->egcf", expert_in, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("egcd,edf->egcf", expert_in, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["wo"].astype(x.dtype))
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), expert_out)
    return out.reshape(B, S, D), aux


def moe_block_dense_ref(
    p: dict, x: jnp.ndarray, *, num_experts: int, top_k: int
) -> jnp.ndarray:
    """Dense-dispatch oracle: every token through every expert, gated.

    Exact (no capacity drops) — the property tests assert the GShard block
    matches this wherever no token was dropped."""
    logits = jnp.einsum("bsd,de->bse", x, p["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, top_k)
    gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    gates = jax.nn.one_hot(gate_idx, num_experts) * gate_vals[..., None]
    gates = gates.sum(axis=-2)  # (B,S,E)
    g = jnp.einsum("bsd,edf->bsef", x, p["wi_gate"].astype(x.dtype))
    u = jnp.einsum("bsd,edf->bsef", x, p["wi_up"].astype(x.dtype))
    h = jax.nn.silu(g) * u
    eo = jnp.einsum("bsef,efd->bsed", h, p["wo"].astype(x.dtype))
    return jnp.einsum("bse,bsed->bsd", gates.astype(x.dtype), eo)
