"""Logical-axis sharding rules (MaxText-style).

Params and activations are annotated with *logical* axis names; a rules
table maps logical names to physical mesh axes.  Hillclimb variants swap
individual rules (e.g. re-shard the KV cache sequence dim) without touching
model code.

Conventions:
- a rule value may be ``None`` (replicate), a mesh-axis name, or a tuple of
  mesh axes (e.g. batch over ``("pod", "data")``);
- axes named in a rule but absent from the mesh are silently dropped, so the
  same rules serve the single-pod (data, model) and multi-pod
  (pod, data, model) meshes;
- if a dim's size does not divide the product of its mapped mesh axes, the
  mapping is dropped for that dim (with the ``strict`` flag raising
  instead) — this is what lets kv_heads=2 fall back to replication instead
  of a lowering error when a config forgets to pad.
"""

from __future__ import annotations

from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec

AxisRule = Union[None, str, Tuple[str, ...]]
LogicalRules = Dict[str, AxisRule]

# The baseline ruleset (paper-faithful megatron-style TP + DP):
DEFAULT_RULES: LogicalRules = {
    "batch": ("pod", "data"),
    "seq": None,
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "qkv": None,
    "mlp": "model",
    "vocab": "model",
    "expert": "model",
    # Expert stacks must shard 2D to fit HBM (memory_analysis caught
    # mixtral's E=8 experts replicating under 16-way TP: 542 GB/device).
    # EP-style orientation won the §Perf comparison: when the expert count
    # doesn't divide TP, shard expert d_model over 'model' (weights stay
    # put; the contraction inserts activation reduces) rather than
    # re-gathering expert weights over 'data' every microbatch.
    "expert_embed": "model",
    "expert_mlp": ("data",),
    "capacity": None,
    "layers": None,
    "ssm_state": None,
    "ssm_heads": "model",
    "conv": None,
    "codebook": None,
    # ANN-index logical axes (device-resident shard probe path)
    "ann_shard": "data",
    "ann_node": None,
    "ann_degree": None,
    "ann_pq_m": None,
    # serving-specific
    "cache_batch": "data",
    "cache_seq": None,
    "cache_heads": "model",
}


def resolve_rule(rule: AxisRule, mesh_axes: Sequence[str]) -> AxisRule:
    """Drop mesh axes not present in the current mesh."""
    if rule is None:
        return None
    if isinstance(rule, str):
        return rule if rule in mesh_axes else None
    kept = tuple(a for a in rule if a in mesh_axes)
    if not kept:
        return None
    return kept if len(kept) > 1 else kept[0]


def _axis_size(mesh: Mesh, rule: AxisRule) -> int:
    if rule is None:
        return 1
    if isinstance(rule, str):
        return mesh.shape[rule]
    size = 1
    for a in rule:
        size *= mesh.shape[a]
    return size


def spec_for(
    logical_axes: Sequence[Optional[str]],
    rules: LogicalRules,
    mesh: Mesh,
    *,
    dim_sizes: Optional[Sequence[int]] = None,
    strict: bool = False,
) -> PartitionSpec:
    """Build a PartitionSpec for one array from its logical axis names."""
    mesh_axes = list(mesh.axis_names)
    used: set = set()
    entries = []
    for i, name in enumerate(logical_axes):
        rule = resolve_rule(rules.get(name) if name else None, mesh_axes)
        # each mesh axis may appear at most once in a PartitionSpec; drop the
        # already-used axes from a tuple rule rather than the whole rule
        if rule is not None:
            flat = (rule,) if isinstance(rule, str) else rule
            kept = tuple(a for a in flat if a not in used)
            rule = None if not kept else (kept[0] if len(kept) == 1 else kept)
        # divisibility check BEFORE marking axes used: a dropped rule must
        # not block later dims from taking the axis (e.g. mixtral's 8
        # experts can't take 'model'; the per-expert ff dim then can)
        if rule is not None and dim_sizes is not None:
            if dim_sizes[i] % _axis_size(mesh, rule) != 0:
                if strict:
                    raise ValueError(
                        f"dim {i} (logical {name!r}, size {dim_sizes[i]}) not divisible "
                        f"by mesh extent {_axis_size(mesh, rule)} of rule {rule!r}"
                    )
                # retry with a prefix of the tuple rule (partial sharding)
                if not isinstance(rule, str):
                    rule = next(
                        (
                            r
                            for r in (rule[:k] for k in range(len(rule) - 1, 0, -1))
                            if dim_sizes[i] % _axis_size(mesh, r if len(r) > 1 else r[0]) == 0
                        ),
                        None,
                    )
                    if rule is not None and len(rule) == 1:
                        rule = rule[0]
                else:
                    rule = None
        if rule is not None:
            flat = (rule,) if isinstance(rule, str) else rule
            used.update(flat)
        entries.append(rule)
    # trim trailing Nones for tidier specs
    while entries and entries[-1] is None:
        entries.pop()
    return PartitionSpec(*entries)


def logical_to_sharding(
    axes_tree,
    rules: LogicalRules,
    mesh: Mesh,
    *,
    shapes_tree=None,
) -> object:
    """Map a pytree of logical-axes tuples to a pytree of NamedShardings.

    ``axes_tree`` leaves are tuples like ``("vocab", "embed")``; if
    ``shapes_tree`` is given (same structure, leaves with ``.shape``),
    divisibility is checked and non-dividing rules fall back to replication.
    """

    def one(axes, shaped=None):
        sizes = None if shaped is None else shaped.shape
        return NamedSharding(mesh, spec_for(axes, rules, mesh, dim_sizes=sizes))

    if shapes_tree is None:
        return jax.tree_util.tree_map(
            one, axes_tree, is_leaf=lambda x: isinstance(x, tuple) or x is None
        )
    return jax.tree_util.tree_map(
        one,
        axes_tree,
        shapes_tree,
        is_leaf=lambda x: isinstance(x, tuple) or x is None,
    )


def with_rules(base: LogicalRules, **overrides: AxisRule) -> LogicalRules:
    out = dict(base)
    out.update(overrides)
    return out
