"""Model substrate: the 10 assigned architectures as composable JAX modules.

Families: dense GQA transformers (chatglm3, qwen2.5, minitron, phi4-mini,
chameleon, musicgen), MoE (dbrx, mixtral), SSM (rwkv6), hybrid (zamba2).
All models share one scan-over-layers decoder skeleton with pluggable
sequence mixers and MLPs, carry logical-axis annotations for pjit sharding,
and expose three entry points: ``forward`` (training), ``prefill`` and
``decode`` (serving with caches).
"""

from repro.models.model import build_model, Model  # noqa: F401
from repro.models.sharding import LogicalRules, logical_to_sharding  # noqa: F401
