"""Roofline analysis: jaxpr FLOP accounting, HLO collective parsing."""

from repro.analysis.flops import count_jaxpr_flops  # noqa: F401
from repro.analysis.hlo import collective_bytes_from_hlo  # noqa: F401
from repro.analysis.roofline import RooflineTerms, compute_roofline  # noqa: F401
