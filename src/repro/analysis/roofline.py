"""Roofline terms for TPU v5e (DESIGN.md §7).

    compute    = FLOPs / (chips × 197e12)          [bf16 peak]
    memory     = bytes / (chips × 819e9)           [HBM]
    collective = coll_bytes / (chips × n_links × 50e9)   [ICI]
                 + dcn_bytes / (chips × dcn_bw)          [multi-pod]

FLOPs come from the trip-count-aware jaxpr counter; bytes from an analytic
traffic model (params read once per step + activation/cache traffic), with
the raw ``cost_analysis`` numbers recorded alongside for transparency;
collective bytes from the HLO parser (trip-corrected).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # bytes/s / chip
ICI_LINK_BW = 50e9  # bytes/s per link
ICI_LINKS = 4  # v5e: 4 usable ICI links per chip in a 2D torus (x±, y±... 4)
DCN_BW = 25e9  # bytes/s per chip cross-pod (conservative DCN share)


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    # inputs
    hlo_flops_raw: float  # cost_analysis (single-visit)
    hlo_bytes_raw: float
    jaxpr_flops: float  # trip-corrected analytic
    model_bytes: float  # analytic traffic model
    coll_bytes_raw: float
    coll_bytes: float  # trip-corrected (ICI share)
    dcn_bytes: float = 0.0
    model_flops: float = 0.0  # 6·N_active·D or 2·N_active per token
    # derived (seconds)
    t_compute: float = 0.0
    t_memory: float = 0.0
    t_collective: float = 0.0
    bottleneck: str = ""
    useful_ratio: float = 0.0  # MODEL_FLOPS / jaxpr_flops
    roofline_fraction: float = 0.0  # max-term bound vs pure-compute bound
    extra: Dict[str, float] = field(default_factory=dict)

    def finalize(self) -> "RooflineTerms":
        self.t_compute = self.jaxpr_flops / (self.chips * PEAK_FLOPS)
        self.t_memory = self.model_bytes / (self.chips * HBM_BW)
        t_ici = self.coll_bytes / (self.chips * ICI_LINKS * ICI_LINK_BW)
        t_dcn = self.dcn_bytes / (self.chips * DCN_BW)
        self.t_collective = t_ici + t_dcn
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        self.bottleneck = max(terms, key=terms.get)
        self.useful_ratio = (
            self.model_flops / self.jaxpr_flops if self.jaxpr_flops else 0.0
        )
        # fraction of the pure-compute roofline this step could achieve if
        # perfectly overlapped: useful_compute_time / max(all terms)
        t_useful = self.model_flops / (self.chips * PEAK_FLOPS)
        bound = max(terms.values())
        self.roofline_fraction = t_useful / bound if bound > 0 else 0.0
        return self


def compute_roofline(**kw) -> RooflineTerms:
    return RooflineTerms(**kw).finalize()
