"""Regenerate the data tables of EXPERIMENTS.md from results/*.jsonl.

    PYTHONPATH=src python -m repro.analysis.report > /tmp/tables.md

Emits markdown for §Dry-run, §Roofline and §Perf; EXPERIMENTS.md embeds the
output (regenerated whenever the dry-run or hillclimb JSONLs change).
"""

from __future__ import annotations

import json


def _rows(path):
    try:
        return [json.loads(l) for l in open(path)]
    except FileNotFoundError:
        return []


def _fmt_bytes(b):
    if b >= 1e12:
        return f"{b/1e12:.2f}TB"
    if b >= 1e9:
        return f"{b/1e9:.2f}GB"
    if b >= 1e6:
        return f"{b/1e6:.1f}MB"
    return f"{b/1e3:.0f}KB"


def _next_move(r):
    """One sentence: what would move the dominant term down."""
    rf = r["roofline"]
    b = rf["bottleneck"]
    kind = r["kind"]
    if kind == "train" and b == "compute":
        if rf["useful_ratio"] < 0.8:
            return "cut non-useful FLOPs (sparse-attn schedule / MoE capacity) — see §Perf"
        return "near compute roofline; next: overlap the remaining collectives"
    if kind == "train" and b == "collective":
        return "per-microbatch weight-grad all-reduces dominate: fewer accumulation rounds or reduce-scatter grads — see §Perf A"
    if kind == "prefill" and b == "compute":
        return "block-sparse attention schedule removes masked-block FLOPs — see §Perf B"
    if kind == "decode" and b == "memory":
        return "decode reads params+cache per token: shrink the cache (seq-sharding, f8 storage — §Perf C) or batch more requests"
    if b == "memory":
        return "reduce bytes/step: lower-precision storage or better layout"
    return "overlap the dominant collective with compute"


def dryrun_tables(rows):
    out = []
    for mesh in ("single", "multi"):
        chips = 256 if mesh == "single" else 512
        out.append(f"\n### Mesh `{mesh}` ({chips} chips)\n")
        out.append(
            "| arch | shape | kind | compile s | args/dev | HLO flops (raw) | "
            "jaxpr flops (trip-corr.) | coll bytes (raw) | coll bytes (global, corr.) |"
        )
        out.append("|---|---|---|---|---|---|---|---|---|")
        for r in rows:
            if r["mesh"] != mesh:
                continue
            if r["kind"] == "skip":
                out.append(
                    f"| {r['arch']} | {r['shape']} | **skip** | — | — | — | — | — | — |"
                )
                continue
            ma = r["memory_analysis"]
            ca = r["cost_analysis"]
            co = r["collectives"]
            rf = r["roofline"]
            out.append(
                f"| {r['arch']} | {r['shape']} | {r['kind']} | {r['compile_s']} | "
                f"{_fmt_bytes(ma['argument_bytes'])} | {ca['flops']:.2e} | "
                f"{rf['jaxpr_flops']:.2e} | {_fmt_bytes(co['raw_bytes'])} | "
                f"{_fmt_bytes(co.get('global_bytes', co['corrected_bytes']))} |"
            )
    return "\n".join(out)


def roofline_table(rows):
    out = [
        "| arch | shape | t_compute s | t_memory s | t_collective s | bottleneck | "
        "MODEL_FLOPS | useful ratio | roofline frac | next move |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r["mesh"] != "single" or r["kind"] in ("skip", "error"):
            continue
        rf = r["roofline"]
        out.append(
            f"| {r['arch']} | {r['shape']} | {rf['t_compute']:.3e} | "
            f"{rf['t_memory']:.3e} | {rf['t_collective']:.3e} | "
            f"**{rf['bottleneck']}** | {rf['model_flops']:.2e} | "
            f"{rf['useful_ratio']:.3f} | {rf['roofline_fraction']:.3f} | {_next_move(r)} |"
        )
    return "\n".join(out)


def perf_table(rows):
    out = [
        "| variant | t_compute | t_memory | t_collective | bottleneck | useful | "
        "roofline frac |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if "error" in r:
            out.append(f"| {r['variant']} | ERROR: {r['error']} | | | | | |")
            continue
        out.append(
            f"| {r['variant']} | {r['t_compute']:.3e} | {r['t_memory']:.3e} | "
            f"{r['t_collective']:.3e} | {r['bottleneck']} | {r['useful_ratio']:.3f} | "
            f"**{r['roofline_fraction']:.3f}** |"
        )
    return "\n".join(out)


def main():
    dry = _rows("results/dryrun.jsonl")
    hill = _rows("results/hillclimb.jsonl")
    order = {a: i for i, a in enumerate([
        "dbrx-132b", "mixtral-8x7b", "chameleon-34b", "chatglm3-6b", "qwen2.5-3b",
        "minitron-8b", "phi4-mini-3.8b", "musicgen-medium", "rwkv6-3b", "zamba2-1.2b",
    ])}
    shape_order = {s: i for i, s in enumerate(["train_4k", "prefill_32k", "decode_32k", "long_500k"])}
    dry.sort(key=lambda r: (order.get(r["arch"], 99), shape_order.get(r["shape"], 9)))
    print("## §Dry-run\n")
    print(dryrun_tables(dry))
    print("\n## §Roofline (single-pod, v5e-256)\n")
    print(roofline_table(dry))
    print("\n## §Perf (hillclimbs)\n")
    print(perf_table(hill))


if __name__ == "__main__":
    main()
