"""HLO text analysis: collective bytes with while-loop trip-count correction.

The post-SPMD HLO (``compiled.as_text()``) names every collective —
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute —
with full operand shapes.  We sum operand bytes per *computation*, then walk
the call graph: a while op multiplies its body's bytes by the loop's trip
count, recovered from the canonical ``compare(iv, constant)`` pattern in the
loop condition.  Scan-over-layers collectives are thereby counted
num_layers×, not once.

Returns both the raw (single-visit) sum — the literal deliverable asked of
``lowered.as_text()`` parsing — and the trip-corrected total used for the
roofline collective term.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    """Bytes of one shape literal like ``bf16[16,512,128]`` (or tuples)."""
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dtype, dims = m.group(1), m.group(2)
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


@dataclass
class CollectiveStats:
    raw_bytes: int = 0  # every collective op counted once (per-device operands)
    corrected_bytes: int = 0  # while bodies × trip count (per-device operands)
    global_bytes: int = 0  # corrected × replica-group size (global payload)
    by_kind: Dict[str, int] = field(default_factory=dict)  # corrected global, per kind
    ops: int = 0


_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_PAIRS_RE = re.compile(r"source_target_pairs=\{(.+?)\}\}")


def _group_size(line: str, kind: str) -> int:
    """Participants per replica group (1 if unparseable)."""
    if kind == "collective-permute":
        m = _PAIRS_RE.search(line)
        if m:
            return m.group(0).count("{") or 1
        return 1
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return max(int(m.group(2)), 1)
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


def _split_computations(hlo: str) -> Dict[str, List[str]]:
    """computation name -> its instruction lines (wrapped lines re-joined).

    XLA text format: computation headers start at column 0 (optionally
    ``ENTRY``-prefixed) and end with ``{``; instructions are indented; long
    instructions wrap onto further lines; the computation closes with a
    column-0 ``}``."""
    comps: Dict[str, List[str]] = {}
    current: Optional[str] = None
    for raw in hlo.splitlines():
        if not raw.strip():
            continue
        col0 = not raw[0].isspace()
        stripped = raw.strip()
        if col0:
            if stripped.startswith("}"):
                current = None
                continue
            m = re.match(r"(?:ENTRY\s+)?%?([\w\.\-]+)\s*\(", stripped)
            if m and stripped.endswith("{"):
                current = m.group(1)
                comps[current] = []
            continue
        if current is None:
            continue
        # new instruction vs continuation of the previous one
        if re.match(r"(ROOT\s+)?%?[\w\.\-]+\s*=", stripped):
            comps[current].append(stripped)
        elif comps[current]:
            comps[current][-1] += " " + stripped
        else:
            comps[current].append(stripped)
    return comps


def _find_entry(hlo: str) -> Optional[str]:
    m = re.search(r"ENTRY\s+%?([\w\.\-]+)", hlo)
    return m.group(1) if m else None


def _trip_count(cond_lines: List[str], comps: Optional[Dict[str, List[str]]] = None) -> int:
    """Recover the trip count from a while condition computation.

    Canonical lowering: ``compare(induction_var, constant), direction=LT``.
    XLA:CPU frequently wraps the compare in a kLoop *fusion*, leaving only the
    scalar constant in the condition computation — so the bound is recovered
    as the largest scalar integer constant there, with the compare direction
    looked up inside the called fusion when available.  Falls back to 1."""
    const_vals: List[int] = []
    direction = None
    for line in cond_lines:
        m = re.search(r"=\s*[su]\d+\[\]\s*constant\((\d+)\)", line)
        if m:
            const_vals.append(int(m.group(1)))
        d = re.search(r"direction=(\w+)", line)
        if d:
            direction = d.group(1)
        if direction is None and comps is not None:
            mc = re.search(r"calls=%?([\w\.\-]+)", line)
            if mc:
                for inner in comps.get(mc.group(1), []):
                    d2 = re.search(r"direction=(\w+)", inner)
                    if d2:
                        direction = d2.group(1)
                        break
    if not const_vals:
        return 1
    v = max(const_vals)
    if direction == "LE":
        v += 1
    return max(v, 1)


def collective_bytes_from_hlo(hlo: str) -> CollectiveStats:
    comps = _split_computations(hlo)
    entry = _find_entry(hlo)

    # per-computation local sums + calls (while/call/fusion/cond)
    local: Dict[str, Dict[str, int]] = {}
    local_global: Dict[str, Dict[str, int]] = {}
    calls: Dict[str, List[Tuple[str, int]]] = {}  # comp -> [(callee, multiplier)]
    for name, lines in comps.items():
        sums: Dict[str, int] = {}
        gsums: Dict[str, int] = {}
        edge: List[Tuple[str, int]] = []
        for line in lines:
            for kind in COLLECTIVE_OPS:
                # match ops like "%ag = bf16[...] all-gather(...)" including
                # -start variants; skip -done (counted at start)
                if re.search(rf"\b{kind}(?:-start)?\(", line) and f"{kind}-done" not in line:
                    lhs = line.split("=", 1)
                    shape_part = lhs[1] if len(lhs) > 1 else line
                    shape_str = shape_part.split(kind)[0]
                    b = _shape_bytes(shape_str)
                    sums[kind] = sums.get(kind, 0) + b
                    gsums[kind] = gsums.get(kind, 0) + b * _group_size(line, kind)
                    break
            m = re.search(r"while\([^)]*\).*?body=%?([\w\.\-]+).*?condition=%?([\w\.\-]+)", line)
            if not m:
                m2 = re.search(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)", line)
                if m2:
                    cond_name, body_name = m2.group(1), m2.group(2)
                    trips = _trip_count(comps.get(cond_name, []), comps)
                    edge.append((body_name, trips))
            else:
                body_name, cond_name = m.group(1), m.group(2)
                trips = _trip_count(comps.get(cond_name, []), comps)
                edge.append((body_name, trips))
            for pat in (r"calls=%?([\w\.\-]+)", r"to_apply=%?([\w\.\-]+)"):
                mc = re.search(pat, line)
                if mc and "while" not in line:
                    edge.append((mc.group(1), 1))
            mb = re.search(r"branches=\{([^}]*)\}", line)
            if mb:
                for br in mb.group(1).split(","):
                    edge.append((br.strip().lstrip("%"), 1))
        local[name] = sums
        local_global[name] = gsums
        calls[name] = edge

    def make_totaler(table):
        memo: Dict[str, Dict[str, int]] = {}

        def total_of(name: str, stack=()) -> Dict[str, int]:
            if name in memo:
                return memo[name]
            if name in stack or name not in table:
                return {}
            out = dict(table.get(name, {}))
            for callee, mult in calls.get(name, []):
                sub = total_of(callee, stack + (name,))
                for k, v in sub.items():
                    out[k] = out.get(k, 0) + v * mult
            memo[name] = out
            return out

        return total_of

    stats = CollectiveStats()
    raw = 0
    ops = 0
    for name, sums in local.items():
        raw += sum(sums.values())
        ops += len(sums)
    corrected = make_totaler(local)(entry, ()) if entry else {}
    corrected_g = make_totaler(local_global)(entry, ()) if entry else {}
    stats.raw_bytes = raw
    stats.by_kind = corrected_g
    stats.corrected_bytes = sum(corrected.values())
    stats.global_bytes = sum(corrected_g.values())
    stats.ops = ops
    return stats
