"""Trip-count-aware FLOP accounting from the jaxpr.

``compiled.cost_analysis()`` visits each HLO instruction once, so a
scan-over-layers module under-reports FLOPs by ~num_layers× (verified in
EXPERIMENTS.md §Dry-run).  The jaxpr still carries every scan's static
``length``, so walking it and multiplying body costs by trip counts gives
the exact analytic FLOP count of the compiled program — including autodiff
(the backward scan is a first-class scan in the jaxpr).

Counted: dot_general (2·M·N·K·batch), conv, and a 1-flop-per-element charge
for arithmetic elementwise/reduce ops.  ``cond`` branches contribute their
*maximum* (conservative for roofline).
"""

from __future__ import annotations

import jax
import numpy as np
from jax import core

ELEMENTWISE = {
    "add", "sub", "mul", "div", "max", "min", "exp", "log", "tanh", "logistic",
    "rsqrt", "sqrt", "neg", "abs", "floor", "ceil", "round", "sign", "pow",
    "integer_pow", "erf", "cumsum", "cumprod", "select_n", "clamp", "and", "or",
    "xor", "not", "erf_inv", "expm1", "log1p", "sin", "cos",
}
REDUCTIONS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "argmax", "argmin"}


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except Exception:
        return 0


def _dot_flops(eqn) -> float:
    dnums = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dnums
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    batch = int(np.prod([lhs.shape[i] for i in lb])) if lb else 1
    contract = int(np.prod([lhs.shape[i] for i in lc])) if lc else 1
    m = int(np.prod([s for i, s in enumerate(lhs.shape) if i not in set(lc) | set(lb)]))
    n = int(np.prod([s for i, s in enumerate(rhs.shape) if i not in set(rc) | set(rb)]))
    return 2.0 * batch * m * n * contract


def _jaxpr_flops(jaxpr: core.Jaxpr) -> float:
    total = 0.0
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        if prim == "dot_general":
            total += _dot_flops(eqn)
        elif prim in ("conv_general_dilated",):
            out = eqn.outvars[0].aval
            rhs = eqn.invars[1].aval
            total += 2.0 * _size(out) * int(np.prod(rhs.shape[:-1]))
        elif prim == "scan":
            body = eqn.params["jaxpr"].jaxpr
            total += eqn.params["length"] * _jaxpr_flops(body)
        elif prim == "while":
            body = eqn.params["body_jaxpr"].jaxpr
            # data-dependent trip count: fall back to a declared bound if the
            # caller attached one (beam search); else count once.
            trips = eqn.params.get("_trip_hint", 1)
            total += trips * _jaxpr_flops(body)
        elif prim == "cond":
            branches = eqn.params["branches"]
            total += max((_jaxpr_flops(b.jaxpr) for b in branches), default=0.0)
        elif prim in ("pjit", "closed_call", "core_call", "xla_call", "remat_call"):
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                total += _jaxpr_flops(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
        elif prim in ("custom_jvp_call", "custom_vjp_call", "custom_vjp_call_jaxpr"):
            inner = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
            if inner is not None:
                total += _jaxpr_flops(inner.jaxpr if hasattr(inner, "jaxpr") else inner)
        elif prim == "checkpoint" or prim == "remat2":
            inner = eqn.params.get("jaxpr")
            if inner is not None:
                total += _jaxpr_flops(inner)
        elif prim in ELEMENTWISE or prim in REDUCTIONS:
            total += float(_size(eqn.outvars[0].aval))
    return total


def count_jaxpr_flops(fn, *args, **kwargs) -> float:
    """Analytic FLOPs of ``fn(*args)`` (args may be ShapeDtypeStructs)."""
    closed = jax.make_jaxpr(fn, **kwargs)(*args)
    return _jaxpr_flops(closed.jaxpr)
