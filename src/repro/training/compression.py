"""Gradient compression with error feedback (DCN/pod-axis all-reduce).

At 2+ pods the cross-pod (DCN) gradient all-reduce is the slowest collective;
int8 quantization with per-tensor scale cuts its bytes 4× vs f32 (2× vs
bf16).  Error feedback keeps the quantization *unbiased over time*: the
residual of each step is added back before quantizing the next — SGD-style
convergence is preserved (tested in tests/test_training.py).

``compressed_psum`` is used inside shard_map data-parallel steps; the pjit
cells keep XLA's native reductions (compression there is a documented
hillclimb option, measured by its collective-bytes delta in §Perf).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grad: jnp.ndarray, error: jnp.ndarray):
    """Returns (q, scale, new_error)."""
    corrected = grad.astype(jnp.float32) + error
    q, scale = quantize_int8(corrected)
    deq = dequantize_int8(q, scale)
    return q, scale, corrected - deq


def compressed_psum(grads: Any, errors: Any, axis_name: str):
    """int8-quantized psum over ``axis_name`` with error feedback.

    Wire bytes: int8 payload + one f32 scale per tensor (vs f32 payload).
    Returns (mean_grads, new_errors)."""
    n = jax.lax.psum(1, axis_name)

    def one(g, e):
        q, scale, new_e = compress_with_feedback(g, e)
        # sum of per-shard dequantized grads; scales differ per shard so
        # dequantize locally and psum the (already low-rate) int8-rounded
        # values — the wire transfer is the int8 tensor + scalar.
        summed = jax.lax.psum(dequantize_int8(q, scale), axis_name)
        return summed / n, new_e

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree_util.tree_unflatten(treedef, [m for m, _ in out])
    new_errors = jax.tree_util.tree_unflatten(treedef, [e for _, e in out])
    return means, new_errors
