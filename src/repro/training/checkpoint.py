"""Snapshot-bound checkpointing — the paper's lifecycle argument applied to
training state.

A checkpoint is committed through the *same* Iceberg-style catalog as table
data: each pytree leaf is one immutable object; the manifest lists them; the
snapshot summary carries step / metrics.  Consequences (all tested):

- **atomicity** — a crash mid-save leaves an uncommitted pile of objects that
  orphan-GC reaps; readers only ever see fully-committed checkpoints;
- **time travel** — restore any retained step;
- **fault tolerance** — resume picks the latest committed snapshot;
- **async save** — leaf uploads happen on a background thread; only the
  commit is synchronous with the train loop.

Elastic restarts: leaves are stored unsharded (host-gathered); on restore
they are re-placed under the *current* mesh's shardings — resharding across
different pod counts is therefore free.
"""

from __future__ import annotations

import io
import json
import threading
from typing import Any, Optional, Tuple

import jax
import numpy as np

from repro.iceberg.catalog import RestCatalog
from repro.iceberg.snapshot import DataFile
from repro.lakehouse.objectstore import ObjectStore


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out.append((name, leaf))
    return out, treedef


class CheckpointManager:
    def __init__(
        self,
        catalog: RestCatalog,
        name: str = "__checkpoints",
        *,
        async_save: bool = True,
        keep_last: int = 3,
    ) -> None:
        self.catalog = catalog
        self.store: ObjectStore = catalog.store
        self.name = name
        self.async_save = async_save
        self.keep_last = keep_last
        self._pending: Optional[threading.Thread] = None
        if not catalog.table_exists(name):
            catalog.create_table(name, {"leaf": "bytes"})

    # -- save ------------------------------------------------------------------
    def save(self, step: int, state: Any, metrics: Optional[dict] = None) -> None:
        """Write leaves (async if configured) then commit the snapshot."""
        self.wait()  # one in-flight save at a time
        flat, _ = _flatten_with_paths(state)
        # materialize to host BEFORE handing off to the save thread: the
        # train step donates its state buffers, so by the time the thread
        # ran the device arrays could already be deleted (a lost checkpoint
        # that only surfaced at restore time)
        leaves = [(name, np.asarray(leaf)) for name, leaf in flat]
        meta = self.catalog.load_table(self.name)

        def do_save():
            files = []
            for name, arr in leaves:
                buf = io.BytesIO()
                np.save(buf, arr, allow_pickle=False)
                key = f"{meta.location}/data/step-{step:08d}/{name.replace('/', '_')}.npy"
                self.store.put(key, buf.getvalue())
                files.append(
                    DataFile(path=key, record_count=1, file_size_bytes=buf.tell())
                )
            summary = {"ckpt.step": str(step)}
            if metrics:
                summary["ckpt.metrics"] = json.dumps(
                    {k: float(v) for k, v in metrics.items()}
                )
            # checkpoints replace rather than accumulate: commit only this
            # step's files as the live set
            def mutate(m):
                from repro.iceberg.snapshot import (
                    FileStatus,
                    Manifest,
                    ManifestEntry,
                    Snapshot,
                    new_snapshot_id,
                    now_ms,
                    write_manifest_list,
                )
                import uuid as _uuid

                token = _uuid.uuid4().hex[:12]
                mpath = f"{m.location}/metadata/manifest-{token}.json"
                lpath = f"{m.location}/metadata/manifest-list-{token}.json"
                Manifest.write(
                    self.store, mpath, [ManifestEntry(FileStatus.ADDED, f) for f in files]
                )
                write_manifest_list(self.store, lpath, [mpath])
                parent = m.current_snapshot()
                snap = Snapshot(
                    snapshot_id=new_snapshot_id(),
                    parent_snapshot_id=parent.snapshot_id if parent else None,
                    sequence_number=(parent.sequence_number + 1) if parent else 1,
                    timestamp_ms=now_ms(),
                    manifest_list=lpath,
                    operation="overwrite",
                    summary=summary,
                )
                m.snapshots.append(snap)
                m.current_snapshot_id = snap.snapshot_id
                # retention
                if len(m.snapshots) > self.keep_last:
                    m.snapshots = m.snapshots[-self.keep_last :]
                return m

            self.catalog.commit_with_retries(self.name, mutate)

        if self.async_save:
            self._pending = threading.Thread(target=do_save, daemon=True)
            self._pending.start()
        else:
            do_save()

    def wait(self) -> None:
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    # -- restore ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        self.wait()
        meta = self.catalog.load_table(self.name)
        snap = meta.current_snapshot()
        if snap is None or "ckpt.step" not in snap.summary:
            return None
        return int(snap.summary["ckpt.step"])

    def available_steps(self) -> list:
        self.wait()
        meta = self.catalog.load_table(self.name)
        return sorted(
            int(s.summary["ckpt.step"]) for s in meta.snapshots if "ckpt.step" in s.summary
        )

    def restore(self, like: Any, step: Optional[int] = None, shardings: Any = None) -> Tuple[Any, int]:
        """Restore into the structure of ``like``; re-place onto ``shardings``
        (possibly a different mesh than the one that saved — elastic)."""
        self.wait()
        meta = self.catalog.load_table(self.name)
        snap = None
        if step is None:
            snap = meta.current_snapshot()
        else:
            for s in meta.snapshots:
                if s.summary.get("ckpt.step") == str(step):
                    snap = s
                    break
        if snap is None or "ckpt.step" not in snap.summary:
            raise FileNotFoundError("no checkpoint found")
        from repro.iceberg.snapshot import live_data_files

        files = {f.path.rsplit("/", 1)[-1]: f.path for f in live_data_files(self.store, snap)}
        leaves, treedef = _flatten_with_paths(like)
        restored = []
        for name, leaf in leaves:
            key = files[name.replace("/", "_") + ".npy"]
            arr = np.load(io.BytesIO(self.store.get(key)), allow_pickle=False)
            restored.append(arr)
        tree = jax.tree_util.tree_unflatten(treedef, restored)
        if shardings is not None:
            tree = jax.device_put(tree, shardings)
        return tree, int(snap.summary["ckpt.step"])
