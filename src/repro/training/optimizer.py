"""AdamW in pure JAX (no optax in this environment).

State is a pytree parallel to params: first/second moments in f32 plus a
scalar step counter.  Weight decay is decoupled (AdamW); global-norm clipping
is fused into the update.  The optimizer state inherits the params' sharding
(same logical axes), so m/v shard exactly like their parameters.
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: Any
    v: Any
    step: jnp.ndarray


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return AdamWState(m=zeros, v=jax.tree.map(jnp.copy, zeros), step=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(
    params,
    grads,
    state: AdamWState,
    *,
    lr: float = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: Optional[float] = 1.0,
) -> Tuple[Any, AdamWState, dict]:
    gnorm = global_norm(grads)
    if clip_norm is not None:
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    step = state.step + 1
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state.m, grads)
    new_v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state.v, grads)

    def upd(p, m, v):
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype)

    new_params = jax.tree.map(upd, params, new_m, new_v)
    return new_params, AdamWState(new_m, new_v, step), {"grad_norm": gnorm}
