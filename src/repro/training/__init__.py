"""Training substrate: optimizer, train-step factory, checkpoints, compression."""

from repro.training.optimizer import adamw_init, adamw_update  # noqa: F401
from repro.training.train_loop import make_train_step, TrainStepConfig  # noqa: F401
from repro.training.checkpoint import CheckpointManager  # noqa: F401
