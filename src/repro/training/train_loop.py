"""Train-step factory: CE loss, microbatched grad accumulation, remat, pjit.

``make_train_step(model, mesh, rules, cfg)`` returns a jit'd function

    (params, opt_state, batch) -> (params, opt_state, metrics)

with in/out shardings derived from the model's logical axes.  Microbatching
runs as a ``lax.scan`` over gradient accumulation steps (essential for the
1M-token train_4k cells); each microbatch's layer stack is rematerialized
(``jax.checkpoint`` around the loss) per the remat policy.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.model import Model, param_shapes
from repro.models.sharding import DEFAULT_RULES, LogicalRules, logical_to_sharding, spec_for
from repro.training.optimizer import AdamWState, adamw_init, adamw_update


@dataclass
class TrainStepConfig:
    microbatches: int = 1
    lr: float = 3e-4
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    aux_loss_weight: float = 0.01
    remat: bool = True
    compute_dtype: str = "bfloat16"


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray) -> jnp.ndarray:
    """Mean CE over all positions; labels == -100 are masked.

    Handles the musicgen (B,S,CB,V) case by folding codebooks into
    positions."""
    if logits.ndim == 4:  # (B,S,CB,V)
        B, S, CB, V = logits.shape
        logits = logits.reshape(B, S * CB, V)
        labels = labels.reshape(B, S * CB)
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, jnp.maximum(labels, 0)[..., None], axis=-1)[..., 0]
    nll = logz - gold
    mask = labels >= 0
    return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1)


def make_train_step(
    model: Model,
    mesh: Mesh,
    rules: Optional[LogicalRules] = None,
    cfg: TrainStepConfig = TrainStepConfig(),
):
    """Returns (train_step, shardings) — shardings has .params/.opt/.batch."""
    rules = rules or DEFAULT_RULES
    shapes = param_shapes(model)
    param_sharding = logical_to_sharding(model.axes, rules, mesh, shapes_tree=shapes)
    # ZeRO-1: optimizer moments additionally shard their 'embed' dims over
    # 'data', so f32 m/v for 30B+ dense configs fit HBM; XLA inserts the
    # once-per-step gather/scatter at the update (EXPERIMENTS §Dry-run).
    from repro.models.sharding import with_rules

    opt_rules = with_rules(rules, embed=("data",))
    moment_sharding = logical_to_sharding(model.axes, opt_rules, mesh, shapes_tree=shapes)
    opt_sharding = AdamWState(
        m=moment_sharding,
        v=moment_sharding,
        step=NamedSharding(mesh, P()),
    )
    ids_rank = 3 if model.cfg.num_codebooks else 2
    batch_logical = ("batch", "seq") + (("codebook",) if ids_rank == 3 else ())
    batch_spec = spec_for(batch_logical, rules, mesh, dim_sizes=None)
    batch_sharding = NamedSharding(mesh, batch_spec)

    def loss_fn(params, ids, labels):
        logits, aux = model.forward(params, ids)
        return cross_entropy(logits, labels) + cfg.aux_loss_weight * aux

    loss_for_grad = jax.checkpoint(loss_fn) if cfg.remat else loss_fn

    def train_step(params, opt_state: AdamWState, ids, labels):
        n_micro = cfg.microbatches
        B = ids.shape[0]
        assert B % n_micro == 0, (B, n_micro)
        mb = B // n_micro
        ids_m = ids.reshape((n_micro, mb) + ids.shape[1:])
        labels_m = labels.reshape((n_micro, mb) + labels.shape[1:])

        def micro(acc, inp):
            mi, ml = inp
            loss, grads = jax.value_and_grad(loss_for_grad)(params, mi, ml)
            acc_g, acc_l = acc
            acc_g = jax.tree.map(jnp.add, acc_g, grads)
            return (acc_g, acc_l + loss), None

        zero_g = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss_sum), _ = jax.lax.scan(micro, (zero_g, jnp.float32(0.0)), (ids_m, labels_m))
        grads = jax.tree.map(lambda g: g / n_micro, grads)
        loss = loss_sum / n_micro
        params2, opt2, stats = adamw_update(
            params, grads, opt_state,
            lr=cfg.lr, weight_decay=cfg.weight_decay, clip_norm=cfg.clip_norm,
        )
        metrics = {"loss": loss, **stats}
        return params2, opt2, metrics

    jit_step = jax.jit(
        train_step,
        in_shardings=(param_sharding, opt_sharding, batch_sharding, batch_sharding),
        out_shardings=(param_sharding, opt_sharding, None),
        donate_argnums=(0, 1),
    )

    class Shardings:
        params = param_sharding
        opt = opt_sharding
        batch = batch_sharding

    return jit_step, Shardings


def init_train_state(model: Model, mesh: Mesh, rules: Optional[LogicalRules] = None, seed: int = 0):
    """Initialize params + optimizer state directly into their shardings."""
    rules = rules or DEFAULT_RULES
    from repro.models.sharding import with_rules

    shapes = param_shapes(model)
    param_sharding = logical_to_sharding(model.axes, rules, mesh, shapes_tree=shapes)
    moment_sharding = logical_to_sharding(
        model.axes, with_rules(rules, embed=("data",)), mesh, shapes_tree=shapes
    )
    params = jax.jit(model.init, out_shardings=param_sharding)(jax.random.PRNGKey(seed))
    opt = jax.jit(adamw_init, out_shardings=AdamWState(
        m=moment_sharding, v=moment_sharding, step=NamedSharding(mesh, P())
    ))(params)
    return params, opt
