"""Iceberg-format substrate: Puffin container, snapshots, catalog, diff, GC.

This package implements the table-format mechanics the paper relies on:

- :mod:`repro.iceberg.puffin` — the Puffin sidecar binary container
  (magic ``PFA1``, concatenated blobs, JSON footer, flags) with per-blob
  compression and byte-range random access.
- :mod:`repro.iceberg.snapshot` — snapshots, manifests, manifest lists.
- :mod:`repro.iceberg.catalog` — REST-catalog semantics: atomic commit with
  optimistic concurrency, time travel, ``set-properties`` metadata-only
  updates (the paper's §7.4 refresh commit).
- :mod:`repro.iceberg.diff` — manifest-level snapshot diff
  (EXISTING / ADDED / DELETED), the primitive behind incremental refresh.
- :mod:`repro.iceberg.gc` — orphan-file cleanup, which reaps superseded
  Puffin index files for free (paper §7.4).
"""

from repro.iceberg.puffin import (  # noqa: F401
    BlobMetadata,
    PuffinReader,
    PuffinWriter,
    read_footer,
)
from repro.iceberg.snapshot import (  # noqa: F401
    DataFile,
    FileStatus,
    Manifest,
    Snapshot,
    TableMetadata,
)
from repro.iceberg.catalog import CommitConflict, RestCatalog  # noqa: F401
from repro.iceberg.diff import SnapshotDiff, diff_snapshots  # noqa: F401
from repro.iceberg.gc import collect_orphans  # noqa: F401
