"""Iceberg table metadata: snapshots, manifests, manifest lists.

A deliberately compact but semantically faithful model of the Iceberg spec
surface the paper uses:

- a table is a chain of immutable **snapshots**;
- each snapshot references a **manifest list**, which references **manifest
  files**, whose entries carry a status flag (EXISTING / ADDED / DELETED)
  and describe the data files live at that snapshot;
- the snapshot **summary** is a free-form string map — the paper binds a
  Puffin index file through ``summary["statistics-file"]``;
- commits are arbitrated by the catalog with optimistic concurrency.

Everything serializes to JSON in the object store under
``<table_location>/metadata/`` so that multiple "engines" (processes) can
read the same table — the multi-engine interoperability property.
"""

from __future__ import annotations

import json
import time
import uuid
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, List, Optional

from repro.lakehouse.objectstore import ObjectStore

STATISTICS_FILE_PROP = "statistics-file"


class FileStatus(str, Enum):
    EXISTING = "EXISTING"
    ADDED = "ADDED"
    DELETED = "DELETED"


@dataclass
class DataFile:
    path: str
    record_count: int
    file_size_bytes: int
    partition: Dict[str, str] = field(default_factory=dict)

    def to_json(self) -> dict:
        return {
            "path": self.path,
            "record-count": self.record_count,
            "file-size-bytes": self.file_size_bytes,
            "partition": self.partition,
        }

    @staticmethod
    def from_json(obj: dict) -> "DataFile":
        return DataFile(
            path=obj["path"],
            record_count=int(obj["record-count"]),
            file_size_bytes=int(obj["file-size-bytes"]),
            partition=dict(obj.get("partition", {})),
        )


@dataclass
class ManifestEntry:
    status: FileStatus
    data_file: DataFile

    def to_json(self) -> dict:
        return {"status": self.status.value, "data-file": self.data_file.to_json()}

    @staticmethod
    def from_json(obj: dict) -> "ManifestEntry":
        return ManifestEntry(FileStatus(obj["status"]), DataFile.from_json(obj["data-file"]))


@dataclass
class Manifest:
    path: str
    entries: List[ManifestEntry]

    def live_files(self) -> List[DataFile]:
        return [e.data_file for e in self.entries if e.status != FileStatus.DELETED]

    @staticmethod
    def write(store: ObjectStore, path: str, entries: List[ManifestEntry]) -> "Manifest":
        payload = json.dumps({"entries": [e.to_json() for e in entries]}).encode()
        store.put(path, payload)
        return Manifest(path, entries)

    @staticmethod
    def read(store: ObjectStore, path: str) -> "Manifest":
        obj = json.loads(store.get(path).decode())
        return Manifest(path, [ManifestEntry.from_json(e) for e in obj["entries"]])


@dataclass
class Snapshot:
    snapshot_id: int
    parent_snapshot_id: Optional[int]
    sequence_number: int
    timestamp_ms: int
    manifest_list: str  # object-store key of the manifest list JSON
    operation: str  # append | delete | replace | overwrite
    summary: Dict[str, str] = field(default_factory=dict)

    @property
    def statistics_file(self) -> Optional[str]:
        return self.summary.get(STATISTICS_FILE_PROP)

    def to_json(self) -> dict:
        return {
            "snapshot-id": self.snapshot_id,
            "parent-snapshot-id": self.parent_snapshot_id,
            "sequence-number": self.sequence_number,
            "timestamp-ms": self.timestamp_ms,
            "manifest-list": self.manifest_list,
            "operation": self.operation,
            "summary": dict(self.summary),
        }

    @staticmethod
    def from_json(obj: dict) -> "Snapshot":
        return Snapshot(
            snapshot_id=int(obj["snapshot-id"]),
            parent_snapshot_id=obj.get("parent-snapshot-id"),
            sequence_number=int(obj["sequence-number"]),
            timestamp_ms=int(obj["timestamp-ms"]),
            manifest_list=obj["manifest-list"],
            operation=obj.get("operation", "append"),
            summary=dict(obj.get("summary", {})),
        )


@dataclass
class TableMetadata:
    table_uuid: str
    location: str
    schema: Dict[str, str]  # column name -> type string (incl. vector cols)
    version: int
    current_snapshot_id: Optional[int]
    snapshots: List[Snapshot] = field(default_factory=list)
    properties: Dict[str, str] = field(default_factory=dict)

    # -- lookups -----------------------------------------------------------
    def snapshot_by_id(self, snapshot_id: int) -> Snapshot:
        for s in self.snapshots:
            if s.snapshot_id == snapshot_id:
                return s
        raise KeyError(f"snapshot {snapshot_id} not found")

    def current_snapshot(self) -> Optional[Snapshot]:
        if self.current_snapshot_id is None:
            return None
        return self.snapshot_by_id(self.current_snapshot_id)

    def snapshot_as_of(self, timestamp_ms: int) -> Snapshot:
        """Time travel: the latest snapshot at or before ``timestamp_ms``."""
        eligible = [s for s in self.snapshots if s.timestamp_ms <= timestamp_ms]
        if not eligible:
            raise KeyError(f"no snapshot as of {timestamp_ms}")
        return max(eligible, key=lambda s: (s.timestamp_ms, s.sequence_number))

    # -- serialization -----------------------------------------------------
    def to_json(self) -> dict:
        return {
            "table-uuid": self.table_uuid,
            "location": self.location,
            "schema": self.schema,
            "version": self.version,
            "current-snapshot-id": self.current_snapshot_id,
            "snapshots": [s.to_json() for s in self.snapshots],
            "properties": dict(self.properties),
        }

    @staticmethod
    def from_json(obj: dict) -> "TableMetadata":
        return TableMetadata(
            table_uuid=obj["table-uuid"],
            location=obj["location"],
            schema=dict(obj["schema"]),
            version=int(obj["version"]),
            current_snapshot_id=obj.get("current-snapshot-id"),
            snapshots=[Snapshot.from_json(s) for s in obj.get("snapshots", [])],
            properties=dict(obj.get("properties", {})),
        )


# -- manifest list helpers ---------------------------------------------------

def write_manifest_list(store: ObjectStore, path: str, manifest_paths: List[str]) -> None:
    store.put(path, json.dumps({"manifests": manifest_paths}).encode())


def read_manifest_list(store: ObjectStore, path: str) -> List[str]:
    return list(json.loads(store.get(path).decode())["manifests"])


def live_data_files(store: ObjectStore, snapshot: Snapshot) -> List[DataFile]:
    """All data files live at ``snapshot`` (flattened across manifests)."""
    out: List[DataFile] = []
    for mpath in read_manifest_list(store, snapshot.manifest_list):
        out.extend(Manifest.read(store, mpath).live_files())
    return out


def new_snapshot_id() -> int:
    return uuid.uuid4().int & ((1 << 62) - 1)


def now_ms() -> int:
    return int(time.time() * 1000)
