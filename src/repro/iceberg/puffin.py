"""Puffin sidecar container — spec-faithful binary layout (paper §2.1, §4).

File structure (Apache Iceberg Puffin spec, mirrored by the paper):

    Magic (4 bytes, ``PFA1``)
    Blob 1 payload (opaque bytes, independently compressed)
    ...
    Blob N payload
    Magic (4 bytes)           --+
    Footer payload (UTF-8 JSON, | footer
      optionally compressed)    |
    Footer payload size (i32 LE)|
    Flags (4 bytes)             |
    Magic (4 bytes)           --+

The footer JSON carries one entry per blob: ``type`` (opaque string),
``fields`` (Iceberg field IDs), ``offset``/``length``, ``compression-codec``
and a free-form ``properties`` map.  Unknown blob types are ignored by
readers — the extension point the paper builds on.

Random access contract (paper §4.2): a reader fetches the tail of the file
(footer) with one byte-range request, parses blob offsets, then range-reads
only the blobs it needs.  :class:`PuffinReader` preserves this contract by
operating over an abstract ``range_reader`` callable so the same code path
serves local files and the object store.
"""

from __future__ import annotations

import json
import struct
import zlib
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

try:  # zstd is the codec the paper uses; fall back to zlib if unavailable.
    import zstandard as _zstd

    _HAVE_ZSTD = True
except Exception:  # pragma: no cover - environment dependent
    _zstd = None
    _HAVE_ZSTD = False

MAGIC = b"PFA1"
_FOOTER_TAIL = 4 + 4 + 4  # payload size + flags + trailing magic

# Footer flag bit 0 of byte 0: footer payload is compressed (spec).
FLAG_FOOTER_COMPRESSED = 0x01


class PuffinError(ValueError):
    """Malformed Puffin file."""


def preferred_codec() -> str:
    """Best codec available in this environment: zstd (the paper's choice)
    when the ``zstandard`` package is importable, else zlib.  Writers that
    don't care about a specific codec should use this so the blob footer
    records whatever was actually applied."""
    return "zstd" if _HAVE_ZSTD else "zlib"


def _compress(codec: Optional[str], data: bytes) -> bytes:
    if codec is None or codec == "none":
        return data
    if codec == "zstd":
        if not _HAVE_ZSTD:
            raise PuffinError("zstd codec requested but zstandard not available")
        return _zstd.ZstdCompressor(level=3).compress(data)
    if codec == "zlib":
        return zlib.compress(data, 6)
    raise PuffinError(f"unknown compression codec: {codec}")


def _decompress(codec: Optional[str], data: bytes) -> bytes:
    if codec is None or codec == "none":
        return data
    if codec == "zstd":
        if not _HAVE_ZSTD:
            raise PuffinError("zstd codec required but zstandard not available")
        return _zstd.ZstdDecompressor().decompress(data)
    if codec == "zlib":
        return zlib.decompress(data)
    raise PuffinError(f"unknown compression codec: {codec}")


@dataclass
class BlobMetadata:
    """One footer entry.  Field names follow the Puffin spec JSON keys."""

    type: str
    offset: int
    length: int  # stored (possibly compressed) length
    fields: List[int] = field(default_factory=list)
    snapshot_id: int = -1
    sequence_number: int = -1
    compression_codec: Optional[str] = None
    properties: Dict[str, str] = field(default_factory=dict)

    def to_json(self) -> dict:
        out = {
            "type": self.type,
            "fields": list(self.fields),
            "snapshot-id": self.snapshot_id,
            "sequence-number": self.sequence_number,
            "offset": self.offset,
            "length": self.length,
            "properties": dict(self.properties),
        }
        if self.compression_codec:
            out["compression-codec"] = self.compression_codec
        return out

    @staticmethod
    def from_json(obj: dict) -> "BlobMetadata":
        return BlobMetadata(
            type=obj["type"],
            offset=int(obj["offset"]),
            length=int(obj["length"]),
            fields=[int(f) for f in obj.get("fields", [])],
            snapshot_id=int(obj.get("snapshot-id", -1)),
            sequence_number=int(obj.get("sequence-number", -1)),
            compression_codec=obj.get("compression-codec"),
            properties=dict(obj.get("properties", {})),
        )


class PuffinWriter:
    """Streaming writer mirroring the reader's layout (paper §5: ~200 lines).

    Usage::

        w = PuffinWriter(file_properties={"created-by": "repro"})
        w.add_blob(b"...", type="flockdb-ann-routing-v1", properties={...})
        w.add_blob(b"...", type="flockdb-ann-index-v1", compression="zstd")
        payload = w.finish()           # full file bytes
    """

    def __init__(
        self,
        file_properties: Optional[Dict[str, str]] = None,
        compress_footer: bool = False,
    ) -> None:
        self._chunks: List[bytes] = [MAGIC]
        self._offset = len(MAGIC)
        self._blobs: List[BlobMetadata] = []
        self._properties = dict(file_properties or {})
        self._compress_footer = compress_footer
        self._finished = False

    @property
    def blobs(self) -> Sequence[BlobMetadata]:
        return tuple(self._blobs)

    def add_blob(
        self,
        payload: bytes,
        *,
        type: str,
        fields: Sequence[int] = (),
        snapshot_id: int = -1,
        sequence_number: int = -1,
        compression: Optional[str] = None,
        properties: Optional[Dict[str, str]] = None,
        precompressed: bool = False,
    ) -> BlobMetadata:
        """``precompressed=True`` marks ``payload`` as already stored-form
        (used when re-assembling a Puffin from another file's raw blob
        ranges during incremental refresh — unchanged shards are byte-copied,
        never re-encoded)."""
        if self._finished:
            raise PuffinError("writer already finished")
        stored = payload if precompressed else _compress(compression, payload)
        meta = BlobMetadata(
            type=type,
            offset=self._offset,
            length=len(stored),
            fields=list(fields),
            snapshot_id=snapshot_id,
            sequence_number=sequence_number,
            compression_codec=compression if compression not in (None, "none") else None,
            properties=dict(properties or {}),
        )
        self._chunks.append(stored)
        self._offset += len(stored)
        self._blobs.append(meta)
        return meta

    def finish(self) -> bytes:
        if self._finished:
            raise PuffinError("writer already finished")
        self._finished = True
        footer_json = json.dumps(
            {
                "blobs": [b.to_json() for b in self._blobs],
                "properties": self._properties,
            },
            separators=(",", ":"),
        ).encode("utf-8")
        flags = bytearray(4)
        if self._compress_footer:
            # Spec: footer compression is zstd-only (lz4 reserved).
            footer_payload = _compress("zstd" if _HAVE_ZSTD else "zlib", footer_json)
            flags[0] |= FLAG_FOOTER_COMPRESSED
        else:
            footer_payload = footer_json
        tail = b"".join(
            [
                MAGIC,
                footer_payload,
                struct.pack("<i", len(footer_payload)),
                bytes(flags),
                MAGIC,
            ]
        )
        self._chunks.append(tail)
        return b"".join(self._chunks)


def read_footer(
    size: int, range_reader: Callable[[int, int], bytes]
) -> tuple[List[BlobMetadata], Dict[str, str]]:
    """Parse the footer using byte-range reads only.

    ``range_reader(offset, length)`` returns bytes.  Two reads are issued:
    one for the fixed tail (to learn the footer payload size), one for the
    payload itself — matching the paper's "HTTP range request for just the
    footer" access pattern.
    """
    if size < len(MAGIC) + _FOOTER_TAIL + len(MAGIC):
        raise PuffinError("file too small to be a Puffin file")
    tail = range_reader(size - _FOOTER_TAIL, _FOOTER_TAIL)
    payload_size = struct.unpack("<i", tail[0:4])[0]
    flags = tail[4:8]
    if tail[8:12] != MAGIC:
        raise PuffinError("bad trailing magic")
    if payload_size < 0:
        raise PuffinError("negative footer payload size")
    footer_start = size - _FOOTER_TAIL - payload_size - len(MAGIC)
    if footer_start < len(MAGIC):
        raise PuffinError("footer overlaps header")
    blob = range_reader(footer_start, len(MAGIC) + payload_size)
    if blob[:4] != MAGIC:
        raise PuffinError("bad footer magic")
    payload = blob[4:]
    if flags[0] & FLAG_FOOTER_COMPRESSED:
        try:
            payload = _decompress("zstd", payload)
        except Exception:
            payload = _decompress("zlib", payload)
    obj = json.loads(payload.decode("utf-8"))
    blobs = [BlobMetadata.from_json(b) for b in obj.get("blobs", [])]
    return blobs, dict(obj.get("properties", {}))


class PuffinReader:
    """Random-access reader over an abstract range-read callable."""

    def __init__(self, size: int, range_reader: Callable[[int, int], bytes]) -> None:
        self._size = size
        self._read = range_reader
        header = range_reader(0, 4)
        if header != MAGIC:
            raise PuffinError("bad header magic")
        self.blobs, self.properties = read_footer(size, range_reader)

    @classmethod
    def from_bytes(cls, data: bytes) -> "PuffinReader":
        return cls(len(data), lambda off, ln: data[off : off + ln])

    def blobs_of_type(self, blob_type: str) -> List[BlobMetadata]:
        return [b for b in self.blobs if b.type == blob_type]

    def read_blob(self, meta: BlobMetadata) -> bytes:
        stored = self._read(meta.offset, meta.length)
        if len(stored) != meta.length:
            raise PuffinError(
                f"short read: wanted {meta.length} bytes at {meta.offset}, got {len(stored)}"
            )
        return _decompress(meta.compression_codec, stored)

    def read_first(self, blob_type: str) -> bytes:
        metas = self.blobs_of_type(blob_type)
        if not metas:
            raise PuffinError(f"no blob of type {blob_type!r}")
        return self.read_blob(metas[0])
