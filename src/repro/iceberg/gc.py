"""Orphan-file cleanup (paper §7.4: superseded Puffin files are "reaped by
the table format's existing orphan-file cleanup").

An object under the table location is *referenced* if it is:
- a metadata json (``v*.metadata.json``) at or below the retained version,
- a manifest list / manifest reachable from any retained snapshot,
- a data file live in any retained snapshot's manifests (any status — DELETED
  entries still reference the file for time travel),
- a Puffin file named by any retained snapshot's summary
  (``statistics-file``, ``ann.stale-statistics-file``, or the fresh-tail
  manifest ``ann.fresh-tail-file``).

Everything else is an orphan.  ``collect_orphans`` returns them;
``expire_and_collect`` additionally drops old snapshots first, which is how
superseded index Puffins (e.g. the pre-refresh index) become orphaned.

Passing ``catalog=`` to ``expire_and_collect`` COMMITS the expiration as a
new metadata version before collecting.  Without it the expiration exists
only in the caller's in-memory copy: the catalog keeps serving the expired
snapshots, and deleting their now-orphaned objects leaves the served
metadata pointing at missing manifests/Puffins (time travel crashes with
NoSuchKey).  Deleting orphans is therefore only safe with the committed
form — the uncommitted form remains for dry-run inspection.
"""

from __future__ import annotations

from typing import List, Optional, Set

from repro.iceberg.snapshot import (
    Manifest,
    TableMetadata,
    read_manifest_list,
    STATISTICS_FILE_PROP,
)
from repro.lakehouse.objectstore import ObjectStore


def _referenced_keys(store: ObjectStore, meta: TableMetadata) -> Set[str]:
    refs: Set[str] = set()
    for v in range(meta.version + 1):
        refs.add(f"{meta.location}/metadata/v{v}.metadata.json")
    for snap in meta.snapshots:
        refs.add(snap.manifest_list)
        for mpath in read_manifest_list(store, snap.manifest_list):
            refs.add(mpath)
            for entry in Manifest.read(store, mpath).entries:
                refs.add(entry.data_file.path)
        for key in (
            STATISTICS_FILE_PROP,
            "ann.stale-statistics-file",
            "ann.fresh-tail-file",
        ):
            if key in snap.summary:
                refs.add(snap.summary[key])
    return refs


def collect_orphans(store: ObjectStore, meta: TableMetadata) -> List[str]:
    refs = _referenced_keys(store, meta)
    return [k for k in store.list(meta.location + "/") if k not in refs]


def expire_snapshots(meta: TableMetadata, keep_last: int = 1) -> TableMetadata:
    """Drop all but the last ``keep_last`` snapshots (by sequence number)."""
    if keep_last < 1:
        raise ValueError("must keep at least one snapshot")
    meta.snapshots.sort(key=lambda s: s.sequence_number)
    meta.snapshots = meta.snapshots[-keep_last:]
    if meta.snapshots:
        meta.current_snapshot_id = meta.snapshots[-1].snapshot_id
    return meta


def expire_and_collect(
    store: ObjectStore,
    meta: TableMetadata,
    keep_last: int = 1,
    delete: bool = False,
    catalog=None,
    table_name: Optional[str] = None,
) -> List[str]:
    """Expire old snapshots, then list (optionally delete) orphans.

    With ``catalog`` (a :class:`repro.iceberg.catalog.RestCatalog`) and
    ``table_name``, the expiration is committed as a metadata-only new
    version first, so the catalog's served snapshot list agrees with what
    remains in storage — required before ``delete=True`` or readers can
    load snapshots whose backing objects are gone."""
    if catalog is not None:
        if table_name is None:
            # the location basename only happens to equal the catalog name
            # today — don't commit against a guessed table
            raise ValueError("table_name is required when catalog is given")
        meta = catalog.expire_snapshots(table_name, keep_last=keep_last)
    else:
        meta = expire_snapshots(meta, keep_last)
    orphans = collect_orphans(store, meta)
    if delete:
        for key in orphans:
            store.delete(key)
    return orphans
