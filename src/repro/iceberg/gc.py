"""Orphan-file cleanup (paper §7.4: superseded Puffin files are "reaped by
the table format's existing orphan-file cleanup").

An object under the table location is *referenced* if it is:
- a metadata json (``v*.metadata.json``) at or below the retained version,
- a manifest list / manifest reachable from any retained snapshot,
- a data file live in any retained snapshot's manifests (any status — DELETED
  entries still reference the file for time travel),
- a Puffin file named by any retained snapshot's summary
  (``statistics-file`` or ``ann.stale-statistics-file``).

Everything else is an orphan.  ``collect_orphans`` returns them;
``expire_and_collect`` additionally drops old snapshots first, which is how
superseded index Puffins become orphaned.
"""

from __future__ import annotations

from typing import List, Set

from repro.iceberg.snapshot import (
    Manifest,
    TableMetadata,
    read_manifest_list,
    STATISTICS_FILE_PROP,
)
from repro.lakehouse.objectstore import ObjectStore


def _referenced_keys(store: ObjectStore, meta: TableMetadata) -> Set[str]:
    refs: Set[str] = set()
    for v in range(meta.version + 1):
        refs.add(f"{meta.location}/metadata/v{v}.metadata.json")
    for snap in meta.snapshots:
        refs.add(snap.manifest_list)
        for mpath in read_manifest_list(store, snap.manifest_list):
            refs.add(mpath)
            for entry in Manifest.read(store, mpath).entries:
                refs.add(entry.data_file.path)
        for key in (STATISTICS_FILE_PROP, "ann.stale-statistics-file"):
            if key in snap.summary:
                refs.add(snap.summary[key])
    return refs


def collect_orphans(store: ObjectStore, meta: TableMetadata) -> List[str]:
    refs = _referenced_keys(store, meta)
    return [k for k in store.list(meta.location + "/") if k not in refs]


def expire_snapshots(meta: TableMetadata, keep_last: int = 1) -> TableMetadata:
    """Drop all but the last ``keep_last`` snapshots (by sequence number)."""
    if keep_last < 1:
        raise ValueError("must keep at least one snapshot")
    meta.snapshots.sort(key=lambda s: s.sequence_number)
    meta.snapshots = meta.snapshots[-keep_last:]
    if meta.snapshots:
        meta.current_snapshot_id = meta.snapshots[-1].snapshot_id
    return meta


def expire_and_collect(
    store: ObjectStore, meta: TableMetadata, keep_last: int = 1, delete: bool = False
) -> List[str]:
    meta = expire_snapshots(meta, keep_last)
    orphans = collect_orphans(store, meta)
    if delete:
        for key in orphans:
            store.delete(key)
    return orphans
