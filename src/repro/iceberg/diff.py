"""Snapshot differ — the primitive behind incremental refresh (paper §7.1).

Given two snapshot IDs, classify every data file as EXISTING (live in both),
ADDED (live only in the target), or DELETED (live only in the base).  The
refresh protocol feeds ADDED files to Vamana greedy insert and DELETED files
to lazy tombstoning.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List

from repro.iceberg.snapshot import DataFile, TableMetadata, live_data_files
from repro.lakehouse.objectstore import ObjectStore


@dataclass
class SnapshotDiff:
    base_snapshot_id: int
    target_snapshot_id: int
    existing: List[DataFile] = field(default_factory=list)
    added: List[DataFile] = field(default_factory=list)
    deleted: List[DataFile] = field(default_factory=list)

    @property
    def is_empty(self) -> bool:
        return not self.added and not self.deleted


def diff_snapshots(
    store: ObjectStore,
    meta: TableMetadata,
    base_snapshot_id: int,
    target_snapshot_id: int,
) -> SnapshotDiff:
    base_files: Dict[str, DataFile] = {
        f.path: f for f in live_data_files(store, meta.snapshot_by_id(base_snapshot_id))
    }
    target_files: Dict[str, DataFile] = {
        f.path: f for f in live_data_files(store, meta.snapshot_by_id(target_snapshot_id))
    }
    diff = SnapshotDiff(base_snapshot_id, target_snapshot_id)
    for path, f in sorted(target_files.items()):
        (diff.existing if path in base_files else diff.added).append(f)
    for path, f in sorted(base_files.items()):
        if path not in target_files:
            diff.deleted.append(f)
    return diff
