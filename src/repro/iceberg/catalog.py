"""REST-catalog semantics: atomic commit via optimistic concurrency.

The paper (§1, §10) leans on the Iceberg REST catalog for commit arbitration:
two concurrent committers race; one wins, the other observes a conflict and
must retry against the new base.  We reproduce that contract with a
conditional put (``if_none_match``) on a monotonically versioned metadata
object — the same mechanism the Hadoop/Object-store catalogs use.

API shape (subset of the REST catalog the paper touches):

- ``create_table`` / ``load_table`` / ``table_exists`` / ``drop_table``
- ``commit(table, base_version, mutate)`` — CAS commit of mutated metadata
- ``commit_with_retries`` — rebase-and-retry loop (paper §10 notes wasted
  work under contention; the retry counter is surfaced for tests)
- snapshot producers: ``append_files``, ``delete_files``,
  ``set_statistics_file`` (the paper's metadata-only index commit, §7.4)
"""

from __future__ import annotations

import json
import threading
import uuid
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

from repro.iceberg.snapshot import (
    DataFile,
    FileStatus,
    Manifest,
    ManifestEntry,
    Snapshot,
    TableMetadata,
    new_snapshot_id,
    now_ms,
    read_manifest_list,
    write_manifest_list,
    STATISTICS_FILE_PROP,
)
from repro.lakehouse.objectstore import NoSuchKey, ObjectStore, PreconditionFailed


class CommitConflict(RuntimeError):
    """Another committer won the race; caller must rebase and retry."""


@dataclass
class CommitStats:
    attempts: int = 0
    conflicts: int = 0


class RestCatalog:
    """Catalog over an object store.  Safe for concurrent in-process use;
    cross-process safety comes from the store's conditional put."""

    def __init__(self, store: ObjectStore, warehouse: str = "warehouse") -> None:
        self.store = store
        self.warehouse = warehouse.strip("/")
        self._lock = threading.Lock()
        self.commit_stats = CommitStats()

    # -- paths ---------------------------------------------------------------
    def _table_dir(self, name: str) -> str:
        return f"{self.warehouse}/{name}"

    def _metadata_key(self, name: str, version: int) -> str:
        return f"{self._table_dir(name)}/metadata/v{version}.metadata.json"

    # -- table lifecycle ------------------------------------------------------
    def create_table(self, name: str, schema: Dict[str, str]) -> TableMetadata:
        meta = TableMetadata(
            table_uuid=str(uuid.uuid4()),
            location=self._table_dir(name),
            schema=dict(schema),
            version=0,
            current_snapshot_id=None,
            snapshots=[],
            properties={},
        )
        try:
            self.store.put(
                self._metadata_key(name, 0),
                json.dumps(meta.to_json()).encode(),
                if_none_match=True,
            )
        except PreconditionFailed:
            raise CommitConflict(f"table {name} already exists") from None
        return meta

    def table_exists(self, name: str) -> bool:
        return self.store.exists(self._metadata_key(name, 0))

    def latest_version(self, name: str) -> int:
        prefix = f"{self._table_dir(name)}/metadata/"
        best = -1
        for key in self.store.list(prefix):
            base = key.rsplit("/", 1)[-1]
            if base.startswith("v") and base.endswith(".metadata.json"):
                try:
                    best = max(best, int(base[1 : -len(".metadata.json")]))
                except ValueError:
                    continue
        if best < 0:
            raise NoSuchKey(name)
        return best

    def load_table(self, name: str, version: Optional[int] = None) -> TableMetadata:
        v = self.latest_version(name) if version is None else version
        data = self.store.get(self._metadata_key(name, v))
        return TableMetadata.from_json(json.loads(data.decode()))

    def drop_table(self, name: str) -> None:
        for key in self.store.list(self._table_dir(name)):
            self.store.delete(key)

    # -- commit ---------------------------------------------------------------
    def commit(
        self,
        name: str,
        base: TableMetadata,
        mutate: Callable[[TableMetadata], TableMetadata],
    ) -> TableMetadata:
        """One CAS attempt: apply ``mutate`` to a copy of ``base``, write
        v(base+1).  ``base`` is never mutated, so a conflicted caller can
        reload and retry against a clean view."""
        base_version = base.version
        new_meta = mutate(TableMetadata.from_json(base.to_json()))
        new_meta.version = base_version + 1
        payload = json.dumps(new_meta.to_json()).encode()
        with self._lock:
            self.commit_stats.attempts += 1
        try:
            self.store.put(self._metadata_key(name, new_meta.version), payload, if_none_match=True)
        except PreconditionFailed:
            with self._lock:
                self.commit_stats.conflicts += 1
            raise CommitConflict(
                f"metadata v{new_meta.version} already exists for {name}"
            ) from None
        return new_meta

    def commit_with_retries(
        self,
        name: str,
        mutate: Callable[[TableMetadata], TableMetadata],
        max_retries: int = 10,
    ) -> TableMetadata:
        """Rebase-and-retry loop — reloads latest metadata on each conflict."""
        for _ in range(max_retries):
            base = self.load_table(name)
            try:
                return self.commit(name, base, mutate)
            except CommitConflict:
                continue
        raise CommitConflict(f"gave up after {max_retries} retries for {name}")

    # -- snapshot producers -----------------------------------------------------
    def _snapshot_paths(self, meta: TableMetadata) -> tuple[str, str]:
        token = uuid.uuid4().hex[:12]
        mdir = f"{meta.location}/metadata"
        return f"{mdir}/manifest-{token}.json", f"{mdir}/manifest-list-{token}.json"

    def _load_tail(self, snap: Snapshot):
        """Decode the fresh-tail manifest a snapshot carries (None if it
        carries none)."""
        from repro.core.blobs import FRESH_TAIL_BLOB_TYPE, decode_fresh_tail_blob
        from repro.iceberg.puffin import PuffinReader

        path = snap.summary.get("ann.fresh-tail-file")
        if path is None:
            return None
        reader = PuffinReader(self.store.stat(path).size, self.store.range_reader(path))
        return decode_fresh_tail_blob(reader.read_first(FRESH_TAIL_BLOB_TYPE))

    def _write_tail(self, meta: TableMetadata, snap: Snapshot, tail) -> None:
        """Persist a fresh-tail manifest as a small Puffin file and bind it
        to ``snap.summary["ann.fresh-tail-file"]``.  Written inside the
        commit closure; a conflicted retry writes a fresh token'd file and
        the loser becomes a GC-able orphan."""
        from repro.core.blobs import FRESH_TAIL_BLOB_TYPE, encode_fresh_tail_blob
        from repro.iceberg.puffin import PuffinWriter

        writer = PuffinWriter(file_properties={"created-by": "repro-flockdb"})
        writer.add_blob(
            encode_fresh_tail_blob(tail),
            type=FRESH_TAIL_BLOB_TYPE,
            snapshot_id=snap.snapshot_id,
            properties={
                "base-snapshot-id": str(tail.base_snapshot_id),
                "row-count": str(tail.total_rows),
            },
        )
        token = uuid.uuid4().hex[:12]
        path = f"{meta.location}/metadata/ann-tail-{token}.puffin"
        self.store.put(path, writer.finish())
        snap.summary["ann.fresh-tail-file"] = path

    def _tail_entry(self, file_path: str):
        """Row-group membership of one freshly written data file."""
        from repro.core.blobs import TailEntry
        from repro.lakehouse.vparquet import VParquetReader

        r = VParquetReader.from_store(self.store, file_path)
        return TailEntry(
            file_path=file_path,
            row_groups=list(range(r.num_row_groups)),
            row_counts=[int(rg["num_rows"]) for rg in r.row_groups],
        )

    def append_files(
        self, name: str, files: List[DataFile], extra_summary: Optional[Dict[str, str]] = None
    ) -> TableMetadata:
        def mutate(meta: TableMetadata) -> TableMetadata:
            manifest_path, list_path = self._snapshot_paths(meta)
            entries = [ManifestEntry(FileStatus.ADDED, f) for f in files]
            Manifest.write(self.store, manifest_path, entries)
            parent = meta.current_snapshot()
            prior = read_manifest_list(self.store, parent.manifest_list) if parent else []
            write_manifest_list(self.store, list_path, prior + [manifest_path])
            snap = Snapshot(
                snapshot_id=new_snapshot_id(),
                parent_snapshot_id=parent.snapshot_id if parent else None,
                sequence_number=(parent.sequence_number + 1) if parent else 1,
                timestamp_ms=now_ms(),
                manifest_list=list_path,
                operation="append",
                summary=dict(extra_summary or {}),
            )
            # Carry forward the statistics-file binding unless overridden: an
            # append invalidates index *freshness* but not its snapshot binding;
            # the refresh protocol decides when to rebind (paper §7).
            if parent and STATISTICS_FILE_PROP not in snap.summary:
                stale = parent.statistics_file or parent.summary.get(
                    "ann.stale-statistics-file"
                )
                if stale:
                    snap.summary["ann.stale-statistics-file"] = stale
                    # Fresh-tail maintenance: the carried index does not
                    # cover the files this commit appends.  Extend the
                    # parent's tail manifest (or start one at the parent —
                    # the last snapshot the index was bound against) with
                    # the new files' row groups, so probes can serve the
                    # appended rows without a rebuild.
                    from repro.core.blobs import FreshTail

                    prior = self._load_tail(parent)
                    base_id = (
                        prior.base_snapshot_id
                        if prior is not None
                        else parent.snapshot_id
                    )
                    entries = list(prior.entries) if prior is not None else []
                    entries.extend(self._tail_entry(f.path) for f in files)
                    self._write_tail(
                        meta, snap, FreshTail(base_snapshot_id=base_id, entries=entries)
                    )
            meta.snapshots.append(snap)
            meta.current_snapshot_id = snap.snapshot_id
            return meta

        return self.commit_with_retries(name, mutate)

    def delete_files(self, name: str, paths: List[str]) -> TableMetadata:
        doomed = set(paths)

        def mutate(meta: TableMetadata) -> TableMetadata:
            parent = meta.current_snapshot()
            if parent is None:
                raise ValueError("cannot delete from an empty table")
            manifest_path, list_path = self._snapshot_paths(meta)
            entries: List[ManifestEntry] = []
            for mpath in read_manifest_list(self.store, parent.manifest_list):
                for e in Manifest.read(self.store, mpath).entries:
                    if e.status == FileStatus.DELETED:
                        continue
                    status = (
                        FileStatus.DELETED if e.data_file.path in doomed else FileStatus.EXISTING
                    )
                    entries.append(ManifestEntry(status, e.data_file))
            Manifest.write(self.store, manifest_path, entries)
            write_manifest_list(self.store, list_path, [manifest_path])
            snap = Snapshot(
                snapshot_id=new_snapshot_id(),
                parent_snapshot_id=parent.snapshot_id,
                sequence_number=parent.sequence_number + 1,
                timestamp_ms=now_ms(),
                manifest_list=list_path,
                operation="delete",
                summary={},
            )
            stale = parent.statistics_file or parent.summary.get(
                "ann.stale-statistics-file"
            )
            if stale:
                snap.summary["ann.stale-statistics-file"] = stale
                # tail entries whose file was just deleted drop out; the
                # rest stay searchable against the new snapshot
                prior = self._load_tail(parent)
                if prior is not None:
                    from repro.core.blobs import FreshTail

                    kept = [e for e in prior.entries if e.file_path not in doomed]
                    if kept:
                        self._write_tail(
                            meta,
                            snap,
                            FreshTail(
                                base_snapshot_id=prior.base_snapshot_id, entries=kept
                            ),
                        )
            meta.snapshots.append(snap)
            meta.current_snapshot_id = snap.snapshot_id
            return meta

        return self.commit_with_retries(name, mutate)

    def expire_snapshots(self, name: str, keep_last: int = 1) -> TableMetadata:
        """Metadata-only commit dropping all but the last ``keep_last``
        snapshots.  This is what makes superseded index Puffin files (and
        their snapshots' manifests) orphaned *in the served metadata*, so a
        subsequent orphan sweep can safely delete them (paper §7.4)."""
        from repro.iceberg.gc import expire_snapshots  # lazy: gc imports snapshot only

        if keep_last < 1:
            raise ValueError("must keep at least one snapshot")
        return self.commit_with_retries(
            name, lambda meta: expire_snapshots(meta, keep_last)
        )

    def set_statistics_file(
        self,
        name: str,
        puffin_path: str,
        *,
        expected_base_snapshot_id: Optional[int] = None,
        extra_summary: Optional[Dict[str, str]] = None,
    ) -> TableMetadata:
        """Metadata-only commit binding a Puffin file (paper §5 Stage 2, §7.4).

        Structurally a REPLACE: the manifest list is reused verbatim; only the
        snapshot summary changes.  ``expected_base_snapshot_id`` implements
        the paper's concurrent-refresh arbitration: if the table moved past
        the snapshot the index was built against, the commit raises and the
        caller must re-diff and retry.
        """

        def mutate(meta: TableMetadata) -> TableMetadata:
            parent = meta.current_snapshot()
            if parent is None:
                raise ValueError("cannot bind statistics to an empty table")
            if (
                expected_base_snapshot_id is not None
                and parent.snapshot_id != expected_base_snapshot_id
            ):
                raise CommitConflict(
                    f"table advanced: expected base {expected_base_snapshot_id}, "
                    f"found {parent.snapshot_id}"
                )
            snap = Snapshot(
                snapshot_id=new_snapshot_id(),
                parent_snapshot_id=parent.snapshot_id,
                sequence_number=parent.sequence_number + 1,
                timestamp_ms=now_ms(),
                manifest_list=parent.manifest_list,  # no data change
                operation="replace",
                summary={STATISTICS_FILE_PROP: puffin_path, **(extra_summary or {})},
            )
            meta.snapshots.append(snap)
            meta.current_snapshot_id = snap.snapshot_id
            return meta

        return self.commit_with_retries(name, mutate)
