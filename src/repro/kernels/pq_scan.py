"""Pallas TPU kernel: PQ asymmetric-distance (ADC) scan.

Paper hot spot: Stage-A beam search and refresh inserts score candidates with
PQ-approximate distances ("~1,000 PQ-approximate distance computations per
insert", §7.2; "PQ-approximate distances for candidate scoring", §6).  On
CPU the paper uses AVX2 LUT gathers; the TPU has no efficient per-lane
gather, so we *reformulate the gather as a one-hot matmul* that the MXU
executes at full rate — the hardware-adaptation called out in DESIGN.md §2:

    scores[q, n] = sum_j LUT[q, j, codes[n, j]]
                 = LUT_flat[q, :] @ onehot(codes)[n, :]      (length m*K)

VMEM budget per grid step (defaults TILE_Q=8, TILE_N=128, m=48, K=256):
  LUT tile   8 × 12288 × 4 B  ≈ 0.39 MB
  onehot   128 × 12288 × 4 B  ≈ 6.3 MB
  codes    128 × 48 × 4 B     ≈ 0.02 MB
  out        8 × 128 × 4 B    ≈ 4 KB          → ≈ 6.7 MB < 16 MB VMEM.

The MXU sees a (TILE_Q × mK) @ (mK × TILE_N) matmul; mK is a multiple of 256
so the contraction dim is 128-aligned.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _pq_scan_kernel(lut_ref, codes_ref, out_ref, *, K: int):
    # lut_ref:   (TILE_Q, m, K) f32
    # codes_ref: (TILE_N, m)    int32
    # out_ref:   (TILE_Q, TILE_N) f32
    lut = lut_ref[...]
    codes = codes_ref[...]
    tile_q, m, k = lut.shape
    tile_n = codes.shape[0]
    # one-hot over the K axis: (TILE_N, m, K)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tile_n, m, K), 2)
    onehot = (codes[:, :, None] == iota_k).astype(jnp.float32)
    # flatten to a single MXU matmul: (TILE_Q, m*K) @ (m*K, TILE_N)
    lut_flat = lut.reshape(tile_q, m * K)
    onehot_flat = onehot.reshape(tile_n, m * K)
    out_ref[...] = jax.lax.dot_general(
        lut_flat,
        onehot_flat,
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )


@functools.partial(jax.jit, static_argnames=("tile_q", "tile_n", "interpret"))
def pq_scan_pallas(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    *,
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """ADC scores via the one-hot-matmul kernel.

    luts:  (Q, m, K) f32;  codes: (N, m) int32.  Q % tile_q == 0 and
    N % tile_n == 0 are required — the ops.py wrapper pads.
    Returns (Q, N) f32.
    """
    q, m, k = luts.shape
    n, m2 = codes.shape
    assert m == m2, (m, m2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_pq_scan_kernel, K=k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m, k), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.float32),
        interpret=interpret,
    )(luts.astype(jnp.float32), codes.astype(jnp.int32))
