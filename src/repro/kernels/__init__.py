"""Pallas TPU kernels for the paper's compute hot spots.

Four kernel families, each with a pure-jnp oracle in
:mod:`repro.kernels.ref` and a padded/jit'd public wrapper in
:mod:`repro.kernels.ops`:

- ``pq_scan``       — PQ asymmetric-distance scan (one-hot-matmul MXU form)
- ``rerank``        — tiled exact-distance matrix for the rerank stage
- ``kmeans_assign`` — K-tiled nearest-centroid assignment (running min)
- ``masked_topk``   — mask-aware exact / PQ-ADC top-k for filtered probes
  (predicate bitmask fused into the tile, in-kernel top-k reduction)

On CPU the kernels run under ``interpret=True`` for validation; production
CPU paths dispatch to the oracles (see ops.py backend rules).
"""

from repro.kernels.ops import (  # noqa: F401
    exact_distances,
    exact_topk,
    kmeans_assign,
    masked_exact_topk,
    masked_exact_topk_multi,
    masked_pq_topk,
    masked_pq_topk_multi,
    pq_scan,
    pq_scan_topk,
)
