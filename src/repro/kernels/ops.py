"""Public jit'd wrappers for the Pallas kernels.

Each op handles tile padding, dtype coercion, and backend dispatch:

- ``backend="auto"``   → real Pallas on TPU; pure-jnp oracle on CPU (fast —
  interpret mode executes the kernel body per grid step in Python and is for
  *validation*, not production CPU work).
- ``backend="pallas"`` → Pallas always (``interpret=True`` off-TPU).  This is
  what the kernel correctness tests use.
- ``backend="ref"``    → the ref.py oracle.

Padding rules preserve semantics: feature dims pad with zeros (no effect on
L2/IP), point/centroid tiles pad with +inf sentinels that can never win a
min/top-k, query tiles pad with zeros and are sliced off the output.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ref
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.pq_scan import pq_scan_pallas
from repro.kernels.rerank import rerank_distances_pallas

_BIG = jnp.float32(3.4e38)  # ~f32 max; safe "never wins" sentinel


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def _pad_to(x: jnp.ndarray, axis: int, multiple: int, value) -> Tuple[jnp.ndarray, int]:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value), size


# -- exact distances ---------------------------------------------------------

def exact_distances(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: int = 128,
    tile_n: int = 128,
) -> jnp.ndarray:
    """(Q, D) × (N, D) → (Q, N) distance matrix (squared L2 or -IP)."""
    backend = _resolve(backend)
    if backend == "ref":
        fn = ref.l2_distances if metric == "l2" else ref.ip_distances
        return fn(queries, points)
    interpret = not _on_tpu()
    q_pad, q0 = _pad_to(queries.astype(jnp.float32), 0, tile_q, 0.0)
    x_pad, n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    q_pad, _ = _pad_to(q_pad, 1, 128, 0.0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    out = rerank_distances_pallas(
        q_pad, x_pad, metric=metric, tile_q=tile_q, tile_n=tile_n, interpret=interpret
    )
    return out[:q0, :n0]


def exact_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k nearest: returns (distances (Q, k), indices (Q, k))."""
    d = exact_distances(queries, points, metric=metric, backend=backend)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


# -- PQ ADC scan ---------------------------------------------------------------

def pq_scan(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    *,
    backend: str = "auto",
    tile_q: int = 8,
    tile_n: int = 128,
) -> jnp.ndarray:
    """ADC scores (Q, N) from per-query LUTs (Q, m, K) and codes (N, m)."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.pq_adc_scores(luts, codes)
    interpret = not _on_tpu()
    luts_p, q0 = _pad_to(luts.astype(jnp.float32), 0, tile_q, 0.0)
    codes_p, n0 = _pad_to(codes.astype(jnp.int32), 0, tile_n, 0)
    out = pq_scan_pallas(
        luts_p, codes_p, tile_q=tile_q, tile_n=tile_n, interpret=interpret
    )
    return out[:q0, :n0]


def pq_scan_topk(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    k: int,
    *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scores = pq_scan(luts, codes, backend=backend)
    neg, idx = jax.lax.top_k(-scores, k)
    return -neg, idx


# -- k-means assignment -----------------------------------------------------------

def kmeans_assign(
    points: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    backend: str = "auto",
    tile_n: int = 256,
    tile_k: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (assignments (N,) int32, squared distances (N,) f32)."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.kmeans_assign(points, centroids)
    interpret = not _on_tpu()
    x_pad, n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    # pad centroid *rows* with a huge coordinate so padded centroids lose
    c = centroids.astype(jnp.float32)
    k = c.shape[0]
    rem = (-k) % tile_k
    if rem:
        filler = jnp.full((rem, c.shape[1]), 1e18, dtype=jnp.float32)
        c = jnp.concatenate([c, filler], axis=0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    c, _ = _pad_to(c, 1, 128, 0.0)
    idx, dist = kmeans_assign_pallas(
        x_pad, c, tile_n=tile_n, tile_k=tile_k, interpret=interpret
    )
    return idx[:n0], dist[:n0]
