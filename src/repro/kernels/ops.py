"""Public jit'd wrappers for the Pallas kernels.

Each op handles tile padding, dtype coercion, and backend dispatch:

- ``backend="auto"``   → real Pallas on TPU; pure-jnp oracle on CPU (fast —
  interpret mode executes the kernel body per grid step in Python and is for
  *validation*, not production CPU work).
- ``backend="pallas"`` → Pallas always (``interpret=True`` off-TPU).  This is
  what the kernel correctness tests use.
- ``backend="ref"``    → the ref.py oracle.

Padding rules preserve semantics: feature dims pad with zeros (no effect on
L2/IP), point/centroid tiles pad with +inf sentinels that can never win a
min/top-k, query tiles pad with zeros and are sliced off the output.

Masked-op contract (``masked_exact_topk`` / ``masked_pq_topk`` and their
``*_multi`` per-query-mask variants):

- ``mask`` is a per-row bitmask over the N points/codes (bool or 0/1
  numeric, length N): truthy = the row may appear in results; falsy rows —
  predicate misses, tombstones — are forced to ``+inf`` *inside* the
  kernel, before the top-k reduction, so they can never displace a passing
  row.  No pool widening, no post-hoc filtering.
- the ``*_multi`` ops take a mask PLANE ``(Q, N)`` instead: row ``q`` is
  query ``q``'s own bitmask, so a coalesced batch carrying heterogeneous
  predicates is still ONE kernel call.  ``Q == 1`` degenerates to the
  single-mask kernel (same tile schedule, no plane materialization).
- the ``*_dedup`` variants take the plane FACTORED as ``(unique_masks
  (m, N), row_index (Q,))`` — when a mostly-homogeneous batch has only m
  distinct predicates, only the m unique rows cross host→device; the
  dense ``(Q, N)`` plane is broadcast on-device (a jnp gather inside the
  same jit) before the kernel sees it.  Results are bit-identical to the
  dense ``*_multi`` call on the expanded plane.
- ``unified_masked_topk`` scores a MIXED-flavor batch in one dispatch: it
  takes both the exact inputs (points) and the ADC inputs (luts, codes)
  plus a per-query ``flavor`` vector (truthy = ADC); the kernel folds mask
  and flavor into one selector plane (0 = masked, 1 = exact, 2 = ADC) and
  each query's rows are scored by its own flavor before the shared top-k
  reduction.  Same sentinel contract.
- Outputs are ``(dists (Q, k) f32, ids (Q, k) int32)``, each row ascending.
  When fewer than ``k`` rows pass, trailing slots hold ``(+inf, -1)`` —
  callers must treat non-finite distance or negative id as "no candidate".
  ``k`` may exceed N; the extra slots are sentinels too.
- Backend dispatch matches every other op: ``auto`` → Pallas on TPU / ref
  on CPU; ``pallas`` forces the kernel (``interpret=True`` off-TPU — the
  parity tests); ``ref`` forces the jnp oracle.  Point/code rows pad to the
  N tile with mask 0 (never win), query rows pad with zeros and are sliced
  off, feature dims pad with zeros.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import autotune, ref
from repro.kernels.kmeans_assign import kmeans_assign_pallas
from repro.kernels.masked_topk import (
    MASKED_THRESHOLD,
    masked_exact_topk_multi_pallas,
    masked_exact_topk_pallas,
    masked_pq_topk_multi_pallas,
    masked_pq_topk_pallas,
    unified_masked_topk_pallas,
)
from repro.kernels.pq_scan import pq_scan_pallas
from repro.kernels.rerank import gather_rerank_pallas, rerank_distances_pallas

_BIG = jnp.float32(3.4e38)  # ~f32 max; safe "never wins" sentinel


def _on_tpu() -> bool:
    try:
        return jax.devices()[0].platform == "tpu"
    except Exception:  # pragma: no cover
        return False


def _resolve(backend: str) -> str:
    if backend == "auto":
        return "pallas" if _on_tpu() else "ref"
    return backend


def _tiles(
    tile_q: Optional[int], tile_n: Optional[int], n_rows: int, d: int, flavor: str
) -> Tuple[int, int]:
    """Resolve a wrapper's tile choice: explicit values win; ``None`` asks
    the autotuner for this (rows, D, flavor) bucket — measured winner from
    the committed sweep fixture, or the old (8, 128) constants on a miss."""
    if tile_q is not None and tile_n is not None:
        return int(tile_q), int(tile_n)
    auto_q, auto_n = autotune.get_tiles(n_rows, d, flavor)
    return (
        int(tile_q) if tile_q is not None else auto_q,
        int(tile_n) if tile_n is not None else auto_n,
    )


def _pad_to(x: jnp.ndarray, axis: int, multiple: int, value) -> Tuple[jnp.ndarray, int]:
    size = x.shape[axis]
    rem = (-size) % multiple
    if rem == 0:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, rem)
    return jnp.pad(x, pad, constant_values=value), size


# -- exact distances ---------------------------------------------------------

def exact_distances(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: int = 128,
    tile_n: int = 128,
) -> jnp.ndarray:
    """(Q, D) × (N, D) → (Q, N) distance matrix (squared L2 or -IP)."""
    backend = _resolve(backend)
    if backend == "ref":
        fn = ref.l2_distances if metric == "l2" else ref.ip_distances
        return fn(queries, points)
    interpret = not _on_tpu()
    q_pad, q0 = _pad_to(queries.astype(jnp.float32), 0, tile_q, 0.0)
    x_pad, n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    q_pad, _ = _pad_to(q_pad, 1, 128, 0.0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    out = rerank_distances_pallas(
        q_pad, x_pad, metric=metric, tile_q=tile_q, tile_n=tile_n, interpret=interpret
    )
    return out[:q0, :n0]


def exact_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Top-k nearest: returns (distances (Q, k), indices (Q, k))."""
    d = exact_distances(queries, points, metric=metric, backend=backend)
    neg, idx = jax.lax.top_k(-d, k)
    return -neg, idx


# -- mask-aware top-k --------------------------------------------------------

def _finalize_masked(out_d, out_i, q0: int):
    """Slice off query padding and normalize sentinels to (+inf, -1)."""
    d = out_d[:q0]
    i = out_i[:q0]
    empty = d >= MASKED_THRESHOLD
    return jnp.where(empty, jnp.inf, d), jnp.where(empty, -1, i)


def _mask_row(mask: jnp.ndarray, tile_n: int) -> jnp.ndarray:
    """(N,) truthy mask -> (1, N_padded) f32; padded rows get 0 (never win)."""
    m = mask.astype(jnp.float32).reshape(1, -1)
    m, _ = _pad_to(m, 1, tile_n, 0.0)
    return m


def _quant_inputs(queries: jnp.ndarray, points: jnp.ndarray, dtype: str, x_scale):
    """Normalize a quantized-scoring call: ``points`` may arrive pre-stored
    (int8/bf16 from a cached device copy, with its ``x_scale``) or f32 to be
    quantized here; queries are always quantized per call.  Returns
    (stored_q, stored_x, q_scale, x_scale)."""
    want = {"bf16": jnp.bfloat16, "int8": jnp.int8}[dtype]
    x = jnp.asarray(points)
    if x.dtype != want:
        x, x_scale = ref.quantize_points(x, dtype)
    qs, q_scale = ref.quantize_points(jnp.asarray(queries), dtype)
    return qs, x, float(q_scale), float(x_scale)


def masked_exact_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
    dtype: str = "f32",
    x_scale: float = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked exact top-k: (Q, D) × (N, D) under a (N,) row bitmask →
    (dists (Q, k), ids (Q, k)) per the masked-op contract above.

    ``dtype`` picks the scoring precision (``f32``/``bf16``/``int8``): for
    quantized dtypes ``points`` may be the pre-quantized stored matrix (pass
    its ``x_scale``) or f32 to quantize on the fly; queries quantize per
    call.  Quantized scores carry value error — callers MUST route the
    surviving pool through the full-precision :func:`gather_rerank` guard
    (the planner/executor do)."""
    backend = _resolve(backend)
    k = int(k)
    flavor = "exact" if dtype == "f32" else f"exact_{dtype}"
    tile_q, tile_n = _tiles(
        tile_q, tile_n, points.shape[0], points.shape[1], flavor
    )
    if dtype != "f32":
        qs, xs, q_scale, x_scale = _quant_inputs(queries, points, dtype, x_scale)
        if backend == "ref":
            return ref.masked_exact_topk_quant(
                queries, xs, mask, k, metric=metric, dtype=dtype, x_scale=x_scale
            )
        interpret = not _on_tpu()
        q_pad, q0 = _pad_to(qs, 0, tile_q, 0)
        x_pad, _n0 = _pad_to(xs, 0, tile_n, 0)
        q_pad, _ = _pad_to(q_pad, 1, 128, 0)
        x_pad, _ = _pad_to(x_pad, 1, 128, 0)
        m = _mask_row(jnp.asarray(mask), tile_n)
        scales = jnp.asarray([[q_scale, x_scale]], dtype=jnp.float32)
        out_d, out_i = masked_exact_topk_pallas(
            q_pad, x_pad, m, k, metric=metric, tile_q=tile_q, tile_n=tile_n,
            interpret=interpret, scales=scales if dtype == "int8" else None,
        )
        return _finalize_masked(out_d, out_i, q0)
    if backend == "ref":
        return ref.masked_exact_topk(queries, points, mask, k, metric=metric)
    interpret = not _on_tpu()
    q_pad, q0 = _pad_to(queries.astype(jnp.float32), 0, tile_q, 0.0)
    x_pad, _n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    q_pad, _ = _pad_to(q_pad, 1, 128, 0.0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    m = _mask_row(jnp.asarray(mask), tile_n)
    out_d, out_i = masked_exact_topk_pallas(
        q_pad, x_pad, m, k, metric=metric, tile_q=tile_q, tile_n=tile_n,
        interpret=interpret,
    )
    return _finalize_masked(out_d, out_i, q0)


def masked_pq_topk(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    *,
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Masked PQ-ADC top-k: per-query LUTs (Q, m, K) × codes (N, m) under a
    (N,) row bitmask → (scores (Q, k), ids (Q, k)) per the masked-op
    contract above."""
    backend = _resolve(backend)
    k = int(k)
    tile_q, tile_n = _tiles(tile_q, tile_n, codes.shape[0], codes.shape[1], "pq")
    if backend == "ref":
        return ref.masked_pq_topk(luts, codes, mask, k)
    interpret = not _on_tpu()
    luts_p, q0 = _pad_to(luts.astype(jnp.float32), 0, tile_q, 0.0)
    codes_p, _n0 = _pad_to(codes.astype(jnp.int32), 0, tile_n, 0)
    m = _mask_row(jnp.asarray(mask), tile_n)
    out_d, out_i = masked_pq_topk_pallas(
        luts_p, codes_p, m, k, tile_q=tile_q, tile_n=tile_n, interpret=interpret
    )
    return _finalize_masked(out_d, out_i, q0)


def _mask_plane(masks: jnp.ndarray, tile_q: int, tile_n: int) -> jnp.ndarray:
    """(Q, N) truthy plane -> (Q_pad, N_pad) f32; padded rows/cols get 0
    (padded queries see every row masked, padded rows never win)."""
    m = masks.astype(jnp.float32)
    m, _ = _pad_to(m, 0, tile_q, 0.0)
    m, _ = _pad_to(m, 1, tile_n, 0.0)
    return m


def masked_exact_topk_multi(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
    dtype: str = "f32",
    x_scale: float = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query-mask exact top-k: (Q, D) × (N, D) under a (Q, N) mask
    PLANE (row q masks query q) → (dists (Q, k), ids (Q, k)) per the
    masked-op contract above.  One kernel call for a whole heterogeneous-
    predicate batch; Q == 1 dispatches to the single-mask kernel.  Scoring
    precision dispatch matches :func:`masked_exact_topk` (``dtype`` +
    ``x_scale``; quantized pools need the :func:`gather_rerank` guard)."""
    masks = jnp.asarray(masks)
    q = queries.shape[0]
    assert masks.shape == (q, points.shape[0]), (masks.shape, queries.shape, points.shape)
    if q == 1:
        return masked_exact_topk(
            queries, points, masks[0], k,
            metric=metric, backend=backend, tile_q=tile_q, tile_n=tile_n,
            dtype=dtype, x_scale=x_scale,
        )
    backend = _resolve(backend)
    k = int(k)
    flavor = "exact" if dtype == "f32" else f"exact_{dtype}"
    tile_q, tile_n = _tiles(
        tile_q, tile_n, points.shape[0], points.shape[1], flavor
    )
    if dtype != "f32":
        qs, xs, q_scale, x_scale = _quant_inputs(queries, points, dtype, x_scale)
        if backend == "ref":
            return ref.masked_exact_topk_quant(
                queries, xs, masks, k, metric=metric, dtype=dtype, x_scale=x_scale
            )
        interpret = not _on_tpu()
        q_pad, q0 = _pad_to(qs, 0, tile_q, 0)
        x_pad, _n0 = _pad_to(xs, 0, tile_n, 0)
        q_pad, _ = _pad_to(q_pad, 1, 128, 0)
        x_pad, _ = _pad_to(x_pad, 1, 128, 0)
        m = _mask_plane(masks, tile_q, tile_n)
        scales = jnp.asarray([[q_scale, x_scale]], dtype=jnp.float32)
        out_d, out_i = masked_exact_topk_multi_pallas(
            q_pad, x_pad, m, k, metric=metric, tile_q=tile_q, tile_n=tile_n,
            interpret=interpret, scales=scales if dtype == "int8" else None,
        )
        return _finalize_masked(out_d, out_i, q0)
    if backend == "ref":
        return ref.masked_exact_topk_multi(queries, points, masks, k, metric=metric)
    interpret = not _on_tpu()
    q_pad, q0 = _pad_to(queries.astype(jnp.float32), 0, tile_q, 0.0)
    x_pad, _n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    q_pad, _ = _pad_to(q_pad, 1, 128, 0.0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    m = _mask_plane(masks, tile_q, tile_n)
    out_d, out_i = masked_exact_topk_multi_pallas(
        q_pad, x_pad, m, k, metric=metric, tile_q=tile_q, tile_n=tile_n,
        interpret=interpret,
    )
    return _finalize_masked(out_d, out_i, q0)


def masked_pq_topk_multi(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    *,
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-query-mask PQ-ADC top-k: per-query LUTs (Q, m, K) × codes (N, m)
    under a (Q, N) mask plane → (scores (Q, k), ids (Q, k)) per the
    masked-op contract above.  Q == 1 dispatches to the single-mask kernel."""
    masks = jnp.asarray(masks)
    q = luts.shape[0]
    assert masks.shape == (q, codes.shape[0]), (masks.shape, luts.shape, codes.shape)
    if q == 1:
        return masked_pq_topk(
            luts, codes, masks[0], k, backend=backend, tile_q=tile_q, tile_n=tile_n
        )
    backend = _resolve(backend)
    k = int(k)
    tile_q, tile_n = _tiles(tile_q, tile_n, codes.shape[0], codes.shape[1], "pq")
    if backend == "ref":
        return ref.masked_pq_topk_multi(luts, codes, masks, k)
    interpret = not _on_tpu()
    luts_p, q0 = _pad_to(luts.astype(jnp.float32), 0, tile_q, 0.0)
    codes_p, _n0 = _pad_to(codes.astype(jnp.int32), 0, tile_n, 0)
    m = _mask_plane(masks, tile_q, tile_n)
    out_d, out_i = masked_pq_topk_multi_pallas(
        luts_p, codes_p, m, k, tile_q=tile_q, tile_n=tile_n, interpret=interpret
    )
    return _finalize_masked(out_d, out_i, q0)


def unified_masked_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    masks: jnp.ndarray,
    flavor: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Single-dispatch mixed-flavor masked top-k: (Q, D) × (N, D) exact AND
    (Q, m, K) × (N, m) PQ-ADC under a (Q, N) mask plane, with a per-query
    ``flavor`` vector (truthy = that query's rows score via ADC).  One
    kernel call answers a fragment whose queries split between the exact
    and PQ plans — the two-dispatch-per-shard path collapses to one."""
    masks = jnp.asarray(masks)
    q = queries.shape[0]
    assert masks.shape == (q, points.shape[0]), (masks.shape, queries.shape, points.shape)
    assert luts.shape[0] == q and codes.shape[0] == points.shape[0], (
        luts.shape, codes.shape,
    )
    backend = _resolve(backend)
    k = int(k)
    tile_q, tile_n = _tiles(
        tile_q, tile_n, points.shape[0], points.shape[1], "unified"
    )
    if backend == "ref":
        return ref.unified_masked_topk(
            queries, points, luts, codes, masks, flavor, k, metric=metric
        )
    interpret = not _on_tpu()
    q_pad, q0 = _pad_to(queries.astype(jnp.float32), 0, tile_q, 0.0)
    x_pad, _n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    q_pad, _ = _pad_to(q_pad, 1, 128, 0.0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    luts_p, _ = _pad_to(luts.astype(jnp.float32), 0, tile_q, 0.0)
    codes_p, _ = _pad_to(codes.astype(jnp.int32), 0, tile_n, 0)
    # selector plane: 0 = masked out, 1 = exact flavor, 2 = ADC flavor —
    # padded query rows / point cols get 0, so they never win
    sel = masks.astype(jnp.float32) * (
        1.0 + jnp.asarray(flavor).astype(jnp.float32).reshape(-1, 1)
    )
    sel = _mask_plane(sel, tile_q, tile_n)
    out_d, out_i = unified_masked_topk_pallas(
        q_pad, x_pad, luts_p, codes_p, sel, k,
        metric=metric, tile_q=tile_q, tile_n=tile_n, interpret=interpret,
    )
    return _finalize_masked(out_d, out_i, q0)


# -- dedup-then-broadcast mask planes ----------------------------------------
#
# A coalesced fragment's (Q, N) mask plane is often highly redundant: most
# production batches carry only a few distinct predicates, so Q rows hold m
# << Q unique bitmasks.  The *_dedup entry points accept the factored form
# (unique_masks (m, N), row_index (Q,)) and broadcast it to the dense plane
# ON DEVICE (jnp.take inside the same jit'd region), so host→device traffic
# shrinks from Q·N to m·N + Q while the kernel and its results stay
# bit-identical to the dense *_multi call.


def expand_mask_plane(unique_masks: jnp.ndarray, row_index: jnp.ndarray) -> jnp.ndarray:
    """(m, N) unique rows + (Q,) row index -> dense (Q, N) plane (device)."""
    return jnp.take(jnp.asarray(unique_masks), jnp.asarray(row_index), axis=0)


def masked_exact_topk_dedup(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    unique_masks: jnp.ndarray,
    row_index: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
    dtype: str = "f32",
    x_scale: float = 1.0,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dedup'd-plane exact top-k: semantics of ``masked_exact_topk_multi``
    on ``unique_masks[row_index]``, shipping only the unique rows."""
    plane = expand_mask_plane(unique_masks, row_index)
    return masked_exact_topk_multi(
        queries, points, plane, k,
        metric=metric, backend=backend, tile_q=tile_q, tile_n=tile_n,
        dtype=dtype, x_scale=x_scale,
    )


def masked_pq_topk_dedup(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    unique_masks: jnp.ndarray,
    row_index: jnp.ndarray,
    k: int,
    *,
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dedup'd-plane PQ-ADC top-k: semantics of ``masked_pq_topk_multi`` on
    ``unique_masks[row_index]``, shipping only the unique rows."""
    plane = expand_mask_plane(unique_masks, row_index)
    return masked_pq_topk_multi(
        luts, codes, plane, k, backend=backend, tile_q=tile_q, tile_n=tile_n
    )


def unified_masked_topk_dedup(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    unique_masks: jnp.ndarray,
    row_index: jnp.ndarray,
    flavor: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Dedup'd-plane mixed-flavor top-k: ``unified_masked_topk`` on
    ``unique_masks[row_index]``, shipping only the unique rows."""
    plane = expand_mask_plane(unique_masks, row_index)
    return unified_masked_topk(
        queries, points, luts, codes, plane, flavor, k,
        metric=metric, backend=backend, tile_q=tile_q, tile_n=tile_n,
    )


# -- pooled gather-rerank -----------------------------------------------------

def gather_rerank(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    pool_ids: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-precision rerank of per-query candidate pools: (Q, D) queries ×
    (N, D) points under (Q, P) ``pool_ids`` (row q = query q's candidate ids;
    slots < 0 or >= N are sentinels) → (dists (Q, k), ids (Q, k)), ascending,
    (+inf, -1) beyond the live pool.  ``k`` may exceed P.

    This is the device replacement for the executor/graph host rerank
    (NumPy ``vectors[pool]`` gather + einsum): the kernel scores candidates
    inside the tiled scan and never materializes the (Q, P, D) gather.  It
    is also the mandatory recall guard behind the quantized (bf16/int8)
    scan flavors — their pools are re-scored here at f32 before results
    leave the executor."""
    backend = _resolve(backend)
    k = int(k)
    pids = jnp.asarray(pool_ids).astype(jnp.int32)
    n0 = points.shape[0]
    # out-of-range ids (stale pools, clipped host fills) become sentinels
    pids = jnp.where((pids < 0) | (pids >= n0), -1, pids)
    if backend == "ref":
        return ref.gather_rerank(queries, points, pids, k, metric=metric)
    tile_q, tile_n = _tiles(
        tile_q, tile_n, points.shape[0], points.shape[1], "gather_rerank"
    )
    interpret = not _on_tpu()
    q_pad, q0 = _pad_to(queries.astype(jnp.float32), 0, tile_q, 0.0)
    x_pad, _n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    q_pad, _ = _pad_to(q_pad, 1, 128, 0.0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    pids_pad, _ = _pad_to(pids, 0, tile_q, -1)  # padded queries: empty pools
    pids_pad, _ = _pad_to(pids_pad, 1, 128, -1)  # pool slots pad with sentinel
    out_d, out_i = gather_rerank_pallas(
        q_pad, x_pad, pids_pad, k, metric=metric, tile_q=tile_q, tile_n=tile_n,
        interpret=interpret,
    )
    return _finalize_masked(out_d, out_i, q0)


# -- PQ ADC scan ---------------------------------------------------------------

def pq_scan(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    *,
    backend: str = "auto",
    tile_q: Optional[int] = None,
    tile_n: Optional[int] = None,
) -> jnp.ndarray:
    """ADC scores (Q, N) from per-query LUTs (Q, m, K) and codes (N, m)."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.pq_adc_scores(luts, codes)
    tile_q, tile_n = _tiles(tile_q, tile_n, codes.shape[0], codes.shape[1], "pq")
    interpret = not _on_tpu()
    luts_p, q0 = _pad_to(luts.astype(jnp.float32), 0, tile_q, 0.0)
    codes_p, n0 = _pad_to(codes.astype(jnp.int32), 0, tile_n, 0)
    out = pq_scan_pallas(
        luts_p, codes_p, tile_q=tile_q, tile_n=tile_n, interpret=interpret
    )
    return out[:q0, :n0]


def pq_scan_topk(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    k: int,
    *,
    backend: str = "auto",
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scores = pq_scan(luts, codes, backend=backend)
    neg, idx = jax.lax.top_k(-scores, k)
    return -neg, idx


# -- k-means assignment -----------------------------------------------------------

def kmeans_assign(
    points: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    backend: str = "auto",
    tile_n: int = 256,
    tile_k: int = 128,
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (assignments (N,) int32, squared distances (N,) f32)."""
    backend = _resolve(backend)
    if backend == "ref":
        return ref.kmeans_assign(points, centroids)
    interpret = not _on_tpu()
    x_pad, n0 = _pad_to(points.astype(jnp.float32), 0, tile_n, 0.0)
    # pad centroid *rows* with a huge coordinate so padded centroids lose
    c = centroids.astype(jnp.float32)
    k = c.shape[0]
    rem = (-k) % tile_k
    if rem:
        filler = jnp.full((rem, c.shape[1]), 1e18, dtype=jnp.float32)
        c = jnp.concatenate([c, filler], axis=0)
    x_pad, _ = _pad_to(x_pad, 1, 128, 0.0)
    c, _ = _pad_to(c, 1, 128, 0.0)
    idx, dist = kmeans_assign_pallas(
        x_pad, c, tile_n=tile_n, tile_k=tile_k, interpret=interpret
    )
    return idx[:n0], dist[:n0]
