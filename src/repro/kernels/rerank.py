"""Pallas TPU kernel: tiled exact-distance matrix for the rerank stage.

Paper hot spot: Stage B computes exact distances between each query and its
oversampled candidate set ("computes exact distances", §6), and the build
path computes full-precision distances during robust-prune.  This is a dense
(Q, D) × (N, D) problem — ideal MXU work.

The kernel computes squared-L2 via the expanded form

    dist = |q|^2 - 2 q·x + |x|^2

with the cross term as a (TILE_Q × D) @ (D × TILE_N) matmul and the norms
reduced in-kernel, or negative inner product for ``metric="ip"``.

VMEM per grid step (TILE_Q=128, TILE_N=128, D≤4096, f32):
  q tile 128×4096×4 ≈ 2 MB, x tile 128×4096×4 ≈ 2 MB, out 64 KB  → ~4.1 MB.
D is padded to a multiple of 128 by the wrapper so the contraction is
MXU-aligned; zero-padding the feature dim changes neither L2 nor IP.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rerank_kernel(q_ref, x_ref, out_ref, *, metric: str):
    q = q_ref[...]  # (TILE_Q, D)
    x = x_ref[...]  # (TILE_N, D)
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_Q, TILE_N)
    if metric == "l2":
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (TILE_Q, 1)
        x2 = jnp.sum(x * x, axis=-1)[None, :]  # (1, TILE_N)
        out_ref[...] = q2 - 2.0 * cross + x2
    else:  # ip
        out_ref[...] = -cross


@functools.partial(
    jax.jit, static_argnames=("metric", "tile_q", "tile_n", "interpret")
)
def rerank_distances_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    *,
    metric: str = "l2",
    tile_q: int = 128,
    tile_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Exact distance matrix (Q, N).  Q, N, D must be tile-aligned
    (the ops.py wrapper pads)."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_rerank_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.float32),
        interpret=interpret,
    )(queries.astype(jnp.float32), points.astype(jnp.float32))
