"""Pallas TPU kernels: tiled exact-distance matrix + pooled gather-rerank.

Paper hot spot: Stage B computes exact distances between each query and its
oversampled candidate set ("computes exact distances", §6), and the build
path computes full-precision distances during robust-prune.  This is a dense
(Q, D) × (N, D) problem — ideal MXU work.

``rerank_distances_pallas`` computes squared-L2 via the expanded form

    dist = |q|^2 - 2 q·x + |x|^2

with the cross term as a (TILE_Q × D) @ (D × TILE_N) matmul and the norms
reduced in-kernel, or negative inner product for ``metric="ip"``.

``gather_rerank_pallas`` is the on-device replacement for the executor's
old host rerank of a per-query candidate pool (NumPy ``vectors[pids]``
gather + einsum): each query row carries P candidate ids into the point
matrix, and the kernel scores exactly those candidates at full precision
with an in-kernel top-k, never materializing the (Q, P, D) gathered tensor
on the host.  The gather itself is reformulated as a one-hot selection —
but applied to the SCORE tile, not the vector tile: per N-tile the kernel
computes the dense (TILE_Q, TILE_N) distance tile it needs anyway (MXU
matmul), builds the (TILE_Q, P, TILE_N) one-hot of ``pool_ids == global
row id``, and contracts it against the score tile into a (TILE_Q, P)
VMEM scratch accumulator.  Selecting scores instead of vectors cuts the
one-hot contraction from O(P·N·D) to O(P·N) FLOPs and shrinks the scratch
from (TILE_Q·P, D) to (TILE_Q, P) — at D=4096, P=256 that is 32 MB (over
budget) down to 8 KB.  Each pool id lives in exactly one N tile, so the
sum over tiles recovers its score exactly.  On the last N step the
accumulated pool scores (sentinel ids < 0 forced to the MASKED sentinel)
run the shared k-step top-k extraction, emitting the same ascending
(MASKED, -1)-sentinel rows as the masked kernels.

VMEM per grid step (TILE_Q=128, TILE_N=128, D≤4096, f32), rerank kernel:
  q tile 128×4096×4 ≈ 2 MB, x tile 128×4096×4 ≈ 2 MB, out 64 KB  → ~4.1 MB.
gather-rerank kernel (TILE_Q=8, TILE_N=128, P≤1024, D≤4096):
  q tile 128 KB, x tile 2 MB, pids 8×1024×4 = 32 KB, scratch 8×1024×4 =
  32 KB, one-hot intermediate 8×1024×128×4 ≈ 4 MB, outputs 2×8×k×4 —
  ~6.2 MB, comfortably under the 16 MB budget.
D is padded to a multiple of 128 by the wrapper so the contraction is
MXU-aligned; zero-padding the feature dim changes neither L2 nor IP.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.masked_topk import MASKED, _topk_merge


def _rerank_kernel(q_ref, x_ref, out_ref, *, metric: str):
    q = q_ref[...]  # (TILE_Q, D)
    x = x_ref[...]  # (TILE_N, D)
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_Q, TILE_N)
    if metric == "l2":
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)  # (TILE_Q, 1)
        x2 = jnp.sum(x * x, axis=-1)[None, :]  # (1, TILE_N)
        out_ref[...] = q2 - 2.0 * cross + x2
    else:  # ip
        out_ref[...] = -cross


@functools.partial(
    jax.jit, static_argnames=("metric", "tile_q", "tile_n", "interpret")
)
def rerank_distances_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    *,
    metric: str = "l2",
    tile_q: int = 128,
    tile_n: int = 128,
    interpret: bool = True,
) -> jnp.ndarray:
    """Exact distance matrix (Q, N).  Q, N, D must be tile-aligned
    (the ops.py wrapper pads)."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_rerank_kernel, metric=metric),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((q, n), jnp.float32),
        interpret=interpret,
    )(queries.astype(jnp.float32), points.astype(jnp.float32))


def _gather_rerank_kernel(
    q_ref, x_ref, pid_ref, od_ref, oi_ref, acc_ref, *, metric, k, tile_n, n_tiles
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    q = q_ref[...]  # (TILE_Q, D)
    x = x_ref[...]  # (TILE_N, D)
    pids = pid_ref[...]  # (TILE_Q, P) int32; < 0 = sentinel slot
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_Q, TILE_N)
    if metric == "l2":
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        x2 = jnp.sum(x * x, axis=-1)[None, :]
        d = q2 - 2.0 * cross + x2
    else:  # ip
        d = -cross
    tq, tn = d.shape
    ids_tile = j * tile_n + jax.lax.broadcasted_iota(jnp.int32, (tn,), 0)
    # one-hot of "pool slot (q, p) lives in this tile's column c" — applied
    # to the score tile, not the vectors (see module docstring)
    onehot = (pids[:, :, None] == ids_tile[None, None, :]).astype(jnp.float32)
    # (TILE_Q, P, TILE_N) × (TILE_Q, TILE_N) -> (TILE_Q, P), batched over q
    contrib = jax.lax.dot_general(
        onehot, d, dimension_numbers=(((2,), (1,)), ((0,), (0,))),
        preferred_element_type=jnp.float32,
    )
    acc_ref[...] += contrib

    @pl.when(j == n_tiles - 1)
    def _finish():
        pool_d = jnp.where(pids < 0, MASKED, acc_ref[...])
        od, oi = _topk_merge(pool_d, pids, k)
        od_ref[...] = od
        oi_ref[...] = oi


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile_q", "tile_n", "interpret")
)
def gather_rerank_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    pool_ids: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Pooled gather-rerank.  queries (Q, D) f32, points (N, D) f32,
    pool_ids (Q, P) int32 (slots < 0 are sentinels and stay (MASKED, -1);
    live ids must be in [0, N)).  Q, N, D must be tile-aligned and P a
    multiple of 128 — the ops.py wrapper pads (pid padding is -1, so padded
    slots never win).  Returns (dists (Q, k) f32 with MASKED sentinels, ids
    (Q, k) int32 with -1 sentinels), each row ascending; ``k`` may exceed
    P."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    q2, p = pool_ids.shape
    assert q2 == q, (pool_ids.shape, q)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(
            _gather_rerank_kernel,
            metric=metric, k=k, tile_n=tile_n, n_tiles=grid[1],
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_q, p), lambda i, j: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((tile_q, p), jnp.float32)],
        interpret=interpret,
    )(
        queries.astype(jnp.float32),
        points.astype(jnp.float32),
        pool_ids.astype(jnp.int32),
    )
