"""Pallas TPU kernel: nearest-centroid assignment with a K-tiled running min.

Paper hot spot: Stage-0 centroid training (coordinator k-means over the 1 %
sample) and Stage-1 shard-ownership confirmation ("assigns each vector to its
nearest centroid", §5) are Lloyd-iteration assignment scans: every vector
against every centroid.

Grid layout: ``(N tiles, K tiles)``.  The output blocks depend only on the
N-tile index, so for a fixed N tile the kernel is re-entered once per K tile
and keeps a **running (min, argmin)** in the output refs — the canonical
Pallas cross-step reduction idiom.  Centroid tiles therefore never need to
fit all of K in VMEM at once.

VMEM per step (TILE_N=256, TILE_K=128, D≤1024 f32): x 1 MB, c 0.5 MB.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kmeans_assign_kernel(x_ref, c_ref, dist_ref, idx_ref, *, tile_k: int):
    k_step = pl.program_id(1)
    x = x_ref[...]  # (TILE_N, D)
    c = c_ref[...]  # (TILE_K, D)
    cross = jax.lax.dot_general(
        x, c, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_N, TILE_K)
    x2 = jnp.sum(x * x, axis=-1, keepdims=True)
    c2 = jnp.sum(c * c, axis=-1)[None, :]
    d = x2 - 2.0 * cross + c2  # (TILE_N, TILE_K)
    local_min = jnp.min(d, axis=1)  # (TILE_N,)
    local_arg = jnp.argmin(d, axis=1).astype(jnp.int32) + k_step * tile_k

    @pl.when(k_step == 0)
    def _init():
        dist_ref[...] = local_min
        idx_ref[...] = local_arg

    @pl.when(k_step != 0)
    def _update():
        prev = dist_ref[...]
        take_new = local_min < prev
        dist_ref[...] = jnp.where(take_new, local_min, prev)
        idx_ref[...] = jnp.where(take_new, local_arg, idx_ref[...])


@functools.partial(jax.jit, static_argnames=("tile_n", "tile_k", "interpret"))
def kmeans_assign_pallas(
    points: jnp.ndarray,
    centroids: jnp.ndarray,
    *,
    tile_n: int = 256,
    tile_k: int = 128,
    interpret: bool = True,
):
    """Returns (assignments (N,) int32, sq_distances (N,) f32).

    N % tile_n == 0 and K % tile_k == 0 required (ops.py pads; padded
    centroids are +inf-normed so they never win the argmin)."""
    n, d = points.shape
    k, d2 = centroids.shape
    assert d == d2, (d, d2)
    assert n % tile_n == 0 and k % tile_k == 0, (n, k)
    grid = (n // tile_n, k // tile_k)
    dist, idx = pl.pallas_call(
        functools.partial(_kmeans_assign_kernel, tile_k=tile_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_n, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_k, d), lambda i, j: (j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile_n,), lambda i, j: (i,)),
            pl.BlockSpec((tile_n,), lambda i, j: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.float32),
            jax.ShapeDtypeStruct((n,), jnp.int32),
        ],
        interpret=interpret,
    )(points.astype(jnp.float32), centroids.astype(jnp.float32))
    return idx, dist
