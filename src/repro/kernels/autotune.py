"""Measured (tile_q, tile_n) selection for the scan/rerank kernels.

The masked-scan family and the gather-rerank kernel were shipped with
hard-coded ``(8, 128)`` tiles.  Those are safe everywhere (the VMEM budget
tables in masked_topk.py / rerank.py are computed at them) but not optimal
everywhere: large shards amortize a taller query tile, small feature dims
leave MXU headroom for a wider N tile.  This module picks tiles per
``(shard row-count, D, flavor)`` from a ONE-TIME measured sweep:

- :func:`sweep` times each candidate tiling on a synthetic workload of the
  given shape/flavor (best-of-``repeat``, ``block_until_ready`` fencing)
  and records the winner in a JSON cache next to this file
  (``autotune_cache.json``, committed as a fixture so CI never measures).
- :func:`get_tiles` is the hot-path lookup ops.py calls when a wrapper is
  invoked with ``tile_q=None``: row counts bucket to the next power of two
  and D to the next multiple of 128 so one sweep generalizes; a cache miss
  returns :data:`DEFAULT_TILES`.

Never-regress guarantee: the candidate list always contains
:data:`DEFAULT_TILES`, and a challenger must beat the default by more than
``HYSTERESIS`` (5%) to displace it — so in measurement noise the tuned
choice degenerates to exactly the old constants, and the acceptance
criterion "autotuned tiles never regress vs the constants" holds
structurally, not statistically.

CLI (regenerates the committed fixture)::

    PYTHONPATH=src python -m repro.kernels.autotune [--out PATH]
"""

from __future__ import annotations

import argparse
import functools
import json
import time
from pathlib import Path
from typing import Dict, Optional, Tuple

DEFAULT_TILES: Tuple[int, int] = (8, 128)

# every candidate keeps tile_n a multiple of 128 (lane width) and tile_q a
# multiple of 8 (f32 sublane) — see the Pallas guide's alignment rules
CANDIDATES: Tuple[Tuple[int, int], ...] = (
    DEFAULT_TILES,
    (8, 256),
    (16, 128),
    (16, 256),
    (32, 128),
)

HYSTERESIS = 0.05  # challenger must beat default by >5% to displace it

FLAVORS = ("exact", "exact_bf16", "exact_int8", "pq", "unified", "gather_rerank")

_CACHE_PATH = Path(__file__).with_name("autotune_cache.json")


def _bucket_rows(n_rows: int) -> int:
    """Next power of two, clamped to [128, 2**20] — one sweep point covers
    every shard whose row count rounds to the same bucket."""
    n = max(128, min(int(n_rows), 1 << 20))
    return 1 << (n - 1).bit_length()


def _bucket_dim(d: int) -> int:
    """Next multiple of 128 (the wrappers pad the feature dim there anyway)."""
    return max(128, ((int(d) + 127) // 128) * 128)


def cache_key(n_rows: int, d: int, flavor: str) -> str:
    return f"{flavor}:n{_bucket_rows(n_rows)}:d{_bucket_dim(d)}"


@functools.lru_cache(maxsize=1)
def _load_cache(path_str: str) -> Dict[str, Tuple[int, int]]:
    path = Path(path_str)
    if not path.exists():
        return {}
    try:
        raw = json.loads(path.read_text())
    except (OSError, json.JSONDecodeError):  # unreadable fixture → defaults
        return {}
    tiles = raw.get("tiles", {})
    out: Dict[str, Tuple[int, int]] = {}
    for key, val in tiles.items():
        try:
            tq, tn = int(val[0]), int(val[1])
        except (TypeError, ValueError, IndexError):
            continue
        if (tq, tn) in CANDIDATES:  # never trust tiles we didn't sweep
            out[key] = (tq, tn)
    return out


def get_tiles(
    n_rows: int, d: int, flavor: str, cache_path: Optional[Path] = None
) -> Tuple[int, int]:
    """Tile choice for a kernel dispatch: measured winner when the sweep has
    seen this ``(rows, D, flavor)`` bucket, :data:`DEFAULT_TILES` otherwise
    (cache miss, missing fixture, unknown flavor — never an error)."""
    cache = _load_cache(str(cache_path or _CACHE_PATH))
    return cache.get(cache_key(n_rows, d, flavor), DEFAULT_TILES)


def clear_cache() -> None:
    """Drop the memoized fixture (tests swap cache files)."""
    _load_cache.cache_clear()


# -- sweep (offline; never runs on the query path) ---------------------------


def _time_call(fn, repeat: int = 3) -> float:
    """Best-of-``repeat`` wall time of ``fn()`` (jax results are fenced)."""
    fn()  # warm-up: compile + first-touch
    best = float("inf")
    for _ in range(repeat):
        t0 = time.perf_counter()
        out = fn()
        for leaf in out if isinstance(out, (tuple, list)) else (out,):
            if hasattr(leaf, "block_until_ready"):
                leaf.block_until_ready()
        best = min(best, time.perf_counter() - t0)
    return best


def _workload(flavor: str, n_rows: int, d: int, seed: int = 0):
    """Synthetic inputs for one sweep point, mirroring the executor's real
    call shapes (Q=32 coalesced queries, k=32, m=8/K=256 PQ geometry)."""
    import numpy as np

    from repro.kernels import ref

    rng = np.random.default_rng(seed)
    q_n, k = 32, 32
    queries = rng.standard_normal((q_n, d)).astype(np.float32)
    points = rng.standard_normal((n_rows, d)).astype(np.float32)
    mask = (rng.random(n_rows) > 0.4).astype(np.float32)
    if flavor in ("exact", "exact_bf16", "exact_int8"):
        dtype = {"exact": "f32", "exact_bf16": "bf16", "exact_int8": "int8"}[flavor]
        stored, x_scale = ref.quantize_points(points, dtype)
        return {
            "queries": queries, "points": stored, "mask": mask, "k": k,
            "dtype": dtype, "x_scale": x_scale,
        }
    if flavor == "pq":
        m_sub, K = 8, 256
        luts = rng.standard_normal((q_n, m_sub, K)).astype(np.float32)
        codes = rng.integers(0, K, size=(n_rows, m_sub)).astype(np.int32)
        return {"luts": luts, "codes": codes, "mask": mask, "k": k}
    if flavor == "unified":
        m_sub, K = 8, 256
        luts = rng.standard_normal((q_n, m_sub, K)).astype(np.float32)
        codes = rng.integers(0, K, size=(n_rows, m_sub)).astype(np.int32)
        masks = (rng.random((q_n, n_rows)) > 0.4).astype(np.float32)
        flav = rng.integers(0, 2, size=q_n).astype(bool)
        return {
            "queries": queries, "points": points, "luts": luts,
            "codes": codes, "masks": masks, "flavor": flav, "k": k,
        }
    if flavor == "gather_rerank":
        pool = rng.integers(0, n_rows, size=(q_n, 128)).astype(np.int32)
        return {"queries": queries, "points": points, "pool_ids": pool, "k": k}
    raise ValueError(f"unknown flavor {flavor!r}")


def _dispatch(flavor: str, work, tile_q: int, tile_n: int):
    from repro.kernels import ops

    if flavor in ("exact", "exact_bf16", "exact_int8"):
        return ops.masked_exact_topk(
            work["queries"], work["points"], work["mask"], work["k"],
            tile_q=tile_q, tile_n=tile_n,
            dtype=work["dtype"], x_scale=work["x_scale"],
        )
    if flavor == "pq":
        return ops.masked_pq_topk(
            work["luts"], work["codes"], work["mask"], work["k"],
            tile_q=tile_q, tile_n=tile_n,
        )
    if flavor == "unified":
        return ops.unified_masked_topk(
            work["queries"], work["points"], work["luts"], work["codes"],
            work["masks"], work["flavor"], work["k"],
            tile_q=tile_q, tile_n=tile_n,
        )
    if flavor == "gather_rerank":
        return ops.gather_rerank(
            work["queries"], work["points"], work["pool_ids"], work["k"],
            tile_q=tile_q, tile_n=tile_n,
        )
    raise ValueError(f"unknown flavor {flavor!r}")


def sweep_point(flavor: str, n_rows: int, d: int, repeat: int = 3):
    """Measure every candidate at one (rows, D, flavor) point.  Returns
    (winning tiles, {tiles: seconds}).  The default wins ties and anything
    within :data:`HYSTERESIS` of it."""
    work = _workload(flavor, n_rows, d)
    times: Dict[Tuple[int, int], float] = {}
    for tq, tn in CANDIDATES:
        times[(tq, tn)] = _time_call(
            lambda tq=tq, tn=tn: _dispatch(flavor, work, tq, tn), repeat=repeat
        )
    base = times[DEFAULT_TILES]
    best, best_t = DEFAULT_TILES, base
    for tiles, t in times.items():
        if t < best_t and t < base * (1.0 - HYSTERESIS):
            best, best_t = tiles, t
    return best, times


def sweep(
    out_path: Optional[Path] = None,
    flavors=FLAVORS,
    row_counts=(2048, 8192),
    dims=(128, 256),
    repeat: int = 3,
) -> Dict[str, Tuple[int, int]]:
    """Run the full sweep and write the JSON fixture.  Keys collapse by
    bucket, so overlapping (rows, dims) points just overwrite each other."""
    import jax

    tiles: Dict[str, Tuple[int, int]] = {}
    for flavor in flavors:
        for n_rows in row_counts:
            for d in dims:
                best, times = sweep_point(flavor, n_rows, d, repeat=repeat)
                key = cache_key(n_rows, d, flavor)
                tiles[key] = best
                print(
                    f"{key}: {best}  "
                    + "  ".join(
                        f"{tq}x{tn}={t * 1e3:.2f}ms" for (tq, tn), t in times.items()
                    )
                )
    payload = {
        "meta": {
            "backend": jax.devices()[0].platform,
            "candidates": [list(c) for c in CANDIDATES],
            "hysteresis": HYSTERESIS,
            "workload": "Q=32 k=32 m=8 K=256 best-of-%d" % repeat,
        },
        "tiles": {k: list(v) for k, v in sorted(tiles.items())},
    }
    path = out_path or _CACHE_PATH
    path.write_text(json.dumps(payload, indent=2) + "\n")
    clear_cache()
    return tiles


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--out", type=Path, default=_CACHE_PATH)
    ap.add_argument("--repeat", type=int, default=3)
    args = ap.parse_args()
    sweep(out_path=args.out, repeat=args.repeat)


if __name__ == "__main__":
    main()
