"""Pallas TPU kernels: mask-aware distance scan with in-kernel top-k.

Filtered probes (attribute predicates, paper §6 + PR 2) previously faked
predicate awareness on the executor: the "mask" plan widened the beam pool
by 1/selectivity and filtered in NumPy afterwards, and the pre-filter exact
scan was a host-side gather.  Both burn compute that a predicate-aware
kernel avoids — the executor-side distance-compute bottleneck SHINE
(arXiv:2507.17647) identifies as the scaling limiter.  These kernels fuse
the per-row predicate/tombstone bitmask into the distance computation
itself: masked-out rows are forced to a ``+inf`` sentinel inside the tile,
and a per-tile top-k reduction keeps only ``k`` survivors per grid step, so
a filtered Stage A is ONE kernel call over (queries × shard rows) with no
pool widening and no post-hoc filtering.

Two scoring flavors share the reduction:

- ``masked_exact_topk_pallas`` — f32 points, squared-L2 / negative-IP via
  the expanded-form matmul (same tiling as the rerank kernel);
- ``masked_pq_topk_pallas``    — PQ-ADC scores via the one-hot matmul
  reformulation of the LUT gather (same trick as ``pq_scan``), with the
  mask fused into the accumulation.

Each flavor also has a **multi-mask** variant (``*_multi_pallas``) whose
mask input is a per-query plane ``(Q, N)`` instead of a shared row
``(1, N)``: tile ``(i, j)`` of the plane rides into grid step ``(i, j)``
alongside the query and point tiles, so a coalesced batch whose queries
carry HETEROGENEOUS predicates is still ONE kernel call — each query's
rows are forced to +inf under its own bitmask before the shared top-k
reduction.  The kernel bodies are identical (``jnp.where(m > 0.5, ...)``
broadcasts a ``(1, TILE_N)`` row and applies a ``(TILE_Q, TILE_N)`` plane
elementwise); only the mask BlockSpec differs.

``unified_masked_topk_pallas`` fuses BOTH scoring flavors into one
dispatch: a fragment whose queries split between exact-flavor and
PQ-ADC-flavor plans (mixed selectivities on a PQ shard) used to cost two
kernel calls per shard — one per flavor.  The unified kernel takes the
exact inputs (queries × points) AND the ADC inputs (LUTs × codes) plus a
**selector plane** ``(Q, N)`` that encodes the per-query mask and flavor
in one f32 value per cell: 0 = masked out, 1 = score full-precision,
2 = score ADC.  Each grid step computes both score tiles and selects per
row before the shared top-k reduction, so the whole mixed-flavor fragment
is ONE dispatch.  (Compute per tile doubles, but at shard scale the
dispatch/transfer overhead dominates the filtered path — the
``table2.filtered_mixed_flavor`` bench row gates the win.)

The exact flavor also scores in reduced precision when asked: the same
kernel body runs on **bf16** inputs (MXU bf16 rate, f32 accumulation via
``preferred_element_type``; norms are upcast before squaring so only the
VALUES are low-precision), and a dedicated **int8** kernel scores
symmetric per-tensor int8 points/queries with int32 accumulation and a
``(1, 2)`` f32 scale input ``[q_scale, x_scale]`` folded in after the
matmul.  Quantized scores carry value error — the ops/executor layers
restore recall by feeding the surviving pool through the full-precision
``gather_rerank`` guard (kernels/rerank.py).

Accumulation pattern: grid ``(Q_tiles, N_tiles)`` with the N axis
innermost; the output BlockSpecs pin ``(i, 0)`` so the same ``(TILE_Q, k)``
distance/id accumulator blocks stay resident in VMEM across the whole N
sweep (the standard Pallas revisiting-reduction idiom — TPU grids execute
sequentially, last axis fastest).  Each step merges the incoming tile's
masked distances into the running top-k with a k-step argmin-extraction
loop built from iota / where / min only — no per-lane gathers, so it
lowers to pure VPU work; the candidate matmul is MXU work.

The unified kernel computes both flavors into ONE shared ``(TILE_Q,
TILE_N)`` score buffer (VMEM scratch) selected per row, instead of two
resident score planes: exact scores land first (ADC rows zeroed), then the
ADC contribution accumulates per subquantizer chunk — the one-hot LUT
selection is built ``(TILE_N, K)`` per subquantizer, never the full
``(TILE_N, m, K)`` tensor.  At m=16, K=256, TILE_N=128 that shrinks the
largest transient from 2 MB to 128 KB and drops one resident plane.

VMEM per grid step — resident blocks (the BlockSpec-walked budget;
see :func:`unified_block_shapes` / :func:`unified_vmem_bytes`, asserted by
tests/test_kernels.py), worst case D=4096, TILE_Q=8, TILE_N=128, m=16,
K=256, k=128:

  flavor    blocks (f32 unless noted)                              resident
  exact     q 8×4096 (128 KB) + x 128×4096 (2 MB) + mask 0.5 KB
            + out 2×8×k                                            ~2.1 MB
  exact/bf16  same blocks at 2 bytes for q and x                   ~1.1 MB
  exact/int8  same blocks at 1 byte for q and x + (1,2) scale      ~0.6 MB
  pq-adc    lut 8×16×256 (128 KB) + codes 128×16 int32 (8 KB)
            + mask + out                                           ~0.2 MB
  unified   q + x + lut + codes + selector 8×128 (4 KB)
            + out + score scratch 8×128 (4 KB)                     ~2.3 MB

Double-buffered inputs (×2) plus the largest transient (the (TILE_N, K)
one-hot chunk, 128 KB) keep the unified worst case at ~4.8 MB — D=4096
fits the 16 MB/core budget with TILE_Q=8 un-halved.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# sentinel for masked-out / empty slots: large f32 that real (squared-L2 or
# negative-IP) scores never reach; converted to +inf by the ops.py wrapper.
# Plain Python floats — jnp scalars would be captured as kernel constants.
MASKED = 3.0e38
MASKED_THRESHOLD = 1.0e38  # scores >= this are "no candidate"


def _topk_merge(cat_d: jnp.ndarray, cat_i: jnp.ndarray, k: int):
    """(TQ, W) masked scores + ids -> ascending (TQ, k) top-k of each row.

    k-step selection: each step one-hot-extracts the row argmin (iota ==
    argmin — no gather), records it into output column ``s`` via an iota
    mask, and overwrites the extracted slot with the sentinel.  Slots whose
    score is the sentinel report id -1.
    """
    tq, w = cat_d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (tq, w), 1)
    out_col = jax.lax.broadcasted_iota(jnp.int32, (tq, k), 1)

    def body(s, carry):
        cd, od, oi = carry
        pick = jnp.argmin(cd, axis=1)  # (TQ,)
        val = jnp.min(cd, axis=1)  # (TQ,)
        sel = col == pick[:, None]  # one-hot (TQ, W)
        pid = jnp.sum(jnp.where(sel, cat_i, 0), axis=1)  # picked id per row
        pid = jnp.where(val < MASKED_THRESHOLD, pid, -1)
        od = jnp.where(out_col == s, val[:, None], od)
        oi = jnp.where(out_col == s, pid[:, None], oi)
        cd = jnp.where(sel, MASKED, cd)
        return cd, od, oi

    od = jnp.full((tq, k), MASKED, jnp.float32)
    oi = jnp.full((tq, k), -1, jnp.int32)
    _, od, oi = jax.lax.fori_loop(0, k, body, (cat_d, od, oi))
    return od, oi


def _merge_tile(d, j, tile_n, od_ref, oi_ref, k):
    """Shared epilogue: mask'd tile scores ``d`` + running accumulators ->
    updated accumulators."""
    tq, tn = d.shape
    ids = j * tile_n + jax.lax.broadcasted_iota(jnp.int32, (tq, tn), 1)
    cat_d = jnp.concatenate([od_ref[...], d], axis=1)
    cat_i = jnp.concatenate([oi_ref[...], ids], axis=1)
    od, oi = _topk_merge(cat_d, cat_i, k)
    od_ref[...] = od
    oi_ref[...] = oi


def _masked_exact_kernel(q_ref, x_ref, m_ref, od_ref, oi_ref, *, metric, k, tile_n):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    q = q_ref[...]  # (TILE_Q, D) f32 or bf16
    x = x_ref[...]  # (TILE_N, D) f32 or bf16
    m = m_ref[...]  # (1, TILE_N) f32, 1.0 = live
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_Q, TILE_N); bf16 inputs run the MXU at bf16 rate, f32 accum
    if metric == "l2":
        # norms upcast first: only the VALUES are reduced precision
        qf = q.astype(jnp.float32)
        xf = x.astype(jnp.float32)
        q2 = jnp.sum(qf * qf, axis=-1, keepdims=True)
        x2 = jnp.sum(xf * xf, axis=-1)[None, :]
        d = q2 - 2.0 * cross + x2
    else:  # ip
        d = -cross
    d = jnp.where(m > 0.5, d, MASKED)  # mask fused before the reduction
    _merge_tile(d, j, tile_n, od_ref, oi_ref, k)


def _masked_exact_q_kernel(
    q_ref, x_ref, s_ref, m_ref, od_ref, oi_ref, *, metric, k, tile_n
):
    """int8 scoring variant: int8 × int8 matmul with int32 accumulation,
    symmetric per-tensor scales ``s_ref = [[q_scale, x_scale]]`` folded in
    after the contraction."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    q = q_ref[...]  # (TILE_Q, D) int8
    x = x_ref[...]  # (TILE_N, D) int8
    s = s_ref[...]  # (1, 2) f32
    m = m_ref[...]  # (1, TILE_N) f32
    sq, sx = s[0, 0], s[0, 1]
    cross_i = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    cross = cross_i.astype(jnp.float32) * (sq * sx)
    if metric == "l2":
        qf = q.astype(jnp.float32) * sq
        xf = x.astype(jnp.float32) * sx
        q2 = jnp.sum(qf * qf, axis=-1, keepdims=True)
        x2 = jnp.sum(xf * xf, axis=-1)[None, :]
        d = q2 - 2.0 * cross + x2
    else:  # ip
        d = -cross
    d = jnp.where(m > 0.5, d, MASKED)
    _merge_tile(d, j, tile_n, od_ref, oi_ref, k)


def _masked_pq_kernel(lut_ref, codes_ref, m_ref, od_ref, oi_ref, *, K, k, tile_n):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    lut = lut_ref[...]  # (TILE_Q, m, K)
    codes = codes_ref[...]  # (TILE_N, m)
    m_mask = m_ref[...]  # (1, TILE_N)
    tile_q, m_sub, _ = lut.shape
    tn = codes.shape[0]
    # ADC gather as a one-hot matmul (MXU-rate; see pq_scan.py)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tn, m_sub, K), 2)
    onehot = (codes[:, :, None] == iota_k).astype(jnp.float32)
    d = jax.lax.dot_general(
        lut.reshape(tile_q, m_sub * K),
        onehot.reshape(tn, m_sub * K),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_Q, TILE_N)
    d = jnp.where(m_mask > 0.5, d, MASKED)
    _merge_tile(d, j, tile_n, od_ref, oi_ref, k)


def _exact_call_dtype(points: jnp.ndarray) -> jnp.dtype:
    """Scoring dtype the exact kernels run at, decided by the point matrix:
    int8 and bf16 stay put (reduced-precision scoring), anything else is
    coerced to f32."""
    if points.dtype in (jnp.int8, jnp.bfloat16):
        return points.dtype
    return jnp.dtype(jnp.float32)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile_q", "tile_n", "interpret")
)
def masked_exact_topk_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
    scales: jnp.ndarray | None = None,
):
    """Masked exact top-k.  queries (Q, D), points (N, D), mask (1, N) f32
    (1.0 = row may win).  Q, N, D must be tile-aligned — the ops.py wrapper
    pads (padded rows carry mask 0, so they never win).  The scoring dtype
    follows ``points``: f32 (default), bf16, or int8 — int8 requires
    ``scales`` (1, 2) f32 ``[[q_scale, x_scale]]`` and int8 queries.
    Returns (dists (Q, k) f32 with MASKED sentinels, ids (Q, k) int32 with
    -1 sentinels), each row ascending."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert mask.shape == (1, n), (mask.shape, n)
    grid = (q // tile_q, n // tile_n)
    out_specs = [
        pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((q, k), jnp.float32),
        jax.ShapeDtypeStruct((q, k), jnp.int32),
    ]
    dt = _exact_call_dtype(points)
    if dt == jnp.int8:
        assert scales is not None, "int8 scoring requires scales (1, 2) f32"
        assert queries.dtype == jnp.int8, queries.dtype
        return pl.pallas_call(
            functools.partial(
                _masked_exact_q_kernel, metric=metric, k=k, tile_n=tile_n
            ),
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
                pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
                pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
                pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(queries, points, scales.astype(jnp.float32), mask.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_masked_exact_kernel, metric=metric, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(queries.astype(dt), points.astype(dt), mask.astype(jnp.float32))


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile_q", "tile_n", "interpret")
)
def masked_exact_topk_multi_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
    scales: jnp.ndarray | None = None,
):
    """Per-query-mask exact top-k.  queries (Q, D), points (N, D),
    masks (Q, N) f32 (row q is query q's bitmask; 1.0 = row may win).  Same
    alignment, scoring-dtype dispatch, and (MASKED, -1) sentinel contract as
    :func:`masked_exact_topk_pallas`; the kernel bodies are shared — only
    the mask BlockSpec changes from a broadcast row to a (i, j) plane
    tile."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert masks.shape == (q, n), (masks.shape, q, n)
    grid = (q // tile_q, n // tile_n)
    out_specs = [
        pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((q, k), jnp.float32),
        jax.ShapeDtypeStruct((q, k), jnp.int32),
    ]
    dt = _exact_call_dtype(points)
    if dt == jnp.int8:
        assert scales is not None, "int8 scoring requires scales (1, 2) f32"
        assert queries.dtype == jnp.int8, queries.dtype
        return pl.pallas_call(
            functools.partial(
                _masked_exact_q_kernel, metric=metric, k=k, tile_n=tile_n
            ),
            grid=grid,
            in_specs=[
                pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
                pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
                pl.BlockSpec((1, 2), lambda i, j: (0, 0)),
                pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
            ],
            out_specs=out_specs,
            out_shape=out_shape,
            interpret=interpret,
        )(queries, points, scales.astype(jnp.float32), masks.astype(jnp.float32))
    return pl.pallas_call(
        functools.partial(_masked_exact_kernel, metric=metric, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(queries.astype(dt), points.astype(dt), masks.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def masked_pq_topk_pallas(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    *,
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Masked PQ-ADC top-k.  luts (Q, m, K) f32, codes (N, m) int32, mask
    (1, N) f32.  Same alignment/sentinel contract as
    :func:`masked_exact_topk_pallas`."""
    q, m, kcode = luts.shape
    n, m2 = codes.shape
    assert m == m2, (m, m2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert mask.shape == (1, n), (mask.shape, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_masked_pq_kernel, K=kcode, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m, kcode), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(luts.astype(jnp.float32), codes.astype(jnp.int32), mask.astype(jnp.float32))


def _unified_kernel(
    q_ref, x_ref, lut_ref, codes_ref, s_ref, od_ref, oi_ref, score_ref,
    *, metric, K, k, tile_n
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    s = s_ref[...]  # (TILE_Q, TILE_N) selector: 0 masked / 1 exact / 2 adc
    is_adc = s > 1.5
    q = q_ref[...]  # (TILE_Q, D)
    x = x_ref[...]  # (TILE_N, D)
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if metric == "l2":
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        x2 = jnp.sum(x * x, axis=-1)[None, :]
        d_exact = q2 - 2.0 * cross + x2
    else:  # ip
        d_exact = -cross
    # One shared score buffer: exact scores land first, ADC cells zeroed so
    # the per-subquantizer contributions below accumulate from a clean slate.
    score_ref[...] = jnp.where(is_adc, 0.0, d_exact)
    lut = lut_ref[...]  # (TILE_Q, m, K)
    codes = codes_ref[...]  # (TILE_N, m)
    m_sub = lut.shape[1]
    tn = codes.shape[0]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tn, K), 1)
    for c in range(m_sub):
        # (TILE_N, K) one-hot for ONE subquantizer — never the full
        # (TILE_N, m, K) tensor
        onehot_c = (codes[:, c][:, None] == iota_k).astype(jnp.float32)
        part = jax.lax.dot_general(
            lut[:, c, :], onehot_c,
            dimension_numbers=(((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (TILE_Q, TILE_N)
        score_ref[...] += jnp.where(is_adc, part, 0.0)
    d = jnp.where(s > 0.5, score_ref[...], MASKED)
    _merge_tile(d, j, tile_n, od_ref, oi_ref, k)


def unified_block_shapes(tile_q: int, tile_n: int, d: int, m: int, K: int, k: int):
    """Resident VMEM blocks of one unified-kernel grid step, keyed by input
    name, as ``(shape, dtype)``.  This is the budget table the module
    docstring quotes; tests walk the BlockSpecs of
    :func:`unified_masked_topk_pallas` and assert they match."""
    return {
        "queries": ((tile_q, d), jnp.float32),
        "points": ((tile_n, d), jnp.float32),
        "luts": ((tile_q, m, K), jnp.float32),
        "codes": ((tile_n, m), jnp.int32),
        "selector": ((tile_q, tile_n), jnp.float32),
        "out_dists": ((tile_q, k), jnp.float32),
        "out_ids": ((tile_q, k), jnp.int32),
        "score_scratch": ((tile_q, tile_n), jnp.float32),
    }


def unified_vmem_bytes(
    tile_q: int, tile_n: int, d: int, m: int, K: int, k: int
) -> int:
    """Worst-case VMEM estimate for one unified grid step: double-buffered
    resident blocks (×2) plus the largest transient — the per-subquantizer
    (TILE_N, K) one-hot chunk."""
    import numpy as _np

    resident = sum(
        int(_np.prod(shape)) * _np.dtype(dt).itemsize
        for shape, dt in unified_block_shapes(tile_q, tile_n, d, m, K, k).values()
    )
    transient = tile_n * K * 4  # one-hot chunk, f32
    return 2 * resident + transient


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile_q", "tile_n", "interpret")
)
def unified_masked_topk_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    selector: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Single-dispatch mixed-flavor masked top-k.  queries (Q, D) f32,
    points (N, D) f32, luts (Q, m, K) f32, codes (N, m) int32, selector
    (Q, N) f32 with 0 = masked out, 1 = exact flavor, 2 = ADC flavor.
    Same alignment and (MASKED, -1) sentinel contract as the other flavors;
    the selector plane is tiled (i, j) like the multi-mask plane."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    q2, m, kcode = luts.shape
    n2, m2 = codes.shape
    assert q2 == q and n2 == n and m == m2, (luts.shape, codes.shape)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert selector.shape == (q, n), (selector.shape, q, n)
    grid = (q // tile_q, n // tile_n)
    # BlockSpecs are built FROM the budget table so the docstring's VMEM
    # numbers and the actual kernel layout cannot drift (tested).
    shapes = unified_block_shapes(tile_q, tile_n, d, m, kcode, k)
    return pl.pallas_call(
        functools.partial(
            _unified_kernel, metric=metric, K=kcode, k=k, tile_n=tile_n
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec(shapes["queries"][0], lambda i, j: (i, 0)),
            pl.BlockSpec(shapes["points"][0], lambda i, j: (j, 0)),
            pl.BlockSpec(shapes["luts"][0], lambda i, j: (i, 0, 0)),
            pl.BlockSpec(shapes["codes"][0], lambda i, j: (j, 0)),
            pl.BlockSpec(shapes["selector"][0], lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec(shapes["out_dists"][0], lambda i, j: (i, 0)),
            pl.BlockSpec(shapes["out_ids"][0], lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM(*shapes["score_scratch"])],
        interpret=interpret,
    )(
        queries.astype(jnp.float32),
        points.astype(jnp.float32),
        luts.astype(jnp.float32),
        codes.astype(jnp.int32),
        selector.astype(jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def masked_pq_topk_multi_pallas(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    *,
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Per-query-mask PQ-ADC top-k.  luts (Q, m, K) f32, codes (N, m) int32,
    masks (Q, N) f32.  Same alignment/sentinel contract as
    :func:`masked_pq_topk_pallas`, mask plane tiled (i, j)."""
    q, m, kcode = luts.shape
    n, m2 = codes.shape
    assert m == m2, (m, m2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert masks.shape == (q, n), (masks.shape, q, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_masked_pq_kernel, K=kcode, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m, kcode), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(luts.astype(jnp.float32), codes.astype(jnp.int32), masks.astype(jnp.float32))
