"""Pallas TPU kernels: mask-aware distance scan with in-kernel top-k.

Filtered probes (attribute predicates, paper §6 + PR 2) previously faked
predicate awareness on the executor: the "mask" plan widened the beam pool
by 1/selectivity and filtered in NumPy afterwards, and the pre-filter exact
scan was a host-side gather.  Both burn compute that a predicate-aware
kernel avoids — the executor-side distance-compute bottleneck SHINE
(arXiv:2507.17647) identifies as the scaling limiter.  These kernels fuse
the per-row predicate/tombstone bitmask into the distance computation
itself: masked-out rows are forced to a ``+inf`` sentinel inside the tile,
and a per-tile top-k reduction keeps only ``k`` survivors per grid step, so
a filtered Stage A is ONE kernel call over (queries × shard rows) with no
pool widening and no post-hoc filtering.

Two scoring flavors share the reduction:

- ``masked_exact_topk_pallas`` — f32 points, squared-L2 / negative-IP via
  the expanded-form matmul (same tiling as the rerank kernel);
- ``masked_pq_topk_pallas``    — PQ-ADC scores via the one-hot matmul
  reformulation of the LUT gather (same trick as ``pq_scan``), with the
  mask fused into the accumulation.

Each flavor also has a **multi-mask** variant (``*_multi_pallas``) whose
mask input is a per-query plane ``(Q, N)`` instead of a shared row
``(1, N)``: tile ``(i, j)`` of the plane rides into grid step ``(i, j)``
alongside the query and point tiles, so a coalesced batch whose queries
carry HETEROGENEOUS predicates is still ONE kernel call — each query's
rows are forced to +inf under its own bitmask before the shared top-k
reduction.  The kernel bodies are identical (``jnp.where(m > 0.5, ...)``
broadcasts a ``(1, TILE_N)`` row and applies a ``(TILE_Q, TILE_N)`` plane
elementwise); only the mask BlockSpec differs.

``unified_masked_topk_pallas`` fuses BOTH scoring flavors into one
dispatch: a fragment whose queries split between exact-flavor and
PQ-ADC-flavor plans (mixed selectivities on a PQ shard) used to cost two
kernel calls per shard — one per flavor.  The unified kernel takes the
exact inputs (queries × points) AND the ADC inputs (LUTs × codes) plus a
**selector plane** ``(Q, N)`` that encodes the per-query mask and flavor
in one f32 value per cell: 0 = masked out, 1 = score full-precision,
2 = score ADC.  Each grid step computes both score tiles and selects per
row before the shared top-k reduction, so the whole mixed-flavor fragment
is ONE dispatch.  (Compute per tile doubles, but at shard scale the
dispatch/transfer overhead dominates the filtered path — the
``table2.filtered_mixed_flavor`` bench row gates the win.)

Accumulation pattern: grid ``(Q_tiles, N_tiles)`` with the N axis
innermost; the output BlockSpecs pin ``(i, 0)`` so the same ``(TILE_Q, k)``
distance/id accumulator blocks stay resident in VMEM across the whole N
sweep (the standard Pallas revisiting-reduction idiom — TPU grids execute
sequentially, last axis fastest).  Each step merges the incoming tile's
masked distances into the running top-k with a k-step argmin-extraction
loop built from iota / where / min only — no per-lane gathers, so it
lowers to pure VPU work; the candidate matmul is MXU work.

VMEM per grid step (exact flavor, TILE_Q=8, TILE_N=128, D≤4096, f32):
  q tile 8×4096×4 ≈ 128 KB, x tile 128×4096×4 ≈ 2 MB, mask 0.5 KB,
  accumulators 2 × 8×k×4 — comfortably under the 16 MB budget.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# sentinel for masked-out / empty slots: large f32 that real (squared-L2 or
# negative-IP) scores never reach; converted to +inf by the ops.py wrapper.
# Plain Python floats — jnp scalars would be captured as kernel constants.
MASKED = 3.0e38
MASKED_THRESHOLD = 1.0e38  # scores >= this are "no candidate"


def _topk_merge(cat_d: jnp.ndarray, cat_i: jnp.ndarray, k: int):
    """(TQ, W) masked scores + ids -> ascending (TQ, k) top-k of each row.

    k-step selection: each step one-hot-extracts the row argmin (iota ==
    argmin — no gather), records it into output column ``s`` via an iota
    mask, and overwrites the extracted slot with the sentinel.  Slots whose
    score is the sentinel report id -1.
    """
    tq, w = cat_d.shape
    col = jax.lax.broadcasted_iota(jnp.int32, (tq, w), 1)
    out_col = jax.lax.broadcasted_iota(jnp.int32, (tq, k), 1)

    def body(s, carry):
        cd, od, oi = carry
        pick = jnp.argmin(cd, axis=1)  # (TQ,)
        val = jnp.min(cd, axis=1)  # (TQ,)
        sel = col == pick[:, None]  # one-hot (TQ, W)
        pid = jnp.sum(jnp.where(sel, cat_i, 0), axis=1)  # picked id per row
        pid = jnp.where(val < MASKED_THRESHOLD, pid, -1)
        od = jnp.where(out_col == s, val[:, None], od)
        oi = jnp.where(out_col == s, pid[:, None], oi)
        cd = jnp.where(sel, MASKED, cd)
        return cd, od, oi

    od = jnp.full((tq, k), MASKED, jnp.float32)
    oi = jnp.full((tq, k), -1, jnp.int32)
    _, od, oi = jax.lax.fori_loop(0, k, body, (cat_d, od, oi))
    return od, oi


def _merge_tile(d, j, tile_n, od_ref, oi_ref, k):
    """Shared epilogue: mask'd tile scores ``d`` + running accumulators ->
    updated accumulators."""
    tq, tn = d.shape
    ids = j * tile_n + jax.lax.broadcasted_iota(jnp.int32, (tq, tn), 1)
    cat_d = jnp.concatenate([od_ref[...], d], axis=1)
    cat_i = jnp.concatenate([oi_ref[...], ids], axis=1)
    od, oi = _topk_merge(cat_d, cat_i, k)
    od_ref[...] = od
    oi_ref[...] = oi


def _masked_exact_kernel(q_ref, x_ref, m_ref, od_ref, oi_ref, *, metric, k, tile_n):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    q = q_ref[...]  # (TILE_Q, D)
    x = x_ref[...]  # (TILE_N, D)
    m = m_ref[...]  # (1, TILE_N) f32, 1.0 = live
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_Q, TILE_N)
    if metric == "l2":
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        x2 = jnp.sum(x * x, axis=-1)[None, :]
        d = q2 - 2.0 * cross + x2
    else:  # ip
        d = -cross
    d = jnp.where(m > 0.5, d, MASKED)  # mask fused before the reduction
    _merge_tile(d, j, tile_n, od_ref, oi_ref, k)


def _masked_pq_kernel(lut_ref, codes_ref, m_ref, od_ref, oi_ref, *, K, k, tile_n):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    lut = lut_ref[...]  # (TILE_Q, m, K)
    codes = codes_ref[...]  # (TILE_N, m)
    m_mask = m_ref[...]  # (1, TILE_N)
    tile_q, m_sub, _ = lut.shape
    tn = codes.shape[0]
    # ADC gather as a one-hot matmul (MXU-rate; see pq_scan.py)
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tn, m_sub, K), 2)
    onehot = (codes[:, :, None] == iota_k).astype(jnp.float32)
    d = jax.lax.dot_general(
        lut.reshape(tile_q, m_sub * K),
        onehot.reshape(tn, m_sub * K),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )  # (TILE_Q, TILE_N)
    d = jnp.where(m_mask > 0.5, d, MASKED)
    _merge_tile(d, j, tile_n, od_ref, oi_ref, k)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile_q", "tile_n", "interpret")
)
def masked_exact_topk_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Masked exact top-k.  queries (Q, D) f32, points (N, D) f32, mask
    (1, N) f32 (1.0 = row may win).  Q, N, D must be tile-aligned — the
    ops.py wrapper pads (padded rows carry mask 0, so they never win).
    Returns (dists (Q, k) f32 with MASKED sentinels, ids (Q, k) int32 with
    -1 sentinels), each row ascending."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert mask.shape == (1, n), (mask.shape, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_masked_exact_kernel, metric=metric, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries.astype(jnp.float32), points.astype(jnp.float32), mask.astype(jnp.float32))


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile_q", "tile_n", "interpret")
)
def masked_exact_topk_multi_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Per-query-mask exact top-k.  queries (Q, D) f32, points (N, D) f32,
    masks (Q, N) f32 (row q is query q's bitmask; 1.0 = row may win).  Same
    alignment and (MASKED, -1) sentinel contract as
    :func:`masked_exact_topk_pallas`; the kernel body is shared — only the
    mask BlockSpec changes from a broadcast row to a (i, j) plane tile."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert masks.shape == (q, n), (masks.shape, q, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_masked_exact_kernel, metric=metric, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(queries.astype(jnp.float32), points.astype(jnp.float32), masks.astype(jnp.float32))


@functools.partial(jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def masked_pq_topk_pallas(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    *,
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Masked PQ-ADC top-k.  luts (Q, m, K) f32, codes (N, m) int32, mask
    (1, N) f32.  Same alignment/sentinel contract as
    :func:`masked_exact_topk_pallas`."""
    q, m, kcode = luts.shape
    n, m2 = codes.shape
    assert m == m2, (m, m2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert mask.shape == (1, n), (mask.shape, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_masked_pq_kernel, K=kcode, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m, kcode), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
            pl.BlockSpec((1, tile_n), lambda i, j: (0, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(luts.astype(jnp.float32), codes.astype(jnp.int32), mask.astype(jnp.float32))


def _unified_kernel(
    q_ref, x_ref, lut_ref, codes_ref, s_ref, od_ref, oi_ref, *, metric, K, k, tile_n
):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        od_ref[...] = jnp.full(od_ref.shape, MASKED, jnp.float32)
        oi_ref[...] = jnp.full(oi_ref.shape, -1, jnp.int32)

    q = q_ref[...]  # (TILE_Q, D)
    x = x_ref[...]  # (TILE_N, D)
    cross = jax.lax.dot_general(
        q, x, dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    if metric == "l2":
        q2 = jnp.sum(q * q, axis=-1, keepdims=True)
        x2 = jnp.sum(x * x, axis=-1)[None, :]
        d_exact = q2 - 2.0 * cross + x2
    else:  # ip
        d_exact = -cross
    lut = lut_ref[...]  # (TILE_Q, m, K)
    codes = codes_ref[...]  # (TILE_N, m)
    tile_q, m_sub, _ = lut.shape
    tn = codes.shape[0]
    iota_k = jax.lax.broadcasted_iota(jnp.int32, (tn, m_sub, K), 2)
    onehot = (codes[:, :, None] == iota_k).astype(jnp.float32)
    d_adc = jax.lax.dot_general(
        lut.reshape(tile_q, m_sub * K),
        onehot.reshape(tn, m_sub * K),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    s = s_ref[...]  # (TILE_Q, TILE_N) selector: 0 masked / 1 exact / 2 adc
    d = jnp.where(s > 1.5, d_adc, d_exact)
    d = jnp.where(s > 0.5, d, MASKED)
    _merge_tile(d, j, tile_n, od_ref, oi_ref, k)


@functools.partial(
    jax.jit, static_argnames=("k", "metric", "tile_q", "tile_n", "interpret")
)
def unified_masked_topk_pallas(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    selector: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Single-dispatch mixed-flavor masked top-k.  queries (Q, D) f32,
    points (N, D) f32, luts (Q, m, K) f32, codes (N, m) int32, selector
    (Q, N) f32 with 0 = masked out, 1 = exact flavor, 2 = ADC flavor.
    Same alignment and (MASKED, -1) sentinel contract as the other flavors;
    the selector plane is tiled (i, j) like the multi-mask plane."""
    q, d = queries.shape
    n, d2 = points.shape
    assert d == d2, (d, d2)
    q2, m, kcode = luts.shape
    n2, m2 = codes.shape
    assert q2 == q and n2 == n and m == m2, (luts.shape, codes.shape)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert selector.shape == (q, n), (selector.shape, q, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(
            _unified_kernel, metric=metric, K=kcode, k=k, tile_n=tile_n
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, d), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_n, d), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_q, m, kcode), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(
        queries.astype(jnp.float32),
        points.astype(jnp.float32),
        luts.astype(jnp.float32),
        codes.astype(jnp.int32),
        selector.astype(jnp.float32),
    )


@functools.partial(jax.jit, static_argnames=("k", "tile_q", "tile_n", "interpret"))
def masked_pq_topk_multi_pallas(
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    *,
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
):
    """Per-query-mask PQ-ADC top-k.  luts (Q, m, K) f32, codes (N, m) int32,
    masks (Q, N) f32.  Same alignment/sentinel contract as
    :func:`masked_pq_topk_pallas`, mask plane tiled (i, j)."""
    q, m, kcode = luts.shape
    n, m2 = codes.shape
    assert m == m2, (m, m2)
    assert q % tile_q == 0 and n % tile_n == 0, (q, n, tile_q, tile_n)
    assert masks.shape == (q, n), (masks.shape, q, n)
    grid = (q // tile_q, n // tile_n)
    return pl.pallas_call(
        functools.partial(_masked_pq_kernel, K=kcode, k=k, tile_n=tile_n),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, m, kcode), lambda i, j: (i, 0, 0)),
            pl.BlockSpec((tile_n, m), lambda i, j: (j, 0)),
            pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j)),
        ],
        out_specs=[
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
            pl.BlockSpec((tile_q, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((q, k), jnp.float32),
            jax.ShapeDtypeStruct((q, k), jnp.int32),
        ],
        interpret=interpret,
    )(luts.astype(jnp.float32), codes.astype(jnp.int32), masks.astype(jnp.float32))
