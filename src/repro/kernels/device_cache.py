"""Identity-keyed caching of device copies of host graph arrays.

The probe hot path used to re-upload O(N·D) vector bytes (and O(N·m) code
bytes) on EVERY kernel dispatch (``jnp.asarray(graph.vectors[:graph.n])``
per call).  These helpers pin one device copy on the owning graph object
and reuse it until the underlying host array actually changes.

Cache key correctness: an entry is ``(host_array, n, device_value)`` and is
valid only while ``entry_array is array and entry_n == n``.  Keying by the
ARRAY OBJECT's identity (not just ``n``) matters: a refresh can swap in a
different array of the same length — keying by ``n`` alone would serve the
stale device copy (the regression test covers exactly this).  Holding a
strong reference to the host array also makes the identity test sound:
``id()`` values recycle after garbage collection, but an object we hold
can't be collected, so ``is`` can never confuse two arrays.
"""

from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.kernels import ref


def cached_device_array(host_obj, attr: str, array, n: int, convert):
    """Return ``convert(array[:n])``, cached on ``host_obj.<attr>`` and
    revalidated by array identity + row count (see module docstring)."""
    entry = getattr(host_obj, attr, None)
    if entry is not None:
        src, src_n, dev = entry
        if src is array and src_n == n:
            return dev
    dev = convert(array[:n])
    setattr(host_obj, attr, (array, n, dev))
    return dev


def device_vectors(graph) -> jnp.ndarray:
    """Cached f32 device copy of ``graph.vectors[:graph.n]``."""
    return cached_device_array(
        graph,
        "_device_vectors_f32",
        graph.vectors,
        graph.n,
        lambda a: jnp.asarray(np.ascontiguousarray(a, np.float32)),
    )


def device_codes(graph) -> jnp.ndarray:
    """Cached int32 device copy of ``graph.pq_codes[:graph.n]``."""
    return cached_device_array(
        graph,
        "_device_codes_i32",
        graph.pq_codes,
        graph.n,
        lambda a: jnp.asarray(np.asarray(a).astype(np.int32)),
    )


def device_vectors_quant(graph, dtype: str):
    """Cached quantized device copy of ``graph.vectors[:graph.n]`` for the
    reduced-precision scan flavors.  Returns ``(stored, x_scale)`` per
    :func:`repro.kernels.ref.quantize_points` — quantization runs once per
    (graph, dtype), not once per probe."""
    return cached_device_array(
        graph,
        f"_device_vectors_{dtype}",
        graph.vectors,
        graph.n,
        lambda a: ref.quantize_points(
            jnp.asarray(np.ascontiguousarray(a, np.float32)), dtype
        ),
    )
