"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts ``assert_allclose`` against the functions here.  They are
also the CPU fallback used when Pallas interpret mode is not wanted (e.g.
inside heavily-iterated host-side build loops).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def l2_distances(queries: jnp.ndarray, points: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distance matrix.

    queries: (Q, D) f32;  points: (N, D) f32  ->  (Q, N) f32.
    Uses the expanded form |q|^2 - 2 q.x + |x|^2 (same math as the kernel so
    numerical behaviour matches to float tolerance).
    """
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)  # (Q, 1)
    x2 = jnp.sum(points * points, axis=-1)[None, :]  # (1, N)
    cross = queries @ points.T  # (Q, N)
    return q2 - 2.0 * cross + x2


def ip_distances(queries: jnp.ndarray, points: jnp.ndarray) -> jnp.ndarray:
    """Negative inner product ("distance": smaller is closer)."""
    return -(queries @ points.T)


def pq_adc_scores(luts: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Asymmetric-distance-computation scores.

    luts:  (Q, m, K) f32 — per-query lookup tables (distance of the query's
           j-th subvector to each of the K codewords of subquantizer j).
    codes: (N, m) integer — PQ codes of the database points.
    Returns (Q, N) f32: ``scores[q, n] = sum_j luts[q, j, codes[n, j]]``.
    """
    codes = codes.astype(jnp.int32)
    # gather per subquantizer: (Q, m, N)
    gathered = jnp.take_along_axis(
        luts, codes.T[None, :, :].astype(jnp.int32), axis=2
    )  # luts (Q,m,K) indexed with (1,m,N) -> (Q,m,N)
    return jnp.sum(gathered, axis=1)


def build_pq_luts(
    queries: jnp.ndarray, codebook: jnp.ndarray, metric: str = "l2"
) -> jnp.ndarray:
    """LUT construction for ADC.

    queries:  (Q, D) f32;  codebook: (m, K, D/m) f32.
    Returns (Q, m, K) f32 of sub-distances.
    """
    m, K, dsub = codebook.shape
    q_sub = queries.reshape(queries.shape[0], m, dsub)  # (Q, m, dsub)
    if metric == "l2":
        diff = q_sub[:, :, None, :] - codebook[None, :, :, :]  # (Q, m, K, dsub)
        return jnp.sum(diff * diff, axis=-1)
    if metric == "ip":
        return -jnp.einsum("qmd,mkd->qmk", q_sub, codebook)
    raise ValueError(f"unknown metric {metric}")


def _masked_topk(scores: jnp.ndarray, mask: jnp.ndarray, k: int):
    """Shared masked top-k epilogue: scores (Q, N), mask (N,) shared across
    queries or (Q, N) per query, truthy.

    Masked-out rows are forced to +inf before the reduction.  Returns
    (dists (Q, k) f32, ids (Q, k) int32) per row ascending; slots beyond
    the number of passing rows hold (+inf, -1) — the masked-op contract
    ops.py documents."""
    n = scores.shape[1]
    mask = jnp.asarray(mask).astype(bool)
    if mask.ndim == 1:
        mask = mask[None, :]
    scores = jnp.where(mask, scores, jnp.inf)
    k_avail = min(k, n)
    neg, idx = jax.lax.top_k(-scores, k_avail)
    d = -neg
    idx = jnp.where(jnp.isinf(d), -1, idx).astype(jnp.int32)
    if k_avail < k:
        pad = ((0, 0), (0, k - k_avail))
        d = jnp.pad(d, pad, constant_values=jnp.inf)
        idx = jnp.pad(idx, pad, constant_values=-1)
    return d.astype(jnp.float32), idx


def masked_exact_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    metric: str = "l2",
):
    """Mask-aware exact top-k: queries (Q, D), points (N, D), mask (N,)."""
    fn = l2_distances if metric == "l2" else ip_distances
    return _masked_topk(fn(queries, points), mask, k)


def masked_pq_topk(luts: jnp.ndarray, codes: jnp.ndarray, mask: jnp.ndarray, k: int):
    """Mask-aware PQ-ADC top-k: luts (Q, m, K), codes (N, m), mask (N,)."""
    return _masked_topk(pq_adc_scores(luts, codes), mask, k)


def masked_exact_topk_multi(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    metric: str = "l2",
):
    """Per-query-mask exact top-k: queries (Q, D), points (N, D), masks
    (Q, N) — row q masks query q independently (heterogeneous predicates
    in one call)."""
    fn = l2_distances if metric == "l2" else ip_distances
    return _masked_topk(fn(queries, points), masks, k)


def masked_pq_topk_multi(
    luts: jnp.ndarray, codes: jnp.ndarray, masks: jnp.ndarray, k: int
):
    """Per-query-mask PQ-ADC top-k: luts (Q, m, K), codes (N, m), masks
    (Q, N)."""
    return _masked_topk(pq_adc_scores(luts, codes), masks, k)


def unified_masked_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    masks: jnp.ndarray,
    flavor: jnp.ndarray,
    k: int,
    metric: str = "l2",
):
    """Single-dispatch mixed-flavor masked top-k: queries (Q, D), points
    (N, D), luts (Q, m, K), codes (N, m), masks (N,) or (Q, N), flavor (Q,)
    truthy (True = score row q with PQ-ADC, False = full-precision).  Each
    query's scores come from ITS flavor; the masked top-k epilogue is
    shared, so a fragment mixing both flavors is one call.

    Like the Pallas kernel, both score planes are computed and selected
    per row: at these shapes the two dense computes beat any
    subset-gather/scatter assembly (eager-mode gathers cost more than the
    matmul they save — measured), and the shared top-k epilogue runs
    once instead of once per flavor."""
    fn = l2_distances if metric == "l2" else ip_distances
    d_exact = fn(queries, points)
    d_adc = pq_adc_scores(luts, codes)
    sel = jnp.asarray(flavor).astype(bool).reshape(-1, 1)
    return _masked_topk(jnp.where(sel, d_adc, d_exact), masks, k)


@functools.partial(jax.jit, static_argnames=("k", "metric"))
def gather_rerank(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    pool_ids: jnp.ndarray,
    k: int,
    metric: str = "l2",
):
    """Exact full-precision rerank of a per-query candidate pool.

    queries (Q, D) f32, points (N, D) f32, pool_ids (Q, P) integer — row q
    holds query q's candidate ids into ``points``; slots < 0 are sentinels
    ("no candidate") and stay (+inf, -1).  Returns (dists (Q, k) f32, ids
    (Q, k) int32) ascending per row, (+inf, -1) beyond the live pool — the
    same sentinel contract as the masked ops.  ``k`` may exceed P.

    This is the semantic ground truth for the old executor host rerank
    (``np.clip`` gather + einsum / squared-difference sum): same direct-form
    L2 so distances agree to float tolerance and ids bit-match on
    non-degenerate pools."""
    pids = jnp.asarray(pool_ids).astype(jnp.int32)
    q = jnp.asarray(queries).astype(jnp.float32)
    x = jnp.asarray(points).astype(jnp.float32)
    safe = jnp.clip(pids, 0, x.shape[0] - 1)
    vecs = x[safe]  # (Q, P, D)
    if metric == "ip":
        d = -jnp.einsum("qpd,qd->qp", vecs, q)
    else:
        diff = vecs - q[:, None, :]
        d = jnp.sum(diff * diff, axis=-1)
    d = jnp.where(pids < 0, jnp.inf, d)
    p = d.shape[1]
    k_avail = min(k, p)
    neg, slot = jax.lax.top_k(-d, k_avail)
    out_d = -neg
    out_i = jnp.take_along_axis(pids, slot, axis=1)
    out_i = jnp.where(jnp.isinf(out_d), -1, out_i).astype(jnp.int32)
    if k_avail < k:
        pad = ((0, 0), (0, k - k_avail))
        out_d = jnp.pad(out_d, pad, constant_values=jnp.inf)
        out_i = jnp.pad(out_i, pad, constant_values=-1)
    return out_d.astype(jnp.float32), out_i


# -- quantized scoring --------------------------------------------------------
#
# bf16/int8 are *storage + matmul-rate* levers: values are quantized, the
# accumulation stays f32 (bf16) / int32 (int8).  The oracles emulate exactly
# that — dequantize the stored values and score in f32 — so they predict the
# recall of the quantized kernels bit-for-bit at the value level, and on
# hardware without native reduced-precision matmul units they double as the
# production CPU path (quantization there buys memory footprint, not FLOPs).

SCORE_DTYPES = ("f32", "bf16", "int8")


def quantize_points(points: jnp.ndarray, dtype: str):
    """Quantize a point matrix for reduced-precision scoring.

    Returns (stored, scale): ``bf16`` stores bfloat16 values (scale 1.0);
    ``int8`` stores symmetric per-tensor int8 with ``scale = max|x| / 127``;
    ``f32`` passes through.  Dequantization is ``stored.astype(f32) *
    scale`` in every case."""
    x = jnp.asarray(points)
    if dtype == "f32":
        return x.astype(jnp.float32), 1.0
    if dtype == "bf16":
        return x.astype(jnp.bfloat16), 1.0
    if dtype == "int8":
        scale = float(jnp.max(jnp.abs(x.astype(jnp.float32)))) / 127.0
        scale = scale or 1.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
        return q.astype(jnp.int8), scale
    raise ValueError(f"unknown score dtype {dtype!r}")


def dequantize_points(stored: jnp.ndarray, scale: float) -> jnp.ndarray:
    return stored.astype(jnp.float32) * jnp.float32(scale)


def masked_exact_topk_quant(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    *,
    metric: str = "l2",
    dtype: str = "bf16",
    x_scale: float = 1.0,
):
    """Quantized-scoring oracle for the masked exact scan: ``points`` is the
    STORED (quantized) matrix from :func:`quantize_points`; queries are
    quantized per call with their own scale.  The scores carry quantization
    error — callers restore recall by feeding the surviving pool through the
    full-precision :func:`gather_rerank` guard.  ``mask`` may be (N,) or a
    (Q, N) plane."""
    xq = dequantize_points(points, x_scale)
    qs, q_scale = quantize_points(queries, dtype)
    qq = dequantize_points(qs, q_scale)
    fn = l2_distances if metric == "l2" else ip_distances
    return _masked_topk(fn(qq, xq), mask, k)


def kmeans_assign(points: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment.

    points: (N, D) f32;  centroids: (K, D) f32.
    Returns (assignments (N,) int32, sq_distances (N,) f32).
    """
    d = l2_distances(points, centroids)  # (N, K)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.min(d, axis=1)
