"""Pure-jnp oracles for every Pallas kernel in this package.

These are the semantic ground truth: each kernel's test sweeps shapes and
dtypes and asserts ``assert_allclose`` against the functions here.  They are
also the CPU fallback used when Pallas interpret mode is not wanted (e.g.
inside heavily-iterated host-side build loops).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def l2_distances(queries: jnp.ndarray, points: jnp.ndarray) -> jnp.ndarray:
    """Squared L2 distance matrix.

    queries: (Q, D) f32;  points: (N, D) f32  ->  (Q, N) f32.
    Uses the expanded form |q|^2 - 2 q.x + |x|^2 (same math as the kernel so
    numerical behaviour matches to float tolerance).
    """
    q2 = jnp.sum(queries * queries, axis=-1, keepdims=True)  # (Q, 1)
    x2 = jnp.sum(points * points, axis=-1)[None, :]  # (1, N)
    cross = queries @ points.T  # (Q, N)
    return q2 - 2.0 * cross + x2


def ip_distances(queries: jnp.ndarray, points: jnp.ndarray) -> jnp.ndarray:
    """Negative inner product ("distance": smaller is closer)."""
    return -(queries @ points.T)


def pq_adc_scores(luts: jnp.ndarray, codes: jnp.ndarray) -> jnp.ndarray:
    """Asymmetric-distance-computation scores.

    luts:  (Q, m, K) f32 — per-query lookup tables (distance of the query's
           j-th subvector to each of the K codewords of subquantizer j).
    codes: (N, m) integer — PQ codes of the database points.
    Returns (Q, N) f32: ``scores[q, n] = sum_j luts[q, j, codes[n, j]]``.
    """
    codes = codes.astype(jnp.int32)
    # gather per subquantizer: (Q, m, N)
    gathered = jnp.take_along_axis(
        luts, codes.T[None, :, :].astype(jnp.int32), axis=2
    )  # luts (Q,m,K) indexed with (1,m,N) -> (Q,m,N)
    return jnp.sum(gathered, axis=1)


def build_pq_luts(
    queries: jnp.ndarray, codebook: jnp.ndarray, metric: str = "l2"
) -> jnp.ndarray:
    """LUT construction for ADC.

    queries:  (Q, D) f32;  codebook: (m, K, D/m) f32.
    Returns (Q, m, K) f32 of sub-distances.
    """
    m, K, dsub = codebook.shape
    q_sub = queries.reshape(queries.shape[0], m, dsub)  # (Q, m, dsub)
    if metric == "l2":
        diff = q_sub[:, :, None, :] - codebook[None, :, :, :]  # (Q, m, K, dsub)
        return jnp.sum(diff * diff, axis=-1)
    if metric == "ip":
        return -jnp.einsum("qmd,mkd->qmk", q_sub, codebook)
    raise ValueError(f"unknown metric {metric}")


def _masked_topk(scores: jnp.ndarray, mask: jnp.ndarray, k: int):
    """Shared masked top-k epilogue: scores (Q, N), mask (N,) shared across
    queries or (Q, N) per query, truthy.

    Masked-out rows are forced to +inf before the reduction.  Returns
    (dists (Q, k) f32, ids (Q, k) int32) per row ascending; slots beyond
    the number of passing rows hold (+inf, -1) — the masked-op contract
    ops.py documents."""
    n = scores.shape[1]
    mask = jnp.asarray(mask).astype(bool)
    if mask.ndim == 1:
        mask = mask[None, :]
    scores = jnp.where(mask, scores, jnp.inf)
    k_avail = min(k, n)
    neg, idx = jax.lax.top_k(-scores, k_avail)
    d = -neg
    idx = jnp.where(jnp.isinf(d), -1, idx).astype(jnp.int32)
    if k_avail < k:
        pad = ((0, 0), (0, k - k_avail))
        d = jnp.pad(d, pad, constant_values=jnp.inf)
        idx = jnp.pad(idx, pad, constant_values=-1)
    return d.astype(jnp.float32), idx


def masked_exact_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    mask: jnp.ndarray,
    k: int,
    metric: str = "l2",
):
    """Mask-aware exact top-k: queries (Q, D), points (N, D), mask (N,)."""
    fn = l2_distances if metric == "l2" else ip_distances
    return _masked_topk(fn(queries, points), mask, k)


def masked_pq_topk(luts: jnp.ndarray, codes: jnp.ndarray, mask: jnp.ndarray, k: int):
    """Mask-aware PQ-ADC top-k: luts (Q, m, K), codes (N, m), mask (N,)."""
    return _masked_topk(pq_adc_scores(luts, codes), mask, k)


def masked_exact_topk_multi(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    masks: jnp.ndarray,
    k: int,
    metric: str = "l2",
):
    """Per-query-mask exact top-k: queries (Q, D), points (N, D), masks
    (Q, N) — row q masks query q independently (heterogeneous predicates
    in one call)."""
    fn = l2_distances if metric == "l2" else ip_distances
    return _masked_topk(fn(queries, points), masks, k)


def masked_pq_topk_multi(
    luts: jnp.ndarray, codes: jnp.ndarray, masks: jnp.ndarray, k: int
):
    """Per-query-mask PQ-ADC top-k: luts (Q, m, K), codes (N, m), masks
    (Q, N)."""
    return _masked_topk(pq_adc_scores(luts, codes), masks, k)


def unified_masked_topk(
    queries: jnp.ndarray,
    points: jnp.ndarray,
    luts: jnp.ndarray,
    codes: jnp.ndarray,
    masks: jnp.ndarray,
    flavor: jnp.ndarray,
    k: int,
    metric: str = "l2",
):
    """Single-dispatch mixed-flavor masked top-k: queries (Q, D), points
    (N, D), luts (Q, m, K), codes (N, m), masks (N,) or (Q, N), flavor (Q,)
    truthy (True = score row q with PQ-ADC, False = full-precision).  Each
    query's scores come from ITS flavor; the masked top-k epilogue is
    shared, so a fragment mixing both flavors is one call.

    Like the Pallas kernel, both score planes are computed and selected
    per row: at these shapes the two dense computes beat any
    subset-gather/scatter assembly (eager-mode gathers cost more than the
    matmul they save — measured), and the shared top-k epilogue runs
    once instead of once per flavor."""
    fn = l2_distances if metric == "l2" else ip_distances
    d_exact = fn(queries, points)
    d_adc = pq_adc_scores(luts, codes)
    sel = jnp.asarray(flavor).astype(bool).reshape(-1, 1)
    return _masked_topk(jnp.where(sel, d_adc, d_exact), masks, k)


def kmeans_assign(points: jnp.ndarray, centroids: jnp.ndarray):
    """Nearest-centroid assignment.

    points: (N, D) f32;  centroids: (K, D) f32.
    Returns (assignments (N,) int32, sq_distances (N,) f32).
    """
    d = l2_distances(points, centroids)  # (N, K)
    idx = jnp.argmin(d, axis=1).astype(jnp.int32)
    return idx, jnp.min(d, axis=1)
