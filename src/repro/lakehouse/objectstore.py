"""S3-compatible object store simulation over a local directory.

The paper's engine reads everything from object storage and treats executor
SSD purely as a cache.  This module provides the storage contract the rest of
the system programs against:

- immutable puts (no partial overwrite; conditional put for CAS commits),
- byte-range gets (``get_range``) — the access pattern Puffin depends on,
- listing by prefix, deletes, etags, and per-object size,
- simple read/write byte accounting so benchmarks can report "data read from
  S3" the way the paper's Table 2 does.

Thread safety: a single lock guards metadata; payload IO is done outside the
lock where possible.  Executors in the in-process runtime share one store
instance, mirroring a shared S3 endpoint.
"""

from __future__ import annotations

import hashlib
import os
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterator, List, Optional


class NoSuchKey(KeyError):
    pass


class PreconditionFailed(RuntimeError):
    """Conditional put failed (CAS conflict)."""


@dataclass
class ObjectStat:
    key: str
    size: int
    etag: str


@dataclass
class StoreMetrics:
    """Byte/op accounting, reset-able per benchmark."""

    bytes_read: int = 0
    bytes_written: int = 0
    get_ops: int = 0
    put_ops: int = 0
    range_gets: int = 0
    per_key_reads: Dict[str, int] = field(default_factory=dict)

    def reset(self) -> None:
        self.bytes_read = 0
        self.bytes_written = 0
        self.get_ops = 0
        self.put_ops = 0
        self.range_gets = 0
        self.per_key_reads.clear()


class ObjectStore:
    """Local-directory object store with S3-like semantics."""

    def __init__(self, root: str) -> None:
        self.root = os.path.abspath(root)
        os.makedirs(self.root, exist_ok=True)
        self._lock = threading.Lock()
        self._etags: Dict[str, str] = {}
        self.metrics = StoreMetrics()

    # -- path mapping ------------------------------------------------------
    def _path(self, key: str) -> str:
        key = key.lstrip("/")
        if ".." in key.split("/"):
            raise ValueError(f"invalid key: {key}")
        return os.path.join(self.root, key)

    # -- writes ------------------------------------------------------------
    def put(self, key: str, data: bytes, *, if_none_match: bool = False) -> ObjectStat:
        """Atomic put.  ``if_none_match=True`` fails if the key exists (CAS
        create — what an Iceberg catalog uses to arbitrate commits)."""
        path = self._path(key)
        etag = hashlib.sha256(data).hexdigest()[:16]
        with self._lock:
            if if_none_match and os.path.exists(path):
                raise PreconditionFailed(key)
            os.makedirs(os.path.dirname(path), exist_ok=True)
            tmp = path + ".tmp.%d" % threading.get_ident()
            with open(tmp, "wb") as f:
                f.write(data)
            os.replace(tmp, path)  # atomic on POSIX
            self._etags[key] = etag
            self.metrics.bytes_written += len(data)
            self.metrics.put_ops += 1
        return ObjectStat(key=key, size=len(data), etag=etag)

    def delete(self, key: str) -> None:
        path = self._path(key)
        with self._lock:
            try:
                os.remove(path)
            except FileNotFoundError:
                raise NoSuchKey(key) from None
            self._etags.pop(key, None)

    # -- reads -------------------------------------------------------------
    def exists(self, key: str) -> bool:
        return os.path.isfile(self._path(key))

    def stat(self, key: str) -> ObjectStat:
        path = self._path(key)
        try:
            size = os.path.getsize(path)
        except OSError:
            raise NoSuchKey(key) from None
        return ObjectStat(key=key, size=size, etag=self._etags.get(key, ""))

    def get(self, key: str) -> bytes:
        path = self._path(key)
        try:
            with open(path, "rb") as f:
                data = f.read()
        except FileNotFoundError:
            raise NoSuchKey(key) from None
        with self._lock:
            self.metrics.bytes_read += len(data)
            self.metrics.get_ops += 1
            self.metrics.per_key_reads[key] = self.metrics.per_key_reads.get(key, 0) + len(data)
        return data

    def get_range(self, key: str, offset: int, length: int) -> bytes:
        """Byte-range get — the Puffin footer/blob access path."""
        path = self._path(key)
        try:
            with open(path, "rb") as f:
                f.seek(offset)
                data = f.read(length)
        except FileNotFoundError:
            raise NoSuchKey(key) from None
        with self._lock:
            self.metrics.bytes_read += len(data)
            self.metrics.get_ops += 1
            self.metrics.range_gets += 1
            self.metrics.per_key_reads[key] = self.metrics.per_key_reads.get(key, 0) + len(data)
        return data

    def range_reader(self, key: str):
        """Callable suitable for :class:`repro.iceberg.puffin.PuffinReader`."""
        return lambda off, ln: self.get_range(key, off, ln)

    # -- listing -----------------------------------------------------------
    def list(self, prefix: str = "") -> List[str]:
        prefix = prefix.lstrip("/")
        out: List[str] = []
        for dirpath, _dirnames, filenames in os.walk(self.root):
            for name in filenames:
                if name.endswith(".tmp") or ".tmp." in name:
                    continue
                full = os.path.join(dirpath, name)
                key = os.path.relpath(full, self.root).replace(os.sep, "/")
                if key.startswith(prefix):
                    out.append(key)
        return sorted(out)

    def iter_stats(self, prefix: str = "") -> Iterator[ObjectStat]:
        for key in self.list(prefix):
            yield self.stat(key)
