"""Lakehouse substrate: object store, columnar file format, table IO paths."""

from repro.lakehouse.objectstore import ObjectStore  # noqa: F401
from repro.lakehouse.vparquet import (  # noqa: F401
    VParquetReader,
    VParquetWriter,
    read_vector_column,
    write_vector_file,
)
from repro.lakehouse.table import LakehouseTable  # noqa: F401
