"""Table-level IO: the INSERT-SELECT write path and the scan read path.

``LakehouseTable`` bundles (catalog, store, table name) and exposes the two
paths the paper's protocols reuse:

- **write path** — partition an embedding corpus into N vparquet data files
  and commit them as an Iceberg append (this is what "the engine's existing
  INSERT-SELECT path" produces);
- **read path** — scan the vector column of selected files / row groups with
  projection, which both the index build (Stage 1) and exact rerank
  (Stage B) use.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from typing import TYPE_CHECKING

from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.vparquet import ColumnSpec, VParquetReader, write_vector_file

if TYPE_CHECKING:  # avoid a lakehouse <-> iceberg import cycle at runtime
    from repro.iceberg.catalog import RestCatalog
    from repro.iceberg.snapshot import Snapshot, TableMetadata


@dataclass
class RowLocation:
    """(file, row group, row offset) — the paper's vector-ID→location tuple."""

    file_path: str
    row_group_id: int
    row_offset: int


class LakehouseTable:
    def __init__(self, catalog: RestCatalog, name: str) -> None:
        self.catalog = catalog
        self.store: ObjectStore = catalog.store
        self.name = name

    # -- write path -----------------------------------------------------------
    def create(self, dim: int) -> TableMetadata:
        return self.catalog.create_table(
            self.name, {"id": "long", "vec": f"vector<float32,{dim}>"}
        )

    def append_vectors(
        self,
        vectors: np.ndarray,
        *,
        num_files: int = 4,
        rows_per_group: int = 4096,
        file_prefix: str = "data",
        attributes: Optional[Dict[str, np.ndarray]] = None,
    ) -> TableMetadata:
        """Write ``vectors`` as ``num_files`` data files and commit an append.

        ``attributes`` adds per-row attribute columns alongside ``vec``:
        int64 (or any numeric) arrays are stored directly, string arrays are
        dictionary-encoded per file — the substrate filtered search scans."""
        from repro.iceberg.snapshot import DataFile  # lazy: avoid import cycle

        vectors = np.ascontiguousarray(vectors, dtype=np.float32)
        meta = self.catalog.load_table(self.name)
        n = vectors.shape[0]
        attrs = {k: np.asarray(v) for k, v in (attributes or {}).items()}
        for name, arr in attrs.items():
            if arr.shape[0] != n:
                raise ValueError(f"attribute {name}: {arr.shape[0]} rows != {n}")
        splits = np.array_split(np.arange(n), num_files)
        existing = len(self.current_files()) if meta.current_snapshot_id else 0
        files: List[DataFile] = []
        for i, idx in enumerate(splits):
            if len(idx) == 0:
                continue
            key = f"{meta.location}/data/{file_prefix}-{existing + i:05d}.vpq"
            size = write_vector_file(
                self.store,
                key,
                vectors[idx],
                rows_per_group=rows_per_group,
                extra_columns={k: v[idx] for k, v in attrs.items()} or None,
            )
            files.append(DataFile(path=key, record_count=len(idx), file_size_bytes=size))
        return self.catalog.append_files(self.name, files)

    def delete_files(self, paths: List[str]) -> TableMetadata:
        return self.catalog.delete_files(self.name, paths)

    # -- read path --------------------------------------------------------------
    def metadata(self) -> TableMetadata:
        return self.catalog.load_table(self.name)

    def current_snapshot(self) -> Optional[Snapshot]:
        return self.metadata().current_snapshot()

    def current_files(self, snapshot_id: Optional[int] = None) -> "List[DataFile]":
        from repro.iceberg.snapshot import live_data_files  # lazy: import cycle

        meta = self.metadata()
        snap = (
            meta.snapshot_by_id(snapshot_id)
            if snapshot_id is not None
            else meta.current_snapshot()
        )
        if snap is None:
            return []
        return live_data_files(self.store, snap)

    def reader(self, file_path: str) -> VParquetReader:
        return VParquetReader.from_store(self.store, file_path)

    def scan_vectors(
        self, snapshot_id: Optional[int] = None, file_paths: Optional[Sequence[str]] = None
    ) -> Tuple[np.ndarray, List[RowLocation]]:
        """Full scan of the vector column (the paper's "no index" path).

        Returns the concatenated vectors plus per-row locations.
        """
        files = self.current_files(snapshot_id)
        if file_paths is not None:
            wanted = set(file_paths)
            files = [f for f in files if f.path in wanted]
        vecs: List[np.ndarray] = []
        locs: List[RowLocation] = []
        for f in files:
            r = self.reader(f.path)
            for rg_id, rg in enumerate(r.row_groups):
                arr = r.read_column("vec", [rg_id])
                vecs.append(arr)
                locs.extend(
                    RowLocation(f.path, rg_id, row) for row in range(rg["num_rows"])
                )
        if not vecs:
            return np.empty((0, 0), np.float32), []
        return np.concatenate(vecs, axis=0), locs

    def attribute_schema(self) -> Dict[str, "ColumnSpec"]:
        """Scalar attribute columns across all live data files — the
        filterable surface of the table (mixed-schema appends contribute
        their union; the first file carrying a column defines its spec)."""
        out: Dict[str, ColumnSpec] = {}
        for f in self.current_files():
            for name, spec in self.reader(f.path).attribute_specs().items():
                out.setdefault(name, spec)
        return out

    def scan_attributes(
        self,
        columns: Optional[Sequence[str]] = None,
        snapshot_id: Optional[int] = None,
    ) -> Dict[str, np.ndarray]:
        """Scan attribute columns, row-aligned with :meth:`scan_vectors`.

        Dictionary-encoded string columns come back as decoded value arrays
        (each file's codes mapped through its own dictionary).  Files
        written without a column (mixed-schema appends) keep the alignment:
        their rows are filled with ``None`` and the column comes back as an
        object array — never a float promotion, which would silently round
        int64 values above 2^53.  Homogeneous tables keep native dtypes."""
        files = self.current_files(snapshot_id)
        readers = [self.reader(f.path) for f in files]
        names = (
            list(columns)
            if columns is not None
            else sorted({n for r in readers for n in r.attribute_specs()})
        )
        out: Dict[str, List[np.ndarray]] = {name: [] for name in names}
        for r in readers:
            for name in names:
                spec = r.columns.get(name)
                if spec is None:
                    out[name].append(np.full(r.num_rows, None, dtype=object))
                    continue
                arr = r.read_column(name)
                if spec.dictionary is not None:
                    arr = np.asarray(spec.dictionary, dtype=object)[arr]
                out[name].append(arr)
        return {k: np.concatenate(v) for k, v in out.items() if v}

    def fetch_rows(
        self, masks: Dict[str, Dict[int, List[int]]]
    ) -> Tuple[np.ndarray, List[RowLocation]]:
        """Stage-B fetch: ``masks[file][row_group] = [row offsets]``."""
        vecs: List[np.ndarray] = []
        locs: List[RowLocation] = []
        for file_path, groups in masks.items():
            r = self.reader(file_path)
            for rg_id, rows in groups.items():
                arr = r.read_rows("vec", rg_id, rows)
                vecs.append(arr)
                locs.extend(RowLocation(file_path, rg_id, row) for row in rows)
        if not vecs:
            return np.empty((0, 0), np.float32), []
        return np.concatenate(vecs, axis=0), locs
