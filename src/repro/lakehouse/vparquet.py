"""``vparquet`` — a minimal columnar file format with row groups.

pyarrow is not available in this environment, so the framework carries its
own Parquet-shaped format.  It preserves the three properties the paper's
protocols depend on:

1. **Column projection** — the index build reads *only* the vector column
   (paper Stage 1: "column projection limited to the vector column").
2. **Row-group granularity** — the exact-rerank stage reads *only* the row
   groups containing candidate vectors (paper Stage B: "per-file row-group
   masks").
3. **Footer-based random access** — readers range-read the footer, then
   range-read only the targeted column chunks.

Layout::

    magic ``VPQ1``
    column chunk bytes (row-group-major, column-minor), each optionally zstd
    footer JSON  { "columns": [{name,dtype,vlen}],
                   "row_groups": [{num_rows, chunks:{col:{offset,length,codec}}}] }
    footer length (u32 LE)
    magic ``VPQ1``
"""

from __future__ import annotations

import json
import struct
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.lakehouse.objectstore import ObjectStore

try:
    import zstandard as _zstd

    _HAVE_ZSTD = True
except Exception:  # pragma: no cover
    _HAVE_ZSTD = False

MAGIC = b"VPQ1"


def _encode(codec: Optional[str], data: bytes) -> bytes:
    if codec == "zstd" and _HAVE_ZSTD:
        return _zstd.ZstdCompressor(level=1).compress(data)
    return data


def _decode(codec: Optional[str], data: bytes) -> bytes:
    if codec == "zstd":
        return _zstd.ZstdDecompressor().decompress(data)
    return data


@dataclass
class ColumnSpec:
    name: str
    dtype: str  # numpy dtype string
    vlen: int  # vector length per row (0 => scalar column)
    # dictionary-encoded string column: the stored ints are codes into this
    # per-file value table (attribute columns for filtered search)
    dictionary: Optional[List[str]] = None


class VParquetWriter:
    def __init__(self, columns: Sequence[ColumnSpec], codec: Optional[str] = None) -> None:
        self.columns = list(columns)
        self.codec = codec if (codec != "zstd" or _HAVE_ZSTD) else None
        self._chunks: List[bytes] = [MAGIC]
        self._offset = len(MAGIC)
        self._row_groups: List[dict] = []

    def write_row_group(self, arrays: Dict[str, np.ndarray]) -> None:
        num_rows = None
        chunk_meta: Dict[str, dict] = {}
        for spec in self.columns:
            arr = np.ascontiguousarray(arrays[spec.name])
            if str(arr.dtype) != spec.dtype:
                raise TypeError(f"column {spec.name}: dtype {arr.dtype} != {spec.dtype}")
            rows = arr.shape[0]
            if spec.vlen and (arr.ndim != 2 or arr.shape[1] != spec.vlen):
                raise ValueError(f"column {spec.name}: expected (N,{spec.vlen}), got {arr.shape}")
            if not spec.vlen and arr.ndim != 1:
                raise ValueError(f"column {spec.name}: expected 1-D, got {arr.shape}")
            if num_rows is None:
                num_rows = rows
            elif rows != num_rows:
                raise ValueError("ragged row group")
            raw = arr.tobytes()
            stored = _encode(self.codec, raw)
            chunk_meta[spec.name] = {
                "offset": self._offset,
                "length": len(stored),
                "codec": self.codec if self.codec else None,
            }
            self._chunks.append(stored)
            self._offset += len(stored)
        self._row_groups.append({"num_rows": int(num_rows or 0), "chunks": chunk_meta})

    def finish(self) -> bytes:
        footer = json.dumps(
            {
                "columns": [
                    {"name": c.name, "dtype": c.dtype, "vlen": c.vlen}
                    | ({"dictionary": c.dictionary} if c.dictionary is not None else {})
                    for c in self.columns
                ],
                "row_groups": self._row_groups,
            },
            separators=(",", ":"),
        ).encode("utf-8")
        self._chunks.append(footer)
        self._chunks.append(struct.pack("<I", len(footer)))
        self._chunks.append(MAGIC)
        return b"".join(self._chunks)


class VParquetReader:
    """Footer-driven reader over a byte-range callable."""

    def __init__(self, size: int, range_reader) -> None:
        self._read = range_reader
        tail = range_reader(size - 8, 8)
        (footer_len,) = struct.unpack("<I", tail[:4])
        if tail[4:8] != MAGIC:
            raise ValueError("bad vparquet trailing magic")
        footer = json.loads(range_reader(size - 8 - footer_len, footer_len).decode("utf-8"))
        self.columns = {
            c["name"]: ColumnSpec(c["name"], c["dtype"], c["vlen"], c.get("dictionary"))
            for c in footer["columns"]
        }
        self.row_groups: List[dict] = footer["row_groups"]

    @classmethod
    def from_bytes(cls, data: bytes) -> "VParquetReader":
        return cls(len(data), lambda off, ln: data[off : off + ln])

    @classmethod
    def from_store(cls, store: ObjectStore, key: str) -> "VParquetReader":
        return cls(store.stat(key).size, store.range_reader(key))

    @property
    def num_rows(self) -> int:
        return sum(rg["num_rows"] for rg in self.row_groups)

    def attribute_specs(self) -> Dict[str, ColumnSpec]:
        """Scalar attribute columns — everything but the reserved ``vec``
        and ``id`` columns.  The single definition of "filterable column"
        shared by zone-map construction and the table scan paths."""
        return {
            name: spec
            for name, spec in self.columns.items()
            if spec.vlen == 0 and name not in ("vec", "id")
        }

    @property
    def num_row_groups(self) -> int:
        return len(self.row_groups)

    def row_group_offsets(self) -> np.ndarray:
        """Starting global row index of each row group (plus total at end)."""
        sizes = [rg["num_rows"] for rg in self.row_groups]
        return np.concatenate([[0], np.cumsum(sizes)]).astype(np.int64)

    def read_column(
        self, name: str, row_group_ids: Optional[Sequence[int]] = None
    ) -> np.ndarray:
        """Read one column from the selected row groups (all if None)."""
        spec = self.columns[name]
        ids = range(len(self.row_groups)) if row_group_ids is None else row_group_ids
        parts: List[np.ndarray] = []
        for rg_id in ids:
            rg = self.row_groups[rg_id]
            meta = rg["chunks"][name]
            raw = _decode(meta["codec"], self._read(meta["offset"], meta["length"]))
            arr = np.frombuffer(raw, dtype=np.dtype(spec.dtype))
            if spec.vlen:
                arr = arr.reshape(rg["num_rows"], spec.vlen)
            parts.append(arr)
        if not parts:
            shape = (0, spec.vlen) if spec.vlen else (0,)
            return np.empty(shape, dtype=np.dtype(spec.dtype))
        return np.concatenate(parts, axis=0)

    def read_rows(
        self, name: str, row_group_id: int, row_offsets: Sequence[int]
    ) -> np.ndarray:
        """Read specific rows of one row group (Stage-B candidate fetch)."""
        col = self.read_column(name, [row_group_id])
        return col[np.asarray(row_offsets, dtype=np.int64)]


# -- convenience helpers used throughout tests/benchmarks -------------------

def dictionary_encode(values: np.ndarray) -> Tuple[np.ndarray, List[str]]:
    """String array → (int32 codes, sorted value dictionary)."""
    strs = np.asarray(values).astype(str)
    dictionary, codes = np.unique(strs, return_inverse=True)
    return codes.astype(np.int32), [str(v) for v in dictionary]


def write_vector_file(
    store: ObjectStore,
    key: str,
    vectors: np.ndarray,
    *,
    rows_per_group: int = 4096,
    codec: Optional[str] = None,
    extra_columns: Optional[Dict[str, np.ndarray]] = None,
) -> int:
    """Write an embedding table file with a ``vec`` column (+ row ``id``).

    ``extra_columns`` carries attribute columns for filtered search: numeric
    arrays are stored as-is; string arrays are dictionary-encoded (int32
    codes + per-file value table in the footer's ``ColumnSpec``)."""
    vectors = np.ascontiguousarray(vectors, dtype=np.float32)
    n, d = vectors.shape
    cols = [ColumnSpec("vec", "float32", d), ColumnSpec("id", "int64", 0)]
    extra = dict(extra_columns or {})
    for name, arr in list(extra.items()):
        arr = np.asarray(arr)
        if arr.dtype.kind in ("U", "S", "O"):
            codes, dictionary = dictionary_encode(arr)
            extra[name] = codes
            cols.append(ColumnSpec(name, "int32", 0, dictionary))
            continue
        vlen = arr.shape[1] if arr.ndim == 2 else 0
        cols.append(ColumnSpec(name, str(arr.dtype), vlen))
    w = VParquetWriter(cols, codec=codec)
    ids = np.arange(n, dtype=np.int64)
    for start in range(0, n, rows_per_group):
        stop = min(start + rows_per_group, n)
        group = {"vec": vectors[start:stop], "id": ids[start:stop]}
        for name, arr in extra.items():
            group[name] = arr[start:stop]
        w.write_row_group(group)
    data = w.finish()
    store.put(key, data)
    return len(data)


def read_vector_column(
    store: ObjectStore, key: str, row_group_ids: Optional[Sequence[int]] = None
) -> np.ndarray:
    return VParquetReader.from_store(store, key).read_column("vec", row_group_ids)
