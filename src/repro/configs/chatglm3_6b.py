"""chatglm3-6b — GQA kv=2, partial (2d) RoPE, QKV bias [arXiv:2406.12793; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b",
    family="dense",
    num_layers=28,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=65024,
    attention="full",
    rope="partial",
    rope_frac=0.5,
    qkv_bias=True,
    mlp="swiglu",
    norm="rmsnorm",
    source="arXiv:2406.12793",
    notes="kv=2 << TP=16 stresses KV replication; hillclimb candidate",
)
