"""zamba2-1.2b — Mamba2 backbone + shared attention block [arXiv:2411.15242; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    num_layers=38,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    attention="full",  # the *shared* block attends; mamba2 layers are attn-free
    rope="full",
    mlp="gelu",
    norm="rmsnorm",
    ssm="mamba2",
    ssm_state=64,
    ssm_expand=2,
    shared_attn_every=6,
    source="arXiv:2411.15242",
    notes="38 mamba2 blocks; one shared attn+mlp block applied every 6 layers",
)
