"""Architecture config schema + registry.

One file per assigned architecture lives next to this module; each exposes
``CONFIG``.  ``get_config(name)`` resolves by arch id (e.g. "dbrx-132b"),
``reduced(cfg)`` produces the smoke-test variant (same family, tiny dims).
"""

from __future__ import annotations

import dataclasses
import importlib
from dataclasses import dataclass
from typing import Dict, List

ARCH_IDS = [
    "dbrx-132b",
    "mixtral-8x7b",
    "chameleon-34b",
    "chatglm3-6b",
    "qwen2.5-3b",
    "minitron-8b",
    "phi4-mini-3.8b",
    "musicgen-medium",
    "rwkv6-3b",
    "zamba2-1.2b",
]

_MODULE_OF = {a: a.replace("-", "_").replace(".", "_") for a in ARCH_IDS}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # -- attention ---------------------------------------------------------
    attention: str = "full"  # full | swa | none
    window: int = 0  # SWA window (mixtral: 4096)
    rope: str = "full"  # full | partial | none
    rope_frac: float = 1.0  # fraction of head_dim rotated (glm: 0.5)
    rope_theta: float = 10_000.0
    qkv_bias: bool = False
    qk_norm: bool = False  # chameleon
    # -- mlp -----------------------------------------------------------------
    mlp: str = "swiglu"  # swiglu | squared_relu | gelu
    norm: str = "rmsnorm"  # rmsnorm | layernorm
    # -- moe -----------------------------------------------------------------
    num_experts: int = 0
    top_k: int = 0
    capacity_factor: float = 1.25
    # -- ssm -----------------------------------------------------------------
    ssm: str = ""  # rwkv6 | mamba2
    ssm_state: int = 0  # mamba2 state dim per head
    ssm_heads: int = 0
    ssm_expand: int = 2  # mamba2 inner expansion
    # -- hybrid (zamba2) ------------------------------------------------------
    shared_attn_every: int = 0  # apply the shared attention block every N layers
    # -- modality backbone stubs -----------------------------------------------
    num_codebooks: int = 0  # musicgen EnCodec streams
    modality: str = "text"  # text | audio-tokens | vlm-tokens
    # -- numerics ----------------------------------------------------------------
    dtype: str = "bfloat16"
    # attention schedule: "dense" (compute-all-blocks + mask; baseline) or
    # "sparse" (static block-visibility schedule; beyond-paper §Perf)
    attn_impl: str = "dense"
    # KV-cache storage dtype override ("" = compute dtype); "float8_e4m3fn"
    # halves decode HBM traffic (beyond-paper §Perf)
    cache_dtype: str = ""
    # pad kv heads up to TP degree when sharding (DESIGN.md §5)
    pad_kv_to_tp: bool = True
    notes: str = ""
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def is_attention_free(self) -> bool:
        return self.attention == "none"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SWA / SSM / hybrid)"""
        return self.attention in ("swa", "none") or self.family in ("ssm", "hybrid")

    def param_count(self) -> int:
        """Analytic total parameter count (embeddings + blocks + head)."""
        d, ff, V = self.d_model, self.d_ff, self.vocab_size
        H, KV, hd = self.num_heads, self.num_kv_heads, self.head_dim
        emb = V * d * (2 if not self.tied_embeddings else 1)
        if self.num_codebooks:
            emb = self.num_codebooks * V * d + self.num_codebooks * V * d
        per_layer = 0
        if self.ssm == "rwkv6":
            inner = d
            # time-mix: r,k,v,w,g projections + output + data-dep lora (approx)
            per_layer += 6 * d * inner + 2 * d * 64
            per_layer += 2 * d * ff  # channel-mix (relu^2, k/v)
        elif self.ssm == "mamba2":
            inner = self.ssm_expand * d
            proj_in = d * (2 * inner + 2 * self.ssm_state * self.ssm_groups + self.ssm_heads_eff)
            per_layer += proj_in + inner * d
        if self.attention in ("full", "swa"):
            att = d * H * hd + 2 * d * KV * hd + H * hd * d
            per_layer += att
        if self.num_experts:
            per_layer += self.num_experts * 3 * d * ff + d * self.num_experts
        elif self.mlp == "swiglu":
            per_layer += 3 * d * ff
        elif self.ssm != "rwkv6":
            per_layer += 2 * d * ff
        if self.family == "hybrid" and self.shared_attn_every:
            # shared block params counted once
            att = d * H * hd + 2 * d * KV * hd + H * hd * d
            shared = att + 3 * d * ff
        else:
            shared = 0
        return emb + self.num_layers * per_layer + shared

    @property
    def tied_embeddings(self) -> bool:
        return False

    @property
    def ssm_groups(self) -> int:
        return 1

    @property
    def ssm_heads_eff(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        if self.ssm == "mamba2":
            return (self.ssm_expand * self.d_model) // 64
        return max(1, self.d_model // 64)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULE_OF:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    mod = importlib.import_module(f"repro.configs.{_MODULE_OF[arch_id]}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_cells(cfg: ModelConfig) -> List[ShapeConfig]:
    """The shape set for this arch; ``long_500k`` only for sub-quadratic
    archs (pure full-attention archs skip it — DESIGN.md §4)."""
    cells = [SHAPES["train_4k"], SHAPES["prefill_32k"], SHAPES["decode_32k"]]
    if cfg.subquadratic:
        cells.append(SHAPES["long_500k"])
    return cells


def reduced(cfg: ModelConfig) -> ModelConfig:
    """Smoke-test variant: same family/topology, tiny dims."""
    kv = min(cfg.num_kv_heads, 2)
    heads = max(4, min(cfg.num_heads, 4))
    kv = min(kv, heads)
    return dataclasses.replace(
        cfg,
        num_layers=min(cfg.num_layers, 4 if not cfg.shared_attn_every else 2 * cfg.shared_attn_every),
        d_model=128,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=32,
        d_ff=256,
        vocab_size=512,
        window=min(cfg.window, 64) if cfg.window else 0,
        num_experts=min(cfg.num_experts, 4),
        top_k=min(cfg.top_k, 2),
        # no-drop capacity in the smoke configs so prefill/decode/forward
        # are exactly consistent (full configs keep the paper-standard 1.25)
        capacity_factor=4.0 if cfg.num_experts else cfg.capacity_factor,
        ssm_state=min(cfg.ssm_state, 16) if cfg.ssm_state else 0,
        ssm_heads=4 if cfg.ssm else 0,
        pad_kv_to_tp=False,
    )
