"""Assigned architecture configs (public literature; see per-file source tags)."""

from repro.configs.base import (  # noqa: F401
    ARCH_IDS,
    SHAPES,
    ModelConfig,
    ShapeConfig,
    all_configs,
    get_config,
    reduced,
    shape_cells,
)
