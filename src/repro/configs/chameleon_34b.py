"""chameleon-34b — early-fusion VLM over VQ image tokens [arXiv:2405.09818; unverified].

Backbone only (assignment): the modality frontend is a stub — input_specs()
provides token ids drawn from the unified text+VQ vocabulary.
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    attention="full",
    rope="full",
    qk_norm=True,
    mlp="swiglu",
    norm="rmsnorm",
    modality="vlm-tokens",
    source="arXiv:2405.09818",
    notes="early fusion; qk-norm for training stability at 34B",
)
