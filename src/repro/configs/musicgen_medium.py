"""musicgen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

Backbone only (assignment): the EnCodec frontend is a stub; input_specs()
provides 4 parallel codebook token streams (delay pattern applied upstream).
"""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="dense",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    attention="full",
    rope="none",  # musicgen uses learned/sinusoidal positions; we use none+learned
    mlp="gelu",
    norm="layernorm",
    num_codebooks=4,
    modality="audio-tokens",
    source="arXiv:2306.05284",
    notes="MHA (kv=24); 4 codebook embeddings summed; 4 output heads",
)
