"""qwen2.5-3b — GQA kv=2, QKV bias [hf:Qwen/Qwen2.5-0.5B family scaling; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="qwen2.5-3b",
    family="dense",
    num_layers=36,
    d_model=2048,
    num_heads=16,
    num_kv_heads=2,
    head_dim=128,
    d_ff=11008,
    vocab_size=151936,
    attention="full",
    rope="full",
    rope_theta=1_000_000.0,
    qkv_bias=True,
    mlp="swiglu",
    norm="rmsnorm",
    source="hf:Qwen/Qwen2.5-3B",
    notes="large vocab (151936) relative to width; vocab-sharded head matters",
)
