"""dbrx-132b — 16-expert top-4 fine-grained MoE [hf:databricks/dbrx-base; unverified]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=10752,
    vocab_size=100352,
    attention="full",
    rope="full",
    rope_theta=500_000.0,
    mlp="swiglu",
    norm="layernorm",
    num_experts=16,
    top_k=4,
    source="hf:databricks/dbrx-base",
    notes="fine-grained MoE: 16 experts, top-4 routing, GQA kv=8",
)
