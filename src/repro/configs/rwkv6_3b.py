"""rwkv6-3b (Finch) — attention-free, data-dependent decay [arXiv:2404.05892; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-3b",
    family="ssm",
    num_layers=32,
    d_model=2560,
    num_heads=40,  # wkv heads = d_model / 64
    num_kv_heads=40,
    head_dim=64,
    d_ff=8960,
    vocab_size=65536,
    attention="none",
    rope="none",
    mlp="squared_relu",  # rwkv channel-mix uses relu^2
    norm="layernorm",
    ssm="rwkv6",
    source="arXiv:2404.05892",
    notes="O(1) decode state: (heads, 64, 64) per layer; long_500k runs",
)
