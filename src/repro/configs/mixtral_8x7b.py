"""mixtral-8x7b — 8-expert top-2 MoE with sliding-window attention [arXiv:2401.04088; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=32000,
    attention="swa",
    window=4096,
    rope="full",
    rope_theta=1_000_000.0,
    mlp="swiglu",
    norm="rmsnorm",
    num_experts=8,
    top_k=2,
    source="arXiv:2401.04088",
    notes="SWA window 4096 makes long_500k servable with a rolling KV cache",
)
