"""Coordinator: index lifecycle orchestration (paper §3.1, §5, §6, §7).

Implements the paper's three protocols against the runtime substrate:

- :meth:`Coordinator.create_index` — Stage 0 (sample + k-means + PQ train on
  the coordinator), Stage 1 (parallel per-shard build on executors, with the
  centroid-mode all-to-all exchange), Stage 2 (assemble the Puffin file,
  optimistic-concurrency commit of ``statistics-file``).
- :meth:`Coordinator.probe` — tiered probe placement: coordinator-local
  centroid pruning below the size threshold, else the three-stage
  distributed probe (Stage A shard beam search → Stage B exact rerank on
  row-group masks → Stage C ordered merge).
- :meth:`Coordinator.probe_batch` — the batched multi-query pipeline:
  centroid routing and tiered placement are vectorized over the whole
  batch, the scheduler coalesces per-(query, shard) probe fragments into
  at most ONE fragment per shard (each executor runs a single batched
  beam search + rerank kernel call for all queries routed to it), Stage B
  reads the union of every query's candidate rows once with per-query
  ownership, and Stage C does a per-query ordered merge.  Per-query
  results are identical to sequential :meth:`probe` calls; dispatch,
  kernel-launch, and I/O costs amortize across the batch.
- :meth:`Coordinator.refresh_index` — manifest diff → per-shard greedy
  insert + lazy tombstones → per-shard rebuild above the tombstone-ratio
  threshold → metadata-only commit.  Unchanged shard blobs are byte-copied
  into the new Puffin, never rebuilt or re-encoded.

Both probe entry points take ``filter=`` (a predicate tree or SQL WHERE
fragment): the coordinator zone-map-prunes shards/row-groups, then plans
per shard by estimated selectivity — pre-filter exact scan (few rows
pass), filter-aware masked beam (mid), or over-fetched post-filter (most
rows pass) — with per-query predicates surviving fragment coalescing.
A batch carrying heterogeneous predicates is NOT split per predicate
group on the kernel path: each coalesced fragment ships its per-query
predicate list and the executor answers every kernel-planned query with
one multi-mask (Q, N)-plane kernel call per shard
(``ProbeReport.kernel_dispatches`` counts the calls).
"""

from __future__ import annotations

import heapq
import time
import uuid
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.blobs import (
    ATTR_ZONEMAP_BLOB_TYPE,
    CENTROID_BLOB_TYPE,
    FRESH_TAIL_BLOB_TYPE,
    ROUTING_BLOB_TYPE,
    SHARD_BLOB_TYPE,
    AttrZoneMap,
    FreshTail,
    RoutingTable,
    ShardInfo,
    build_zonemap,
    decode_fresh_tail_blob,
    decode_routing_blob,
    decode_zonemap_blob,
    encode_routing_blob,
    encode_zonemap_blob,
)
from repro.core.centroid_index import CentroidIndex, build_centroid_index
from repro.core.kmeans import train_kmeans
from repro.core.pq import train_pq
from repro.iceberg.catalog import RestCatalog
from repro.iceberg.diff import diff_snapshots
from repro.iceberg.puffin import PuffinReader, PuffinWriter, preferred_codec
from repro.iceberg.snapshot import Snapshot, TableMetadata
from repro.lakehouse.table import LakehouseTable
from repro.runtime import fragments as F
from repro.runtime import planner
from repro.runtime.planner import PlanOp, ProbePlan
from repro.runtime.predicates import Predicate, parse_predicate, row_group_mask
from repro.runtime.scheduler import ExecutorPool, Scheduler
from repro.serving.cache import ShardProbeCache, query_digest
from repro.serving.metrics import MetricsRegistry

TOMBSTONE_REBUILD_THRESHOLD = 0.20  # paper §7.3

# Fresh-tail compaction: once the appended-but-unindexed tail crosses this
# many rows, fold it into the Vamana shards (a refresh commit) — below it
# the exact tail scan is cheaper than a graph rebuild (paper §7.3's
# incremental-refresh economics applied to the delta tier).
TAIL_COMPACT_THRESHOLD_ROWS = 4096

# Selectivity-adaptive filtered-probe planning lives in runtime/planner.py
# (the probe-plan IR): the coordinator asks the planner for per-(query,
# shard) plan ops and ships them with the tasks; executors interpret them.


@dataclass
class IndexConfig:
    name: str
    column: str = "vec"
    R: int = 64
    L: int = 100
    alpha: float = 1.2
    metric: str = "l2"
    pq_m: int = 0  # 0 => full-precision graph only
    pq_nbits: int = 8
    num_shards: Optional[int] = None  # default: one per live executor
    partitions_per_shard: int = 4
    include_vectors: bool = True
    sample_rate: float = 0.01
    # PQ codebooks train on this sample: too small a floor measurably hurts
    # ADC quality (EXPERIMENTS §1) — 8k ≈ 1% of the smallest bench corpus
    min_sample: int = 8192
    partition_mode: str = "centroid"  # centroid | file
    coordinator_probe_threshold_mb: float = 100.0  # paper §3.3
    oversample: int = 4  # paper §9.3
    build_passes: int = 2
    build_batch: int = 128


@dataclass
class BuildReport:
    puffin_path: str
    snapshot_id: int
    base_snapshot_id: int
    num_shards: int
    vector_count: int
    total_bytes: int
    stage0_seconds: float
    stage1_seconds: float
    stage2_seconds: float
    shard_results: List[F.IndexBuildResult] = field(default_factory=list)


@dataclass
class ProbeHit:
    file_path: str
    row_group: int
    row_offset: int
    distance: float


@dataclass
class ProbeReport:
    hits: List[List[ProbeHit]]  # per query
    strategy: str
    files_scanned: int
    bytes_read: int
    stage_a_seconds: float = 0.0
    stage_b_seconds: float = 0.0
    stage_c_seconds: float = 0.0
    shards_probed: int = 0
    cache_hits: int = 0
    # batched pipeline: how many queries rode this probe and how many
    # shard-probe fragments were actually dispatched after coalescing
    batch_size: int = 0
    probe_fragments: int = 0
    # filtered search: predicate pushed through the probe, zone-map pruning
    # effect, and the selectivity-adaptive plan that was chosen
    filtered: bool = False
    filter_plan: str = ""  # e.g. "prefilter:2,pruned:1"
    shards_pruned: int = 0
    # (query, shard) probe fragments dropped by zone pruning BEFORE
    # coalescing — the per-query signal; shards_pruned is the per-predicate
    # union of whole shards
    fragments_pruned: int = 0
    row_groups_pruned: int = 0
    est_selectivity: float = 1.0
    # masked top-k kernel calls summed over the probed shards: with the
    # mask-plane executor path a coalesced fragment costs ONE dispatch per
    # shard — the unified kernel fuses exact and PQ-ADC flavors — however
    # many distinct predicates the batch carries
    kernel_dispatches: int = 0
    # MaskedBeam accounting, summed over the probed shards: query rows
    # answered by the predicate-aware traversal (big-shard selective
    # filters), and how many of those under-delivered and were re-answered
    # by the fused exact-masked fallback — the bench bounds the fallback
    # rate so a "beam win" can't silently be the fallback doing the work
    masked_beam_rows: int = 0
    masked_beam_fallbacks: int = 0
    # the probe-plan IR artifact (runtime/planner.py ProbePlan): the
    # per-(query, shard) op grid the coordinator planned, loggable and
    # round-trippable via to_json/from_json.  None on unplanned paths
    # (scan/centroid, unfiltered single probes) — but ALWAYS present when a
    # fresh tail was served: the tail adds exactly one ExactScan op per
    # unindexed row group, keyed by its synthetic negative id.
    plan: Optional[ProbePlan] = None
    # fresh-tail tier: rows appended since the index's base snapshot that
    # this probe served through tail ExactScan ops ...
    tail_rows: int = 0
    # ... and rows the probe could NOT see.  The tail tier makes this an
    # invariant 0; it is nonzero only with ``include_tail=False`` (the
    # pre-fix silent-drop behavior, kept reachable for regression tests).
    unindexed_rows: int = 0
    # the probed snapshot serves a stale index binding (an append/delete
    # landed after the index was built and no refresh has committed since)
    stale: bool = False
    # serving-tier trail: which executor served each fragment of this probe
    # ("probe:<shard>@<executor>" for Stage A / tail fragments,
    # "rerank@<executor>" for Stage B) — the audit trail for lease failover
    served_by: List[str] = field(default_factory=list)
    # degradation labels the serving tier applied before issuing this probe
    # (e.g. "shrink_k(x0.5)", "skip_tail"); empty = full-quality answer.
    # The coordinator never sets this — the micro-batcher stamps it so
    # degraded answers are labeled, not silent.
    degraded: Tuple[str, ...] = ()
    # cache provenance: "shard" when at least one Stage-A fragment was
    # answered from the coordinator's snapshot-keyed shard-probe cache,
    # "semantic" on the report a semantic-cache entry carries; None means
    # the answer was fully computed.  (cache_hits above stays the
    # executor-local blob-cache count — a different layer.)
    cache: Optional[str] = None
    # snapshot the probe resolved its index binding against (None on the
    # scan path) — the serving tier's semantic cache watermarks on it
    snapshot_id: Optional[int] = None
    # (query, shard) Stage-A fragments served from the shard-probe cache,
    # skipping mask evaluation and the kernel dispatch for that fragment
    shard_cache_hits: int = 0


@dataclass
class RefreshReport:
    puffin_path: str
    snapshot_id: int
    base_snapshot_id: int
    inserted: int
    tombstoned: int
    shards_refreshed: int
    shards_rebuilt: int
    shards_reused: int
    seconds: float
    noop: bool = False


class Coordinator:
    def __init__(
        self,
        catalog: RestCatalog,
        pool: ExecutorPool,
        *,
        enable_speculation: bool = False,
        max_attempts: int = 4,
        metrics: Optional["MetricsRegistry"] = None,
        probe_cache: Optional[ShardProbeCache] = None,
    ) -> None:
        self.catalog = catalog
        self.store = catalog.store
        self.pool = pool
        # optional cross-batch Stage-A shard-probe cache (serving/cache.py);
        # None (the default) keeps every probe fully computed
        self.probe_cache = probe_cache
        # one serving-tier metrics registry shared with the scheduler and
        # its lease table: counters for re-dispatches, lease grants/expiries
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        if self.probe_cache is not None and self.probe_cache.metrics is None:
            self.probe_cache.metrics = self.metrics
        self.scheduler = Scheduler(
            pool,
            enable_speculation=enable_speculation,
            max_attempts=max_attempts,
            metrics=self.metrics,
        )
        # serving-tier result caches subscribed for push invalidation: a
        # refresh/compaction commit moves their snapshot watermark at the
        # commit itself — the pull path (watermarking drained probe
        # reports) only fires after a probe, which would leave a window
        # where a cached whole answer for the old snapshot still serves
        self._result_caches: Dict[str, List[object]] = {}
        # decoded attribute zone maps, keyed by (immutable) puffin path —
        # filtered probes on the serving path must not re-decode the blob
        self._zonemap_cache: Dict[str, Optional[AttrZoneMap]] = {}
        # decoded fresh-tail manifests, keyed by (immutable) tail puffin path
        self._tail_cache: Dict[str, FreshTail] = {}

    # ---------------------------------------------------------- invalidation
    def register_result_cache(self, table_name: str, cache: object) -> None:
        """Subscribe a result cache (anything with ``observe_snapshot``) to
        commit-time invalidation for ``table_name``.  Idempotent."""
        subscribed = self._result_caches.setdefault(table_name, [])
        if not any(rc is cache for rc in subscribed):
            subscribed.append(cache)

    def unregister_result_cache(self, table_name: str, cache: object) -> None:
        subscribed = self._result_caches.get(table_name, [])
        self._result_caches[table_name] = [rc for rc in subscribed if rc is not cache]

    def _invalidate_caches(self, table_name: str, new_snapshot_id: int) -> None:
        """The commit is the invalidation token: drop shard-probe entries
        keyed by any older snapshot and move every subscribed result
        cache's watermark, so neither layer can serve a pre-commit answer."""
        if self.probe_cache is not None:
            self.probe_cache.invalidate(table_name, new_snapshot_id)
        for rc in self._result_caches.get(table_name, ()):
            rc.observe_snapshot(new_snapshot_id)

    # ------------------------------------------------------------------ build
    def create_index(self, table_name: str, cfg: IndexConfig) -> BuildReport:
        table = LakehouseTable(self.catalog, table_name)
        meta = table.metadata()
        snap = meta.current_snapshot()
        if snap is None:
            raise ValueError(f"table {table_name} has no snapshot")
        files = [f.path for f in table.current_files()]
        if not files:
            raise ValueError(f"table {table_name} has no data files")
        live = self.pool.live()
        num_shards = cfg.num_shards or max(1, len(live))

        # ---- Stage 0: sampling + centroid training (coordinator) --------
        t0 = time.time()
        sample = self._sample_vectors(table, files, cfg)
        k = num_shards * cfg.partitions_per_shard
        k = min(k, max(1, sample.shape[0] // 4))
        centroids, _ = train_kmeans(sample, k, iters=15, seed=0)
        shard_of_partition = self._pack_partitions(sample, centroids, num_shards)
        pq_codebook = None
        if cfg.pq_m:
            pq_codebook = train_pq(
                sample, m=cfg.pq_m, nbits=cfg.pq_nbits, metric=cfg.metric
            ).codebook
        stage0 = time.time() - t0

        # ---- Stage 1: parallel shard build (executors) --------------------
        t1 = time.time()
        token = uuid.uuid4().hex[:8]
        out_prefix = f"{meta.location}/metadata/ann-{cfg.name}-snap-{snap.snapshot_id}-{token}"
        build_tasks: List[F.IndexBuildTaskInfo] = []
        if cfg.partition_mode == "centroid":
            exchanged = self._exchange(files, centroids, shard_of_partition, num_shards)
            for sid in range(num_shards):
                payload = exchanged.get(sid)
                if payload is None:
                    continue
                build_tasks.append(
                    F.IndexBuildTaskInfo(
                        task_id=f"build-{cfg.name}-{sid}",
                        shard_id=sid,
                        assigned_files=[],
                        partition_centroids=centroids,
                        shard_of_partition=shard_of_partition,
                        R=cfg.R,
                        L=cfg.L,
                        alpha=cfg.alpha,
                        metric=cfg.metric,
                        pq_m=cfg.pq_m,
                        pq_nbits=cfg.pq_nbits,
                        pq_codebook=pq_codebook,
                        include_vectors=cfg.include_vectors,
                        output_path=f"{out_prefix}-shard-{sid}.blob",
                        partition_mode=cfg.partition_mode,
                        build_passes=cfg.build_passes,
                        build_batch=cfg.build_batch,
                        exchanged=payload,
                    )
                )
        else:  # file mode: each shard owns a file subset, no exchange
            file_groups = [list(files[i::num_shards]) for i in range(num_shards)]
            for sid, group in enumerate(file_groups):
                if not group:
                    continue
                build_tasks.append(
                    F.IndexBuildTaskInfo(
                        task_id=f"build-{cfg.name}-{sid}",
                        shard_id=sid,
                        assigned_files=group,
                        partition_centroids=centroids,
                        shard_of_partition=shard_of_partition,
                        R=cfg.R,
                        L=cfg.L,
                        alpha=cfg.alpha,
                        metric=cfg.metric,
                        pq_m=cfg.pq_m,
                        pq_nbits=cfg.pq_nbits,
                        pq_codebook=pq_codebook,
                        include_vectors=cfg.include_vectors,
                        output_path=f"{out_prefix}-shard-{sid}.blob",
                        partition_mode="file",
                        build_passes=cfg.build_passes,
                        build_batch=cfg.build_batch,
                    )
                )
        results: List[F.IndexBuildResult] = self.scheduler.run_wave(build_tasks)
        stage1 = time.time() - t1

        # ---- Stage 2: assemble Puffin + commit (coordinator) -----------------
        t2 = time.time()
        centroid_index = build_centroid_index(table, metric=cfg.metric)
        zonemap = build_zonemap(self.store, files)
        if zonemap is not None:
            zonemap.shard_membership = {
                r.shard_id: r.rg_membership for r in results if r.rg_membership
            }
        puffin_path, total_bytes = self._assemble_puffin(
            meta,
            snap,
            cfg,
            centroids,
            shard_of_partition,
            results,
            centroid_index,
            files,
            out_prefix,
            zonemap=zonemap,
        )
        new_meta = self.catalog.set_statistics_file(
            table_name,
            puffin_path,
            expected_base_snapshot_id=snap.snapshot_id,
            extra_summary={
                "ann.index-name": cfg.name,
                "ann.base-snapshot-id": str(snap.snapshot_id),
                "ann.num-shards": str(len(results)),
            },
        )
        # CREATE INDEX commits a new snapshot too — same invalidation flow
        self._invalidate_caches(table_name, new_meta.current_snapshot_id)
        stage2 = time.time() - t2
        return BuildReport(
            puffin_path=puffin_path,
            snapshot_id=new_meta.current_snapshot_id,
            base_snapshot_id=snap.snapshot_id,
            num_shards=len(results),
            vector_count=sum(r.vector_count for r in results),
            total_bytes=total_bytes,
            stage0_seconds=stage0,
            stage1_seconds=stage1,
            stage2_seconds=stage2,
            shard_results=results,
        )

    # -- Stage-0 helpers ------------------------------------------------------
    def _sample_vectors(
        self, table: LakehouseTable, files: List[str], cfg: IndexConfig
    ) -> np.ndarray:
        rng = np.random.default_rng(0)
        order = rng.permutation(len(files))
        total_rows = 0
        parts: List[np.ndarray] = []
        for fi in order:
            reader = table.reader(files[fi])
            parts.append(reader.read_column("vec"))
            total_rows += parts[-1].shape[0]
            if total_rows >= cfg.min_sample / max(cfg.sample_rate, 1e-9) * cfg.sample_rate and len(
                parts
            ) >= max(1, int(0.1 * len(files))):
                break
        vecs = np.concatenate(parts)
        want = max(cfg.min_sample, int(cfg.sample_rate * vecs.shape[0]))
        if vecs.shape[0] > want:
            vecs = vecs[rng.choice(vecs.shape[0], want, replace=False)]
        return vecs

    def _pack_partitions(
        self, sample: np.ndarray, centroids: np.ndarray, num_shards: int
    ) -> np.ndarray:
        """Greedy bin-pack partitions onto shards by sampled mass."""
        from repro.core.kmeans import assign

        part = assign(sample, centroids)
        counts = np.bincount(part, minlength=centroids.shape[0])
        shard_of = np.zeros(centroids.shape[0], np.uint32)
        loads = [(0, s) for s in range(num_shards)]
        heapq.heapify(loads)
        for p in np.argsort(-counts):
            load, s = heapq.heappop(loads)
            shard_of[p] = s
            heapq.heappush(loads, (load + int(counts[p]), s))
        return shard_of

    def _exchange(
        self,
        files: List[str],
        centroids: np.ndarray,
        shard_of_partition: np.ndarray,
        num_shards: int,
    ) -> Dict[int, tuple]:
        """Stage-1a all-to-all: executors scan their file subsets and group
        vectors by owner shard; the coordinator merges the groups."""
        live = self.pool.live()
        n_scan = max(1, len(live))
        scan_tasks = [
            F.ScanPartitionTaskInfo(
                task_id=f"scan-{i}",
                assigned_files=list(files[i::n_scan]),
                partition_centroids=centroids,
                shard_of_partition=shard_of_partition,
                num_shards=num_shards,
            )
            for i in range(n_scan)
            if files[i::n_scan]
        ]
        scan_results: List[F.ScanPartitionResult] = self.scheduler.run_wave(scan_tasks)
        merged: Dict[int, tuple] = {}
        for sid in range(num_shards):
            vec_parts, fidx_parts, rg_parts, ro_parts, paths = [], [], [], [], []
            for res in scan_results:
                if sid not in res.per_shard:
                    continue
                v, fi, rg, ro, p = res.per_shard[sid]
                base = len(paths)
                paths.extend(p)
                vec_parts.append(v)
                fidx_parts.append(fi.astype(np.uint32) + base)
                rg_parts.append(rg)
                ro_parts.append(ro)
            if vec_parts:
                merged[sid] = (
                    np.concatenate(vec_parts),
                    np.concatenate(fidx_parts),
                    np.concatenate(rg_parts),
                    np.concatenate(ro_parts),
                    paths,
                )
        return merged

    # -- Stage-2 helpers ----------------------------------------------------------
    def _assemble_puffin(
        self,
        meta: TableMetadata,
        snap: Snapshot,
        cfg: IndexConfig,
        centroids: np.ndarray,
        shard_of_partition: np.ndarray,
        results: List[F.IndexBuildResult],
        centroid_index: CentroidIndex,
        covered_files: List[str],
        out_prefix: str,
        tombstone_ratios: Optional[Dict[int, float]] = None,
        raw_shard_bytes: Optional[Dict[int, bytes]] = None,
        zonemap: Optional[AttrZoneMap] = None,
    ) -> Tuple[str, int]:
        writer = PuffinWriter(
            file_properties={
                "created-by": "repro-flockdb",
                "ann.index-name": cfg.name,
            }
        )
        ratios = tombstone_ratios or {}
        shards = [
            ShardInfo(
                shard_id=r.shard_id,
                blob_index=2 + i,  # 0 = routing, 1 = centroid index
                vector_count=r.vector_count,
                byte_size=r.byte_size,
                tombstone_ratio=ratios.get(r.shard_id, 0.0),
                executor_hint=r.executor_id,
            )
            for i, r in enumerate(results)
        ]
        routing = RoutingTable(
            base_snapshot_id=snap.snapshot_id,
            dims=centroids.shape[1],
            metric=cfg.metric,
            params={
                "R": str(cfg.R),
                "L": str(cfg.L),
                "alpha": str(cfg.alpha),
                "pq_m": str(cfg.pq_m),
                "pq_nbits": str(cfg.pq_nbits),
                "oversample": str(cfg.oversample),
                "include_vectors": str(cfg.include_vectors),
                "partition_mode": cfg.partition_mode,
            },
            shards=shards,
            covered_files=covered_files,
            partition_centroids=centroids,
            shard_of_partition=shard_of_partition,
        )
        writer.add_blob(
            encode_routing_blob(routing),
            type=ROUTING_BLOB_TYPE,
            snapshot_id=snap.snapshot_id,
            properties={"ann.index-name": cfg.name},
        )
        writer.add_blob(
            centroid_index.to_blob(),
            type=CENTROID_BLOB_TYPE,
            snapshot_id=snap.snapshot_id,
            # zstd when available, zlib otherwise — the footer records the
            # codec actually applied, so readers stay environment-agnostic
            compression=preferred_codec(),
            properties={
                "dimensions": str(centroid_index.dim),
                "metric": cfg.metric,
                "entry-count": str(centroid_index.num_files),
                "computed-against-snapshot": str(snap.snapshot_id),
            },
        )
        for r in results:
            if raw_shard_bytes and r.shard_id in raw_shard_bytes:
                payload = raw_shard_bytes[r.shard_id]
            else:
                payload = self.store.get(r.output_path)
            writer.add_blob(
                payload,
                type=SHARD_BLOB_TYPE,
                snapshot_id=snap.snapshot_id,
                properties={
                    "shard-id": str(r.shard_id),
                    "vector-count": str(r.vector_count),
                    "tombstone-ratio": f"{ratios.get(r.shard_id, 0.0):.6f}",
                },
            )
        if zonemap is not None:
            # appended AFTER the shard blobs so ShardInfo.blob_index stays
            # stable (0 = routing, 1 = centroid, 2.. = shards)
            writer.add_blob(
                encode_zonemap_blob(zonemap),
                type=ATTR_ZONEMAP_BLOB_TYPE,
                snapshot_id=snap.snapshot_id,
                properties={"columns": ",".join(sorted(zonemap.columns))},
            )
        data = writer.finish()
        puffin_path = f"{out_prefix}.puffin"
        self.store.put(puffin_path, data)
        # the standalone shard blobs are now redundant: orphaned + GC-able
        return puffin_path, len(data)

    # ------------------------------------------------------------------ probe
    def _resolve_index(
        self,
        table_name: str,
        snapshot_id: Optional[int] = None,
        as_of_ms: Optional[int] = None,
    ) -> Tuple[TableMetadata, Snapshot, str, PuffinReader]:
        meta = self.catalog.load_table(table_name)
        if as_of_ms is not None:
            snap = meta.snapshot_as_of(as_of_ms)
        elif snapshot_id is not None:
            snap = meta.snapshot_by_id(snapshot_id)
        else:
            snap = meta.current_snapshot()
        if snap is None:
            raise ValueError("no snapshot")
        # Resolution order: a freshly-bound index, else the stale binding
        # carried forward by append/delete commits (the index remains usable
        # but covers only the files live at its base snapshot — the paper's
        # freshness bound, §10 "update granularity is the snapshot").
        path = snap.statistics_file or snap.summary.get("ann.stale-statistics-file")
        if path is None:
            raise LookupError(f"snapshot {snap.snapshot_id} has no ANN index bound")
        reader = PuffinReader(self.store.stat(path).size, self.store.range_reader(path))
        return meta, snap, path, reader

    def _resolve_tail(self, snap: Snapshot) -> Optional[FreshTail]:
        """Fresh-tail manifest for ``snap``: non-None only when the snapshot
        serves a stale index binding (``statistics_file`` unset — a fresh
        index covers everything) and an append since the index's base
        snapshot recorded unindexed row groups.  Tail Puffin files are
        immutable, so the decode is cached per path."""
        if snap.statistics_file is not None:
            return None
        path = snap.summary.get("ann.fresh-tail-file")
        if path is None:
            return None
        tail = self._tail_cache.get(path)
        if tail is None:
            reader = PuffinReader(
                self.store.stat(path).size, self.store.range_reader(path)
            )
            tail = decode_fresh_tail_blob(reader.read_first(FRESH_TAIL_BLOB_TYPE))
            if len(self._tail_cache) >= 8:
                self._tail_cache.pop(next(iter(self._tail_cache)))
            self._tail_cache[path] = tail
        return tail if tail.entries else None

    def probe(
        self,
        table_name: str,
        queries: np.ndarray,
        k: int,
        *,
        strategy: str = "auto",
        n_probe: int = 16,
        snapshot_id: Optional[int] = None,
        as_of_ms: Optional[int] = None,
        use_pq: Optional[bool] = None,
        L: Optional[int] = None,
        filter: Optional[object] = None,
        include_tail: bool = True,
        scan_dtype: str = "f32",
    ) -> ProbeReport:
        """Vector top-k query.  ``strategy``: auto | diskann | centroid | scan.

        ``scan_dtype`` (``f32`` | ``bf16`` | ``int8``) selects the scoring
        precision of planner-emitted ExactScan ops; reduced-precision scans
        always restore full-precision distances through the gather-rerank
        guard (planner.quant_guard_pool), so only Stage-A scan bandwidth —
        not the returned distances — is quantized.

        ``filter`` pushes an attribute predicate (a
        :class:`repro.runtime.predicates.Predicate` or a SQL WHERE fragment
        string) through the probe: results are the top-k among rows
        satisfying it.  ``strategy="scan"`` with a filter is the brute-force
        post-filter oracle.

        ``include_tail=False`` disables the fresh-tail tier: rows appended
        since the index's base snapshot are silently dropped (the pre-fix
        behavior) and surface as ``ProbeReport.unindexed_rows`` instead."""
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        pred = self._coerce_filter(filter)
        self.store.metrics.reset()
        table = LakehouseTable(self.catalog, table_name)
        if strategy == "scan":
            # reads the snapshot's own file list — fresh by construction
            return self._probe_scan(table, queries, k, snapshot_id, pred=pred)
        meta, snap, puffin_path, reader = self._resolve_index(
            table_name, snapshot_id, as_of_ms
        )
        full_tail = self._resolve_tail(snap)
        tail = full_tail if include_tail else None
        routing = decode_routing_blob(reader.read_first(ROUTING_BLOB_TYPE))
        shard_blobs = reader.blobs_of_type(SHARD_BLOB_TYPE)
        strategy = self._choose_strategy(strategy, routing, shard_blobs)
        if strategy == "centroid":
            report = self._probe_centroid(
                table, reader, queries, k, n_probe, pred=pred,
                puffin_path=puffin_path, tail=tail,
            )
        else:
            report = self._probe_diskann(
                table,
                routing,
                shard_blobs,
                puffin_path,
                queries,
                k,
                use_pq=use_pq,
                L=L,
                pred=pred,
                zonemap=(
                    self._read_zonemap(reader, puffin_path) if pred is not None else None
                ),
                tail=tail,
                scan_dtype=scan_dtype,
            )
        self._apply_tail_report(report, snap, full_tail, served=tail is not None)
        return report

    @staticmethod
    def _apply_tail_report(
        report: ProbeReport,
        snap: Snapshot,
        full_tail: Optional[FreshTail],
        served: bool,
    ) -> None:
        """Freshness accounting, uniform across index-backed probe paths:
        every appended-but-unindexed row is either served through the tail
        tier (``tail_rows``) or dropped (``unindexed_rows`` — nonzero only
        with ``include_tail=False``)."""
        report.stale = snap.statistics_file is None
        if full_tail is None:
            return
        if served:
            report.tail_rows = full_tail.total_rows
        else:
            report.unindexed_rows = full_tail.total_rows

    @staticmethod
    def _choose_strategy(strategy: str, routing: RoutingTable, shard_blobs) -> str:
        """Tiered placement (paper §3.3): large sharded indexes go to
        executors; otherwise coordinator-local centroid probing.  The
        decision is per-index, so one evaluation covers a whole batch."""
        if strategy != "auto":
            return strategy
        threshold = 100.0 * 1024 * 1024
        if shard_blobs and sum(b.length for b in shard_blobs) > 0:
            total = sum(b.length for b in shard_blobs)
            strategy = "diskann" if total > 0 else "centroid"
            # small graphs still probe distributed if present; centroid
            # path is chosen when only the centroid blob exists or the
            # index is tiny enough to fit the coordinator budget.
            if total <= threshold and not routing.shards:
                strategy = "centroid"
        else:
            strategy = "centroid"
        return strategy

    # -- filtered-search planning ------------------------------------------
    @staticmethod
    def _coerce_filter(filter: Optional[object]) -> Optional[Predicate]:
        if filter is None or isinstance(filter, Predicate):
            return filter
        if isinstance(filter, str):
            return parse_predicate(filter)
        raise TypeError(f"filter must be a Predicate or SQL fragment, got {type(filter)}")

    def _read_zonemap(
        self, reader: PuffinReader, puffin_path: Optional[str] = None
    ) -> Optional[AttrZoneMap]:
        """Decode the index's zone-map blob, cached per puffin path (index
        Puffin files are immutable, so the decoded map never goes stale)."""
        if puffin_path is not None and puffin_path in self._zonemap_cache:
            return self._zonemap_cache[puffin_path]
        metas = reader.blobs_of_type(ATTR_ZONEMAP_BLOB_TYPE)
        zm = decode_zonemap_blob(reader.read_blob(metas[0])) if metas else None
        if puffin_path is not None:
            if len(self._zonemap_cache) >= 8:
                self._zonemap_cache.pop(next(iter(self._zonemap_cache)))
            self._zonemap_cache[puffin_path] = zm
        return zm

    @staticmethod
    def _plan_summary(ops: Dict[int, PlanOp], pruned: List[int]) -> str:
        """Token:count summary of one predicate's per-shard ops, in the
        historical prefilter/mask/postfilter vocabulary."""
        counts: Dict[str, int] = {}
        for op in ops.values():
            tok = planner.op_token(op)
            counts[tok] = counts.get(tok, 0) + 1
        parts = [f"{m}:{c}" for m, c in sorted(counts.items())]
        if pruned:
            parts.append(f"pruned:{len(pruned)}")
        return ",".join(parts)

    @staticmethod
    def _tail_only_plan(
        tail: Optional[FreshTail], k: int, batch: int
    ) -> Optional[ProbePlan]:
        """Descriptive plan for the coordinator-local (centroid) path: the
        centroid rerank is exact over every routed row, so the only IR worth
        recording is the tail tier — one ExactScan per unindexed row group,
        same synthetic ids as the distributed path."""
        if tail is None:
            return None
        tail_ops = planner.plan_tail(
            [cnt for _, _, cnt in tail.row_group_list()], k=k, oversample=1
        )
        return ProbePlan(
            k=k,
            oversample=1,
            use_pq=False,
            ops=[dict(tail_ops) for _ in range(batch)],
            est_selectivity=1.0,
            pruned_shards=(),
        )

    def _refresh_zonemap(
        self, reader: PuffinReader, puffin_path: str, covered: List[str]
    ) -> Optional[AttrZoneMap]:
        """Zone map for a refreshed index: reuse the prior map's zones for
        files it already covers (data files are immutable) and scan only the
        files it has never seen."""
        prior = self._read_zonemap(reader, puffin_path)
        if prior is None:
            return build_zonemap(self.store, covered)
        missing = [fp for fp in covered if fp not in prior.zones]
        fresh = build_zonemap(self.store, missing) if missing else None
        columns = dict(prior.columns)
        zones = {fp: prior.zones[fp] for fp in covered if fp in prior.zones}
        if fresh is not None:
            columns.update(fresh.columns)
            zones.update(fresh.zones)
        if not columns:
            return None
        return AttrZoneMap(columns=columns, zones=zones)

    def _filtered_masks(
        self,
        table: LakehouseTable,
        files: Sequence[str],
        pred: Optional[Predicate],
        zonemap: Optional[AttrZoneMap] = None,
    ) -> Tuple[Dict[str, Dict[int, List[int]]], int]:
        """Coordinator-side row masks for the scan/centroid paths: per file
        and row group, the offsets passing ``pred`` (all offsets when no
        predicate).  Zone maps skip row groups that cannot match before any
        attribute column is read.  Returns (masks, row_groups_pruned)."""
        masks: Dict[str, Dict[int, List[int]]] = {}
        rg_pruned = 0
        for fp in files:
            r = table.reader(fp)
            zones = zonemap.zones.get(fp) if zonemap is not None else None
            groups: Dict[int, List[int]] = {}
            for rg in range(len(r.row_groups)):
                if pred is not None and zones is not None and rg < len(zones):
                    if not pred.zone_may_match(zones[rg]):
                        rg_pruned += 1
                        continue
                if pred is None:
                    groups[rg] = list(range(r.row_groups[rg]["num_rows"]))
                else:
                    offs = np.flatnonzero(row_group_mask(pred, r, rg))
                    if len(offs):
                        groups[rg] = [int(o) for o in offs]
            if groups:
                masks[fp] = groups
        return masks, rg_pruned

    def probe_batch(
        self,
        table_name: str,
        queries: np.ndarray,
        k: int,
        *,
        strategy: str = "auto",
        n_probe: int = 16,
        snapshot_id: Optional[int] = None,
        as_of_ms: Optional[int] = None,
        use_pq: Optional[bool] = None,
        L: Optional[int] = None,
        n_route: Optional[int] = None,
        filter: Optional[object] = None,
        include_tail: bool = True,
        oversample: Optional[int] = None,
        replay_plan: Optional[ProbePlan] = None,
        scan_dtype: str = "f32",
    ) -> ProbeReport:
        """Batched vector top-k over ``queries (B, dim)``.

        Semantics match ``[probe(q) for q in queries]`` exactly, but the
        whole batch moves through the pipeline together: routing and tiered
        placement are vectorized, the scheduler coalesces shard probes to at
        most one fragment per shard, executors answer all of a fragment's
        queries with batched kernels, and Stage B reads the union of the
        batch's candidate rows once (per-query ownership keeps results
        independent).  ``n_route`` optionally restricts each query to the
        shards owning its ``n_route`` nearest partitions (recall dial; the
        default probes every shard, preserving exact parity with ``probe``).

        ``oversample`` overrides the index's configured Stage-B rerank
        multiplier for this probe (the serving tier's DropOversample
        degradation step); ``None`` keeps the routing-table value.

        ``replay_plan`` replays a previously planned (possibly deserialized
        — ``ProbePlan.from_json``) per-(query, shard) op grid: the
        coordinator skips selectivity estimation and plan construction
        entirely and dispatches the plan's ops as-is.  The caller must pass
        the same ``filter`` the plan was built under (executors still need
        the predicates to build row masks); fresh-tail ops are re-planned
        against the CURRENT tail, since the tail may have grown or been
        compacted since the plan was captured.  Only the diskann strategy
        is plannable."""
        queries = np.atleast_2d(np.asarray(queries, np.float32))
        B = queries.shape[0]
        preds = self._coerce_filters_batch(filter, B)
        self.store.metrics.reset()
        table = LakehouseTable(self.catalog, table_name)
        if replay_plan is not None and strategy in ("scan", "centroid"):
            raise ValueError(f"replay_plan is not supported for strategy={strategy!r}")
        if strategy == "scan":
            if preds is None or len(set(preds)) == 1:
                report = self._probe_scan(
                    table, queries, k, snapshot_id, pred=preds[0] if preds else None
                )
            else:
                report = self._grouped_filtered(
                    lambda q, p: self._probe_scan(table, q, k, snapshot_id, pred=p),
                    queries,
                    preds,
                )
            report.batch_size = B
            return report
        meta, snap, puffin_path, reader = self._resolve_index(
            table_name, snapshot_id, as_of_ms
        )
        full_tail = self._resolve_tail(snap)
        tail = full_tail if include_tail else None
        routing = decode_routing_blob(reader.read_first(ROUTING_BLOB_TYPE))
        shard_blobs = reader.blobs_of_type(SHARD_BLOB_TYPE)
        strategy = self._choose_strategy(strategy, routing, shard_blobs)
        if replay_plan is not None and strategy != "diskann":
            raise ValueError(f"replay_plan is not supported for strategy={strategy!r}")
        if strategy == "centroid":
            if preds is None or len(set(preds)) == 1:
                report = self._probe_centroid_batch(
                    table, reader, queries, k, n_probe,
                    pred=preds[0] if preds else None, puffin_path=puffin_path,
                    tail=tail,
                )
            else:
                # per-group batches keep per-query file ownership, so mixed
                # filters still return exactly the sequential probes' hits
                report = self._grouped_filtered(
                    lambda q, p: self._probe_centroid_batch(
                        table, reader, q, k, n_probe, pred=p,
                        puffin_path=puffin_path, tail=tail,
                    ),
                    queries,
                    preds,
                )
        else:
            report = self._probe_diskann_batch(
                table,
                routing,
                reader,
                puffin_path,
                queries,
                k,
                use_pq=use_pq,
                L=L,
                n_route=n_route,
                preds=preds,
                zonemap=(
                    self._read_zonemap(reader, puffin_path)
                    if preds and replay_plan is None
                    else None
                ),
                tail=tail,
                scan_dtype=scan_dtype,
                oversample_override=oversample,
                replay_plan=replay_plan,
                cache_ctx=(
                    (table_name, snap.snapshot_id)
                    if self.probe_cache is not None
                    else None
                ),
            )
        self._apply_tail_report(report, snap, full_tail, served=tail is not None)
        report.batch_size = B
        report.snapshot_id = snap.snapshot_id
        return report

    def _coerce_filters_batch(
        self, filter: Optional[object], batch_size: int
    ) -> Optional[List[Optional[Predicate]]]:
        """Normalize probe_batch's ``filter`` argument: a single predicate
        (or WHERE string) fans out to every query; a sequence is per-query,
        ``None`` entries meaning that query is unfiltered."""
        if filter is None:
            return None
        if isinstance(filter, (Predicate, str)):
            return [self._coerce_filter(filter)] * batch_size
        preds = [self._coerce_filter(f) for f in filter]
        if len(preds) != batch_size:
            raise ValueError(f"{len(preds)} filters for {batch_size} queries")
        return None if all(p is None for p in preds) else preds

    def _grouped_filtered(
        self,
        fn,
        queries: np.ndarray,
        preds: List[Optional[Predicate]],
    ) -> ProbeReport:
        """Stitch heterogeneous-filter batches on paths whose masks are
        coordinator-computed (scan/centroid): one sub-probe per distinct
        predicate, hits re-interleaved into batch order, I/O stats summed."""
        groups: Dict[Optional[Predicate], List[int]] = {}
        for qi, p in enumerate(preds):
            groups.setdefault(p, []).append(qi)
        hits: List[Optional[List[ProbeHit]]] = [None] * len(preds)
        out: Optional[ProbeReport] = None
        for p, rows in groups.items():
            rep = fn(queries[rows], p)
            for j, qi in enumerate(rows):
                hits[qi] = rep.hits[j]
            if out is None:
                out = rep
            else:
                out.files_scanned += rep.files_scanned
                out.stage_a_seconds += rep.stage_a_seconds
                out.stage_b_seconds += rep.stage_b_seconds
                out.stage_c_seconds += rep.stage_c_seconds
                out.shards_probed += rep.shards_probed
                out.probe_fragments += rep.probe_fragments
                out.shards_pruned += rep.shards_pruned
                out.fragments_pruned += rep.fragments_pruned
                out.row_groups_pruned += rep.row_groups_pruned
                out.kernel_dispatches += rep.kernel_dispatches
                out.masked_beam_rows += rep.masked_beam_rows
                out.masked_beam_fallbacks += rep.masked_beam_fallbacks
        assert out is not None
        out.hits = hits
        # per-group bytes_read snapshots are cumulative since the batch's
        # reset — the final snapshot is the batch total
        out.bytes_read = self.store.metrics.bytes_read
        out.filtered = any(p is not None for p in preds)
        return out

    def _probe_scan(
        self,
        table: LakehouseTable,
        queries: np.ndarray,
        k: int,
        snapshot_id=None,
        pred: Optional[Predicate] = None,
    ) -> ProbeReport:
        """No-index baseline (paper Table 2 column 1): full scan + exact.
        With ``pred`` this is the brute-force post-filter oracle: every
        passing row is exact-ranked, so the result is the true filtered
        top-k."""
        t0 = time.time()
        files = [f.path for f in table.current_files(snapshot_id)]
        masks, _ = self._filtered_masks(table, files, pred)
        report = self._rerank_and_merge(table, masks, queries, k, "l2")
        report.strategy = "scan"
        report.files_scanned = len(files)
        report.stage_b_seconds = time.time() - t0
        report.bytes_read = self.store.metrics.bytes_read
        report.filtered = pred is not None
        return report

    def _probe_centroid(
        self,
        table: LakehouseTable,
        reader: PuffinReader,
        queries: np.ndarray,
        k: int,
        n_probe: int,
        pred: Optional[Predicate] = None,
        puffin_path: Optional[str] = None,
        tail: Optional[FreshTail] = None,
    ) -> ProbeReport:
        """Coordinator-tier probe (paper Table 2 column 2): prune the file
        list with the centroid index, then exact-rerank only those files.
        With a predicate the masks keep only passing rows, and the zone map
        (when the index carries one) skips row groups that cannot match.
        Fresh-tail files (appended since the index's base snapshot — the
        centroid index has never seen them) join every query's file list."""
        t0 = time.time()
        ci = CentroidIndex.from_blob(reader.read_first(CENTROID_BLOB_TYPE))
        pruned: List[str] = []
        per_query_files: List[List[str]] = []
        for q in queries:
            fl = ci.probe_topk(q, n_probe)
            per_query_files.append(fl)
            pruned.extend(fl)
        if tail is not None:
            pruned.extend(e.file_path for e in tail.entries)
        pruned = sorted(set(pruned))
        stage_a = time.time() - t0
        zonemap = self._read_zonemap(reader, puffin_path) if pred is not None else None
        masks, rg_pruned = self._filtered_masks(table, pruned, pred, zonemap)
        report = self._rerank_and_merge(table, masks, queries, k, ci.metric)
        report.strategy = "centroid"
        report.files_scanned = len(pruned)
        report.plan = self._tail_only_plan(tail, k, queries.shape[0])
        report.stage_a_seconds = stage_a
        report.bytes_read = self.store.metrics.bytes_read
        report.filtered = pred is not None
        report.row_groups_pruned = rg_pruned
        return report

    def _probe_centroid_batch(
        self,
        table: LakehouseTable,
        reader: PuffinReader,
        queries: np.ndarray,
        k: int,
        n_probe: int,
        pred: Optional[Predicate] = None,
        puffin_path: Optional[str] = None,
        tail: Optional[FreshTail] = None,
    ) -> ProbeReport:
        """Batched coordinator-tier probe: ONE vectorized centroid-routing
        pass produces every query's file list; the union of those files is
        read and reranked once, with per-file ownership keeping each query's
        result set identical to its sequential probe.  ``pred`` (shared by
        the whole batch on this path) restricts masks to passing rows.
        Fresh-tail files are owned by every query of the batch."""
        t0 = time.time()
        ci = CentroidIndex.from_blob(reader.read_first(CENTROID_BLOB_TYPE))
        per_query_files = ci.probe_topk_batch(queries, n_probe)
        file_owners: Dict[str, set] = {}
        for qi, fl in enumerate(per_query_files):
            for fp in fl:
                file_owners.setdefault(fp, set()).add(qi)
        if tail is not None:
            everyone = set(range(queries.shape[0]))
            for e in tail.entries:
                file_owners.setdefault(e.file_path, set()).update(everyone)
        pruned = sorted(file_owners)
        stage_a = time.time() - t0
        zonemap = self._read_zonemap(reader, puffin_path) if pred is not None else None
        masks, rg_pruned = self._filtered_masks(table, pruned, pred, zonemap)
        report = self._rerank_and_merge(
            table, masks, queries, k, ci.metric, file_owners=file_owners
        )
        report.strategy = "centroid"
        report.files_scanned = len(pruned)
        report.plan = self._tail_only_plan(tail, k, queries.shape[0])
        report.stage_a_seconds = stage_a
        report.bytes_read = self.store.metrics.bytes_read
        report.filtered = pred is not None
        report.row_groups_pruned = rg_pruned
        return report

    def _probe_diskann(
        self,
        table: LakehouseTable,
        routing: RoutingTable,
        shard_blobs,
        puffin_path: str,
        queries: np.ndarray,
        k: int,
        *,
        use_pq: Optional[bool] = None,
        L: Optional[int] = None,
        pred: Optional[Predicate] = None,
        zonemap: Optional[AttrZoneMap] = None,
        tail: Optional[FreshTail] = None,
        scan_dtype: str = "f32",
    ) -> ProbeReport:
        """Three-stage distributed probe (paper §6, Figure 3).  With a
        predicate, the zone map first prunes shards whose member row groups
        cannot match, then every surviving shard searches under its
        selectivity-adaptive plan.  A fresh tail adds one ExactScan fragment
        per unindexed row group to the same Stage-A wave; its exact hits
        merge with the graph candidates under the shared sentinel contract."""
        oversample = int(routing.params.get("oversample", "4"))
        if use_pq is None:
            use_pq = int(routing.params.get("pq_m", "0")) > 0
        L_eff = L or int(routing.params.get("L", "100"))
        ops: Dict[int, PlanOp] = {}
        pruned: List[int] = []
        est_frac = 1.0
        plan: Optional[ProbePlan] = None
        if pred is not None:
            ops, pruned, est_frac = planner.plan_filtered(
                pred, zonemap, routing, k=k, oversample=oversample,
                use_pq=use_pq, scan_dtype=scan_dtype,
            )
        tail_list = tail.row_group_list() if tail is not None else []
        tail_ops: Dict[int, PlanOp] = (
            planner.plan_tail(
                [cnt for _, _, cnt in tail_list],
                k=k,
                oversample=oversample,
                est_frac=est_frac,
            )
            if tail_list
            else {}
        )
        if pred is not None or tail_ops:
            plan_row = dict(ops)
            plan_row.update({sid: planner.Skip() for sid in pruned})
            plan_row.update(tail_ops)
            plan = ProbePlan(
                k=k,
                oversample=oversample,
                use_pq=use_pq,
                ops=[plan_row],
                est_selectivity=est_frac,
                pruned_shards=tuple(pruned),
            )
        # ---- Stage A: parallel shard beam search -------------------------
        t0 = time.time()
        blob_by_index = {i: b for i, b in enumerate(PuffinReader(
            self.store.stat(puffin_path).size, self.store.range_reader(puffin_path)
        ).blobs)}
        tasks = []
        for s in routing.shards:
            if pred is not None and s.shard_id not in ops:
                continue  # zone-pruned
            b = blob_by_index[s.blob_index]
            tasks.append(
                F.ProbeTaskInfo(
                    task_id=f"probe-{s.shard_id}",
                    cache_key=f"{puffin_path}#shard{s.shard_id}",
                    shard_id=s.shard_id,
                    puffin_path=puffin_path,
                    blob_offset=b.offset,
                    blob_length=b.length,
                    blob_codec=b.compression_codec,
                    queries=queries,
                    k=k,
                    L=L_eff,
                    use_pq=use_pq,
                    oversample=oversample,
                    predicate=pred,
                    plan_op=ops.get(s.shard_id),
                )
            )
        Q = queries.shape[0]
        tail_tasks = self._tail_tasks(
            tail_list,
            tail_ops,
            queries,
            np.arange(Q, dtype=np.int64),
            k=k,
            oversample=oversample,
            metric=routing.metric,
            filters=[pred] * Q if pred is not None else None,
        )
        results = self.scheduler.run_wave(tasks + tail_tasks)
        probe_results: List[F.ProbeResult] = results[: len(tasks)]
        tail_results: List[F.BatchProbeResult] = results[len(tasks):]
        stage_a = time.time() - t0
        # ---- merge + Stage B: exact rerank on row-group masks ---------------
        t1 = time.time()
        keep = k * oversample
        merged: List[List[F.ProbeCandidate]] = []
        for qi in range(Q):
            cands: List[F.ProbeCandidate] = []
            for r in probe_results:
                cands.extend(r.candidates[qi])
            for r in tail_results:
                cands.extend(r.candidates.get(qi, []))
            cands.sort(key=lambda c: c.approx_distance)
            merged.append(cands[:keep])
        masks: Dict[str, Dict[int, set]] = {}
        for qi in range(Q):
            for c in merged[qi]:
                masks.setdefault(c.file_path, {}).setdefault(c.row_group, set()).add(
                    c.row_offset
                )
        masks_l = {
            fp: {rg: sorted(rows) for rg, rows in groups.items()}
            for fp, groups in masks.items()
        }
        report = self._rerank_and_merge(table, masks_l, queries, k, routing.metric)
        report.strategy = "diskann"
        report.served_by = [
            f"probe:{r.shard_id}@{r.executor_id}" for r in results
        ] + report.served_by
        report.files_scanned = len(masks_l)
        report.stage_a_seconds = stage_a
        report.stage_b_seconds = time.time() - t1 - report.stage_c_seconds
        report.shards_probed = len(tasks)
        report.cache_hits = sum(1 for r in probe_results if r.cache_hit)
        report.kernel_dispatches = sum(r.kernel_dispatches for r in results)
        report.masked_beam_rows = sum(r.masked_beam_rows for r in results)
        report.masked_beam_fallbacks = sum(r.masked_beam_fallbacks for r in results)
        report.bytes_read = self.store.metrics.bytes_read
        if pred is not None:
            report.filtered = True
            report.filter_plan = self._plan_summary(ops, pruned)
            report.shards_pruned = len(pruned)
            report.fragments_pruned = len(pruned)  # one fragment per shard here
            report.est_selectivity = est_frac
        report.plan = plan
        return report

    @staticmethod
    def _tail_tasks(
        tail_list: List[Tuple[str, int, int]],
        tail_ops: Dict[int, PlanOp],
        queries: np.ndarray,
        query_index: np.ndarray,
        *,
        k: int,
        oversample: int,
        metric: str,
        filters: Optional[List[Optional[Predicate]]],
    ) -> List[F.TailScanTaskInfo]:
        """One Stage-A fragment per fresh-tail row group, carrying the whole
        query block (tail fragments pass through coalescing unmerged)."""
        B = queries.shape[0]
        tasks: List[F.TailScanTaskInfo] = []
        for i, (fp, rg, _cnt) in enumerate(tail_list):
            tid = -(i + 1)
            tasks.append(
                F.TailScanTaskInfo(
                    task_id=f"tail-{i}",
                    cache_key=fp,
                    file_path=fp,
                    row_group=rg,
                    tail_id=tid,
                    queries=queries,
                    query_index=query_index,
                    k=k,
                    oversample=oversample,
                    metric=metric,
                    filters=list(filters) if filters is not None else None,
                    plan_ops=[tail_ops[tid]] * B,
                )
            )
        return tasks

    def _route_queries(
        self, routing: RoutingTable, queries: np.ndarray, n_route: Optional[int]
    ) -> List[List[int]]:
        """Vectorized shard routing for a batch: per query, the shards to
        probe.  Default (``n_route`` unset) routes every query to every
        shard — exact parity with the sequential probe.  With ``n_route``,
        one batched distance pass against the partition centroids keeps only
        the shards owning each query's nearest partitions."""
        shard_ids = [s.shard_id for s in routing.shards]
        B = queries.shape[0]
        cents = routing.partition_centroids
        if n_route is None or cents is None or routing.shard_of_partition is None:
            return [list(shard_ids) for _ in range(B)]
        # (B, P) distances in one pass, under the index's own metric
        if routing.metric == "ip":
            d = -(queries @ cents.T)
        else:
            d = (
                np.sum(queries * queries, axis=1)[:, None]
                - 2.0 * queries @ cents.T
                + np.sum(cents * cents, axis=1)[None, :]
            )
        keep = min(n_route, cents.shape[0])
        nearest = np.argsort(d, axis=1)[:, :keep]  # (B, keep) partition ids
        owner = np.asarray(routing.shard_of_partition)
        available = set(shard_ids)
        out: List[List[int]] = []
        for qi in range(B):
            shards = {int(owner[p]) for p in nearest[qi]} & available
            # a query must probe at least one shard even if its nearest
            # partitions all map to shards that produced no blob
            out.append(sorted(shards) if shards else list(shard_ids))
        return out

    def _probe_diskann_batch(
        self,
        table: LakehouseTable,
        routing: RoutingTable,
        reader: PuffinReader,
        puffin_path: str,
        queries: np.ndarray,
        k: int,
        *,
        use_pq: Optional[bool] = None,
        L: Optional[int] = None,
        n_route: Optional[int] = None,
        preds: Optional[List[Optional[Predicate]]] = None,
        zonemap: Optional[AttrZoneMap] = None,
        tail: Optional[FreshTail] = None,
        scan_dtype: str = "f32",
        oversample_override: Optional[int] = None,
        replay_plan: Optional[ProbePlan] = None,
        cache_ctx: Optional[Tuple[str, int]] = None,
    ) -> ProbeReport:
        """Batched three-stage distributed probe.

        Stage A: per-(query, shard) fragments are handed to the scheduler,
        which coalesces them into ≤ one fragment per shard; each executor
        answers its fragment with one batched beam-search pass.  Stage B:
        the union of every query's surviving candidates is reranked in one
        wave with per-row ownership.  Stage C: per-query ordered merge.

        ``preds`` carries per-query predicates (None entries = unfiltered
        query).  Filtered and unfiltered queries share coalesced fragments;
        the zone map drops a (query, shard) fragment before dispatch when no
        member row group of that shard can match the query's predicate.

        With ``replay_plan`` the per-(query, shard) ops come from the
        caller's plan verbatim (planning is skipped entirely); tail ops
        (negative synthetic ids) are ignored and re-planned fresh."""
        if replay_plan is not None:
            if replay_plan.k != k:
                raise ValueError(
                    f"replay plan was built for k={replay_plan.k}, got k={k}"
                )
            if len(replay_plan.ops) != queries.shape[0]:
                raise ValueError(
                    f"replay plan covers {len(replay_plan.ops)} queries, "
                    f"got {queries.shape[0]}"
                )
            oversample = (
                replay_plan.oversample
                if oversample_override is None
                else oversample_override
            )
            use_pq = replay_plan.use_pq
        elif oversample_override is not None:
            oversample = max(1, int(oversample_override))
        else:
            oversample = int(routing.params.get("oversample", "4"))
        if use_pq is None:
            use_pq = int(routing.params.get("pq_m", "0")) > 0
        L_eff = L or int(routing.params.get("L", "100"))
        t0 = time.time()
        # the already-open reader has the footer parsed — no re-read
        blob_by_index = dict(enumerate(reader.blobs))
        route = self._route_queries(routing, queries, n_route)
        B = queries.shape[0]
        # replay: the op grid is taken as-is (shard ops only — synthetic
        # negative tail ids are dropped; the tail is re-planned below)
        replay_ops: List[Dict[int, PlanOp]] = (
            [{sid: op for sid, op in row.items() if sid >= 0} for row in replay_plan.ops]
            if replay_plan is not None
            else []
        )
        # one plan per distinct predicate; shared across its queries
        plans: Dict[Predicate, Tuple[Dict[int, PlanOp], List[int], float]] = {}
        if preds and replay_plan is None:
            for p in preds:
                if p is not None and p not in plans:
                    plans[p] = planner.plan_filtered(
                        p, zonemap, routing,
                        k=k, oversample=oversample, use_pq=use_pq,
                        scan_dtype=scan_dtype,
                    )
        # pre-pass: which shards end up with MIXED fragments (filtered and
        # unfiltered queries coalesced together)?  An unfiltered query on a
        # mixed shard needs a planner op of its own — a shared beam, or a
        # size-capped all-ones exact row on small shards — instead of the
        # old uncapped O(N·D) all-ones scan.
        shard_filtered: Dict[int, bool] = {}
        shard_unfiltered: Dict[int, bool] = {}
        if replay_plan is None:
            for s in routing.shards:
                for qi in range(B):
                    if s.shard_id not in route[qi]:
                        continue
                    pred = preds[qi] if preds else None
                    if pred is None:
                        shard_unfiltered[s.shard_id] = True
                    elif s.shard_id in plans[pred][0]:
                        shard_filtered[s.shard_id] = True
        fragments_pruned = 0
        ops_grid: List[Dict[int, PlanOp]] = [dict() for _ in range(B)]
        tasks: List[F.BatchProbeTaskInfo] = []
        # cross-batch shard-probe cache (serving/cache.py): keys carry the
        # snapshot id, predicate, search params, plan op, and the exact
        # query bytes, so a hit replays the identical Stage-A fragment
        cache = self.probe_cache if cache_ctx is not None else None
        q_digests: List[bytes] = (
            [query_digest(queries[qi]) for qi in range(B)] if cache is not None else []
        )
        cached: Dict[Tuple[int, int], List[F.ProbeCandidate]] = {}
        cache_puts: List[Tuple[tuple, int, int]] = []  # (key, qi, shard_id)
        for s in routing.shards:
            b = blob_by_index[s.blob_index]
            mixed = shard_filtered.get(s.shard_id, False) and shard_unfiltered.get(
                s.shard_id, False
            )
            for qi in range(B):
                if s.shard_id not in route[qi]:
                    continue
                pred = preds[qi] if preds else None
                op: Optional[PlanOp] = None
                if replay_plan is not None:
                    op = replay_ops[qi].get(s.shard_id)
                    if isinstance(op, planner.Skip):
                        fragments_pruned += 1
                        ops_grid[qi][s.shard_id] = op
                        continue  # the replayed plan pruned this fragment
                elif pred is not None:
                    shard_ops, _pruned, _frac = plans[pred]
                    if s.shard_id not in shard_ops:
                        fragments_pruned += 1
                        ops_grid[qi][s.shard_id] = planner.Skip()
                        continue  # zone-pruned for this query's predicate
                    op = shard_ops[s.shard_id]
                elif plans:
                    op = planner.plan_unfiltered(
                        s.vector_count, mixed=mixed, k=k, oversample=oversample
                    )
                if op is not None:
                    ops_grid[qi][s.shard_id] = op
                if cache is not None:
                    ckey = (
                        cache_ctx[0],
                        cache_ctx[1],
                        s.shard_id,
                        pred,
                        (k, L_eff, use_pq, oversample),
                        op,
                        q_digests[qi],
                    )
                    ent = cache.get(ckey)
                    if ent is not None:
                        # Stage-A hit: skip mask evaluation and the kernel
                        # dispatch for this fragment; the cached candidates
                        # re-merge below in this shard's routing slot
                        cached[(qi, s.shard_id)] = ent.candidates
                        continue
                    cache_puts.append((ckey, qi, s.shard_id))
                tasks.append(
                    F.BatchProbeTaskInfo(
                        task_id=f"probe-{s.shard_id}-q{qi}",
                        cache_key=f"{puffin_path}#shard{s.shard_id}",
                        shard_id=s.shard_id,
                        puffin_path=puffin_path,
                        blob_offset=b.offset,
                        blob_length=b.length,
                        blob_codec=b.compression_codec,
                        queries=queries[qi : qi + 1],
                        query_index=np.array([qi], np.int64),
                        k=k,
                        L=L_eff,
                        use_pq=use_pq,
                        oversample=oversample,
                        filters=[pred] if pred is not None else None,
                        plan_ops=[op] if op is not None else None,
                    )
                )
        # fresh-tail fragments: every query scans every tail row group (tail
        # rows are outside the routing table, so n_route cannot skip them)
        tail_list = tail.row_group_list() if tail is not None else []
        tail_ops: Dict[int, PlanOp] = (
            planner.plan_tail(
                [cnt for _, _, cnt in tail_list], k=k, oversample=oversample
            )
            if tail_list
            else {}
        )
        for qi in range(B):
            ops_grid[qi].update(tail_ops)
        tail_tasks = self._tail_tasks(
            tail_list,
            tail_ops,
            queries,
            np.arange(B, dtype=np.int64),
            k=k,
            oversample=oversample,
            metric=routing.metric,
            filters=preds,
        )
        results: List[F.BatchProbeResult] = self.scheduler.run_coalesced_wave(
            tasks + tail_tasks
        )
        # coalescing preserves first-appearance order, so the tail fragments
        # (appended last, never merged) are the trailing results
        n_shard_results = len(results) - len(tail_tasks)
        probe_results = results[:n_shard_results]
        tail_results = results[n_shard_results:]
        by_shard = {r.shard_id: r for r in probe_results}
        if cache is not None:
            for ckey, qi, sid in cache_puts:
                r = by_shard.get(sid)
                if r is not None:
                    cache.put(
                        ckey,
                        r.candidates.get(qi, []),
                        table_name=cache_ctx[0],
                        snapshot_id=cache_ctx[1],
                        served_by=r.executor_id,
                    )
        stage_a = time.time() - t0
        # ---- merge + Stage B: exact rerank with per-row ownership ----------
        t1 = time.time()
        keep = k * oversample
        merged: List[List[F.ProbeCandidate]] = []
        for qi in range(B):
            cands: List[F.ProbeCandidate] = []
            # routing order (== uncached result order): a cache hit drops
            # its candidates into exactly the slot the live fragment would
            # have filled, so the stable sort below ties-break identically
            # and the final hits are bit-identical to the uncached path
            for s in routing.shards:
                hit = cached.get((qi, s.shard_id))
                if hit is not None:
                    cands.extend(hit)
                else:
                    r = by_shard.get(s.shard_id)
                    if r is not None:
                        cands.extend(r.candidates.get(qi, []))
            for r in tail_results:  # tail fragments merge last, as dispatched
                cands.extend(r.candidates.get(qi, []))
            cands.sort(key=lambda c: c.approx_distance)
            merged.append(cands[:keep])
        masks: Dict[str, Dict[int, set]] = {}
        row_owners: Dict[str, Dict[int, Dict[int, set]]] = {}
        for qi in range(B):
            for c in merged[qi]:
                masks.setdefault(c.file_path, {}).setdefault(c.row_group, set()).add(
                    c.row_offset
                )
                row_owners.setdefault(c.file_path, {}).setdefault(
                    c.row_group, {}
                ).setdefault(c.row_offset, set()).add(qi)
        masks_l = {
            fp: {rg: sorted(rows) for rg, rows in groups.items()}
            for fp, groups in masks.items()
        }
        report = self._rerank_and_merge(
            table, masks_l, queries, k, routing.metric, row_owners=row_owners
        )
        report.strategy = "diskann"
        report.served_by = [
            f"probe:{r.shard_id}@{r.executor_id}" for r in results
        ] + report.served_by
        report.files_scanned = len(masks_l)
        report.stage_a_seconds = stage_a
        report.stage_b_seconds = time.time() - t1 - report.stage_c_seconds
        report.shards_probed = len(probe_results)
        report.probe_fragments = len(probe_results)
        report.cache_hits = sum(1 for r in probe_results if r.cache_hit)
        report.shard_cache_hits = len(cached)
        if cached:
            report.cache = "shard"
        report.kernel_dispatches = sum(r.kernel_dispatches for r in results)
        report.masked_beam_rows = sum(r.masked_beam_rows for r in results)
        report.masked_beam_fallbacks = sum(r.masked_beam_fallbacks for r in results)
        report.bytes_read = self.store.metrics.bytes_read
        all_pruned: set = set()
        if plans:
            report.filtered = True
            all_pruned = {sid for _, pruned, _ in plans.values() for sid in pruned}
            report.shards_pruned = len(all_pruned)
            report.fragments_pruned = fragments_pruned
            report.filter_plan = ";".join(
                self._plan_summary(ops, pruned) for ops, pruned, _ in plans.values()
            )
            report.est_selectivity = float(
                np.mean([frac for _, _, frac in plans.values()])
            )
        elif replay_plan is not None:
            report.filtered = bool(preds)
            all_pruned = set(replay_plan.pruned_shards)
            report.shards_pruned = len(all_pruned)
            report.fragments_pruned = fragments_pruned
            report.filter_plan = "replay"
            report.est_selectivity = replay_plan.est_selectivity
        if plans or tail_tasks or replay_plan is not None:
            report.plan = ProbePlan(
                k=k,
                oversample=oversample,
                use_pq=use_pq,
                ops=ops_grid,
                est_selectivity=report.est_selectivity,
                pruned_shards=tuple(sorted(all_pruned)),
            )
        return report

    def _rerank_and_merge(
        self,
        table: LakehouseTable,
        masks: Dict[str, Dict[int, List[int]]],
        queries: np.ndarray,
        k: int,
        metric: str,
        file_owners: Optional[Dict[str, set]] = None,
        row_owners: Optional[Dict[str, Dict[int, Dict[int, set]]]] = None,
    ) -> ProbeReport:
        """Stage B (parallel rerank) + Stage C (ordered merge).

        ``file_owners`` / ``row_owners`` carry batched-probe ownership: each
        query's Stage-C merge sees only the rows it routed to, even though
        the union of the batch's rows is read and scored once."""
        live = self.pool.live()
        n_exec = max(1, len(live))
        file_list = sorted(masks.keys())
        groups = [file_list[i::n_exec] for i in range(n_exec)]
        tasks = []
        for gi, group in enumerate(groups):
            if not group:
                continue
            tasks.append(
                F.RerankTaskInfo(
                    task_id=f"rerank-{gi}",
                    cache_key=group[0],
                    masks={fp: masks[fp] for fp in group},
                    queries=queries,
                    metric=metric,
                    file_owners=(
                        {fp: file_owners[fp] for fp in group if fp in file_owners}
                        if file_owners
                        else None
                    ),
                    row_owners=(
                        {fp: row_owners[fp] for fp in group if fp in row_owners}
                        if row_owners
                        else None
                    ),
                )
            )
        results: List[F.RerankResult] = self.scheduler.run_wave(tasks) if tasks else []
        # Stage C: streaming loser-tree merge (here: heap merge per query)
        t2 = time.time()
        Q = queries.shape[0]
        hits: List[List[ProbeHit]] = []
        for qi in range(Q):
            rows = []
            for r in results:
                rows.extend(r.rows[qi])
            best = heapq.nsmallest(k, rows, key=lambda x: x.distance)
            hits.append(
                [ProbeHit(b.file_path, b.row_group, b.row_offset, b.distance) for b in best]
            )
        stage_c = time.time() - t2
        return ProbeReport(
            hits=hits,
            strategy="",
            files_scanned=0,
            bytes_read=0,
            stage_c_seconds=stage_c,
            served_by=[f"rerank@{r.executor_id}" for r in results],
        )

    # ------------------------------------------------------------------ refresh
    def refresh_index(self, table_name: str, index_name: str) -> RefreshReport:
        """REFRESH INDEX (paper §7): manifest diff → greedy insert + lazy
        tombstones → selective shard rebuild → metadata-only commit."""
        t_start = time.time()
        meta, snap, puffin_path, reader = self._resolve_index(table_name)
        routing = decode_routing_blob(reader.read_first(ROUTING_BLOB_TYPE))
        base_id = routing.base_snapshot_id
        # The index must be refreshed against the *current* data snapshot.
        diff = diff_snapshots(self.store, meta, base_id, snap.snapshot_id)
        if diff.is_empty:
            return RefreshReport(
                puffin_path=puffin_path,
                snapshot_id=snap.snapshot_id,
                base_snapshot_id=base_id,
                inserted=0,
                tombstoned=0,
                shards_refreshed=0,
                shards_rebuilt=0,
                shards_reused=len(routing.shards),
                seconds=time.time() - t_start,
                noop=True,
            )
        added = [f.path for f in diff.added]
        removed = [f.path for f in diff.deleted]
        blob_metas = reader.blobs
        token = uuid.uuid4().hex[:8]
        out_prefix = (
            f"{meta.location}/metadata/ann-{index_name}-snap-{snap.snapshot_id}-{token}"
        )
        tasks = []
        for s in routing.shards:
            b = blob_metas[s.blob_index]
            tasks.append(
                F.RefreshTaskInfo(
                    task_id=f"refresh-{s.shard_id}",
                    cache_key=f"{puffin_path}#shard{s.shard_id}",
                    shard_id=s.shard_id,
                    puffin_path=puffin_path,
                    blob_offset=b.offset,
                    blob_length=b.length,
                    blob_codec=b.compression_codec,
                    added_files=added,
                    removed_files=removed,
                    partition_centroids=routing.partition_centroids,
                    shard_of_partition=routing.shard_of_partition,
                    output_path=f"{out_prefix}-shard-{s.shard_id}.blob",
                    include_vectors=routing.params.get("include_vectors", "True")
                    == "True",
                )
            )
        results: List[F.RefreshResult] = self.scheduler.run_wave(tasks)
        # rebuild any shard past the tombstone threshold (paper §7.3: only
        # that shard, at the next maintenance window — we do it inline)
        rebuilt = 0
        final: List[F.IndexBuildResult] = []
        ratios: Dict[int, float] = {}
        cfg = IndexConfig(
            name=index_name,
            R=int(routing.params["R"]),
            L=int(routing.params["L"]),
            alpha=float(routing.params["alpha"]),
            metric=routing.metric,
            pq_m=int(routing.params.get("pq_m", "0")),
            pq_nbits=int(routing.params.get("pq_nbits", "8")),
            include_vectors=routing.params.get("include_vectors", "True") == "True",
            partition_mode=routing.params.get("partition_mode", "centroid"),
        )
        for r in results:
            if r.tombstone_ratio > TOMBSTONE_REBUILD_THRESHOLD:
                rb = self._rebuild_shard(r, cfg, routing, out_prefix)
                final.append(rb)
                ratios[rb.shard_id] = 0.0
                rebuilt += 1
            else:
                final.append(
                    F.IndexBuildResult(
                        shard_id=r.shard_id,
                        output_path=r.output_path,
                        vector_count=r.vector_count,
                        byte_size=r.byte_size,
                        executor_id=r.executor_id,
                        build_seconds=r.refresh_seconds,
                        rg_membership=r.rg_membership,
                    )
                )
                ratios[r.shard_id] = r.tombstone_ratio
        table = LakehouseTable(self.catalog, table_name)
        centroid_index = build_centroid_index(table, metric=routing.metric)
        covered = [f.path for f in table.current_files()]
        # the zone map is rebuilt against the refresh target snapshot, with
        # shard membership from the refreshed (live-row) location maps —
        # data files are immutable, so zones carry over from the previous
        # index and only files the old map never saw are scanned (refresh
        # attribute I/O scales with the append delta, not the table)
        zonemap = self._refresh_zonemap(reader, puffin_path, covered)
        if zonemap is not None:
            zonemap.shard_membership = {
                r.shard_id: r.rg_membership for r in final if r.rg_membership
            }
        # snapshot to bind against is the CURRENT one (the diff target)
        puffin_new, total_bytes = self._assemble_puffin(
            meta,
            snap,
            cfg,
            routing.partition_centroids,
            routing.shard_of_partition,
            final,
            centroid_index,
            covered,
            out_prefix,
            tombstone_ratios=ratios,
            zonemap=zonemap,
        )
        new_meta = self.catalog.set_statistics_file(
            table_name,
            puffin_new,
            expected_base_snapshot_id=snap.snapshot_id,
            extra_summary={
                "ann.index-name": index_name,
                "ann.base-snapshot-id": str(snap.snapshot_id),
                "ann.num-shards": str(len(final)),
                "ann.refreshed-from": str(base_id),
            },
        )
        self._invalidate_caches(table_name, new_meta.current_snapshot_id)
        return RefreshReport(
            puffin_path=puffin_new,
            snapshot_id=new_meta.current_snapshot_id,
            base_snapshot_id=snap.snapshot_id,
            inserted=sum(r.inserted for r in results),
            tombstoned=sum(r.tombstoned for r in results),
            shards_refreshed=len(results),
            shards_rebuilt=rebuilt,
            shards_reused=0,
            seconds=time.time() - t_start,
        )

    def compact_tail(
        self,
        table_name: str,
        index_name: str,
        *,
        threshold_rows: int = TAIL_COMPACT_THRESHOLD_ROWS,
        force: bool = False,
    ) -> Optional[RefreshReport]:
        """Fold the fresh tail into the Vamana shards once it crosses the
        size threshold (the background compaction policy).  Delegates to
        :meth:`refresh_index` — the manifest diff already covers the tail's
        files, and the refresh commit binds a new ``statistics-file``
        snapshot summary, which implicitly resets the tail (time travel to
        the pre-compaction snapshot still sees — and serves — its tail;
        orphaned tail Puffins are reaped by the ordinary GC).  Returns None
        when there is no tail or it is still below ``threshold_rows``."""
        meta = self.catalog.load_table(table_name)
        snap = meta.current_snapshot()
        if snap is None:
            return None
        tail = self._resolve_tail(snap)
        if tail is None:
            return None
        if not force and tail.total_rows < threshold_rows:
            return None
        return self.refresh_index(table_name, index_name)

    def _rebuild_shard(
        self,
        refresh_result: F.RefreshResult,
        cfg: IndexConfig,
        routing: RoutingTable,
        out_prefix: str,
    ) -> F.IndexBuildResult:
        """Full rebuild of a single over-tombstoned shard from live vectors."""
        from repro.core.blobs import decode_shard_blob

        raw = self.store.get(refresh_result.output_path)
        graph, locmap = decode_shard_blob(raw)
        live_ids = np.flatnonzero(~graph.tombstones[: graph.n])
        vectors = graph.vectors[live_ids]
        pq_codebook = graph.pq.codebook if graph.pq is not None else None
        task = F.IndexBuildTaskInfo(
            task_id=f"rebuild-{refresh_result.shard_id}",
            shard_id=refresh_result.shard_id,
            partition_centroids=routing.partition_centroids,
            shard_of_partition=routing.shard_of_partition,
            R=cfg.R,
            L=cfg.L,
            alpha=cfg.alpha,
            metric=cfg.metric,
            pq_m=cfg.pq_m,
            pq_nbits=cfg.pq_nbits,
            pq_codebook=pq_codebook,
            include_vectors=cfg.include_vectors,
            output_path=f"{out_prefix}-shard-{refresh_result.shard_id}-rebuilt.blob",
            exchanged=(
                vectors,
                locmap.file_idx[live_ids],
                locmap.row_group[live_ids],
                locmap.row_offset[live_ids],
                list(locmap.file_paths),
            ),
        )
        [result] = self.scheduler.run_wave([task])
        return result
