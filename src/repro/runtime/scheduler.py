"""Cache-aware scheduler with failure recovery and straggler mitigation.

Responsibilities (DESIGN.md §6):

- **Cache-aware placement** — tasks carry a ``cache_key``; executors that
  already hold the key (L1 or SSD) are preferred, mirroring the paper's
  "cache-aware scheduler" reuse (§3.1, §5).
- **Failure recovery** — a heartbeat monitor marks dead executors; their
  in-flight fragments are reassigned (attempt+1) to survivors.  Completed
  shard blobs are durable in the object store, so reassignment is
  idempotent: tasks write to deterministic output paths.
- **Straggler mitigation** — speculative backup tasks: once half the wave is
  done, any task running longer than ``speculation_factor ×`` the median
  completed latency is duplicated onto an idle executor; first finisher
  wins, the loser's (identical) output is harmlessly overwritten / orphaned.
- **Elasticity** — executors can be added/removed between (or during)
  waves; the dispatch loop only consults the live set.
- **Leased placement** — shard→executor affinity is explicit, expiring
  state in a :class:`repro.serving.leases.LeaseTable`: live executors renew
  their leases from the poll loop, dispatch prefers valid lease holders
  (replicated ≥2 per shard), and a fragment whose executor's lease lapsed
  mid-wave — death observed by heartbeat or by ``ExecutorDead`` at task
  entry — is re-dispatched to a surviving holder
  (``stats.redispatches``).  Safe because executors are stateless: the
  survivor re-reads the shard from the Puffin blob and produces the
  identical result.
"""

from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass
from typing import Dict, List, Optional

from repro.runtime import fragments as F
from repro.runtime.executor import Executor, ExecutorDead, InjectedFailure
from repro.serving.leases import LeaseTable
from repro.serving.metrics import MetricsRegistry


@dataclass
class SchedulerStats:
    dispatched: int = 0
    reassigned: int = 0
    speculative: int = 0
    failures_seen: int = 0
    cache_preferred_hits: int = 0
    # batched-probe coalescing: fragments offered to run_coalesced_wave vs
    # fragments eliminated by merging same-shard probes
    probe_fragments_offered: int = 0
    probe_fragments_coalesced: int = 0
    # fragments re-dispatched to a survivor because their executor's lease
    # lapsed (executor died mid-wave, seen via heartbeat or ExecutorDead)
    redispatches: int = 0
    # dispatches that preferred a valid lease holder for the fragment's shard
    lease_preferred_hits: int = 0


class ExecutorPool:
    """Live executor set with heartbeat checks."""

    def __init__(self, executors: List[Executor]) -> None:
        self._lock = threading.Lock()
        self._executors: Dict[str, Executor] = {e.executor_id: e for e in executors}

    def add(self, executor: Executor) -> None:
        with self._lock:
            self._executors[executor.executor_id] = executor

    def remove(self, executor_id: str) -> None:
        with self._lock:
            self._executors.pop(executor_id, None)

    def live(self) -> List[Executor]:
        with self._lock:
            return [e for e in self._executors.values() if e.heartbeat()]

    def all(self) -> List[Executor]:
        with self._lock:
            return list(self._executors.values())

    def get(self, executor_id: str) -> Optional[Executor]:
        with self._lock:
            return self._executors.get(executor_id)


@dataclass
class _Attempt:
    task_index: int
    executor: Executor
    thread: threading.Thread
    started: float
    speculative: bool = False
    # set once this attempt's fragment has been re-dispatched elsewhere
    # (its executor died mid-wave); keeps the monitor from requeueing twice
    abandoned: bool = False


class Scheduler:
    def __init__(
        self,
        pool: ExecutorPool,
        *,
        max_attempts: int = 4,
        enable_speculation: bool = False,
        speculation_factor: float = 3.0,
        poll_interval: float = 0.005,
        lease_table: Optional[LeaseTable] = None,
        metrics: Optional[MetricsRegistry] = None,
    ) -> None:
        self.pool = pool
        self.max_attempts = max_attempts
        self.enable_speculation = enable_speculation
        self.speculation_factor = speculation_factor
        self.poll_interval = poll_interval
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.leases = (
            lease_table if lease_table is not None else LeaseTable(metrics=self.metrics)
        )
        self.stats = SchedulerStats()

    def run_coalesced_wave(self, tasks: List[object]) -> List[object]:
        """Coalesce batchable shard-probe fragments, then dispatch the wave.

        Per-(query, shard) probe fragments targeting the same shard blob with
        the same search parameters merge into a single fragment carrying the
        stacked query block — ≤ one dispatch per shard for a whole batch
        instead of B × shards.  Results align to the MERGED fragment list."""
        merged = F.coalesce_batch_probes(tasks)
        self.stats.probe_fragments_offered += len(tasks)
        self.stats.probe_fragments_coalesced += len(tasks) - len(merged)
        return self.run_wave(merged)

    def run_wave(self, tasks: List[object]) -> List[object]:
        """Dispatch a wave of fragments; returns results aligned to tasks.

        Raises RuntimeError if any task exhausts ``max_attempts`` or the
        executor pool dies entirely.
        """
        n = len(tasks)
        results: List[Optional[object]] = [None] * n
        done = [False] * n
        attempts_count = [0] * n
        pending: "queue.Queue[int]" = queue.Queue()
        for i in range(n):
            pending.put(i)
        inflight: List[_Attempt] = []
        completed_latencies: List[float] = []
        lock = threading.Lock()
        errors: List[str] = []

        def run_one(idx: int, executor: Executor, speculative: bool, attempt_obj: list):
            try:
                out = executor.handle(tasks[idx])
                with lock:
                    if not done[idx]:
                        done[idx] = True
                        results[idx] = out
                        completed_latencies.append(time.time() - attempt_obj[0].started)
            except (ExecutorDead, InjectedFailure, Exception) as exc:  # noqa: BLE001
                if isinstance(exc, ExecutorDead):
                    # the holder died mid-wave: lapse its leases immediately
                    # so no later pick in this wave prefers it
                    executor.kill()
                    self.leases.expire_holder(executor.executor_id)
                with lock:
                    self.stats.failures_seen += 1
                    if not done[idx]:
                        attempts_count[idx] += 1
                        if attempts_count[idx] >= self.max_attempts:
                            errors.append(f"task {idx} failed {attempts_count[idx]}x: {exc!r}")
                            done[idx] = True  # give up; surfaced below
                        else:
                            self.stats.reassigned += 1
                            if isinstance(exc, ExecutorDead):
                                self.stats.redispatches += 1
                                self.metrics.counter("redispatches").inc()
                            pending.put(idx)

        busy: Dict[str, int] = {}

        def pick_executor(idx: int) -> Optional[Executor]:
            live_all = self.pool.live()
            live = [e for e in live_all if busy.get(e.executor_id, 0) == 0]
            if not live:
                return None
            key = getattr(tasks[idx], "cache_key", None)
            if key:
                # lease-checked dispatch: top the shard's lease up to its
                # replica target from the whole live set, then prefer a free
                # valid holder (cached holders first, else primary order)
                lease = self.leases.ensure(key, [e.executor_id for e in live_all])
                holders = lease.valid_holders(self.leases._clock())
                holding = [e for e in live if e.executor_id in holders]
                if holding:
                    self.stats.lease_preferred_hits += 1
                    cached = [e for e in holding if e.has_cached(key)]
                    if cached:
                        self.stats.cache_preferred_hits += 1
                        return cached[0]
                    return min(holding, key=lambda e: holders.index(e.executor_id))
                cached = [e for e in live if e.has_cached(key)]
                if cached:
                    self.stats.cache_preferred_hits += 1
                    return cached[0]
            # least-loaded by completed count for spread
            return min(live, key=lambda e: e.tasks_done)

        while True:
            with lock:
                all_done = all(done)
            if all_done:
                break
            live_now = self.pool.live()
            if not live_now:
                raise RuntimeError("entire executor pool is dead")
            # heartbeats renew leases; executors that stopped answering age out
            for e in live_now:
                self.leases.renew(e.executor_id)
            # reap finished attempts; re-dispatch fragments whose executor
            # died while holding them (lease lapsed mid-wave) — safe because
            # executors are stateless, so the survivor recomputes the
            # identical result and done-first-wins dedupes
            for att in list(inflight):
                if not att.thread.is_alive():
                    busy[att.executor.executor_id] = max(
                        0, busy.get(att.executor.executor_id, 0) - 1
                    )
                    inflight.remove(att)
                elif not att.abandoned and not att.executor.heartbeat():
                    att.abandoned = True
                    self.leases.expire_holder(att.executor.executor_id)
                    with lock:
                        if done[att.task_index]:
                            continue
                        self.stats.redispatches += 1
                    self.metrics.counter("redispatches").inc()
                    pending.put(att.task_index)
            # dispatch pending
            try:
                while True:
                    idx = pending.get_nowait()
                    with lock:
                        if done[idx]:
                            continue
                    ex = pick_executor(idx)
                    if ex is None:
                        pending.put(idx)
                        break
                    holder: list = []
                    th = threading.Thread(
                        target=run_one, args=(idx, ex, False, holder), daemon=True
                    )
                    att = _Attempt(idx, ex, th, time.time())
                    holder.append(att)
                    busy[ex.executor_id] = busy.get(ex.executor_id, 0) + 1
                    inflight.append(att)
                    self.stats.dispatched += 1
                    th.start()
            except queue.Empty:
                pass
            # speculation
            if self.enable_speculation and completed_latencies:
                with lock:
                    frac_done = sum(done) / n
                if frac_done >= 0.5:
                    lat = sorted(completed_latencies)
                    median = lat[len(lat) // 2]
                    for att in list(inflight):
                        if att.speculative:
                            continue
                        with lock:
                            if done[att.task_index]:
                                continue
                        if time.time() - att.started > self.speculation_factor * max(
                            median, 1e-3
                        ):
                            ex = pick_executor(att.task_index)
                            if ex is not None and ex is not att.executor:
                                holder = []
                                th = threading.Thread(
                                    target=run_one,
                                    args=(att.task_index, ex, True, holder),
                                    daemon=True,
                                )
                                spec = _Attempt(att.task_index, ex, th, time.time(), True)
                                holder.append(spec)
                                busy[ex.executor_id] = busy.get(ex.executor_id, 0) + 1
                                inflight.append(spec)
                                att.speculative = True  # don't re-speculate
                                self.stats.speculative += 1
                                th.start()
            time.sleep(self.poll_interval)
        if errors:
            raise RuntimeError("; ".join(errors))
        return results  # type: ignore[return-value]
