"""SQL frontend: the paper's DDL + vector-query routing (§6, §8).

The paper adds ``CREATE/REFRESH/DROP INDEX`` in the SqlLexer fallback path
and rewrites ``ORDER BY <distance>(col, literal) LIMIT K`` /
``WHERE <distance>(col, literal) < t`` plans into the distributed probe.
This module is that layer: a small pattern-based parser producing typed
statements, routed to the coordinator.

Supported grammar (case-insensitive):

    CREATE VECTOR INDEX <name> ON <table> (<column>)
        [WITH (R=64, L=100, ALPHA=1.2, PQ_M=48, PQ_NBITS=8, SHARDS=4)]
    REFRESH INDEX <name> ON <table>
    DROP INDEX <name> ON <table>
    SELECT * FROM <table> [WHERE <pred> [AND|OR <pred> ...]]
        ORDER BY L2_DISTANCE(<col>, [v,...]) LIMIT <k>
    SELECT * FROM <table> WHERE L2_DISTANCE(<col>, [v,...]) < <t>

where each ``<pred>`` is an attribute predicate —
``col = <lit>``, ``col IN (<lit>, ...)``, ``col < | <= | > | >= <num>`` or
``col BETWEEN <num> AND <num>`` (AND binds tighter than OR) — pushed
through the probe path as a filtered vector search.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from repro.runtime.coordinator import Coordinator, IndexConfig
from repro.runtime.predicates import PredicateError, parse_predicate


class SqlError(ValueError):
    pass


@dataclass
class IndexDDLInfo:
    action: str  # create | refresh | drop
    index_name: str
    table: str
    column: str = "vec"
    options: dict = field(default_factory=dict)


_CREATE = re.compile(
    r"^\s*CREATE\s+VECTOR\s+INDEX\s+(\w+)\s+ON\s+(\w+)\s*\(\s*(\w+)\s*\)"
    r"(?:\s+WITH\s*\(([^)]*)\))?\s*;?\s*$",
    re.I,
)
_REFRESH = re.compile(r"^\s*REFRESH\s+INDEX\s+(\w+)\s+ON\s+(\w+)\s*;?\s*$", re.I)
_DROP = re.compile(r"^\s*DROP\s+INDEX\s+(\w+)\s+ON\s+(\w+)\s*;?\s*$", re.I)
_TOPK = re.compile(
    r"^\s*SELECT\s+\*\s+FROM\s+(\w+)"
    r"(?:\s+WHERE\s+(?!(?:L2|IP)_DISTANCE\s*\()(.+?))?"
    r"\s+ORDER\s+BY\s+(L2|IP)_DISTANCE\s*\(\s*(\w+)\s*,"
    r"\s*\[([^\]]*)\]\s*\)\s+LIMIT\s+(\d+)\s*;?\s*$",
    re.I | re.S,
)
_THRESH = re.compile(
    r"^\s*SELECT\s+\*\s+FROM\s+(\w+)\s+WHERE\s+(L2|IP)_DISTANCE\s*\(\s*(\w+)\s*,"
    r"\s*\[([^\]]*)\]\s*\)\s*<\s*([\d.eE+-]+)\s*;?\s*$",
    re.I,
)


def _parse_options(raw: Optional[str]) -> dict:
    out = {}
    if not raw:
        return out
    for part in raw.split(","):
        if not part.strip():
            continue
        k, _, v = part.partition("=")
        out[k.strip().lower()] = v.strip()
    return out


def _parse_vector(raw: str) -> np.ndarray:
    try:
        return np.asarray([float(x) for x in raw.split(",") if x.strip()], np.float32)
    except ValueError as e:
        raise SqlError(f"bad vector literal: {e}") from None


class SqlFrontend:
    """Parses + routes statements.  ``batcher`` (optional) is a
    :class:`repro.serving.serve_loop.ProbeMicroBatcher`: when attached,
    single top-k SELECTs are submitted to it so concurrent frontend threads
    share coalesced batch probes instead of issuing one probe each."""

    def __init__(self, coordinator: Coordinator, batcher=None) -> None:
        self.coordinator = coordinator
        self.batcher = batcher

    def parse(self, sql: str):
        if m := _CREATE.match(sql):
            return IndexDDLInfo("create", m.group(1), m.group(2), m.group(3),
                                _parse_options(m.group(4)))
        if m := _REFRESH.match(sql):
            return IndexDDLInfo("refresh", m.group(1), m.group(2))
        if m := _DROP.match(sql):
            return IndexDDLInfo("drop", m.group(1), m.group(2))
        if m := _TOPK.match(sql):
            pred = None
            if m.group(2) is not None:
                try:
                    pred = parse_predicate(m.group(2))
                except PredicateError as e:
                    raise SqlError(f"bad WHERE clause: {e}") from None
            return ("topk", m.group(1), m.group(3).lower(), m.group(4),
                    _parse_vector(m.group(5)), int(m.group(6)), pred)
        if m := _THRESH.match(sql):
            return ("threshold", m.group(1), m.group(2).lower(), m.group(3),
                    _parse_vector(m.group(4)), float(m.group(5)), None)
        raise SqlError(f"unrecognized statement: {sql[:80]!r}")

    def execute(self, sql: str):
        stmt = self.parse(sql)
        if isinstance(stmt, IndexDDLInfo):
            return self._execute_ddl(stmt)
        kind, table, metric, _col, vec, arg, pred = stmt
        if kind == "topk":
            if self.batcher is not None and self.batcher.table_name == table:
                return self.batcher.submit(vec, k=arg, filter=pred).result()
            report = self.coordinator.probe(
                table, vec, arg, strategy="auto", filter=pred
            )
            return report.hits[0]
        # threshold query: centroid index gives *exact* file pruning
        # (paper §4.1); rerank then filters by the bound
        report = self.coordinator.probe(
            table, vec, k=1024, strategy="centroid", n_probe=10**9
        )
        thresh_sq = arg * arg if metric == "l2" else arg  # probe returns squared L2
        return [h for h in report.hits[0] if h.distance <= thresh_sq]

    def execute_many(self, sqls: List[str]) -> List[object]:
        """Micro-batched execution of a statement block.

        Consecutive runs of top-k SELECTs against the same table with the
        same LIMIT drain into ONE ``Coordinator.probe_batch`` call (the
        batched pipeline: coalesced shard fragments, batched kernels) —
        filtered and unfiltered SELECTs coalesce together, each query
        carrying its own WHERE predicate through the batch; every other
        statement executes exactly as :meth:`execute` would.  Results come
        back in statement order."""
        parsed = [self.parse(s) for s in sqls]
        results: List[object] = [None] * len(sqls)
        run: List[int] = []  # indices of the current coalescible run

        def flush() -> None:
            if not run:
                return
            if len(run) == 1:
                results[run[0]] = self.execute(sqls[run[0]])
            else:
                _, table, _, _, _, k, _ = parsed[run[0]]
                queries = np.stack([parsed[i][4] for i in run])
                filters = [parsed[i][6] for i in run]
                report = self.coordinator.probe_batch(
                    table,
                    queries,
                    k,
                    strategy="auto",
                    filter=filters if any(f is not None for f in filters) else None,
                )
                for i, hits in zip(run, report.hits):
                    results[i] = hits
            run.clear()

        for i, stmt in enumerate(parsed):
            coalescible = not isinstance(stmt, IndexDDLInfo) and stmt[0] == "topk"
            if coalescible and run:
                _, t0, m0, _, v0, k0, _ = parsed[run[0]]
                _, t1, m1, _, v1, k1, _ = stmt
                if (t1, m1, k1) != (t0, m0, k0) or v1.shape != v0.shape:
                    flush()
            if coalescible:
                run.append(i)
            else:
                flush()
                results[i] = self.execute(sqls[i])
        flush()
        return results

    def _execute_ddl(self, ddl: IndexDDLInfo):
        if ddl.action == "create":
            o = ddl.options
            cfg = IndexConfig(
                name=ddl.index_name,
                column=ddl.column,
                R=int(o.get("r", 64)),
                L=int(o.get("l", 100)),
                alpha=float(o.get("alpha", 1.2)),
                pq_m=int(o.get("pq_m", 0)),
                pq_nbits=int(o.get("pq_nbits", 8)),
                num_shards=int(o["shards"]) if "shards" in o else None,
                build_passes=int(o.get("passes", 2)),
            )
            return self.coordinator.create_index(ddl.table, cfg)
        if ddl.action == "refresh":
            return self.coordinator.refresh_index(ddl.table, ddl.index_name)
        if ddl.action == "drop":
            # unbinding = metadata-only commit with no statistics-file; the
            # orphaned Puffin is reaped by GC
            self.coordinator.catalog.load_table(ddl.table)

            def mutate(m):
                snap = m.current_snapshot()
                if snap is not None:
                    snap.summary.pop("statistics-file", None)
                    snap.summary.pop("ann.stale-statistics-file", None)
                return m

            return self.coordinator.catalog.commit_with_retries(ddl.table, mutate)
        raise SqlError(ddl.action)
