"""Distributed runtime: coordinator, executor fleet, cache-aware scheduler.

In-process simulation of the paper's FlockDB deployment shape: one
coordinator, N stateless executors with local SSD caches, a shared object
store, and an Iceberg REST catalog as the source of truth.  Executors run on
their own threads; the scheduler provides cache-aware placement, heartbeat
failure detection with task reassignment, and speculative backup tasks for
straggler mitigation (DESIGN.md §6).
"""

from repro.runtime.fragments import (  # noqa: F401
    IndexBuildResult,
    IndexBuildTaskInfo,
    ProbeResult,
    ProbeTaskInfo,
    RefreshResult,
    RefreshTaskInfo,
    RerankResult,
    RerankTaskInfo,
)
from repro.runtime.executor import Executor, ExecutorDead  # noqa: F401
from repro.runtime.scheduler import ExecutorPool, Scheduler  # noqa: F401
from repro.runtime.coordinator import Coordinator, IndexConfig  # noqa: F401
from repro.runtime.predicates import (  # noqa: F401
    And,
    Eq,
    In,
    Or,
    Predicate,
    Range,
    parse_predicate,
)
