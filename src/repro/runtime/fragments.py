"""PlanFragment / TaskInfo structs (paper §3.1, §5, §6).

``IndexBuildTaskInfo`` rides alongside the engine's ordinary WriteTaskInfo —
here they are the task vocabulary the scheduler dispatches.  Payloads carry
numpy arrays directly (the in-process stand-in for Arrow IPC)."""

from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Dict, List, Optional, Sequence, Set, Tuple

import numpy as np


@dataclass
class TaskBase:
    task_id: str
    attempt: int = 0
    # scheduler placement hint: executors caching this key are preferred
    cache_key: Optional[str] = None


# -- build (paper §5) ---------------------------------------------------------


@dataclass
class IndexBuildTaskInfo(TaskBase):
    shard_id: int = 0
    assigned_files: List[str] = field(default_factory=list)
    # Stage-0 broadcast: partition centroids + which shard owns each partition
    partition_centroids: Optional[np.ndarray] = None  # (P, D)
    shard_of_partition: Optional[np.ndarray] = None  # (P,)
    # algorithm parameters
    R: int = 64
    L: int = 100
    alpha: float = 1.2
    metric: str = "l2"
    pq_m: int = 0  # 0 => no PQ
    pq_nbits: int = 8
    pq_codebook: Optional[np.ndarray] = None  # (m, K, dsub) broadcast from Stage 0
    include_vectors: bool = True
    # destination object for the serialized shard blob
    output_path: str = ""
    partition_mode: str = "centroid"  # centroid | file
    build_passes: int = 2
    build_batch: int = 128
    # pre-exchanged payload (centroid-mode all-to-all):
    # (vectors, file_idx, row_group, row_offset, file_paths)
    exchanged: Optional[tuple] = None


@dataclass
class IndexBuildResult:
    shard_id: int
    output_path: str
    vector_count: int
    byte_size: int
    executor_id: str
    build_seconds: float
    # per-partition vector counts (routing-table population, paper §5 Stage 1)
    partition_counts: Optional[np.ndarray] = None
    # (file_path, row_group) pairs this shard's vectors came from — the
    # zone-map membership that lets the coordinator prune whole shards on
    # attribute predicates
    rg_membership: Optional[List[Tuple[str, int]]] = None


@dataclass
class ScanPartitionTaskInfo(TaskBase):
    """Pre-build exchange: scan assigned files, group vectors by owner shard."""

    assigned_files: List[str] = field(default_factory=list)
    partition_centroids: Optional[np.ndarray] = None
    shard_of_partition: Optional[np.ndarray] = None
    num_shards: int = 0


@dataclass
class ScanPartitionResult:
    executor_id: str
    # per-shard: (vectors, file_idx, row_group, row_offset, file_paths)
    per_shard: Dict[int, Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, List[str]]] = field(
        default_factory=dict
    )


# -- probe (paper §6) ------------------------------------------------------------


@dataclass
class ProbeTaskInfo(TaskBase):
    shard_id: int = 0
    puffin_path: str = ""
    blob_offset: int = 0
    blob_length: int = 0
    blob_codec: Optional[str] = None
    queries: Optional[np.ndarray] = None  # (Q, D)
    k: int = 10
    L: int = 100
    use_pq: bool = True
    oversample: int = 4
    # filtered search: predicate tree applied to every query of this task,
    # with the planner's per-shard plan op (runtime/planner.py IR; None
    # falls back to planner.default_filtered_op — the mid-band mask plan)
    predicate: Optional[object] = None
    plan_op: Optional[object] = None


@dataclass
class ProbeCandidate:
    file_path: str
    row_group: int
    row_offset: int
    approx_distance: float
    vec_id: int
    shard_id: int


@dataclass
class ProbeResult:
    shard_id: int
    executor_id: str
    # per query: list of candidates
    candidates: List[List[ProbeCandidate]] = field(default_factory=list)
    cache_hit: bool = False
    probe_seconds: float = 0.0
    # masked top-k kernel calls this task issued (observability for the
    # heterogeneous-filter coalescing win; 0 on pure beam paths)
    kernel_dispatches: int = 0
    # MaskedBeam accounting: rows answered by the predicate-aware
    # traversal, and how many of those under-delivered and were
    # re-answered by the fused exact-masked fallback
    masked_beam_rows: int = 0
    masked_beam_fallbacks: int = 0


@dataclass
class BatchProbeTaskInfo(TaskBase):
    """Coalesced shard probe (batched pipeline): ONE fragment per shard
    carrying every batch query routed to it, instead of one fragment per
    (query, shard).  ``query_index`` maps each row of ``queries`` back to its
    position in the coordinator's batch so results merge per query."""

    shard_id: int = 0
    puffin_path: str = ""
    blob_offset: int = 0
    blob_length: int = 0
    blob_codec: Optional[str] = None
    queries: Optional[np.ndarray] = None  # (B_sub, D)
    query_index: Optional[np.ndarray] = None  # (B_sub,) positions in the batch
    k: int = 10
    L: int = 100
    use_pq: bool = True
    oversample: int = 4
    # per-query predicates, row-aligned with ``queries`` (None entry = that
    # query is unfiltered).  ``filters`` being None means the whole fragment
    # is unfiltered.  Per-query masks survive fragment coalescing: merged
    # fragments concatenate these lists alongside the query block.  The
    # executor answers every kernel-planned query of the merged fragment
    # with ONE masked-kernel call per shard — a (Q, N) mask plane (dedup'd
    # to unique predicate rows), fusing exact and PQ-ADC flavors into the
    # same dispatch when the batch mixes them — so the coalesce key
    # deliberately ignores predicates: fragments are NEVER split per
    # predicate group, however heterogeneous the batch.
    filters: Optional[List[Optional[object]]] = None
    # row-aligned planner ops (runtime/planner.py PlanOp; None entry =
    # planner default for that row: Beam for unfiltered rows,
    # default_filtered_op for filtered ones)
    plan_ops: Optional[List[Optional[object]]] = None

    def coalesce_key(self) -> tuple:
        """Fragments with equal keys search the same shard blob with the
        same parameters and may be merged into one dispatch."""
        return (
            self.puffin_path,
            self.shard_id,
            self.blob_offset,
            self.k,
            self.L,
            self.use_pq,
            self.oversample,
        )


@dataclass
class TailScanTaskInfo(TaskBase):
    """Fresh-tail tier (appended-but-unindexed rows): ONE fragment per tail
    row group carrying every query routed to it.  Tail rows have no graph
    and no PQ codes, so the executor scores them with the masked exact
    kernel — same (+inf, -1) sentinel contract as shard probes — and
    returns a :class:`BatchProbeResult` keyed by ``tail_id`` (negative, so
    tail candidates never collide with shard ids in the merge)."""

    file_path: str = ""
    row_group: int = 0
    tail_id: int = -1  # synthetic plan-grid id (-1, -2, ... in tail order)
    queries: Optional[np.ndarray] = None  # (B_sub, D)
    query_index: Optional[np.ndarray] = None  # (B_sub,) positions in the batch
    k: int = 10
    oversample: int = 4
    metric: str = "l2"
    # row-aligned per-query predicates / planner ops (same semantics as
    # BatchProbeTaskInfo); None list entry = unfiltered / planner default
    filters: Optional[List[Optional[object]]] = None
    plan_ops: Optional[List[Optional[object]]] = None


@dataclass
class BatchProbeResult:
    shard_id: int
    executor_id: str
    # original batch position -> candidates for that query
    candidates: Dict[int, List[ProbeCandidate]] = field(default_factory=dict)
    cache_hit: bool = False
    probe_seconds: float = 0.0
    # masked top-k kernel calls this fragment cost: 1 per scoring flavor on
    # the mask-plane path, vs one per distinct predicate on the legacy
    # group loop — the coordinator sums these into
    # ``ProbeReport.kernel_dispatches`` and the bench gates on the drop
    kernel_dispatches: int = 0
    # MaskedBeam accounting (summed into the matching ProbeReport fields):
    # rows answered by the predicate-aware traversal, and how many of
    # those under-delivered into the fused exact-masked fallback
    masked_beam_rows: int = 0
    masked_beam_fallbacks: int = 0


def coalesce_batch_probes(tasks: Sequence[object]) -> List[object]:
    """Merge :class:`BatchProbeTaskInfo` fragments sharing a coalesce key
    into one fragment whose query block is the concatenation of the group's
    queries.  Non-batchable tasks pass through unchanged; output order is the
    order of first appearance (so shard-ordered input stays shard-ordered)."""
    groups: Dict[tuple, List[BatchProbeTaskInfo]] = {}
    order: List[tuple] = []  # ("task", obj) | ("group", key)
    for t in tasks:
        if isinstance(t, BatchProbeTaskInfo):
            key = t.coalesce_key()
            if key not in groups:
                groups[key] = []
                order.append(("group", key))
            groups[key].append(t)
        else:
            order.append(("task", t))
    out: List[object] = []
    for kind, item in order:
        if kind == "task":
            out.append(item)
            continue
        group = groups[item]
        if len(group) == 1:
            out.append(group[0])
            continue
        first = group[0]
        # per-query filters and plan ops ride along with their query rows; a
        # group with any filtered/planned member materializes aligned lists
        filters = None
        plan_ops = None
        if any(g.filters for g in group):
            filters = []
            for g in group:
                nq = g.queries.shape[0]
                filters.extend(g.filters if g.filters else [None] * nq)
        if any(g.plan_ops for g in group):
            plan_ops = []
            for g in group:
                nq = g.queries.shape[0]
                plan_ops.extend(g.plan_ops if g.plan_ops else [None] * nq)
        out.append(
            replace(
                first,
                task_id=f"{first.task_id}x{len(group)}",
                queries=np.concatenate([g.queries for g in group]),
                query_index=np.concatenate(
                    [np.asarray(g.query_index, np.int64) for g in group]
                ),
                filters=filters,
                plan_ops=plan_ops,
            )
        )
    return out


@dataclass
class RerankTaskInfo(TaskBase):
    # file -> row_group -> row offsets
    masks: Dict[str, Dict[int, List[int]]] = field(default_factory=dict)
    queries: Optional[np.ndarray] = None
    metric: str = "l2"
    # Batched-probe ownership: which batch queries may receive each row.
    # ``file_owners[fp]`` grants every row of ``fp`` to a query subset
    # (centroid routing); ``row_owners[fp][rg][off]`` grants a single row
    # (per-query DiskANN candidates).  Both None => every query owns every
    # row (single-query probes and full scans — the pre-batching semantics).
    file_owners: Optional[Dict[str, Set[int]]] = None
    row_owners: Optional[Dict[str, Dict[int, Dict[int, Set[int]]]]] = None


@dataclass
class RerankRow:
    file_path: str
    row_group: int
    row_offset: int
    distance: float


@dataclass
class RerankResult:
    executor_id: str
    # per query: list of reranked rows
    rows: List[List[RerankRow]] = field(default_factory=list)


# -- refresh (paper §7) -------------------------------------------------------------


@dataclass
class RefreshTaskInfo(TaskBase):
    shard_id: int = 0
    puffin_path: str = ""
    blob_offset: int = 0
    blob_length: int = 0
    blob_codec: Optional[str] = None
    added_files: List[str] = field(default_factory=list)
    removed_files: List[str] = field(default_factory=list)
    partition_centroids: Optional[np.ndarray] = None
    shard_of_partition: Optional[np.ndarray] = None
    output_path: str = ""
    include_vectors: bool = True


@dataclass
class RefreshResult:
    shard_id: int
    output_path: str
    executor_id: str
    inserted: int
    tombstoned: int
    vector_count: int
    byte_size: int
    tombstone_ratio: float
    refresh_seconds: float = 0.0
    # refreshed (file, row_group) membership over LIVE rows, for the
    # rebuilt zone map's shard-pruning table
    rg_membership: Optional[List[Tuple[str, int]]] = None
