"""Probe-plan IR: the single module that chooses probe plan ops.

Planning for the tiered probe path used to be smeared across three layers:
the coordinator picked prefilter/mask/postfilter bands from zone-map
selectivity (``MASK_MAX_FRAC`` and friends lived in coordinator.py), the
executor re-derived per-query kernel flavors from measured match counts
(``_plan_flavor`` / ``_pq_pool``), and the kernels imposed their own
dispatch granularity.  Any drift between those layers silently broke the
bit-for-bit parity the multi-mask tests and the ``table2.filtered_hetero``
bench gate assert.  This module turns the control flow into data:

- **Plan ops** — :class:`ExactScan`, :class:`PQScan`, :class:`Beam`,
  :class:`PostfilterBeam`, :class:`MaskedBeam`, :class:`Skip` — are frozen,
  hashable,
  JSON-serializable dataclasses annotated with the selectivity evidence
  (``est_frac``) that justified them.
- **Coordinator planning** (:func:`plan_filtered`, :func:`plan_unfiltered`)
  maps zone-map selectivity estimates (histogram-backed for int ranges) to
  per-(query, shard) ops before dispatch.
- **Executor resolution** (:func:`resolve`) refines a coordinator op once
  the exact predicate match count is known — tiny passing sets collapse to
  an exact scan, PQ scans get their pool pinned — so the executor is a pure
  plan *interpreter* with no thresholds of its own.
- **ProbePlan** bundles the per-(query, shard) op grid into a loggable,
  replayable artifact that rides :class:`~repro.runtime.coordinator.ProbeReport`.

Every selectivity threshold and flavor-classification rule in the probe
path lives HERE and nowhere else; both the mask-plane interpreter and the
retained ``force_group_loop`` baseline call the same :func:`resolve`, so
the two paths cannot drift apart.
"""

from __future__ import annotations

from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Tuple

# ---------------------------------------------------------------------------
# thresholds (the ONLY copies in the repo)
# ---------------------------------------------------------------------------

# Selectivity bands for filtered-probe planning: estimated passing fraction
# at or below PREFILTER_MAX_FRAC gets the pre-filter exact scan, up to
# MASK_MAX_FRAC the mask-aware kernel scan (masked rows lose inside the
# tile), above it the over-fetched post-filter beam.  The mask plan used to
# widen a beam pool by 1/selectivity — worth it only below ~0.5; as a
# single masked kernel call it stays cheaper than post-filter over-fetch up
# to much higher fractions, so the band is wide.
PREFILTER_MAX_FRAC = 0.10
MASK_MAX_FRAC = 0.75

# A query whose predicate passes at most max(SMALL_MATCH_FACTOR * k_eff,
# SMALL_MATCH_FLOOR) rows is cheaper to exact-scan than to search, whatever
# band the coordinator planned — executor-side resolution applies this once
# the true match count is known.
SMALL_MATCH_FACTOR = 4
SMALL_MATCH_FLOOR = 64

# Masked-ADC pool for the PQ mask plan: every passing code row is scored,
# the top pool survivors get the full-precision rerank.
PQ_POOL_FACTOR = 4
PQ_POOL_FLOOR = 32

# An unfiltered query riding a MIXED fragment (some queries filtered, some
# not) may share the fragment's masked-kernel dispatch as an all-ones row —
# but an all-ones row is an O(N·D) exact scan, so only below this shard
# size; larger shards route those queries to a shared beam pass instead.
EXACT_SCAN_MAX_ROWS = 4096

# Masked-beam traversal (big shards): the admitted-candidate target is
# k_eff widened by ~1/est_frac so the traversal converges instead of
# starving at low selectivity, clamped — beam width drives max_iters
# (~1.3*L), so beyond ~4x the widened traversal costs more than the
# exact-masked fallback it is trying to avoid.
MASKED_BEAM_MAX_WIDEN = 4.0

# Post-filter over-fetch: the beam pool is k_eff * clamp(1/est_frac,
# MIN_OVERFETCH, MAX_OVERFETCH).  Band-planned shards only reach the
# postfilter op above MASK_MAX_FRAC, where 1/frac < 1.34 — for them the
# MIN clamp (the historical 2x over-fetch) is the operative size, and the
# histograms' contribution to sizing is the accuracy of est_frac itself
# (band placement; a skew-corrected estimate below the band boundary means
# the shard takes the masked-kernel plan instead).  The MAX headroom
# applies to PostfilterBeam ops built OUTSIDE the band logic —
# hand-authored or replayed plans, future band shifts — and the
# exact-masked fallback bounds recall loss in every case.
POSTFILTER_MIN_OVERFETCH = 2.0
POSTFILTER_MAX_OVERFETCH = 4.0

# Quantized exact scans (ExactScan.dtype of "bf16"/"int8") score with value
# error, so they never emit results directly: the scan's top pool — k_eff
# widened by QUANT_GUARD_FACTOR, floored — feeds the full-precision
# gather-rerank guard, which re-scores the pool at f32 and emits the final
# k_eff.  The widening is what restores recall: a true top-k row demoted a
# few places by quantization noise still lands inside the pool.
QUANT_GUARD_FACTOR = 4
QUANT_GUARD_FLOOR = 32

# Scoring dtypes a plan may annotate on ExactScan (mirrors
# kernels/ref.SCORE_DTYPES; "f32" means no guard stage).
SCAN_DTYPES = ("f32", "bf16", "int8")


def quant_guard_pool(k_eff: int) -> int:
    """Oversampled pool a quantized scan hands the full-precision
    gather-rerank guard."""
    return max(QUANT_GUARD_FLOOR, QUANT_GUARD_FACTOR * max(1, int(k_eff)))


# ---------------------------------------------------------------------------
# plan ops
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class PlanOp:
    """Base class: a per-(query, shard) probe instruction."""

    def to_json(self) -> dict:
        out = {"op": type(self).__name__}
        out.update(asdict(self))
        return out


@dataclass(frozen=True)
class Skip(PlanOp):
    """No work for this (query, shard): zone-pruned before dispatch, or the
    measured match count was zero."""

    reason: str = "zone-pruned"


@dataclass(frozen=True)
class Beam(PlanOp):
    """Ordinary (unfiltered) graph beam search; ``width`` is the requested
    candidate count (k * oversample — the executor's ``_shard_search``
    honors it, capped by live rows; 0 falls back to the task's own
    k * oversample)."""

    width: int = 0


@dataclass(frozen=True)
class ExactScan(PlanOp):
    """Masked exact scan: one masked top-k kernel call ranks exactly the
    rows passing the (predicate AND tombstone) bitmask.  ``k`` is the
    output column count; ``est_frac`` the selectivity evidence (1.0 for the
    all-ones scan of an unfiltered query riding a mixed fragment).

    ``dtype`` annotates the scan's scoring precision (``f32``/``bf16``/
    ``int8``).  Quantized scans are a two-stage plan: the reduced-precision
    kernel ranks a :func:`quant_guard_pool`-sized pool, and the
    full-precision gather-rerank guard re-scores that pool before anything
    leaves the executor — so quantization costs bandwidth, not recall."""

    k: int = 0
    est_frac: float = 1.0
    dtype: str = "f32"


@dataclass(frozen=True)
class PQScan(PlanOp):
    """Masked PQ-ADC scan: one masked ADC kernel call scores every passing
    code row, the top ``pool`` survivors get a full-precision rerank down
    to ``k``."""

    pool: int = 0
    k: int = 0
    est_frac: float = 1.0


@dataclass(frozen=True)
class PostfilterBeam(PlanOp):
    """Most rows pass: over-fetch an ordinary beam to ``pool`` candidates,
    drop the ones failing the predicate, fall back to the masked exact scan
    for queries the beam under-delivered."""

    pool: int = 0
    k: int = 0
    est_frac: float = 1.0


@dataclass(frozen=True)
class MaskedBeam(PlanOp):
    """Predicate-aware graph traversal for a shard too large for even a
    masked linear scan: the beam expands *through* masked nodes — they keep
    their connectivity role in the frontier — but only mask-passing nodes
    are admitted to the result set.  ``width`` is the admitted-candidate
    target (k_eff widened by ~1/est_frac, clamped to
    MASKED_BEAM_MAX_WIDEN·k_eff, so the traversal converges instead of
    starving at low selectivity); the executor falls back to the fused
    exact-masked scan for queries the widened beam still under-delivers."""

    width: int = 0
    k: int = 0
    est_frac: float = 1.0


_OP_TYPES = {
    cls.__name__: cls
    for cls in (Skip, Beam, ExactScan, PQScan, PostfilterBeam, MaskedBeam)
}


def op_from_json(obj: dict) -> PlanOp:
    kind = obj.get("op")
    cls = _OP_TYPES.get(kind)
    if cls is None:
        raise ValueError(f"unknown plan op {kind!r}")
    kwargs = {k: v for k, v in obj.items() if k != "op"}
    return cls(**kwargs)


def op_token(op: PlanOp) -> str:
    """Human summary token for ``ProbeReport.filter_plan`` — kept aligned
    with the historical prefilter/mask/postfilter vocabulary so plan
    strings stay greppable across PRs."""
    if isinstance(op, Skip):
        return "pruned"
    if isinstance(op, Beam):
        return "beam"
    if isinstance(op, PQScan):
        return "mask"
    if isinstance(op, PostfilterBeam):
        return "postfilter"
    if isinstance(op, MaskedBeam):
        return "mbeam"
    # ExactScan: the band it came from is legible from the evidence
    if op.est_frac >= 1.0:
        return "exact"  # all-ones scan (unfiltered row in a mixed fragment)
    if op.est_frac <= PREFILTER_MAX_FRAC:
        return "prefilter"
    return "mask"


# ---------------------------------------------------------------------------
# coordinator-side planning
# ---------------------------------------------------------------------------


def postfilter_pool(k: int, oversample: int, frac: float) -> int:
    """Histogram-fed over-fetch sizing for the postfilter beam (see the
    POSTFILTER_* constants)."""
    k_eff = max(1, k * oversample)
    over = 1.0 / max(frac, 1e-6)
    over = min(max(over, POSTFILTER_MIN_OVERFETCH), POSTFILTER_MAX_OVERFETCH)
    return int(round(k_eff * over))


def masked_beam_width(k: int, oversample: int, frac: float) -> int:
    """Admitted-candidate target for a MaskedBeam: k_eff widened by
    1/est_frac so a low-selectivity traversal still surfaces k_eff passing
    nodes, clamped at MASKED_BEAM_MAX_WIDEN (see the constant's note)."""
    k_eff = max(1, k * oversample)
    widen = min(max(1.0 / max(frac, 1e-6), 1.0), MASKED_BEAM_MAX_WIDEN)
    return int(round(k_eff * widen))


def band_op(
    frac: float,
    *,
    k: int,
    oversample: int,
    use_pq: bool,
    shard_rows: Optional[int] = None,
    scan_dtype: str = "f32",
) -> PlanOp:
    """Map a shard's estimated passing fraction to its plan op.

    ``shard_rows`` is the shard-size evidence (``ShardInfo.vector_count``
    via the routing table): on a shard above EXACT_SCAN_MAX_ROWS every
    masked linear scan — prefilter or mask band — is the O(N·D) row the
    size cap exists to forbid, so selective predicates take the
    predicate-aware :class:`MaskedBeam` traversal instead.  Callers without
    size evidence (hand-built tasks, :func:`default_filtered_op`) omit it
    and keep the scan bands."""
    assert scan_dtype in SCAN_DTYPES, scan_dtype
    k_eff = max(1, k * oversample)
    big = shard_rows is not None and shard_rows > EXACT_SCAN_MAX_ROWS
    if big and frac <= MASK_MAX_FRAC:
        return MaskedBeam(
            width=masked_beam_width(k, oversample, frac), k=k_eff, est_frac=frac
        )
    if frac <= PREFILTER_MAX_FRAC:
        return ExactScan(k=k_eff, est_frac=frac, dtype=scan_dtype)
    if frac <= MASK_MAX_FRAC:
        if use_pq:
            pool = max(PQ_POOL_FACTOR * k_eff, PQ_POOL_FLOOR)
            return PQScan(pool=pool, k=k_eff, est_frac=frac)
        return ExactScan(k=k_eff, est_frac=frac, dtype=scan_dtype)
    return PostfilterBeam(
        pool=postfilter_pool(k, oversample, frac), k=k_eff, est_frac=frac
    )


def plan_filtered(
    pred, zonemap, routing, *, k: int, oversample: int, use_pq: bool,
    scan_dtype: str = "f32",
) -> Tuple[Dict[int, PlanOp], List[int], float]:
    """Per-shard plan ops for one predicate: zone-prune a shard outright or
    choose its band op from the estimated passing fraction of its member
    row groups (histogram-backed for int ranges).  Without a zone map
    (index built before the table had attributes) every shard gets the
    conservative over-fetched post-filter plan.

    Returns (shard_id -> op, pruned shard ids, global passing fraction)."""
    if zonemap is None:
        op = PostfilterBeam(
            pool=postfilter_pool(k, oversample, 1.0),
            k=max(1, k * oversample),
            est_frac=1.0,
        )
        return {s.shard_id: op for s in routing.shards}, [], 1.0

    def _frac(zones) -> float:
        rows, est = 0, 0.0
        for z in zones:
            c = next(iter(z.values())).count if z else 0
            rows += c
            est += pred.estimate_fraction(z) * c
        return est / max(rows, 1)

    all_zones = [z for per_file in zonemap.zones.values() for z in per_file]
    global_frac = _frac(all_zones)
    ops: Dict[int, PlanOp] = {}
    pruned: List[int] = []
    for s in routing.shards:
        shard_zones = zonemap.shard_zones(s.shard_id)
        if shard_zones is not None and not any(
            pred.zone_may_match(z) for z in shard_zones
        ):
            pruned.append(s.shard_id)
            continue
        frac = _frac(shard_zones) if shard_zones else global_frac
        ops[s.shard_id] = band_op(
            frac,
            k=k,
            oversample=oversample,
            use_pq=use_pq,
            shard_rows=s.vector_count,
            scan_dtype=scan_dtype,
        )
    return ops, pruned, global_frac


def plan_unfiltered(
    shard_rows: int, *, mixed: bool, k: int, oversample: int
) -> PlanOp:
    """Op for an unfiltered query: a plain beam, except when it rides a
    MIXED fragment on a small shard, where an all-ones exact-scan row is
    cheaper than splitting the fragment's kernel dispatch — the scan is
    size-capped (EXACT_SCAN_MAX_ROWS), never an unbounded O(N·D) row."""
    k_eff = max(1, k * oversample)
    if mixed and shard_rows <= EXACT_SCAN_MAX_ROWS:
        return ExactScan(k=k_eff, est_frac=1.0)
    return Beam(width=k_eff)


def plan_tail(
    row_counts: List[int], *, k: int, oversample: int, est_frac: float = 1.0
) -> Dict[int, PlanOp]:
    """Plan ops for the fresh-tail tier: one op per appended-but-unindexed
    row group, keyed by its synthetic plan-grid id (-1, -2, ... in tail
    order — negative so tail rows never collide with shard ids).

    Tail row groups have no graph and no PQ codes, so every op is an
    :class:`ExactScan` over the row group's rows (the masked kernel path —
    predicates ride the same bitmask input as shard scans).  ``est_frac``
    carries the predicate's estimated passing fraction as evidence; the
    executor still resolves against the measured match count, so a
    zero-match tail row group collapses to :class:`Skip`."""
    k_eff = max(1, k * oversample)
    return {
        -(i + 1): ExactScan(k=min(k_eff, max(1, int(n))), est_frac=est_frac)
        for i, n in enumerate(row_counts)
    }


def default_filtered_op(k: int, oversample: int, use_pq: bool) -> PlanOp:
    """Fallback for tasks carrying a predicate but no coordinator op (e.g.
    hand-built tasks in tests): the mid-band mask plan, matching the old
    ``filter_mode="mask"`` default."""
    return band_op(0.5, k=k, oversample=oversample, use_pq=use_pq)


# ---------------------------------------------------------------------------
# executor-side resolution
# ---------------------------------------------------------------------------


def resolve(
    op: PlanOp, *, match_count: int, k: int, oversample: int, has_pq: bool
) -> PlanOp:
    """Refine a coordinator op with the measured predicate match count.

    This is the per-query flavor classification both executor paths (the
    mask-plane interpreter AND the ``force_group_loop`` baseline) share, so
    they can never drift apart:

    - zero matches  -> :class:`Skip`;
    - a small passing set (<= max(SMALL_MATCH_FACTOR·k_eff,
      SMALL_MATCH_FLOOR)) -> :class:`ExactScan`, whatever the band —
      scanning a handful of rows exactly beats searching;
    - :class:`PQScan` keeps its ADC pool (pinned: the not-small condition
      guarantees k_eff == k·oversample, so the pool is one shared constant
      for every PQ-flavor query of a fragment), degrading to
      :class:`ExactScan` when the shard carries no codes;
    - :class:`PostfilterBeam` keeps its coordinator-sized pool;
    - :class:`Beam` / :class:`Skip` pass through untouched.
    """
    if isinstance(op, (Skip, Beam)):
        return op
    if match_count <= 0:
        return Skip(reason="no-match")
    # the scoring-dtype annotation survives every ExactScan refinement —
    # collapses FROM other op kinds score f32 (tiny sets gain nothing from
    # quantization, and non-scan ops carry no annotation to preserve)
    dtype = op.dtype if isinstance(op, ExactScan) else "f32"
    k_eff = min(max(1, k * oversample), match_count)
    small = match_count <= max(SMALL_MATCH_FACTOR * k_eff, SMALL_MATCH_FLOOR)
    if small:
        return ExactScan(k=k_eff, est_frac=op.est_frac, dtype=dtype)
    if isinstance(op, PQScan):
        if not has_pq:
            return ExactScan(k=k_eff, est_frac=op.est_frac)
        pool = min(match_count, max(PQ_POOL_FACTOR * k_eff, PQ_POOL_FLOOR))
        return PQScan(pool=int(pool), k=k_eff, est_frac=op.est_frac)
    if isinstance(op, PostfilterBeam):
        return PostfilterBeam(pool=op.pool, k=k_eff, est_frac=op.est_frac)
    if isinstance(op, MaskedBeam):
        width = max(k_eff, min(op.width, match_count))
        return MaskedBeam(width=int(width), k=k_eff, est_frac=op.est_frac)
    return ExactScan(k=k_eff, est_frac=op.est_frac, dtype=dtype)


# ---------------------------------------------------------------------------
# the plan artifact
# ---------------------------------------------------------------------------


@dataclass
class ProbePlan:
    """The serializable per-(query, shard) op grid of one probe.

    ``ops[qi][shard_id]`` is the coordinator op for query ``qi`` on that
    shard (zone-pruned fragments appear as :class:`Skip` entries, so the
    plan records every routing decision, not just the dispatched ones).  A
    single-predicate :meth:`Coordinator.probe` plans one pseudo-query row.
    The plan rides ``ProbeReport.plan`` — loggable, diffable in tests, and
    replayable through :meth:`from_json`."""

    k: int
    oversample: int
    use_pq: bool
    ops: List[Dict[int, PlanOp]] = field(default_factory=list)
    est_selectivity: float = 1.0
    pruned_shards: Tuple[int, ...] = ()

    def op_for(self, qi: int, shard_id: int) -> Optional[PlanOp]:
        if qi >= len(self.ops):
            return None
        return self.ops[qi].get(shard_id)

    def summary(self) -> str:
        """Token:count plan string, one segment per distinct per-query op
        row — e.g. ``"mask:2,prefilter:1,pruned:1"`` — matching the legacy
        ``filter_plan`` vocabulary."""
        segments: List[str] = []
        for row in self.ops:
            counts: Dict[str, int] = {}
            for op in row.values():
                tok = op_token(op)
                counts[tok] = counts.get(tok, 0) + 1
            seg = ",".join(f"{t}:{c}" for t, c in sorted(counts.items()))
            if seg and seg not in segments:
                segments.append(seg)
        return ";".join(segments)

    def kernel_eligible(self, qi: int, shard_id: int) -> bool:
        """Whether this (query, shard) is planned onto a masked-kernel
        dispatch (vs a beam/postfilter pass)."""
        op = self.op_for(qi, shard_id)
        return isinstance(op, (ExactScan, PQScan))

    def to_json(self) -> dict:
        return {
            "k": self.k,
            "oversample": self.oversample,
            "use_pq": self.use_pq,
            "est_selectivity": self.est_selectivity,
            "pruned_shards": list(self.pruned_shards),
            "ops": [
                {str(sid): op.to_json() for sid, op in sorted(row.items())}
                for row in self.ops
            ],
        }

    @staticmethod
    def from_json(obj: dict) -> "ProbePlan":
        return ProbePlan(
            k=int(obj["k"]),
            oversample=int(obj["oversample"]),
            use_pq=bool(obj["use_pq"]),
            est_selectivity=float(obj.get("est_selectivity", 1.0)),
            pruned_shards=tuple(obj.get("pruned_shards", ())),
            ops=[
                {int(sid): op_from_json(op) for sid, op in row.items()}
                for row in obj.get("ops", [])
            ],
        )
