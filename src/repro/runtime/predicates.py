"""Attribute-predicate IR for filtered vector search.

Hybrid queries — "nearest neighbors of q WHERE category = 'x' AND price < t"
— push a predicate tree through the probe path.  The same tree is consumed
at three altitudes:

- **zone pruning** (coordinator): :meth:`Predicate.zone_may_match` against a
  per-row-group zone (min/max for numeric columns, value→count tags for
  dictionary columns) decides whether a row group can contain a match, and
  :meth:`Predicate.estimate_fraction` turns the zone statistics into a
  selectivity estimate that drives per-shard plan selection;
- **row masking** (executor / coordinator scan): :func:`row_group_mask`
  evaluates the tree against a row group's attribute arrays, mapping string
  literals through the file's own dictionary so per-file code spaces never
  leak into the IR;
- **SQL surface** (frontend): :func:`parse_predicate` parses the WHERE
  fragment grammar ``col = lit | col IN (...) | col <op> num |
  col BETWEEN a AND b`` combined with AND / OR (AND binds tighter).

Predicates are equality-comparable dataclasses: fragment coalescing groups
queries whose predicate trees compare equal, so one mask evaluation covers
every query in the group.
"""

from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np


class PredicateError(ValueError):
    pass


@dataclass(frozen=True)
class ColumnHistogram:
    """Equi-width histogram of one int column over one FILE (all row
    groups).  Range predicates estimate their passing fraction from bin
    overlap — far tighter than the (hi-lo)/span guess on skewed data — and
    the estimate feeds the planner's PostfilterBeam pool sizing.  Stored
    once per (file, column) in the ``repro.attr-zonemap-v1`` blob and
    attached to each row group's :class:`ZoneStats` at decode (the per-rg
    estimate therefore reflects the file's distribution)."""

    lo: float
    hi: float
    counts: Tuple[int, ...]

    @property
    def total(self) -> int:
        return sum(self.counts)

    def fraction_between(self, lo: Optional[float], hi: Optional[float]) -> float:
        """Estimated fraction of rows with lo <= value <= hi (either bound
        optional; bound exclusivity is below bin resolution and ignored)."""
        if self.total == 0:
            return 0.0
        q_lo = self.lo if lo is None else max(float(lo), self.lo)
        # +1 closes the last bin: values == hi land in [hi, hi+width) terms
        q_hi = (self.hi + 1.0) if hi is None else min(float(hi) + 1.0, self.hi + 1.0)
        if q_hi <= q_lo:
            return 0.0
        width = (self.hi + 1.0 - self.lo) / len(self.counts)
        covered = 0.0
        for b, c in enumerate(self.counts):
            b_lo = self.lo + b * width
            b_hi = b_lo + width
            overlap = min(q_hi, b_hi) - max(q_lo, b_lo)
            if overlap > 0:
                covered += c * (overlap / width)
        return min(1.0, covered / self.total)

    def to_json(self) -> dict:
        return {"lo": self.lo, "hi": self.hi, "counts": list(self.counts)}

    @staticmethod
    def from_json(obj: dict) -> "ColumnHistogram":
        return ColumnHistogram(
            lo=float(obj["lo"]), hi=float(obj["hi"]), counts=tuple(obj["counts"])
        )

    @staticmethod
    def build(values, bins: int = 16) -> Optional["ColumnHistogram"]:
        arr = np.asarray(values)
        if arr.size == 0:
            return None
        lo, hi = float(arr.min()), float(arr.max())
        counts, _edges = np.histogram(arr, bins=bins, range=(lo, hi + 1.0))
        return ColumnHistogram(lo=lo, hi=hi, counts=tuple(int(c) for c in counts))

    @staticmethod
    def merge(
        hists: List["ColumnHistogram"], bins: int = 16
    ) -> Optional["ColumnHistogram"]:
        """Merge several (file-level) histograms into one equi-width
        histogram over the union range, distributing each source bin's mass
        into the overlapped target bins proportionally.  Counts may come
        out fractional — the merged histogram is an in-memory estimation
        aid (shard-level selectivity evidence), never serialized."""
        hists = [h for h in hists if h is not None and h.total > 0]
        if not hists:
            return None
        if len(hists) == 1:
            return hists[0]
        lo = min(h.lo for h in hists)
        hi = max(h.hi for h in hists)
        width = (hi + 1.0 - lo) / bins
        counts = [0.0] * bins
        for h in hists:
            src_w = (h.hi + 1.0 - h.lo) / len(h.counts)
            for b, c in enumerate(h.counts):
                if not c:
                    continue
                b_lo = h.lo + b * src_w
                b_hi = b_lo + src_w
                t0 = max(0, int((b_lo - lo) / width))
                t1 = min(bins - 1, int((b_hi - lo - 1e-9) / width))
                for t in range(t0, t1 + 1):
                    tb_lo = lo + t * width
                    overlap = min(b_hi, tb_lo + width) - max(b_lo, tb_lo)
                    if overlap > 0:
                        counts[t] += c * (overlap / src_w)
        return ColumnHistogram(lo=lo, hi=hi, counts=tuple(counts))


@dataclass(frozen=True)
class ZoneStats:
    """One (row_group, column) zone-map entry.

    Numeric columns carry ``min``/``max`` plus an optional file-level
    equi-width :class:`ColumnHistogram`; dictionary columns carry
    ``values`` (value → row count).  ``count`` is the row-group size.  The
    histogram is serialized once per (file, column) by the zone-map blob
    codec, not inside each zone entry."""

    count: int
    min: Optional[float] = None
    max: Optional[float] = None
    values: Optional[Dict[str, int]] = None
    hist: Optional[ColumnHistogram] = None

    def to_json(self) -> dict:
        out: dict = {"count": self.count}
        if self.values is not None:
            out["values"] = dict(self.values)
        else:
            out["min"] = self.min
            out["max"] = self.max
        return out

    @staticmethod
    def from_json(obj: dict) -> "ZoneStats":
        if "values" in obj:
            return ZoneStats(count=int(obj["count"]), values=dict(obj["values"]))
        return ZoneStats(count=int(obj["count"]), min=obj["min"], max=obj["max"])


# ---------------------------------------------------------------------------
# predicate tree
# ---------------------------------------------------------------------------


def _codes_for(values: Sequence, dictionary: List[str]) -> np.ndarray:
    """Map literal values to this file's dictionary codes (-1 = absent)."""
    lut = {v: i for i, v in enumerate(dictionary)}
    return np.asarray([lut.get(str(v), -1) for v in values], np.int64)


@dataclass(frozen=True)
class Predicate:
    def columns(self) -> frozenset:
        raise NotImplementedError

    def mask(self, arr: np.ndarray, dictionary: Optional[List[str]]) -> np.ndarray:
        raise NotImplementedError

    def evaluate(
        self,
        columns: Dict[str, np.ndarray],
        dictionaries: Optional[Dict[str, List[str]]] = None,
    ) -> np.ndarray:
        """Row mask over aligned attribute arrays.  ``dictionaries`` maps
        dictionary-encoded column names to their value tables; when a column
        is passed as decoded values (strings), omit its dictionary."""
        raise NotImplementedError

    def zone_may_match(self, zones: Dict[str, ZoneStats]) -> bool:
        """False only if NO row in the zone can satisfy the predicate.
        Columns missing from the zone are conservatively assumed to match."""
        raise NotImplementedError

    def estimate_fraction(self, zones: Dict[str, ZoneStats]) -> float:
        """Estimated fraction of the zone's rows that pass (∈ [0, 1])."""
        raise NotImplementedError


@dataclass(frozen=True)
class _Leaf(Predicate):
    column: str = ""

    def columns(self) -> frozenset:
        return frozenset({self.column})

    def evaluate(self, columns, dictionaries=None):
        arr = columns[self.column]
        dictionary = (dictionaries or {}).get(self.column)
        return self.mask(np.asarray(arr), dictionary)


@dataclass(frozen=True)
class Eq(_Leaf):
    value: object = None

    def mask(self, arr, dictionary):
        if dictionary is not None:
            (code,) = _codes_for([self.value], dictionary)
            return arr == code
        if arr.dtype.kind in ("U", "S", "O"):
            return arr.astype(str) == str(self.value)
        if isinstance(self.value, str):  # string literal vs numeric column
            return np.zeros(arr.shape[0], bool)
        return arr == self.value

    def zone_may_match(self, zones):
        z = zones.get(self.column)
        if z is None:
            return True
        if z.values is not None:
            return str(self.value) in z.values
        if isinstance(self.value, str):
            return False
        return z.min <= self.value <= z.max

    def estimate_fraction(self, zones):
        z = zones.get(self.column)
        if z is None or z.count == 0:
            return 1.0
        if z.values is not None:
            return z.values.get(str(self.value), 0) / z.count
        if not self.zone_may_match(zones):
            return 0.0
        span = max(float(z.max) - float(z.min), 1.0)
        return min(1.0, 1.0 / span)


@dataclass(frozen=True)
class In(_Leaf):
    values: Tuple = ()

    def mask(self, arr, dictionary):
        if dictionary is not None:
            codes = _codes_for(self.values, dictionary)
            return np.isin(arr, codes[codes >= 0])
        if arr.dtype.kind in ("U", "S", "O"):
            return np.isin(arr.astype(str), [str(v) for v in self.values])
        nums = [v for v in self.values if not isinstance(v, str)]
        return np.isin(arr, nums) if nums else np.zeros(arr.shape[0], bool)

    def zone_may_match(self, zones):
        z = zones.get(self.column)
        if z is None:
            return True
        if z.values is not None:
            return any(str(v) in z.values for v in self.values)
        return any(
            z.min <= v <= z.max for v in self.values if not isinstance(v, str)
        )

    def estimate_fraction(self, zones):
        z = zones.get(self.column)
        if z is None or z.count == 0:
            return 1.0
        if z.values is not None:
            return min(1.0, sum(z.values.get(str(v), 0) for v in self.values) / z.count)
        span = max(float(z.max) - float(z.min), 1.0)
        hits = sum(
            1 for v in self.values if not isinstance(v, str) and z.min <= v <= z.max
        )
        return min(1.0, hits / span)


@dataclass(frozen=True)
class Range(_Leaf):
    """lo <= col <= hi (either bound optional; exclusivity per flag)."""

    lo: Optional[float] = None
    hi: Optional[float] = None
    lo_inclusive: bool = True
    hi_inclusive: bool = True

    def mask(self, arr, dictionary):
        if dictionary is not None or arr.dtype.kind in ("U", "S", "O"):
            # range over a string/dictionary column matches nothing — the
            # same conservative convention as Eq/In type mismatches, so a
            # mistyped WHERE never crash-loops executor task retries
            return np.zeros(arr.shape[0], bool)
        out = np.ones(arr.shape[0], bool)
        if self.lo is not None:
            out &= (arr >= self.lo) if self.lo_inclusive else (arr > self.lo)
        if self.hi is not None:
            out &= (arr <= self.hi) if self.hi_inclusive else (arr < self.hi)
        return out

    def zone_may_match(self, zones):
        z = zones.get(self.column)
        if z is None:
            return True
        if z.values is not None:
            return False  # range over a dictionary column matches nothing
        if self.lo is not None and (z.max < self.lo or (z.max == self.lo and not self.lo_inclusive)):
            return False
        if self.hi is not None and (z.min > self.hi or (z.min == self.hi and not self.hi_inclusive)):
            return False
        return True

    def estimate_fraction(self, zones):
        z = zones.get(self.column)
        if z is None or z.count == 0:
            return 1.0
        if z.values is not None:
            return 0.0
        if not self.zone_may_match(zones):
            return 0.0
        if z.hist is not None:
            # histogram-backed estimate: bin-overlap mass instead of the
            # uniform (hi-lo)/span guess — robust to skewed columns, and
            # the signal the planner's band selection keys on.  The
            # histogram is FILE-level, so condition it on this row group's
            # own [min, max]: P(pass | value in rg range).  That keeps the
            # per-rg tightening the span estimator had (a sorted column's
            # fully-passing row group must estimate ~1.0, not the file-
            # wide fraction) on top of the skew-awareness.
            z_lo, z_hi = float(z.min), float(z.max)
            # fraction_between treats both bounds as inclusive; histograms
            # only exist for int columns, so a strict bound shifts by
            # exactly one — without this, 'price < 1' on a column
            # concentrated at 1 would count value 1's whole mass and flip
            # the planner band from prefilter to postfilter
            lo_q = self.lo if (self.lo is None or self.lo_inclusive) else float(self.lo) + 1.0
            hi_q = self.hi if (self.hi is None or self.hi_inclusive) else float(self.hi) - 1.0
            lo_c = z_lo if lo_q is None else max(float(lo_q), z_lo)
            hi_c = z_hi if hi_q is None else min(float(hi_q), z_hi)
            if hi_c < lo_c:
                return 0.0
            denom = z.hist.fraction_between(z_lo, z_hi)
            if denom > 0.0:
                return min(1.0, z.hist.fraction_between(lo_c, hi_c) / denom)
        span = float(z.max) - float(z.min)
        if span <= 0:
            return 1.0
        lo = float(z.min) if self.lo is None else max(float(z.min), float(self.lo))
        hi = float(z.max) if self.hi is None else min(float(z.max), float(self.hi))
        return min(1.0, max(0.0, (hi - lo) / span))


@dataclass(frozen=True)
class And(Predicate):
    children: Tuple[Predicate, ...] = ()

    def columns(self):
        return frozenset().union(*(c.columns() for c in self.children))

    def evaluate(self, columns, dictionaries=None):
        out = self.children[0].evaluate(columns, dictionaries)
        for c in self.children[1:]:
            out = out & c.evaluate(columns, dictionaries)
        return out

    def zone_may_match(self, zones):
        return all(c.zone_may_match(zones) for c in self.children)

    def estimate_fraction(self, zones):
        f = 1.0
        for c in self.children:
            f *= c.estimate_fraction(zones)
        return f


@dataclass(frozen=True)
class Or(Predicate):
    children: Tuple[Predicate, ...] = ()

    def columns(self):
        return frozenset().union(*(c.columns() for c in self.children))

    def evaluate(self, columns, dictionaries=None):
        out = self.children[0].evaluate(columns, dictionaries)
        for c in self.children[1:]:
            out = out | c.evaluate(columns, dictionaries)
        return out

    def zone_may_match(self, zones):
        return any(c.zone_may_match(zones) for c in self.children)

    def estimate_fraction(self, zones):
        return min(1.0, sum(c.estimate_fraction(zones) for c in self.children))


# ---------------------------------------------------------------------------
# row-group evaluation against a vparquet reader
# ---------------------------------------------------------------------------


def row_group_mask(pred: Predicate, reader, rg_id: int) -> np.ndarray:
    """Evaluate ``pred`` over one row group of a :class:`VParquetReader`,
    reading only the referenced attribute columns (column projection).

    A file written without one of the referenced columns (mixed-schema
    appends) matches nothing on that column's leaves — a NaN sentinel
    column makes every Eq/In/Range over it evaluate False while Or-siblings
    on present columns still work.  The oracle's scan path and the
    executor's bitmask path share this function, so parity is preserved
    rather than the probe crashing on older files."""
    columns: Dict[str, np.ndarray] = {}
    dictionaries: Dict[str, List[str]] = {}
    n_rows = reader.row_groups[rg_id]["num_rows"]
    for name in sorted(pred.columns()):
        spec = reader.columns.get(name)
        # missing columns AND non-scalar columns (e.g. the vector column
        # itself) get the sentinel — a 2-D read would otherwise corrupt the
        # row mask shape, crashing the index path while the scan path
        # silently mis-filtered
        if spec is None or spec.vlen != 0:
            columns[name] = np.full(n_rows, np.nan)  # NaN: no leaf matches
            continue
        columns[name] = reader.read_column(name, [rg_id])
        if spec.dictionary is not None:
            dictionaries[name] = spec.dictionary
    return pred.evaluate(columns, dictionaries)


# ---------------------------------------------------------------------------
# WHERE-fragment parser
# ---------------------------------------------------------------------------

_TOKEN = re.compile(
    r"\s*(?:(?P<str>'(?:[^']|'')*')|(?P<num>-?\d+(?:\.\d+)?(?:[eE][+-]?\d+)?)"
    r"|(?P<op><=|>=|!=|<>|=|<|>|\(|\)|,)|(?P<word>\w+))"
)


def _tokenize(text: str) -> List[Tuple[str, str]]:
    out, pos = [], 0
    while pos < len(text):
        m = _TOKEN.match(text, pos)
        if not m or m.end() == pos:
            if text[pos:].strip():
                raise PredicateError(f"bad predicate near {text[pos:pos+20]!r}")
            break
        pos = m.end()
        if m.group("str") is not None:
            out.append(("str", m.group("str")[1:-1].replace("''", "'")))
        elif m.group("num") is not None:
            raw = m.group("num")
            out.append(("num", float(raw) if ("." in raw or "e" in raw.lower()) else int(raw)))
        elif m.group("op") is not None:
            out.append(("op", m.group("op")))
        else:
            out.append(("word", m.group("word")))
    return out


class _Parser:
    def __init__(self, tokens: List[Tuple[str, str]]) -> None:
        self.toks = tokens
        self.pos = 0

    def _peek(self):
        return self.toks[self.pos] if self.pos < len(self.toks) else (None, None)

    def _next(self):
        tok = self._peek()
        self.pos += 1
        return tok

    def _expect_word(self, *words: str):
        kind, val = self._next()
        if kind != "word" or val.upper() not in words:
            raise PredicateError(f"expected {'/'.join(words)}, got {val!r}")
        return val.upper()

    def parse(self) -> Predicate:
        pred = self._or()
        if self.pos != len(self.toks):
            raise PredicateError(f"trailing tokens at {self.toks[self.pos:]}")
        return pred

    def _or(self) -> Predicate:
        terms = [self._and()]
        while self._peek()[0] == "word" and self._peek()[1].upper() == "OR":
            self._next()
            terms.append(self._and())
        return terms[0] if len(terms) == 1 else Or(tuple(terms))

    def _and(self) -> Predicate:
        terms = [self._atom()]
        while self._peek()[0] == "word" and self._peek()[1].upper() == "AND":
            self._next()
            terms.append(self._atom())
        return terms[0] if len(terms) == 1 else And(tuple(terms))

    def _atom(self) -> Predicate:
        kind, val = self._next()
        if kind == "op" and val == "(":
            inner = self._or()
            kind, val = self._next()
            if (kind, val) != ("op", ")"):
                raise PredicateError("unbalanced parenthesis")
            return inner
        if kind != "word":
            raise PredicateError(f"expected column name, got {val!r}")
        column = val
        kind, op = self._next()
        if kind == "word" and op.upper() == "IN":
            k, v = self._next()
            if (k, v) != ("op", "("):
                raise PredicateError("IN requires a parenthesized list")
            values = []
            while True:
                k, v = self._next()
                if k not in ("str", "num"):
                    raise PredicateError(f"bad IN literal {v!r}")
                values.append(v)
                k, v = self._next()
                if (k, v) == ("op", ")"):
                    break
                if (k, v) != ("op", ","):
                    raise PredicateError("bad IN list")
            return In(column, tuple(values))
        if kind == "word" and op.upper() == "BETWEEN":
            k, lo = self._next()
            if k != "num":
                raise PredicateError("BETWEEN requires numeric bounds")
            self._expect_word("AND")
            k, hi = self._next()
            if k != "num":
                raise PredicateError("BETWEEN requires numeric bounds")
            return Range(column, lo=lo, hi=hi)
        if kind != "op" or op not in ("=", "!=", "<>", "<", "<=", ">", ">="):
            raise PredicateError(f"bad operator {op!r}")
        k, lit = self._next()
        if k not in ("str", "num"):
            raise PredicateError(f"bad literal {lit!r}")
        if op == "=":
            return Eq(column, lit)
        if op in ("!=", "<>"):
            raise PredicateError("!= is not supported (no zone-safe pruning)")
        if k == "str":
            raise PredicateError(f"range comparison on string literal {lit!r}")
        if op == "<":
            return Range(column, hi=lit, hi_inclusive=False)
        if op == "<=":
            return Range(column, hi=lit)
        if op == ">":
            return Range(column, lo=lit, lo_inclusive=False)
        return Range(column, lo=lit)


def parse_predicate(text: str) -> Predicate:
    """Parse a SQL WHERE fragment into a :class:`Predicate` tree."""
    toks = _tokenize(text)
    if not toks:
        raise PredicateError("empty predicate")
    return _Parser(toks).parse()
