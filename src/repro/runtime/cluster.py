"""Local cluster factory: object store + catalog + executor fleet + coordinator.

The in-process analogue of deploying FlockDB: one object store ("S3"), one
REST catalog, N executors each with an SSD-cache directory, one coordinator.
Used by examples, benchmarks, and integration tests.
"""

from __future__ import annotations

import os
from dataclasses import dataclass
from typing import List

from repro.iceberg.catalog import RestCatalog
from repro.lakehouse.objectstore import ObjectStore
from repro.runtime.coordinator import Coordinator
from repro.runtime.executor import Executor
from repro.runtime.scheduler import ExecutorPool


@dataclass
class LocalCluster:
    root: str
    store: ObjectStore
    catalog: RestCatalog
    executors: List[Executor]
    pool: ExecutorPool
    coordinator: Coordinator

    def add_executor(self) -> Executor:
        """Elastic scale-out: a brand new, empty-cache executor."""
        eid = f"ex-{len(self.executors)}"
        ex = Executor(
            eid,
            self.store,
            os.path.join(self.root, "cache", eid),
        )
        self.executors.append(ex)
        self.pool.add(ex)
        return ex

    def remove_executor(self, executor_id: str) -> None:
        """Elastic scale-in (the executor's cache is disposable state)."""
        self.pool.remove(executor_id)


def make_local_cluster(
    root: str,
    num_executors: int = 4,
    *,
    enable_speculation: bool = False,
    max_attempts: int = 4,
    lease_ttl: float | None = None,
    probe_cache=None,
) -> LocalCluster:
    store = ObjectStore(os.path.join(root, "s3"))
    catalog = RestCatalog(store)
    executors = [
        Executor(f"ex-{i}", store, os.path.join(root, "cache", f"ex-{i}"))
        for i in range(num_executors)
    ]
    pool = ExecutorPool(executors)
    coordinator = Coordinator(
        catalog,
        pool,
        enable_speculation=enable_speculation,
        max_attempts=max_attempts,
        # optional serving-tier ShardProbeCache — None keeps every probe
        # fully computed (the default for tests and benches)
        probe_cache=probe_cache,
    )
    if lease_ttl is not None:
        # chaos / failover tests shrink the shard-lease TTL so a silent
        # executor ages out of its leases within the test's patience
        coordinator.scheduler.leases.ttl = float(lease_ttl)
    return LocalCluster(
        root=root,
        store=store,
        catalog=catalog,
        executors=executors,
        pool=pool,
        coordinator=coordinator,
    )
