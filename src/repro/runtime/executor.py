"""Executor process model (paper §3.1): stateless worker + SSD/L1 caches.

An executor owns:
- an **SSD cache** directory keyed by ``(object_path, credential_fingerprint,
  byte_range, etag)`` — raw blob bytes survive across tasks and are safe to
  lose (the object store is the source of truth);
- an **L1 cache** of deserialized Vamana graphs (bounded LRU);
- task handlers for the five fragment kinds: partition scan, shard build,
  shard probe, exact rerank, shard refresh.

Failure-injection hooks (``kill()``, ``fail_next()``, ``delay_next()``)
drive the fault-tolerance and straggler tests.
"""

from __future__ import annotations

import hashlib
import os
import threading
import time
from collections import OrderedDict
from typing import Dict, List, Optional, Tuple

import numpy as np

from repro.core.blobs import ShardLocationMap, decode_shard_blob, encode_shard_blob
from repro.runtime import planner
from repro.runtime.predicates import row_group_mask
from repro.core.vamana import VamanaGraph, VamanaParams, build_vamana
from repro.core.pq import PQCodebook, encode as pq_encode
from repro.iceberg.puffin import _decompress  # codec shared with Puffin blobs
from repro.kernels import device_cache, ops
from repro.lakehouse.objectstore import ObjectStore
from repro.lakehouse.vparquet import VParquetReader
from repro.runtime import fragments as F

import jax.numpy as jnp


class ExecutorDead(RuntimeError):
    """Raised when a task lands on a dead executor (heartbeat timeout)."""


class InjectedFailure(RuntimeError):
    """Deterministic task failure for tests."""


def _scan_files_with_locations(
    store: ObjectStore, files: List[str]
) -> Tuple[np.ndarray, np.ndarray, np.ndarray, np.ndarray, List[str]]:
    """Read the vector column of ``files`` with per-row locations.

    Returns (vectors, file_idx, row_group, row_offset, file_paths)."""
    vecs: List[np.ndarray] = []
    fidx: List[np.ndarray] = []
    rgrp: List[np.ndarray] = []
    roff: List[np.ndarray] = []
    for i, path in enumerate(files):
        r = VParquetReader.from_store(store, path)
        for rg_id, rg in enumerate(r.row_groups):
            arr = r.read_column("vec", [rg_id])
            n = arr.shape[0]
            vecs.append(arr)
            fidx.append(np.full(n, i, np.uint32))
            rgrp.append(np.full(n, rg_id, np.uint32))
            roff.append(np.arange(n, dtype=np.uint32))
    if not vecs:
        return (
            np.empty((0, 0), np.float32),
            np.empty(0, np.uint32),
            np.empty(0, np.uint32),
            np.empty(0, np.uint32),
            list(files),
        )
    return (
        np.concatenate(vecs),
        np.concatenate(fidx),
        np.concatenate(rgrp),
        np.concatenate(roff),
        list(files),
    )


def _locmap_membership(
    locmap: ShardLocationMap, n: int, live: Optional[np.ndarray] = None
) -> List[Tuple[str, int]]:
    """Distinct (file_path, row_group) pairs a shard's (live) rows occupy —
    the zone-map membership used for coordinator-side shard pruning."""
    fidx = np.asarray(locmap.file_idx[:n], np.int64)
    rgrp = np.asarray(locmap.row_group[:n], np.int64)
    if live is not None:
        fidx, rgrp = fidx[live[:n]], rgrp[live[:n]]
    return sorted({(locmap.file_paths[int(f)], int(g)) for f, g in zip(fidx, rgrp)})


def _owner_shards(
    vectors: np.ndarray, centroids: np.ndarray, shard_of_partition: np.ndarray
) -> np.ndarray:
    part, _ = ops.kmeans_assign(
        jnp.asarray(vectors), jnp.asarray(centroids), backend="ref"
    )
    return shard_of_partition[np.asarray(part)]


class Executor:
    def __init__(
        self,
        executor_id: str,
        store: ObjectStore,
        cache_dir: str,
        *,
        l1_capacity: int = 4,
        credential_fingerprint: str = "default-cred",
    ) -> None:
        self.executor_id = executor_id
        self.store = store
        self.cache_dir = cache_dir
        os.makedirs(cache_dir, exist_ok=True)
        self.cred = credential_fingerprint
        self._l1: "OrderedDict[str, Tuple[VamanaGraph, ShardLocationMap]]" = OrderedDict()
        self._l1_capacity = l1_capacity
        # filtered search: (shard key, predicate) -> per-vector-id bool mask
        self._mask_cache: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
        self._mask_cache_capacity = 64
        self._lock = threading.Lock()
        # debug/bench escape hatches: route heterogeneous-filter fragments
        # through the legacy one-kernel-call-per-predicate-group loop
        # instead of the single mask-plane call (parity tests and the
        # table2.filtered_hetero bench compare the two paths), and/or keep
        # mixed exact+PQ fragments on separate per-flavor dispatches
        # instead of the fused unified kernel (the
        # table2.filtered_mixed_flavor bench compares one vs two dispatches
        # per shard).  Both paths interpret the SAME planner-resolved ops.
        self.force_group_loop = False
        self.force_split_flavors = False
        # failure injection
        self.dead = False
        self._fail_budget = 0
        self._delay_next = 0.0
        self._kill_mid_task = 0
        self._kill_hold_s = 0.0
        # metrics
        self.tasks_done = 0
        self.cache_hits = 0
        self.cache_misses = 0
        # masked top-k kernel calls issued (single- and multi-mask flavors).
        # The executor-wide total is lock-guarded; the per-TASK counts in
        # Probe/BatchProbeResult come from a thread-local tally (each task
        # attempt runs on its own scheduler thread), so concurrent probes
        # on one executor cannot misattribute each other's dispatches.
        self.masked_kernel_dispatches = 0
        # gather-rerank kernel calls (ADC-pool reranks + quantized-scan
        # guards).  Deliberately a SEPARATE counter: rerank stages have
        # never counted toward masked_kernel_dispatches, and the dispatch-
        # count invariants the fragment tests assert must keep meaning
        # "masked scan dispatches".
        self.rerank_kernel_dispatches = 0
        self._dispatch_tls = threading.local()

    # -- health -----------------------------------------------------------
    def heartbeat(self) -> bool:
        return not self.dead

    def kill(self) -> None:
        self.dead = True

    def revive(self) -> None:
        self.dead = False
        self._kill_mid_task = 0  # disarm any unspent chaos budget

    def fail_next(self, count: int = 1) -> None:
        self._fail_budget = count

    def delay_next(self, seconds: float) -> None:
        self._delay_next = seconds

    def kill_next(self, count: int = 1, *, hold_s: float = 0.0) -> None:
        """Chaos hook: die while HOLDING the next ``count`` accepted tasks.

        Unlike ``kill()`` (dead before the next task is even accepted) the
        executor passes the gate, goes heartbeat-dead mid-task — holding the
        fragment for ``hold_s`` so the scheduler's lease monitor can observe
        the death — and then loses the result (``ExecutorDead``).  This is
        the mid-wave failure the lease re-dispatch path exists for."""
        self._kill_mid_task = count
        self._kill_hold_s = hold_s

    def _gate(self) -> None:
        if self.dead:
            raise ExecutorDead(self.executor_id)
        if self._fail_budget > 0:
            self._fail_budget -= 1
            raise InjectedFailure(f"injected failure on {self.executor_id}")
        if self._delay_next > 0:
            d, self._delay_next = self._delay_next, 0.0
            time.sleep(d)

    # -- SSD cache ------------------------------------------------------------
    def _cache_path(self, object_path: str, offset: int, length: int) -> str:
        etag = ""
        try:
            etag = self.store.stat(object_path).etag
        except Exception:
            pass
        key = hashlib.sha1(
            f"{object_path}|{self.cred}|{offset}|{length}|{etag}".encode()
        ).hexdigest()
        return os.path.join(self.cache_dir, key + ".blob")

    def has_cached(self, cache_key: Optional[str]) -> bool:
        if not cache_key:
            return False
        with self._lock:
            if any(k.startswith(cache_key) for k in self._l1):
                return True
        # any SSD entry tagged with this logical key
        marker = os.path.join(self.cache_dir, hashlib.sha1(cache_key.encode()).hexdigest() + ".key")
        return os.path.exists(marker)

    def _mark_cached(self, cache_key: Optional[str]) -> None:
        if not cache_key:
            return
        marker = os.path.join(self.cache_dir, hashlib.sha1(cache_key.encode()).hexdigest() + ".key")
        with open(marker, "wb") as f:
            f.write(b"1")

    def fetch_range_cached(self, object_path: str, offset: int, length: int) -> Tuple[bytes, bool]:
        """Range-read through the SSD cache.  Returns (bytes, cache_hit)."""
        cpath = self._cache_path(object_path, offset, length)
        if os.path.exists(cpath):
            with open(cpath, "rb") as f:
                self.cache_hits += 1
                return f.read(), True
        data = self.store.get_range(object_path, offset, length)
        tmp = cpath + ".tmp"
        with open(tmp, "wb") as f:
            f.write(data)
        os.replace(tmp, cpath)
        self.cache_misses += 1
        return data, False

    def _load_shard(
        self, puffin_path: str, offset: int, length: int, codec: Optional[str], cache_key: Optional[str]
    ) -> Tuple[VamanaGraph, ShardLocationMap, bool]:
        l1_key = f"{cache_key or puffin_path}@{offset}"
        with self._lock:
            if l1_key in self._l1:
                self._l1.move_to_end(l1_key)
                self.cache_hits += 1
                g, lm = self._l1[l1_key]
                return g, lm, True
        raw, hit = self.fetch_range_cached(puffin_path, offset, length)
        payload = _decompress(codec, raw)
        graph, locmap = decode_shard_blob(payload, lazy_vectors=True)
        if not np.any(graph.vectors[: graph.n]):
            # lean blob (paper §4.3 retention policy): full-precision vectors
            # omitted — re-fetch them from Parquet through the location map
            # (the "extra round trip" trade-off), then L1-cache as usual.
            graph.vectors[: graph.n] = self._fetch_vectors(locmap, graph.n)
        with self._lock:
            self._l1[l1_key] = (graph, locmap)
            while len(self._l1) > self._l1_capacity:
                self._l1.popitem(last=False)
        self._mark_cached(cache_key)
        return graph, locmap, hit

    def _fetch_vectors(self, locmap: ShardLocationMap, n: int) -> np.ndarray:
        """Read each indexed vector's row from its source Parquet row group."""
        readers: dict = {}
        out = None
        for vid in range(n):
            fpath = locmap.file_paths[int(locmap.file_idx[vid])]
            if fpath not in readers:
                readers[fpath] = VParquetReader.from_store(self.store, fpath)
            row = readers[fpath].read_rows(
                "vec", int(locmap.row_group[vid]), [int(locmap.row_offset[vid])]
            )[0]
            if out is None:
                out = np.empty((n, row.shape[0]), np.float32)
            out[vid] = row
        return out if out is not None else np.empty((0, 0), np.float32)

    # -- filtered search ----------------------------------------------------
    def _count_dispatch(self) -> None:
        """Record one masked-kernel call: executor-wide total (locked) +
        the current task's thread-local tally (see __init__)."""
        with self._lock:
            self.masked_kernel_dispatches += 1
        self._dispatch_tls.count = getattr(self._dispatch_tls, "count", 0) + 1

    def _count_rerank(self) -> None:
        """Record one gather-rerank kernel call (see the counter's note in
        __init__ — separate from masked-scan dispatch accounting)."""
        with self._lock:
            self.rerank_kernel_dispatches += 1

    def _task_dispatches(self) -> int:
        return getattr(self._dispatch_tls, "count", 0)

    def _count_mbeam(self, rows: int, fallbacks: int) -> None:
        """Tally MaskedBeam accounting for the current task: how many rows
        the predicate-aware traversal answered, and how many of those
        under-delivered and were re-answered by the fused exact-masked
        fallback (thread-local, reset per task like the dispatch count)."""
        t = self._dispatch_tls
        t.mbeam_rows = getattr(t, "mbeam_rows", 0) + rows
        t.mbeam_fallbacks = getattr(t, "mbeam_fallbacks", 0) + fallbacks

    def _task_mbeam(self) -> Tuple[int, int]:
        t = self._dispatch_tls
        return getattr(t, "mbeam_rows", 0), getattr(t, "mbeam_fallbacks", 0)

    def _reset_task_tallies(self) -> None:
        self._dispatch_tls.count = 0
        self._dispatch_tls.mbeam_rows = 0
        self._dispatch_tls.mbeam_fallbacks = 0

    def _resolve_op(self, task, op, live_mask: np.ndarray, has_pq: bool):
        """Refine a planner op with the measured match count.  ALL
        selectivity thresholds and flavor classification live in
        runtime/planner.py — the executor only interprets the resolved op,
        and both the mask-plane path and the ``force_group_loop`` baseline
        resolve through this one call, so the two can never drift apart
        (the bit-for-bit parity the tests and the bench gates assert)."""
        if op is None:
            op = planner.default_filtered_op(task.k, task.oversample, task.use_pq)
        return planner.resolve(
            op,
            match_count=int(live_mask.sum()),
            k=task.k,
            oversample=task.oversample,
            has_pq=task.use_pq and has_pq,
        )

    @staticmethod
    def _dedup_rows(
        masks: List[np.ndarray], keys: List[object]
    ) -> Tuple[List[np.ndarray], np.ndarray]:
        """Dedup-then-broadcast mask-plane builder: per-query mask rows
        keyed by their predicate collapse to the unique rows plus a (Q,)
        row index.  The ops-layer ``*_dedup`` kernels broadcast the plane
        on-device, so host->device traffic for a mostly-homogeneous batch
        is m unique rows, not Q."""
        pos: Dict[object, int] = {}
        unique: List[np.ndarray] = []
        idx = np.empty(len(masks), np.int64)
        for j, (m, key) in enumerate(zip(masks, keys)):
            p = pos.get(key)
            if p is None:
                p = len(unique)
                pos[key] = p
                unique.append(m)
            idx[j] = p
        return unique, idx

    def _predicate_mask(self, locmap: ShardLocationMap, n: int, pred, shard_key: str) -> np.ndarray:
        """Executor-side row bitmask: does vector id's source row satisfy
        ``pred``?  Each (file, row_group) referenced by the location map is
        evaluated once with attribute-column projection; the per-id gather is
        cached per (shard, row-count, predicate) so repeated filtered probes
        reuse it.  ``n`` rides in the key as the shard's version: a refresh
        appends rows (the location map is append-only), so a mask computed
        against the pre-refresh row set can never be served for the
        refreshed shard — and ``_refresh_shard`` also drops this shard's
        entries outright."""
        key = (shard_key, n, pred)
        with self._lock:
            if key in self._mask_cache:
                self._mask_cache.move_to_end(key)
                return self._mask_cache[key]
        mask = np.zeros(n, bool)
        fidx = np.asarray(locmap.file_idx[:n], np.int64)
        rgrp = np.asarray(locmap.row_group[:n], np.int64)
        roff = np.asarray(locmap.row_offset[:n], np.int64)
        readers: Dict[str, VParquetReader] = {}
        for fi, rg in {(int(a), int(b)) for a, b in zip(fidx, rgrp)}:
            fpath = locmap.file_paths[fi]
            if fpath not in readers:
                readers[fpath] = VParquetReader.from_store(self.store, fpath)
            rg_mask = row_group_mask(pred, readers[fpath], rg)
            sel = np.flatnonzero((fidx == fi) & (rgrp == rg))
            mask[sel] = rg_mask[roff[sel]]
        with self._lock:
            self._mask_cache[key] = mask
            while len(self._mask_cache) > self._mask_cache_capacity:
                self._mask_cache.popitem(last=False)
        return mask

    def _exact_masked(
        self,
        graph,
        queries: np.ndarray,
        live_mask: np.ndarray,
        k_eff: int,
        dtype: str = "f32",
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Kernel-backed pre-filter exact scan: one ``masked_exact_topk``
        call ranks only the rows passing the mask (masked-out rows are
        forced to +inf inside the kernel tile — no host-side gather).
        Exact by construction — the high-selectivity plan and the fallback
        when beam search can't surface enough passing candidates.  Output
        is always (Q, k_eff); slots beyond the passing-row count hold
        (+inf, -1) per the masked-op contract.

        ``dtype`` != f32 runs the plan's two-stage quantized form: the
        reduced-precision scan ranks a quant_guard_pool-sized pool from the
        cached quantized device copy, and the full-precision gather-rerank
        guard re-scores that pool down to ``k_eff`` — quantization never
        reaches the emitted distances."""
        self._count_dispatch()
        q = jnp.asarray(np.ascontiguousarray(queries, np.float32))
        if dtype != "f32":
            stored, x_scale = device_cache.device_vectors_quant(graph, dtype)
            pool = min(planner.quant_guard_pool(k_eff), graph.n)
            _qd, pids = ops.masked_exact_topk(
                q, stored, jnp.asarray(live_mask), int(pool),
                metric=graph.params.metric, backend="auto",
                dtype=dtype, x_scale=x_scale,
            )
            return self._rerank_pool(
                graph, queries, np.asarray(pids, np.int64), int(k_eff)
            )
        d, ids = ops.masked_exact_topk(
            q,
            device_cache.device_vectors(graph),
            jnp.asarray(live_mask),
            int(k_eff),
            metric=graph.params.metric,
            backend="auto",
        )
        return np.asarray(d), np.asarray(ids, np.int64)

    def _masked_pq_stage(
        self, graph, queries: np.ndarray, live_mask: np.ndarray, pool: int, k_out: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """PQScan interpretation on PQ shards: ONE masked ADC kernel call
        scores every passing code row (mask fused into the pq_scan
        accumulation) at the planner-resolved ``pool``, then the pooled
        survivors get the same full-precision rerank the unfiltered PQ path
        applies to its beam pool.  Every passing row is scored, so the pool
        can never under-deliver below min(pool, match_count)."""
        from repro.core.pq import build_luts

        q = np.ascontiguousarray(queries, np.float32)
        luts = build_luts(graph.pq, q)  # (Q, m, K)
        codes = self._device_codes(graph)
        self._count_dispatch()
        _pq_d, pids = ops.masked_pq_topk(
            jnp.asarray(luts),
            codes,
            jnp.asarray(live_mask),
            int(pool),
            backend="auto",
        )
        return self._rerank_pool(graph, q, np.asarray(pids, np.int64), k_out)

    def _device_codes(self, graph):
        """Codes are immutable between refreshes; cache the int32 device
        copy on the graph object (identity-keyed — see
        kernels/device_cache.py) instead of re-widening O(N·m) bytes per
        probe."""
        return device_cache.device_codes(graph)

    def _device_vectors(self, graph):
        """Cached f32 device copy of the shard's vectors (identity-keyed,
        like ``_device_codes``) — every kernel dispatch that used to ship
        ``jnp.asarray(graph.vectors[:graph.n])`` per call reuses this."""
        return device_cache.device_vectors(graph)

    def _rerank_pool(
        self, graph, q: np.ndarray, pids: np.ndarray, k_out: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Exact full-precision rerank of a candidate pool (Q, pool) — ADC
        survivors and quantized-scan guard pools alike: ONE gather-rerank
        kernel call scores each row's pool ids against the cached device
        vectors (kernels/rerank.py — the (Q, P, D) host gather and einsum
        this used to do in NumPy never materializes).  Sentinel slots
        (pid < 0) stay (+inf, -1); rows are independent, so the math is
        identical whether the pool came from a per-group call or one
        multi-mask call over the whole fragment."""
        self._count_rerank()
        d, ids = ops.gather_rerank(
            jnp.asarray(np.ascontiguousarray(q, np.float32)),
            self._device_vectors(graph),
            jnp.asarray(np.ascontiguousarray(pids, np.int64).astype(np.int32)),
            int(k_out),
            metric=graph.params.metric,
            backend="auto",
        )
        return np.asarray(d), np.asarray(ids, np.int64)

    def _exact_masked_plane(
        self,
        graph,
        queries: np.ndarray,
        unique_masks,
        row_index,
        k_out: int,
        dtype: str = "f32",
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Heterogeneous-predicate ExactScan: ONE kernel call answers every
        query of a coalesced fragment under its own bitmask row, shipped as
        the dedup'd (m, N) unique rows + (Q,) index — the per-predicate-
        group kernel loop collapses to a single dispatch per shard.
        Quantized ``dtype`` runs the same two-stage scan+guard form as
        ``_exact_masked``."""
        self._count_dispatch()
        q = jnp.asarray(np.ascontiguousarray(queries, np.float32))
        if dtype != "f32":
            stored, x_scale = device_cache.device_vectors_quant(graph, dtype)
            pool = min(planner.quant_guard_pool(k_out), graph.n)
            _qd, pids = ops.masked_exact_topk_dedup(
                q, stored,
                jnp.asarray(np.stack(unique_masks)),
                jnp.asarray(row_index),
                int(pool),
                metric=graph.params.metric, backend="auto",
                dtype=dtype, x_scale=x_scale,
            )
            return self._rerank_pool(
                graph, queries, np.asarray(pids, np.int64), int(k_out)
            )
        d, ids = ops.masked_exact_topk_dedup(
            q,
            self._device_vectors(graph),
            jnp.asarray(np.stack(unique_masks)),
            jnp.asarray(row_index),
            int(k_out),
            metric=graph.params.metric,
            backend="auto",
        )
        return np.asarray(d), np.asarray(ids, np.int64)

    def _masked_pq_plane(
        self,
        graph,
        queries: np.ndarray,
        unique_masks,
        row_index,
        pool: int,
        k_out: int,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Heterogeneous-predicate PQScan: ONE multi-mask ADC kernel call
        (dedup'd plane) scores every query's passing codes at the shared
        ``pool`` size, then the shared exact rerank.  One pool suffices for
        bit-for-bit parity with the per-group path: planner.resolve pins
        the PQScan pool to the same constant for every PQ-flavor query of a
        fragment (see its docstring)."""
        from repro.core.pq import build_luts

        q = np.ascontiguousarray(queries, np.float32)
        luts = build_luts(graph.pq, q)  # (Q, m, K)
        codes = self._device_codes(graph)
        self._count_dispatch()
        _pq_d, pids = ops.masked_pq_topk_dedup(
            jnp.asarray(luts),
            codes,
            jnp.asarray(np.stack(unique_masks)),
            jnp.asarray(row_index),
            int(pool),
            backend="auto",
        )
        return self._rerank_pool(graph, q, np.asarray(pids, np.int64), k_out)

    def _unified_masked_stage(
        self,
        graph,
        queries: np.ndarray,
        unique_masks,
        row_index,
        flavor: np.ndarray,
        pq_pool: int,
        k_out: int,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Mixed-flavor fragment: ONE ``unified_masked_topk`` call scores
        exact-flavor rows full-precision and PQ-flavor rows via ADC in the
        same dispatch (per-query flavor selector fused into the mask
        plane).  The call returns max(k_out, pq_pool) columns: exact rows
        keep their first k_out (identical to a dedicated exact dispatch —
        the top-k extraction is prefix-stable), PQ rows feed their
        ``pq_pool`` ADC survivors through the shared full-precision
        rerank (identical to a dedicated ADC dispatch).  Collapses the
        two-dispatch-per-shard mixed fragment to one."""
        from repro.core.pq import build_luts

        q = np.ascontiguousarray(queries, np.float32)
        luts = build_luts(graph.pq, q)  # (Q, m, K)
        codes = self._device_codes(graph)
        kk = int(max(k_out, pq_pool))
        self._count_dispatch()
        d, ids = ops.unified_masked_topk_dedup(
            jnp.asarray(q),
            self._device_vectors(graph),
            jnp.asarray(luts),
            codes,
            jnp.asarray(np.stack(unique_masks)),
            jnp.asarray(row_index),
            jnp.asarray(flavor),
            kk,
            metric=graph.params.metric,
            backend="auto",
        )
        d = np.asarray(d)
        ids = np.asarray(ids, np.int64)
        out_d = np.empty((q.shape[0], k_out), np.float32)
        out_i = np.empty((q.shape[0], k_out), np.int64)
        ex = ~flavor
        out_d[ex] = d[ex, :k_out]
        out_i[ex] = ids[ex, :k_out]
        if flavor.any():
            rd, ri = self._rerank_pool(
                graph, q[flavor], ids[flavor][:, : int(pq_pool)], k_out
            )
            out_d[flavor] = rd
            out_i[flavor] = ri
        return out_d, out_i

    def _filtered_search(
        self, task, graph, locmap, queries: np.ndarray, pred, op
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Stage-A search under an attribute predicate: interpret the
        planner's per-shard plan ``op`` for a group of queries sharing one
        predicate.

        The op is resolved against the measured match count
        (planner.resolve — the only place flavor thresholds live), then
        executed: ExactScan and PQScan ride the mask-aware kernels
        (kernels/masked_topk.py — the predicate/tombstone bitmask goes into
        the kernel as a tile input, masked-out rows score +inf before the
        in-kernel top-k); PostfilterBeam over-fetches the ordinary beam to
        the planner-sized pool and filters after, falling back to the
        kernel-backed exact masked scan whenever the beam cannot surface
        enough passing candidates — a filtered probe never silently returns
        fewer candidates than the shard actually holds."""
        shard_key = f"{task.cache_key or task.puffin_path}@{task.blob_offset}"
        mask = self._predicate_mask(locmap, graph.n, pred, shard_key)
        live_mask = mask & ~graph.tombstones[: graph.n]
        final = self._resolve_op(task, op, live_mask, graph.pq is not None)
        Qn = queries.shape[0]
        if isinstance(final, planner.Skip):
            return (
                np.full((Qn, 1), np.inf, np.float32),
                np.full((Qn, 1), -1, np.int64),
            )
        if isinstance(final, planner.PQScan):
            return self._masked_pq_stage(
                graph, queries, live_mask, final.pool, final.k
            )
        if isinstance(final, planner.ExactScan):
            return self._exact_masked(
                graph, queries, live_mask, final.k,
                dtype=getattr(final, "dtype", "f32"),
            )
        if isinstance(final, planner.MaskedBeam):
            return self._masked_beam(task, graph, queries, live_mask, final)
        return self._postfilter_beam(task, graph, queries, live_mask, final)

    def _postfilter_beam_core(
        self, task, graph, queries: np.ndarray, mask_plane: np.ndarray, pool: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """The ONE copy of the PostfilterBeam machinery, shared by the
        per-group interpreter (shared mask, broadcast) and the pooled
        mask-plane path (per-row masks): over-fetch the ordinary beam to
        the planner-sized pool, drop each row's candidates failing ITS
        mask, and return the full post-filtered pool sorted ascending per
        row (failures pushed to the (+inf, -1) tail).  Callers slice their
        per-row output widths and apply their fallback policy."""
        p = min(int(pool), graph.num_live)
        L = max(task.L, p)
        if task.use_pq and graph.pq is not None:
            dists, ids = graph.search_pq(queries, p, L=L)
        else:
            dists, ids = graph.search(queries, p, L=L)
        safe = np.clip(ids, 0, graph.n - 1)
        passing = (
            np.take_along_axis(mask_plane, safe, axis=1)
            & (ids >= 0)
            & np.isfinite(dists)
        )
        dists = np.where(passing, dists, np.inf)
        ids = np.where(passing, ids, -1)
        order = np.argsort(dists, axis=1)
        return (
            np.take_along_axis(dists, order, axis=1),
            np.take_along_axis(ids, order, axis=1),
        )

    def _postfilter_beam(
        self, task, graph, queries: np.ndarray, live_mask: np.ndarray, op
    ) -> Tuple[np.ndarray, np.ndarray]:
        """PostfilterBeam interpretation for a group sharing one mask:
        most rows pass, so the over-fetched beam surfaces enough; queries
        it under-delivered fall back to the exact masked scan."""
        plane = np.broadcast_to(live_mask, (queries.shape[0], live_mask.shape[0]))
        dists, ids = self._postfilter_beam_core(task, graph, queries, plane, op.pool)
        dists = dists[:, : op.k]
        ids = ids[:, : op.k]
        short = np.isinf(dists).any(axis=1)
        if short.any():
            # beam under-delivered for some queries — kernel-backed exact
            # masked scan returns exactly op.k columns, so rows align
            rows = np.flatnonzero(short)
            ed, ei = self._exact_masked(graph, queries[rows], live_mask, op.k)
            dists[rows] = ed
            ids[rows] = ei
        return dists, ids

    def _masked_beam_core(
        self, task, graph, queries: np.ndarray, unique_masks, row_index, width: int, k: int
    ) -> Tuple[np.ndarray, np.ndarray]:
        """The ONE copy of the MaskedBeam machinery, shared by the
        per-group interpreter and the pooled mask-plane path: the
        predicate-aware traversal (``VamanaGraph.search_masked`` — masked
        nodes expand for connectivity, only mask-passing nodes are
        admitted) at the planner-widened admitted-candidate target, the
        mask shipped as the dedup'd unique rows + row index.  The widened
        ``width`` sizes only the ADMIT target — the beam depth stays at
        ``max(task.L, k)``, because admitted candidates come from every
        neighbor the traversal evaluates, not just the final pool.  This
        is the structural edge over PostfilterBeam, whose pool must deepen
        by 1/frac to surface enough passing rows.  This is a beam pass,
        not a masked-kernel dispatch — like Beam/PostfilterBeam passes it
        does not count toward ``kernel_dispatches`` (its fused fallback
        does)."""
        w = max(1, min(int(width), graph.num_live))
        L = max(task.L, min(int(k), graph.num_live))
        return graph.search_masked(
            queries,
            w,
            np.stack(unique_masks),
            row_index,
            L=L,
            use_pq=task.use_pq and graph.pq is not None,
        )

    def _masked_beam(
        self, task, graph, queries: np.ndarray, live_mask: np.ndarray, op
    ) -> Tuple[np.ndarray, np.ndarray]:
        """MaskedBeam interpretation for a group sharing one mask: the
        widened predicate-aware traversal delivers ``op.k`` admitted
        candidates per row; rows it under-delivers fall back to the exact
        masked scan — a filtered probe never silently returns fewer
        candidates than the shard actually holds."""
        dists, ids = self._masked_beam_core(
            task,
            graph,
            queries,
            [live_mask],
            np.zeros(queries.shape[0], np.int64),
            op.width,
            op.k,
        )
        dists = dists[:, : op.k]
        ids = ids[:, : op.k]
        short = np.isinf(dists).any(axis=1)
        self._count_mbeam(queries.shape[0], int(short.sum()))
        if short.any():
            rows = np.flatnonzero(short)
            ed, ei = self._exact_masked(graph, queries[rows], live_mask, op.k)
            dists[rows] = ed
            ids[rows] = ei
        return dists, ids

    # -- dispatch ------------------------------------------------------------
    def handle(self, task) -> object:
        self._gate()
        if self._kill_mid_task > 0:
            self._kill_mid_task -= 1
            self.dead = True  # heartbeat goes dark while the task is held
            if self._kill_hold_s > 0:
                time.sleep(self._kill_hold_s)
            raise ExecutorDead(self.executor_id)
        if isinstance(task, F.ScanPartitionTaskInfo):
            result = self._scan_partition(task)
        elif isinstance(task, F.IndexBuildTaskInfo):
            result = self._build_shard(task)
        elif isinstance(task, F.ProbeTaskInfo):
            result = self._probe_shard(task)
        elif isinstance(task, F.BatchProbeTaskInfo):
            result = self._probe_shard_batch(task)
        elif isinstance(task, F.TailScanTaskInfo):
            result = self._tail_scan(task)
        elif isinstance(task, F.RerankTaskInfo):
            result = self._rerank(task)
        elif isinstance(task, F.RefreshTaskInfo):
            result = self._refresh_shard(task)
        else:
            raise TypeError(f"unknown task type {type(task)}")
        self.tasks_done += 1
        return result

    # -- handlers --------------------------------------------------------------
    def _scan_partition(self, task: F.ScanPartitionTaskInfo) -> F.ScanPartitionResult:
        vectors, fidx, rgrp, roff, paths = _scan_files_with_locations(
            self.store, task.assigned_files
        )
        out = F.ScanPartitionResult(executor_id=self.executor_id)
        if vectors.shape[0] == 0:
            return out
        owners = _owner_shards(vectors, task.partition_centroids, task.shard_of_partition)
        for shard in range(task.num_shards):
            sel = np.flatnonzero(owners == shard)
            if len(sel) == 0:
                continue
            out.per_shard[shard] = (
                vectors[sel],
                fidx[sel],
                rgrp[sel],
                roff[sel],
                paths,
            )
        return out

    def _build_shard(self, task: F.IndexBuildTaskInfo) -> F.IndexBuildResult:
        t0 = time.time()
        if task.exchanged is not None:
            vectors, fidx, rgrp, roff, paths = task.exchanged
        else:
            vectors, fidx, rgrp, roff, paths = _scan_files_with_locations(
                self.store, task.assigned_files
            )
            if task.partition_mode == "centroid" and task.partition_centroids is not None:
                owners = _owner_shards(
                    vectors, task.partition_centroids, task.shard_of_partition
                )
                sel = np.flatnonzero(owners == task.shard_id)
                vectors, fidx, rgrp, roff = vectors[sel], fidx[sel], rgrp[sel], roff[sel]
        if vectors.shape[0] == 0:
            raise ValueError(f"shard {task.shard_id}: no vectors to index")
        params = VamanaParams(R=task.R, L=task.L, alpha=task.alpha, metric=task.metric)
        graph = build_vamana(
            vectors, params, passes=task.build_passes, batch=task.build_batch,
            seed=task.shard_id,
        )
        if task.pq_m:
            pq = PQCodebook(task.pq_codebook, task.metric)
            graph.attach_pq(pq, pq_encode(pq, vectors))
        # per-partition counts for the routing table
        counts = None
        if task.partition_centroids is not None:
            part, _ = ops.kmeans_assign(
                jnp.asarray(vectors), jnp.asarray(task.partition_centroids), backend="ref"
            )
            counts = np.bincount(
                np.asarray(part), minlength=task.partition_centroids.shape[0]
            )
        locmap = ShardLocationMap(paths, fidx, rgrp, roff)
        blob = encode_shard_blob(graph, locmap, include_vectors=task.include_vectors)
        self.store.put(task.output_path, blob)
        return F.IndexBuildResult(
            shard_id=task.shard_id,
            output_path=task.output_path,
            vector_count=graph.n,
            byte_size=len(blob),
            executor_id=self.executor_id,
            build_seconds=time.time() - t0,
            partition_counts=counts,
            rg_membership=_locmap_membership(locmap, graph.n),
        )

    def _shard_search(
        self,
        task,
        graph,
        queries: Optional[np.ndarray] = None,
        width: Optional[int] = None,
    ) -> Tuple[np.ndarray, np.ndarray]:
        """Shared Stage-A search: batched beam search (PQ ADC when the shard
        carries codes) over however many queries the fragment brought.
        ``width`` is a planner Beam op's requested candidate count; absent,
        the task's own k * oversample applies (same value on every
        coordinator-built plan — the parameter keeps replayed plans
        honest)."""
        q = task.queries if queries is None else queries
        k_eff = min(width or task.k * task.oversample, graph.num_live)
        L = max(task.L, k_eff)
        if task.use_pq and graph.pq is not None:
            return graph.search_pq(q, k_eff, L=L)
        return graph.search(q, k_eff, L=L)

    def _row_candidates(
        self, graph, locmap, dists_row, ids_row, shard_id: int
    ) -> List[F.ProbeCandidate]:
        cands: List[F.ProbeCandidate] = []
        for d, vid in zip(dists_row, ids_row):
            if not np.isfinite(d) or vid < 0 or vid >= graph.n:
                continue
            fpath, rg, ro = locmap.lookup(int(vid))
            cands.append(
                F.ProbeCandidate(
                    file_path=fpath,
                    row_group=rg,
                    row_offset=ro,
                    approx_distance=float(d),
                    vec_id=int(vid),
                    shard_id=shard_id,
                )
            )
        return cands

    def _probe_shard(self, task: F.ProbeTaskInfo) -> F.ProbeResult:
        t0 = time.time()
        graph, locmap, hit = self._load_shard(
            task.puffin_path, task.blob_offset, task.blob_length, task.blob_codec, task.cache_key
        )
        self._reset_task_tallies()
        if task.predicate is not None:
            dists, ids = self._filtered_search(
                task, graph, locmap, task.queries, task.predicate, task.plan_op
            )
        else:
            dists, ids = self._shard_search(task, graph)
        mb_rows, mb_fb = self._task_mbeam()
        result = F.ProbeResult(
            shard_id=task.shard_id, executor_id=self.executor_id, cache_hit=hit,
            kernel_dispatches=self._task_dispatches(),
            masked_beam_rows=mb_rows, masked_beam_fallbacks=mb_fb,
        )
        for qi in range(task.queries.shape[0]):
            result.candidates.append(
                self._row_candidates(graph, locmap, dists[qi], ids[qi], task.shard_id)
            )
        result.probe_seconds = time.time() - t0
        return result

    def _tail_scan(self, task: F.TailScanTaskInfo) -> F.BatchProbeResult:
        """Fresh-tail tier Stage A: score one appended-but-unindexed row
        group for every query routed to it with ONE masked exact kernel
        dispatch.  Tail rows have no graph and no PQ codes, so every plan
        op is an ExactScan; predicates become per-query bitmask rows
        (dedup'd plane when the batch mixes them), and the kernel's
        (+inf, -1) sentinel contract covers zero-match predicates and
        k > live-rows exactly as shard scans do — sentinel slots are
        dropped before candidates leave the executor."""
        t0 = time.time()
        result = F.BatchProbeResult(
            shard_id=task.tail_id, executor_id=self.executor_id
        )
        self._reset_task_tallies()
        qidx = np.asarray(task.query_index, np.int64)
        reader = VParquetReader.from_store(self.store, task.file_path)
        vectors = np.ascontiguousarray(
            reader.read_column("vec", [task.row_group]), np.float32
        )
        n = vectors.shape[0]
        if n == 0:
            for qi in qidx:
                result.candidates[int(qi)] = []
            result.probe_seconds = time.time() - t0
            return result
        q = np.ascontiguousarray(task.queries, np.float32)
        k_eff = min(max(1, task.k * task.oversample), n)
        all_rows = np.ones(n, bool)
        masks: List[np.ndarray] = []
        keys: List[object] = []
        for bi in range(q.shape[0]):
            pred = task.filters[bi] if task.filters else None
            if pred is None:
                masks.append(all_rows)
                keys.append(None)
            else:
                masks.append(row_group_mask(pred, reader, task.row_group))
                keys.append(pred)
        unique, row_index = self._dedup_rows(masks, keys)
        self._count_dispatch()
        if len(unique) == 1:
            d, ids = ops.masked_exact_topk(
                jnp.asarray(q),
                jnp.asarray(vectors),
                jnp.asarray(unique[0]),
                int(k_eff),
                metric=task.metric,
                backend="auto",
            )
        else:
            d, ids = ops.masked_exact_topk_dedup(
                jnp.asarray(q),
                jnp.asarray(vectors),
                jnp.asarray(np.stack(unique)),
                jnp.asarray(row_index),
                int(k_eff),
                metric=task.metric,
                backend="auto",
            )
        d = np.asarray(d)
        ids = np.asarray(ids, np.int64)
        for bi, qi in enumerate(qidx):
            result.candidates[int(qi)] = [
                F.ProbeCandidate(
                    file_path=task.file_path,
                    row_group=task.row_group,
                    row_offset=int(vid),
                    approx_distance=float(dist),
                    vec_id=int(vid),
                    shard_id=task.tail_id,
                )
                for dist, vid in zip(d[bi], ids[bi])
                if np.isfinite(dist) and vid >= 0
            ]
        result.kernel_dispatches = self._task_dispatches()
        result.probe_seconds = time.time() - t0
        return result

    def _probe_shard_batch(self, task: F.BatchProbeTaskInfo) -> F.BatchProbeResult:
        """Coalesced Stage A: one shard load, then interpret each query's
        planner op and answer every kernel-planned query of the fragment
        with ONE masked-kernel call per shard — regardless of how many
        distinct predicates the batch carries, and regardless of whether
        their resolved flavors mix exact and PQ-ADC scoring (the unified
        kernel fuses both into the same dispatch).  Each query gets its own
        row of a dedup'd mask plane assembled from the per-predicate
        ``_mask_cache`` bitmasks (tombstones AND-ed in); unfiltered queries
        ride a shared beam pass, or a size-capped all-ones kernel row on
        small shards, per their planner op.  The legacy per-predicate-group
        loop survives only behind ``force_group_loop`` for parity/bench
        comparison."""
        t0 = time.time()
        graph, locmap, hit = self._load_shard(
            task.puffin_path, task.blob_offset, task.blob_length, task.blob_codec, task.cache_key
        )
        result = F.BatchProbeResult(
            shard_id=task.shard_id, executor_id=self.executor_id, cache_hit=hit
        )
        self._reset_task_tallies()
        qidx = np.asarray(task.query_index, np.int64)
        if not task.filters:
            # fully-unfiltered fragments keep the batched beam search: its
            # hits must stay byte-identical to sequential probe() calls
            dists, ids = self._shard_search(task, graph)
            for bi, qi in enumerate(qidx):
                result.candidates[int(qi)] = self._row_candidates(
                    graph, locmap, dists[bi], ids[bi], task.shard_id
                )
            result.probe_seconds = time.time() - t0
            return result
        if self.force_group_loop:
            self._probe_groups(task, graph, locmap, result, qidx, range(len(qidx)))
        else:
            self._probe_mask_plane(task, graph, locmap, result, qidx)
        result.kernel_dispatches = self._task_dispatches()
        result.masked_beam_rows, result.masked_beam_fallbacks = self._task_mbeam()
        result.probe_seconds = time.time() - t0
        return result

    def _probe_groups(
        self, task, graph, locmap, result, qidx: np.ndarray, rows
    ) -> None:
        """Legacy per-predicate-group Stage A: one batched pass per distinct
        (predicate, plan op) among ``rows`` — N distinct predicates degrade
        to N sequential kernel/beam passes.  Kept ONLY behind
        ``force_group_loop`` as the parity/bench comparison baseline; it
        interprets the same planner-resolved ops as the mask-plane path, so
        the two paths answer bit-identically."""
        groups: Dict[tuple, List[int]] = {}
        for bi in rows:
            op = task.plan_ops[bi] if task.plan_ops else None
            groups.setdefault((task.filters[bi], op), []).append(bi)
        for (pred, op), members in groups.items():
            queries = task.queries[members]
            if pred is None:
                if isinstance(op, planner.ExactScan):
                    # all-ones row on a small shard: the same size-capped
                    # exact scan the mask-plane path ships
                    live = ~graph.tombstones[: graph.n]
                    k_out = max(1, min(op.k, graph.n))
                    dists, ids = self._exact_masked(
                        graph, queries, live, k_out,
                        dtype=getattr(op, "dtype", "f32"),
                    )
                else:
                    w = op.width if isinstance(op, planner.Beam) else 0
                    dists, ids = self._shard_search(
                        task, graph, queries, width=w or None
                    )
            else:
                dists, ids = self._filtered_search(
                    task, graph, locmap, queries, pred, op
                )
            for j, bi in enumerate(members):
                result.candidates[int(qidx[bi])] = self._row_candidates(
                    graph, locmap, dists[j], ids[j], task.shard_id
                )

    def _probe_mask_plane(
        self, task, graph, locmap, result, qidx: np.ndarray
    ) -> None:
        """Mask-plane Stage A: resolve every query's planner op against its
        measured match count (planner.resolve — the executor itself holds
        no thresholds), then answer ALL kernel-planned queries with one
        masked-kernel call: a single flavor dispatches the dedup'd-plane
        exact or ADC kernel; a fragment mixing both flavors dispatches the
        unified kernel ONCE with a per-query flavor selector.  Beam-planned
        rows (unfiltered queries on large shards) share one batched beam
        pass, and PostfilterBeam rows share over-fetched beam passes
        grouped by pool with a single fused masked-kernel fallback —
        heterogeneous predicates never multiply kernel dispatches."""
        shard_key = f"{task.cache_key or task.puffin_path}@{task.blob_offset}"
        n = graph.n
        tomb_live = ~graph.tombstones[:n]
        k_out = max(1, min(task.k * task.oversample, n))
        exact_rows: List[int] = []
        exact_masks: List[np.ndarray] = []
        exact_keys: List[object] = []
        exact_dtypes: List[str] = []  # per-row planner scan dtype
        pq_rows: List[int] = []
        pq_masks: List[np.ndarray] = []
        pq_keys: List[object] = []
        beam_rows: Dict[int, List[int]] = {}  # planner Beam width -> rows
        post_rows: Dict[int, List[int]] = {}
        post_masks: Dict[int, np.ndarray] = {}
        post_ks: Dict[int, int] = {}
        mbeam_rows: Dict[int, List[int]] = {}  # planner MaskedBeam width -> rows
        mbeam_masks: Dict[int, np.ndarray] = {}
        mbeam_ks: Dict[int, int] = {}
        pq_pool = 0
        for bi in range(len(qidx)):
            pred = task.filters[bi]
            op = task.plan_ops[bi] if task.plan_ops else None
            if pred is None:
                if isinstance(op, planner.ExactScan):
                    # unfiltered query in a mixed fragment on a small
                    # shard: all-ones row (only tombstones masked) rides
                    # the fragment's kernel call
                    exact_rows.append(bi)
                    exact_masks.append(tomb_live)
                    exact_keys.append(None)
                    exact_dtypes.append(getattr(op, "dtype", "f32"))
                else:
                    w = op.width if isinstance(op, planner.Beam) else 0
                    beam_rows.setdefault(int(w), []).append(bi)
                continue
            live = self._predicate_mask(locmap, n, pred, shard_key) & tomb_live
            final = self._resolve_op(task, op, live, graph.pq is not None)
            if isinstance(final, planner.Skip):
                result.candidates[int(qidx[bi])] = []
            elif isinstance(final, planner.PQScan):
                pq_rows.append(bi)
                pq_masks.append(live)
                pq_keys.append(pred)
                pq_pool = final.pool  # pinned: identical for every PQ row
            elif isinstance(final, planner.ExactScan):
                exact_rows.append(bi)
                exact_masks.append(live)
                exact_keys.append(pred)
                exact_dtypes.append(getattr(final, "dtype", "f32"))
            elif isinstance(final, planner.MaskedBeam):
                mbeam_rows.setdefault(int(final.width), []).append(bi)
                mbeam_masks[bi] = live
                mbeam_ks[bi] = final.k  # planner-resolved k_eff
            else:  # PostfilterBeam
                post_rows.setdefault(int(final.pool), []).append(bi)
                post_masks[bi] = live
                post_ks[bi] = final.k  # planner-resolved k_eff

        def _emit(rows, dists, ids):
            for j, bi in enumerate(rows):
                result.candidates[int(qidx[bi])] = self._row_candidates(
                    graph, locmap, dists[j], ids[j], task.shard_id
                )

        # Reduced-precision exact rows never join the unified fusion: the
        # unified kernel scores exact rows full-precision only.  Group the
        # quantized rows per dtype (each gets its own scan+guard dispatch)
        # and keep the f32 subset for the fusion/plane logic below.
        quant_groups: Dict[str, List[int]] = {}
        for pos, dt in enumerate(exact_dtypes):
            if dt != "f32":
                quant_groups.setdefault(dt, []).append(pos)
        if quant_groups:
            for dt, poss in sorted(quant_groups.items()):
                rows = [exact_rows[p] for p in poss]
                masks = [exact_masks[p] for p in poss]
                keys = [exact_keys[p] for p in poss]
                unique, idx = self._dedup_rows(masks, keys)
                if len(unique) == 1:
                    dists, ids = self._exact_masked(
                        graph, task.queries[rows], unique[0], k_out, dtype=dt
                    )
                else:
                    dists, ids = self._exact_masked_plane(
                        graph, task.queries[rows], unique, idx, k_out, dtype=dt
                    )
                _emit(rows, dists, ids)
            keep = [p for p, dt in enumerate(exact_dtypes) if dt == "f32"]
            exact_rows = [exact_rows[p] for p in keep]
            exact_masks = [exact_masks[p] for p in keep]
            exact_keys = [exact_keys[p] for p in keep]

        if exact_rows and pq_rows and not self.force_split_flavors:
            # mixed flavors: ONE unified dispatch for the whole fragment
            rows = exact_rows + pq_rows
            unique, idx = self._dedup_rows(
                exact_masks + pq_masks, exact_keys + pq_keys
            )
            flavor = np.zeros(len(rows), bool)
            flavor[len(exact_rows):] = True
            dists, ids = self._unified_masked_stage(
                graph, task.queries[rows], unique, idx, flavor, pq_pool, k_out
            )
            _emit(rows, dists, ids)
        else:
            # Homogeneous-predicate short-circuit inside each flavor: one
            # unique mask row ships the single-mask kernel; otherwise the
            # dedup'd plane (m unique rows + row index, broadcast
            # on-device) — either way ONE dispatch per flavor.
            if exact_rows:
                unique, idx = self._dedup_rows(exact_masks, exact_keys)
                if len(unique) == 1:
                    dists, ids = self._exact_masked(
                        graph, task.queries[exact_rows], unique[0], k_out
                    )
                else:
                    dists, ids = self._exact_masked_plane(
                        graph, task.queries[exact_rows], unique, idx, k_out
                    )
                _emit(exact_rows, dists, ids)
            if pq_rows:
                unique, idx = self._dedup_rows(pq_masks, pq_keys)
                if len(unique) == 1:
                    dists, ids = self._masked_pq_stage(
                        graph, task.queries[pq_rows], unique[0], pq_pool, k_out
                    )
                else:
                    dists, ids = self._masked_pq_plane(
                        graph, task.queries[pq_rows], unique, idx, pq_pool, k_out
                    )
                _emit(pq_rows, dists, ids)
        for w, rows in sorted(beam_rows.items()):
            dists, ids = self._shard_search(
                task, graph, task.queries[rows], width=w or None
            )
            _emit(rows, dists, ids)
        short_rows: List[int] = []
        if post_rows:
            short_rows += self._postfilter_pooled(
                task, graph, locmap, result, qidx, post_rows, post_masks, post_ks
            )
        if mbeam_rows:
            short_rows += self._masked_beam_pooled(
                task, graph, locmap, result, qidx, mbeam_rows, mbeam_masks, mbeam_ks
            )
        if short_rows:
            self._fused_exact_fallback(
                task,
                graph,
                locmap,
                result,
                qidx,
                sorted(short_rows),
                {**post_masks, **mbeam_masks},
            )

    def _masked_beam_pooled(
        self,
        task,
        graph,
        locmap,
        result,
        qidx: np.ndarray,
        rows_by_width: Dict[int, List[int]],
        masks_by_row: Dict[int, np.ndarray],
        ks_by_row: Dict[int, int],
    ) -> List[int]:
        """MaskedBeam rows of a fragment: one predicate-aware traversal per
        distinct planner width (usually a single pass — resolution keeps
        the width shared unless match counts cap it), each row's mask
        riding the dedup'd plane, each row sliced to ITS planner-resolved
        k.  Returns the under-delivered rows so they join the fragment's
        ONE fused masked-kernel fallback alongside any short postfilter
        rows.  Per-query results are identical to interpreting each row
        alone: traversal rows are independent and the fallback math is
        per-row."""
        short_rows: List[int] = []
        total = 0
        for width, rows in sorted(rows_by_width.items()):
            unique, idx = self._dedup_rows(
                [masks_by_row[bi] for bi in rows],
                [task.filters[bi] for bi in rows],
            )
            dists, ids = self._masked_beam_core(
                task,
                graph,
                task.queries[rows],
                unique,
                idx,
                width,
                max(ks_by_row[bi] for bi in rows),
            )
            total += len(rows)
            for j, bi in enumerate(rows):
                kj = ks_by_row[bi]
                dj, ij = dists[j, :kj], ids[j, :kj]
                if np.isinf(dj).any():
                    short_rows.append(bi)
                else:
                    result.candidates[int(qidx[bi])] = self._row_candidates(
                        graph, locmap, dj, ij, task.shard_id
                    )
        self._count_mbeam(total, len(short_rows))
        return short_rows

    def _fused_exact_fallback(
        self,
        task,
        graph,
        locmap,
        result,
        qidx: np.ndarray,
        short_rows: List[int],
        masks_by_row: Dict[int, np.ndarray],
    ) -> None:
        """ONE fused masked-kernel call answers every beam row the fragment
        under-delivered — postfilter and masked-beam rows alike — instead
        of per-predicate (or per-path) fallback dispatches."""
        k_out = max(1, min(task.k * task.oversample, graph.n))
        unique, idx = self._dedup_rows(
            [masks_by_row[bi] for bi in short_rows],
            [task.filters[bi] for bi in short_rows],
        )
        if len(unique) == 1:
            d, i = self._exact_masked(
                graph, task.queries[short_rows], unique[0], k_out
            )
        else:
            d, i = self._exact_masked_plane(
                graph, task.queries[short_rows], unique, idx, k_out
            )
        for j, bi in enumerate(short_rows):
            result.candidates[int(qidx[bi])] = self._row_candidates(
                graph, locmap, d[j], i[j], task.shard_id
            )

    def _postfilter_pooled(
        self,
        task,
        graph,
        locmap,
        result,
        qidx: np.ndarray,
        rows_by_pool: Dict[int, List[int]],
        masks_by_row: Dict[int, np.ndarray],
        ks_by_row: Dict[int, int],
    ) -> List[int]:
        """PostfilterBeam rows of a fragment: one over-fetched beam pass
        per distinct planner pool (NOT per distinct predicate — usually a
        single pass) through the shared ``_postfilter_beam_core``, each row
        post-filtered under its own mask and sliced to ITS planner-resolved
        k.  Returns the under-delivered rows so they join the fragment's
        ONE fused masked-kernel fallback call (shared with short
        masked-beam rows) instead of per-predicate fallbacks.  Per-query
        results are identical to interpreting each row alone: beam rows are
        independent and the fallback math is per-row."""
        short_rows: List[int] = []
        for pool, rows in sorted(rows_by_pool.items()):
            plane = np.stack([masks_by_row[bi] for bi in rows])
            dists, ids = self._postfilter_beam_core(
                task, graph, task.queries[rows], plane, pool
            )
            for j, bi in enumerate(rows):
                kj = ks_by_row[bi]
                dj, ij = dists[j, :kj], ids[j, :kj]
                if np.isinf(dj).any():
                    short_rows.append(bi)
                else:
                    result.candidates[int(qidx[bi])] = self._row_candidates(
                        graph, locmap, dj, ij, task.shard_id
                    )
        return short_rows

    def _rerank(self, task: F.RerankTaskInfo) -> F.RerankResult:
        rows_flat: List[Tuple[str, int, int]] = []
        # per flat row: None => every query owns it, else the owning set
        owners_flat: List[Optional[set]] = []
        vec_parts: List[np.ndarray] = []
        for fpath, groups in task.masks.items():
            reader = VParquetReader.from_store(self.store, fpath)
            f_own = task.file_owners.get(fpath) if task.file_owners else None
            r_own = task.row_owners.get(fpath) if task.row_owners else None
            for rg_id, offsets in groups.items():
                arr = reader.read_rows("vec", rg_id, offsets)
                vec_parts.append(arr)
                rg_own = r_own.get(rg_id) if r_own is not None else None
                for off in offsets:
                    rows_flat.append((fpath, rg_id, off))
                    if rg_own is not None:
                        owners_flat.append(rg_own.get(off, set()))
                    else:
                        owners_flat.append(f_own)
        result = F.RerankResult(executor_id=self.executor_id)
        q = np.ascontiguousarray(task.queries, np.float32)
        if not rows_flat:
            result.rows = [[] for _ in range(q.shape[0])]
            return result
        cands = np.concatenate(vec_parts)
        # the union of every query's rows is read and scored ONCE — a single
        # batched kernel call; ownership filters the (Q, N) matrix afterwards
        d = np.asarray(
            ops.exact_distances(
                jnp.asarray(q), jnp.asarray(cands), metric=task.metric, backend="ref"
            )
        )
        for qi in range(q.shape[0]):
            result.rows.append(
                [
                    F.RerankRow(fp, rg, ro, float(d[qi, ci]))
                    for ci, (fp, rg, ro) in enumerate(rows_flat)
                    if owners_flat[ci] is None or qi in owners_flat[ci]
                ]
            )
        return result

    def _refresh_shard(self, task: F.RefreshTaskInfo) -> F.RefreshResult:
        t0 = time.time()
        graph, locmap, _hit = self._load_shard(
            task.puffin_path, task.blob_offset, task.blob_length, task.blob_codec, task.cache_key
        )
        # deletions first: tombstone every vector whose source file was removed
        tombstoned = 0
        if task.removed_files:
            removed = set(task.removed_files)
            path_arr = np.array(
                [locmap.file_paths[int(i)] for i in locmap.file_idx[: graph.n]]
            )
            doomed = np.flatnonzero(np.isin(path_arr, list(removed)))
            fresh = doomed[~graph.tombstones[doomed]]
            graph.tombstone(fresh)
            tombstoned = int(len(fresh))
        # insertions: scan added files, filter to this shard's ownership
        inserted = 0
        if task.added_files:
            vectors, fidx, rgrp, roff, paths = _scan_files_with_locations(
                self.store, task.added_files
            )
            if vectors.shape[0]:
                owners = _owner_shards(
                    vectors, task.partition_centroids, task.shard_of_partition
                )
                sel = np.flatnonzero(owners == task.shard_id)
                if len(sel):
                    graph.insert_batch(vectors[sel])
                    inserted = int(len(sel))
                    # extend the location map
                    base = len(locmap.file_paths)
                    locmap.file_paths.extend(paths)
                    locmap.file_idx = np.concatenate(
                        [locmap.file_idx, fidx[sel] + base]
                    )
                    locmap.row_group = np.concatenate([locmap.row_group, rgrp[sel]])
                    locmap.row_offset = np.concatenate([locmap.row_offset, roff[sel]])
        blob = encode_shard_blob(graph, locmap, include_vectors=task.include_vectors)
        self.store.put(task.output_path, blob)
        # The refresh mutated the graph/locmap objects IN PLACE — the very
        # objects the L1 cache serves under the pre-refresh key.  Evict that
        # entry (a later probe of the old snapshot must re-decode the
        # pristine old blob) and drop every cached predicate mask for this
        # shard: the row set changed, so (shard, predicate) bitmasks
        # computed before the refresh are stale.
        l1_key = f"{task.cache_key or task.puffin_path}@{task.blob_offset}"
        with self._lock:
            self._l1.pop(l1_key, None)
            for key in [kk for kk in self._mask_cache if kk[0] == l1_key]:
                del self._mask_cache[key]
        return F.RefreshResult(
            shard_id=task.shard_id,
            output_path=task.output_path,
            executor_id=self.executor_id,
            inserted=inserted,
            tombstoned=tombstoned,
            vector_count=graph.n,
            byte_size=len(blob),
            tombstone_ratio=graph.tombstone_ratio,
            refresh_seconds=time.time() - t0,
            rg_membership=_locmap_membership(
                locmap, graph.n, live=~graph.tombstones[: graph.n]
            ),
        )
