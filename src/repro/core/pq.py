"""Product quantization (Jégou et al. [10]) — train / encode / ADC LUTs.

Paper defaults: ``m = 48`` subquantizers, ``nbits = 8`` (K = 256 codewords).
The codebook shape is ``(m, K, D/m)`` float32; codes are ``(N, m)`` uint8.
The in-memory footprint claim of §3.2 / §9.2 (48 B per vector at m=48)
falls directly out of this layout and is validated in the benchmarks.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

import jax.numpy as jnp
import numpy as np

from repro.core.kmeans import train_kmeans
from repro.kernels import ops, ref


@dataclass
class PQCodebook:
    codebook: np.ndarray  # (m, K, dsub) float32
    metric: str = "l2"

    @property
    def m(self) -> int:
        return self.codebook.shape[0]

    @property
    def K(self) -> int:
        return self.codebook.shape[1]

    @property
    def dsub(self) -> int:
        return self.codebook.shape[2]

    @property
    def dim(self) -> int:
        return self.m * self.dsub

    @property
    def nbits(self) -> int:
        return int(np.log2(self.K))

    # -- serialization (flat f32 + shape header handled by blob codec) -----
    def tobytes(self) -> bytes:
        return np.ascontiguousarray(self.codebook, dtype=np.float32).tobytes()

    @staticmethod
    def frombytes(data: bytes, m: int, K: int, dsub: int, metric: str = "l2") -> "PQCodebook":
        arr = np.frombuffer(data, dtype=np.float32).reshape(m, K, dsub).copy()
        return PQCodebook(arr, metric)


def train_pq(
    vectors: np.ndarray,
    m: int = 48,
    nbits: int = 8,
    *,
    iters: int = 12,
    seed: int = 0,
    sample_cap: int = 65536,
    metric: str = "l2",
) -> PQCodebook:
    """Train one k-means codebook per subquantizer."""
    vectors = np.ascontiguousarray(vectors, dtype=np.float32)
    n, d = vectors.shape
    if d % m:
        raise ValueError(f"dim {d} not divisible by m={m}")
    K = 1 << nbits
    rng = np.random.default_rng(seed)
    if n > sample_cap:
        vectors = vectors[rng.choice(n, size=sample_cap, replace=False)]
    dsub = d // m
    sub = vectors.reshape(-1, m, dsub)
    codebook = np.empty((m, K, dsub), dtype=np.float32)
    for j in range(m):
        k_eff = min(K, sub.shape[0])
        cents, _ = train_kmeans(sub[:, j, :], k_eff, iters=iters, seed=seed + j)
        if k_eff < K:  # degenerate tiny-corpus case: tile the codebook
            reps = int(np.ceil(K / k_eff))
            cents = np.tile(cents, (reps, 1))[:K]
        codebook[j] = cents
    return PQCodebook(codebook, metric)


def encode(pq: PQCodebook, vectors: np.ndarray, batch: int = 8192) -> np.ndarray:
    """PQ-encode vectors -> (N, m) uint8 codes."""
    vectors = np.ascontiguousarray(vectors, dtype=np.float32)
    n, d = vectors.shape
    if d != pq.dim:
        raise ValueError(f"dim {d} != codebook dim {pq.dim}")
    out = np.empty((n, pq.m), dtype=np.uint8)
    for start in range(0, n, batch):
        stop = min(start + batch, n)
        sub = vectors[start:stop].reshape(stop - start, pq.m, pq.dsub)
        for j in range(pq.m):
            idx, _ = ops.kmeans_assign(
                jnp.asarray(sub[:, j, :]), jnp.asarray(pq.codebook[j]), backend="ref"
            )
            out[start:stop, j] = np.asarray(idx).astype(np.uint8)
    return out


def decode(pq: PQCodebook, codes: np.ndarray) -> np.ndarray:
    """Reconstruct approximate vectors from codes (N, m) -> (N, D)."""
    n = codes.shape[0]
    out = np.empty((n, pq.dim), dtype=np.float32)
    for j in range(pq.m):
        out[:, j * pq.dsub : (j + 1) * pq.dsub] = pq.codebook[j][codes[:, j]]
    return out


def build_luts(pq: PQCodebook, queries: np.ndarray) -> jnp.ndarray:
    """Per-query ADC lookup tables (Q, m, K)."""
    return ref.build_pq_luts(
        jnp.asarray(queries, dtype=jnp.float32), jnp.asarray(pq.codebook), pq.metric
    )


def adc_scores(
    pq: PQCodebook,
    queries: np.ndarray,
    codes: np.ndarray,
    *,
    backend: str = "auto",
) -> jnp.ndarray:
    """Full ADC scan: (Q, N) approximate distances."""
    luts = build_luts(pq, queries)
    return ops.pq_scan(luts, jnp.asarray(codes.astype(np.int32)), backend=backend)


def reconstruction_error(pq: PQCodebook, vectors: np.ndarray, sample: Optional[int] = 4096) -> float:
    """Mean squared PQ reconstruction error (quality diagnostic)."""
    if sample and vectors.shape[0] > sample:
        rng = np.random.default_rng(0)
        vectors = vectors[rng.choice(vectors.shape[0], sample, replace=False)]
    approx = decode(pq, encode(pq, vectors))
    return float(np.mean(np.sum((vectors - approx) ** 2, axis=1)))
